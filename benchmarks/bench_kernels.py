"""Kernel roofline benchmark + the BENCH_kernels.json regression gate.

Two kinds of rows, both from ``benchmarks.roofline``'s alignment-kernel
cost models:

  model     analytic flops/hbm_bytes at the default pow2 bucket shapes
            (``kernel_rooflines``) — deterministic functions of the
            shapes, so the CI gate compares THESE against the recorded
            baseline: >20% more HBM bytes or FLOPs for the same shape
            means a kernel regressed its traffic (e.g. a direction
            matrix leaked back into HBM). No wall-clock noise.
  measured  the same kernels actually executed once at smoke shapes with
            wall time and achieved-vs-peak fractions (``achieved``) —
            informational under the CPU interpreter, the real number on
            TPU.

The headline invariant is checked directly: at every default bucket
shape the fused banded pairs kernel must move strictly fewer HBM bytes
than the O(n·m) SW direction-matrix path.

CLI: ``python -m benchmarks.bench_kernels [--json PATH] [--check]
[--write-baseline]`` — ``run.py --json kernels`` drives the same
functions for CI.
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

BASELINE = Path(__file__).parent / "baselines" / "BENCH_kernels.json"

# keys that identify a row across runs; everything else is a metric
_KEY_FIELDS = ("kernel", "mode", "B", "n", "m", "N", "M", "L", "band",
               "pack")
# gated metrics: deterministic, so any drift is a code change
_GATED = ("flops", "hbm_bytes")
_TOL = 0.20


def _key(row):
    return tuple((k, row.get(k)) for k in _KEY_FIELDS)


def model_rows():
    from . import roofline
    return [{**r, "mode": "model"} for r in roofline.kernel_rooflines()]


def measured_rows(smoke: bool = True):
    """Run each kernel once at smoke shapes; wall time + achieved fracs."""
    import jax.numpy as jnp
    import numpy as np

    from repro.align import backends
    from repro.kernels.distance import match_valid_pallas
    from . import common, roofline

    rng = np.random.default_rng(0)
    B, n, m, W = (4, 64, 64, 16) if smoke else (16, 256, 256, 32)
    sub = jnp.asarray(np.where(np.eye(6), 2.0, -1.0), jnp.float32)
    Q = jnp.asarray(rng.integers(0, 4, (B, n)), jnp.int8)
    T = jnp.asarray(rng.integers(0, 4, (B, m)), jnp.int8)
    qlens = jnp.full((B,), n, jnp.int32)
    tlens = jnp.full((B,), m, jnp.int32)
    b = T[0]

    rows = []

    def run(name, cost, fn, *args):
        us, _ = common.time_call(fn, *args, repeats=3, warmup=1)
        row = {**roofline.achieved(cost, us / 1e6), "mode": "measured"}
        rows.append(row)
        common.emit(f"kernels/{name}/B{B}", us,
                    f"hbm_bytes={int(cost['hbm_bytes'])}")

    run("sw_forward", roofline.sw_forward_cost(B, n, m),
        lambda: backends.pallas_align_pairs(
            Q, qlens, T, tlens, sub, gap_open=3, gap_extend=1))
    run("banded_forward", roofline.banded_forward_cost(B, n, m, W),
        lambda: backends.banded_pallas_align_batch(
            Q, qlens, b, m, sub, gap_open=3, gap_extend=1, band=W,
            block_rows=n))
    run("fused_pairs", roofline.fused_pairs_cost(B, n, m, W),
        lambda: backends.banded_pallas_align_pairs(
            Q, qlens, T, tlens, sub, gap_open=3, gap_extend=1, band=W))
    run("distance", roofline.distance_cost(B * 8, B * 8, n),
        lambda: match_valid_pallas(
            jnp.asarray(rng.integers(0, 6, (B * 8, n)), jnp.int8),
            jnp.asarray(rng.integers(0, 6, (B * 8, n)), jnp.int8),
            n_chars=4, gap_code=5, bn=B * 8, bl=n))
    return rows


def kernel_matrix(smoke: bool = True):
    return model_rows() + measured_rows(smoke=smoke)


def check_invariants(rows):
    """The fused pairs kernel must move strictly fewer HBM bytes than the
    direction-matrix SW path at every model shape."""
    failures = []
    by_shape = {}
    for r in rows:
        if r.get("mode") != "model":
            continue
        by_shape.setdefault((r.get("B"), r.get("n"), r.get("m")),
                            {})[r["kernel"]] = r
    for shape, kernels in by_shape.items():
        sw, fused = kernels.get("sw_forward"), kernels.get("fused_pairs")
        if sw and fused and not fused["hbm_bytes"] < sw["hbm_bytes"]:
            failures.append(
                f"fused_pairs hbm_bytes {fused['hbm_bytes']:.0f} not < "
                f"sw_forward {sw['hbm_bytes']:.0f} at shape {shape}")
    return failures


def check_against_baseline(rows, baseline_path: Path = BASELINE,
                           tol: float = _TOL):
    """Regressions vs the recorded baseline: >tol more of any gated
    metric for a row the baseline knows. New rows pass (they have no
    baseline yet); vanished rows fail (coverage loss is a regression)."""
    if not baseline_path.exists():
        return [f"no baseline at {baseline_path} (run --write-baseline)"]
    recorded = json.loads(baseline_path.read_text())
    if isinstance(recorded, dict):
        # a BENCH_kernels.json artifact ({"rows": ..., "metrics": ...})
        # recorded as the baseline works too
        recorded = recorded["rows"]
    base = {tuple(map(tuple, k)): v for k, v in
            ((_key(r), r) for r in recorded)}
    cur = {_key(r): r for r in rows}
    failures = []
    for k, b in base.items():
        if b.get("mode") != "model":
            continue
        r = cur.get(k)
        if r is None:
            failures.append(f"baseline row vanished: {dict(k)}")
            continue
        for metric in _GATED:
            if metric in b and r.get(metric, 0) > b[metric] * (1 + tol):
                failures.append(
                    f"{dict(k)}: {metric} {r[metric]:.3g} > baseline "
                    f"{b[metric]:.3g} (+{tol:.0%})")
    return failures


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--json", default=None, metavar="PATH")
    ap.add_argument("--check", action="store_true",
                    help="gate against the recorded baseline")
    ap.add_argument("--write-baseline", action="store_true",
                    help="record the model rows as the new baseline")
    args = ap.parse_args()

    rows = kernel_matrix(smoke=args.smoke)
    failures = check_invariants(rows)
    if args.check:
        failures += check_against_baseline(rows)
    if args.write_baseline:
        BASELINE.parent.mkdir(parents=True, exist_ok=True)
        with open(BASELINE, "w") as f:
            json.dump([r for r in rows if r["mode"] == "model"], f, indent=1)
        print(f"# wrote baseline to {BASELINE}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=1)
        print(f"# wrote {len(rows)} kernel rows to {args.json}")
    if failures:
        raise SystemExit("BENCH_kernels gate failed:\n  " +
                         "\n  ".join(failures))


if __name__ == "__main__":
    main()
