"""ML tree refinement: logL gain + bootstrap throughput vs the NJ baseline.

The paper's Table 5 scores trees by maximum-likelihood value; these rows
track what native refinement buys on the Φ_DNA analogue family: the
JC69 logL of the unrefined NJ tree vs the refined tree (same data, so
the gain is the refinement win), the BIC-selected model, and the
nonparametric-bootstrap replicate throughput (replicates are the
embarrassingly parallel tree-stage workload — one weighted distance
matrix + one NJ per replicate, vmapped or mesh-sharded).

``BENCH_ml.json`` rows (see docs/BENCHMARKS.md):
  bench/ml/refine_phi_dna_nN     — engine build incl. refine; derived
                                   logl_nj / logl_ml / gain / model / nni
  bench/ml/bootstrap_phi_dna_nN_BK — K replicates; derived replicates/s
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import alphabet as ab
from repro.core.msa import MSAConfig, center_star_msa
from repro.data import phi_dna
from repro.phylo import MLRefiner, TreeEngine

from .common import emit, time_host


def ml_matrix(smoke: bool = False):
    """refine + bootstrap rows on the Φ_DNA analogue (BENCH_ml rows)."""
    scales = [1] if smoke else [1, 2]
    n_boot = 16 if smoke else 64
    steps = 60 if smoke else 150
    for scale in scales:
        fam = phi_dna(scale)
        res = center_star_msa(fam.seqs, MSAConfig(method="kmer"))
        msa = np.asarray(res.msa)
        n = msa.shape[0]
        eng = TreeEngine(gap_code=ab.DNA.gap_code, n_chars=ab.DNA.n_chars,
                         backend="dense", refine="ml", model="auto",
                         ml_steps=steps, nni_rounds=2)
        us, r = time_host(eng.build, msa)
        gain = r.logl["final"] - r.logl["initial"]
        emit(f"bench/ml/refine_phi_dna_n{n}", us,
             f"logl_nj={r.logl['initial']:.1f};logl_ml={r.logl['final']:.1f};"
             f"gain={gain:.2f};model={r.model}")

        refiner = MLRefiner(gap_code=ab.DNA.gap_code, n_chars=ab.DNA.n_chars,
                            seed=0)
        refiner.bootstrap(msa, r.children, r.blen, r.root, n_boot)  # warmup
        t0 = time.perf_counter()
        sup = refiner.bootstrap(msa, r.children, r.blen, r.root, n_boot)
        dt = time.perf_counter() - t0
        finite = sup[np.isfinite(sup)]
        emit(f"bench/ml/bootstrap_phi_dna_n{n}_B{n_boot}", dt * 1e6,
             f"replicates_per_s={n_boot / max(dt, 1e-9):.1f};"
             f"mean_support={finite.mean():.3f}")


def main():
    ml_matrix()


if __name__ == "__main__":
    main()
