"""Paper Tables 2-4: MSA running time + avg SP, scaled to container size.

The paper's numbers are cluster wall-times on 672..17M sequences; the
algorithmic claims we validate here at CPU scale are (a) the k-mer/trie path
beats plain center-star on similar DNA while matching SP, (b) both scale
linearly in N for fixed length, (c) the SW path handles diverged proteins.
Every row prints name,us_per_call,derived-metrics CSV like the paper tables.
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core import alphabet as ab
from repro.core.msa import MSAConfig, center_star_msa
from repro.core.sp_score import avg_sp
from repro.data import SimConfig, simulate_family

from .common import emit


def _family(n, length, alphabet="dna", sub=0.004, indel=0.0004, seed=0):
    return simulate_family(SimConfig(n_leaves=n, root_len=length,
                                     alphabet=alphabet, branch_sub=sub,
                                     branch_indel=indel, seed=seed))


def _run(seqs, cfg, alpha):
    t0 = time.perf_counter()
    res = center_star_msa(seqs, cfg)
    dt = (time.perf_counter() - t0) * 1e6
    sp = float(avg_sp(jnp.asarray(res.msa), gap_code=alpha.gap_code,
                      n_chars=alpha.n_chars))
    return dt, sp, res


def table2_genome_msa():
    """Φ_DNA analogue: highly similar genomes; plain (original center star)
    vs kmer (HAlign/HAlign-II trie path) at 1x and 4x scale."""
    for scale in (1, 4):
        fam = _family(12 * scale, 1024, seed=scale)
        # warm both paths once on a small family to exclude compile time
        warm = fam.seqs[:4]
        for method, k in (("plain", 0), ("kmer", 11)):
            cfg = MSAConfig(method=method, k=k or 11, max_anchors=128,
                            max_seg=48)
            _run(warm, cfg, ab.DNA)
            us, sp, res = _run(fam.seqs, cfg, ab.DNA)
            emit(f"table2/dna_{scale}x/{method}", us,
                 f"avgSP={sp:.1f};N={len(fam.seqs)};fallback={res.n_fallback}")


def table3_rna_msa():
    """Φ_RNA analogue: moderately diverged ~1.4k nt sequences."""
    fam = _family(16, 1440, sub=0.01, indel=0.001, seed=7)
    for method in ("plain", "kmer"):
        cfg = MSAConfig(method=method, k=10, max_anchors=192, max_seg=64)
        _run(fam.seqs[:4], cfg, ab.DNA)
        us, sp, res = _run(fam.seqs, cfg, ab.DNA)
        emit(f"table3/rna/{method}", us,
             f"avgSP={sp:.1f};fallback={res.n_fallback}")


def table4_protein_msa():
    """Φ_Protein analogue: diverged proteins, BLOSUM62 affine-gap DP
    center star (HAlign-II / SparkSW class; center-star assembly requires
    full-length rows, so stage-1 alignment is global — local SW scoring is
    kernel-validated separately) vs the progressive (MUSCLE-class) baseline."""
    fam = _family(16, 459, alphabet="protein", sub=0.05, indel=0.002, seed=3)
    cfg = MSAConfig(method="sw", alphabet="protein", gap_open=11,
                    gap_extend=1)
    _run(fam.seqs[:4], cfg, ab.PROTEIN)
    us, sp, _ = _run(fam.seqs, cfg, ab.PROTEIN)
    emit("table4/protein/centerstar_blosum", us, f"avgSP={sp:.1f}")
    # the MUSCLE-class baseline the paper compares against
    import time as _t
    from repro.core.progressive import progressive_msa
    cfg = MSAConfig(method="plain", alphabet="protein", gap_open=8)
    progressive_msa(fam.seqs[:4], cfg)   # warm
    t0 = _t.perf_counter()
    res = progressive_msa(fam.seqs, cfg)
    us = (_t.perf_counter() - t0) * 1e6
    sp = float(avg_sp(jnp.asarray(res.msa), gap_code=ab.PROTEIN.gap_code,
                      n_chars=ab.PROTEIN.n_chars))
    emit("table4/protein/progressive_baseline", us, f"avgSP={sp:.1f}")


def backend_matrix(smoke: bool = False):
    """repro.align backend x method timing rows (engine dispatch).

    The CI smoke artifact (BENCH_msa.json) tracks this table so backend
    regressions show up in the bench trajectory. ``smoke`` shrinks the
    family so the interpreted Pallas kernel stays in CI budget.
    """
    n, length = (6, 96) if smoke else (12, 512)
    fam = _family(n, length, seed=2)
    warm = fam.seqs[:3]
    for backend in ("jnp", "pallas", "banded"):
        for method in ("plain", "kmer"):
            cfg = MSAConfig(method=method, k=8, max_anchors=64, max_seg=48,
                            backend=backend, band=96)
            _run(warm, cfg, ab.DNA)
            us, sp, res = _run(fam.seqs, cfg, ab.DNA)
            emit(f"bench/msa/{backend}/{method}", us,
                 f"avgSP={sp:.1f};N={len(fam.seqs)};L={length};"
                 f"fallback={res.n_fallback}")


def obs_overhead_row(smoke: bool = False, repeats: int = 5):
    """Instrumentation guardrail (ISSUE 8): the obs-enabled backend-matrix
    path must cost < 3% over ``repro.obs.disabled()`` (plus a small
    absolute floor so sub-second smoke runs don't flake on timer noise).
    """
    import repro.obs as obs

    n, length = (6, 96) if smoke else (12, 512)
    fam = _family(n, length, seed=2)
    cfg = MSAConfig(method="plain", backend="jnp")
    _run(fam.seqs, cfg, ab.DNA)          # warm: compile every bucket

    def median_s():
        times = sorted(_run(fam.seqs, cfg, ab.DNA)[0] for _ in range(repeats))
        return times[repeats // 2] / 1e6

    with obs.disabled():
        off_s = median_s()
    on_s = median_s()
    ratio = on_s / off_s
    emit("bench/msa/obs_overhead", on_s * 1e6,
         f"off_us={off_s * 1e6:.1f};ratio={ratio:.3f}")
    budget = off_s * 1.03 + 0.025
    if on_s > budget:
        raise SystemExit(
            f"obs overhead guardrail failed: enabled {on_s * 1e3:.1f}ms > "
            f"disabled {off_s * 1e3:.1f}ms * 1.03 + 25ms")
    return ratio


def linear_scaling_in_n():
    """HAlign-II's O(n) scaling in sequence count for fixed length."""
    base = None
    for n in (8, 16, 32):
        fam = _family(n, 512, seed=n)
        cfg = MSAConfig(method="kmer", k=10, max_anchors=96, max_seg=48)
        _run(fam.seqs[:4], cfg, ab.DNA)
        us, sp, _ = _run(fam.seqs, cfg, ab.DNA)
        base = base or us / n
        emit(f"scaling/n{n}", us, f"us_per_seq={us / n:.0f};"
             f"vs_linear={us / n / base:.2f}")


def main():
    table2_genome_msa()
    table3_rna_msa()
    table4_protein_msa()
    backend_matrix()
    linear_scaling_in_n()


if __name__ == "__main__":
    main()
