"""Observability service smoke: a real ``serve_msa`` process under load.

The CI step behind the ``BENCH_obs`` artifact (ISSUE 8): start the
launcher as a subprocess, fire a mixed align / tree / search burst over
HTTP, scrape ``GET /metrics``, and assert the exposition parses
(``repro.obs.metrics.parse_exposition``) and carries every required
metric family. SIGINT then exercises the graceful-drain path, and the
``--metrics-out`` snapshot the server writes on exit lands in the
artifact next to the scrape.

  PYTHONPATH=src python -m benchmarks.bench_obs [--json PATH]

Rows:
  bench/obs/burst     wall time of the mixed burst (requests/sec)
  bench/obs/scrape    /metrics size + family count
"""
from __future__ import annotations

import argparse
import json
import os
import signal
import socket
import subprocess
import sys
import tempfile
import time
import urllib.error
import urllib.request
from pathlib import Path

from .common import emit

# families the exposition must carry after a mixed burst; a rename or a
# lost instrumentation point fails CI here
REQUIRED_FAMILIES = (
    "repro_requests_started_total",
    "repro_requests_finished_total",
    "repro_requests_active",
    "repro_request_seconds",
    "repro_queue_wait_seconds",
    "repro_batch_pairs",
    "repro_cache_requests_total",
    "repro_align_calls_total",
    "repro_align_pairs_total",
    "repro_tree_builds_total",
    "repro_search_queries_total",
    "repro_span_seconds",
)


def _free_port() -> int:
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def _post(url: str, obj: dict, timeout: float = 120.0) -> dict:
    req = urllib.request.Request(
        url, data=json.dumps(obj).encode(),
        headers={"Content-Type": "application/json"})
    with urllib.request.urlopen(req, timeout=timeout) as r:
        return json.loads(r.read())


def _get(url: str, timeout: float = 30.0) -> bytes:
    with urllib.request.urlopen(url, timeout=timeout) as r:
        return r.read()


def _fasta(path: Path, names, seqs):
    path.write_text("".join(f">{n}\n{s}\n" for n, s in zip(names, seqs)))


def service_smoke(json_path: str | None = None) -> dict:
    import numpy as np

    rng = np.random.default_rng(0)

    def seq(L):
        return "".join("ACGT"[c] for c in rng.integers(0, 4, L))

    def mutate(s, k=3):
        s = list(s)
        for _ in range(k):
            s[rng.integers(0, len(s))] = "ACGT"[rng.integers(0, 4)]
        return "".join(s)

    tmp = Path(tempfile.mkdtemp(prefix="bench_obs_"))
    db_seqs = [seq(90) for _ in range(8)]
    _fasta(tmp / "db.fasta", [f"db{i}" for i in range(8)], db_seqs)
    metrics_out = tmp / "metrics.json"
    trace_out = tmp / "trace.json"

    port = _free_port()
    base = f"http://127.0.0.1:{port}"
    env = dict(os.environ)
    env["PYTHONPATH"] = str(Path(__file__).resolve().parents[1] / "src")
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.serve_msa",
         "--port", str(port), "--max-wait-ms", "2",
         "--search-db", str(tmp / "db.fasta"),
         "--metrics-out", str(metrics_out),
         "--trace-out", str(trace_out)],
        env=env, stdout=subprocess.PIPE, stderr=subprocess.STDOUT)
    try:
        deadline = time.time() + 180
        while True:
            try:
                json.loads(_get(f"{base}/healthz", timeout=5))
                break
            except (urllib.error.URLError, OSError):
                if proc.poll() is not None:
                    out = proc.stdout.read().decode(errors="replace")
                    raise RuntimeError(f"serve_msa died at startup:\n{out}")
                if time.time() > deadline:
                    raise RuntimeError("serve_msa did not become healthy")
                time.sleep(0.5)

        # mixed burst: aligns (with one repeat for a cache hit), a tree
        # on the first result, and a search against the db
        fam = [seq(80)]
        fam += [mutate(fam[0]) for _ in range(3)]
        t0 = time.perf_counter()
        n_requests = 0
        first = _post(f"{base}/align", {"sequences": fam})
        n_requests += 1
        assert first["trace_id"], "align response carries no trace_id"
        for _ in range(3):
            _post(f"{base}/align",
                  {"sequences": [mutate(s) for s in fam]})
            n_requests += 1
        _post(f"{base}/align", {"sequences": fam})     # cache hit
        n_requests += 1
        tree = _post(f"{base}/tree",
                     {"msa_id": first["alignment"]["msa_id"]})
        n_requests += 1
        assert tree["newick"].endswith(";")
        srch = _post(f"{base}/search",
                     {"sequences": [mutate(db_seqs[0]), mutate(db_seqs[3])]})
        n_requests += 1
        assert srch["queries"], "search returned no per-query results"
        burst_s = time.perf_counter() - t0
        emit("bench/obs/burst", burst_s * 1e6,
             f"requests={n_requests};rps={n_requests / burst_s:.1f}")

        # the scrape is the artifact's payload: it must parse and carry
        # every required family
        from repro.obs.metrics import parse_exposition
        text = _get(f"{base}/metrics").decode()
        families = parse_exposition(text)
        missing = [f for f in REQUIRED_FAMILIES if f not in families]
        if missing:
            raise SystemExit(
                "BENCH_obs gate failed; /metrics lacks families:\n  " +
                "\n  ".join(missing))
        statusz = _get(f"{base}/statusz").decode()
        assert "active_requests" in statusz
        emit("bench/obs/scrape", len(text),
             f"families={len(families)};required_ok={len(REQUIRED_FAMILIES)}")

        proc.send_signal(signal.SIGINT)
        proc.wait(timeout=120)
        snapshot = (json.loads(metrics_out.read_text())
                    if metrics_out.exists() else None)
        if snapshot is None:
            raise SystemExit("server exited without writing --metrics-out")
        started = sum(s["value"] for s in
                      snapshot["repro_requests_started_total"]["samples"])
        finished = sum(s["value"] for s in
                       snapshot["repro_requests_finished_total"]["samples"])
        rejected = sum(s["value"] for s in snapshot.get(
            "repro_requests_rejected_total",
            {"samples": []})["samples"])
        if started != finished + rejected:
            raise SystemExit(
                f"request counters do not reconcile: started {started} != "
                f"finished {finished} + rejected {rejected}")

        from .common import ROWS
        artifact = {"rows": ROWS, "metrics": snapshot,
                    "scrape_families": sorted(families)}
        if json_path:
            with open(json_path, "w") as f:
                json.dump(artifact, f, indent=1)
            print(f"# wrote BENCH_obs artifact to {json_path}")
        return artifact
    finally:
        if proc.poll() is None:
            proc.kill()
            proc.wait()


def main(argv=None):
    ap = argparse.ArgumentParser(prog="benchmarks.bench_obs")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the BENCH_obs artifact to PATH")
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    service_smoke(json_path=args.json)


if __name__ == "__main__":
    main()
