"""Paper Figures 5-6: memory per worker and scaling with workers.

Fig 6 (scaling): this container has one physical core, so adding virtual
devices cannot reduce wall time; what the Spark cluster property actually
rests on is that per-worker WORK is N/w and the merge is one max-reduce. We
therefore measure the per-worker shard time t(N/w) for w = 1..8 on one
device (strong scaling of the partitioned map stage) plus the (tiny) merge.

Fig 5 (memory/worker): read the dry-run artifacts — bytes/device for the MSA
cells on the 256-chip vs 512-chip meshes (flat in cluster size = the paper's
'extremely high memory efficiency' claim, quantified).
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import jax.numpy as jnp
import numpy as np

from repro.core import alphabet as ab
from repro.core import kmer_index
from repro.core.msa import MSAConfig, kmer_align_batch
from repro.data import SimConfig, simulate_family

from .common import emit


def fig6_scaling():
    fam = simulate_family(SimConfig(n_leaves=64, root_len=512,
                                    branch_sub=0.004, branch_indel=0.0004,
                                    seed=5))
    S, lens = ab.encode_batch(fam.seqs, ab.DNA)
    center, lc = S[0], lens[0]
    table = kmer_index.build_center_index(center, lc, k=10)
    sub = ab.dna_matrix().astype(jnp.float32)

    def shard_time(n_shard):
        q = S[1:1 + n_shard]
        ql = lens[1:1 + n_shard]
        args = dict(k=10, stride=1, max_anchors=96, max_seg=48, gap_open=3,
                    gap_extend=1, gap_code=ab.DNA.gap_code)
        out = kmer_align_batch(q, ql, center, lc, table, sub, **args)
        out[0].block_until_ready()
        t0 = time.perf_counter()
        out = kmer_align_batch(q, ql, center, lc, table, sub, **args)
        out[0].block_until_ready()
        return (time.perf_counter() - t0) * 1e6

    t1 = None
    for w in (1, 2, 4, 8):
        us = shard_time(63 // w)
        t1 = t1 or us
        emit(f"fig6/workers{w}", us,
             f"shard={63 // w};speedup_vs_w1={t1 / us:.2f}")


def fig5_memory_from_dryrun():
    path = Path(__file__).resolve().parent.parent / "results/dryrun_all.json"
    if not path.exists():
        emit("fig5/memory", 0.0, "dryrun_all.json missing (run launch.dryrun)")
        return
    recs = json.loads(path.read_text())
    for r in recs:
        if r.get("shape") == "msa" and "temp_size_in_bytes" in r:
            emit(f"fig5/{r['arch']}/{r['mesh']}", 0.0,
                 f"args_MB={r.get('argument_size_in_bytes', 0) / 1e6:.0f};"
                 f"temp_MB={r['temp_size_in_bytes'] / 1e6:.0f}")


def main():
    fig6_scaling()
    fig5_memory_from_dryrun()


if __name__ == "__main__":
    main()
