"""Search-stage benchmark: seed-prefiltered vs exhaustive homology search.

The search pillar's claim is that k-mer anchor seeding makes the DP the
rare path: almost every (query, DB) pair dies in the O(1)-per-pair
prefilter, and the pairs that survive carry essentially all the true
hits. Rows:

  bench/search/index/D*    index build (encode + per-row k-mer tables)
                           vs database size
  bench/search/qps/D*      end-to-end queries/sec vs database size at a
                           selective prefilter (``min_anchors=3``), with
                           the survival rate (the fraction of the B x D
                           matrix that reached the DP)
  bench/search/recall      top-k hit recall of the default prefilter
                           (``min_anchors=1``) against the exhaustive
                           all-pairs oracle (``exhaustive=True`` rescores
                           every pair, same gates) — the acceptance
                           gate: 1.0 under ``--smoke``

  PYTHONPATH=src python -m benchmarks.bench_search [--smoke] [--json PATH]
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from .common import emit


def _make_db(rng, n_fam: int, fam_size: int, n_decoys: int, L: int):
    """Planted-family database + one mutated query per family."""
    def rseq(n):
        return "".join("ACGT"[i] for i in rng.integers(0, 4, n))

    def mut(s, p=0.08):
        return "".join("ACGT"[rng.integers(0, 4)] if rng.random() < p else c
                       for c in s)

    names, seqs, queries = [], [], []
    for fi in range(n_fam):
        base = rseq(int(rng.integers(int(L * 0.8), int(L * 1.2))))
        for j in range(fam_size):
            names.append(f"fam{fi}_m{j}")
            seqs.append(mut(base))
        queries.append((f"query{fi}", mut(base)))
    for j in range(n_decoys):
        names.append(f"decoy{j}")
        seqs.append(rseq(L))
    return names, seqs, queries


def _hit_set(result):
    return [{h["target"] for h in q["hits"]} for q in result["queries"]]


def search_matrix(smoke: bool = False):
    from repro.search import SearchConfig, SearchEngine

    sizes = [(4, 4, 16)] if smoke else [(4, 4, 16), (8, 6, 80), (16, 8, 300)]
    L = 150 if smoke else 300
    # two prefilter settings: min_anchors=1 (the default — any chained
    # anchor reaches the DP; this is the setting the recall guarantee is
    # stated for) and min_anchors=3 (selective: random same-length pairs
    # chain ~1-2 spurious 6-mer anchors, family pairs chain many — the
    # qps/survival rows measure a prefilter that actually filters)
    recall_eng = SearchEngine(SearchConfig(max_hits=10, max_evalue=1e-3))
    sel_eng = SearchEngine(SearchConfig(max_hits=10, max_evalue=1e-3,
                                        min_anchors=3))
    recall_num = recall_den = 0
    for n_fam, fam_size, n_decoys in sizes:
        rng = np.random.default_rng(0)
        names, seqs, queries = _make_db(rng, n_fam, fam_size, n_decoys, L)
        D = len(seqs)
        t0 = time.perf_counter()
        index = sel_eng.build_index(names, seqs)
        emit(f"bench/search/index/D{D}", (time.perf_counter() - t0) * 1e6,
             f"residues={index.db_residues};k={index.k}")

        q_names = [n for n, _ in queries]
        q_seqs = [s for _, s in queries]
        sel_eng.search(q_names, q_seqs, index)             # warm (compiles)
        t0 = time.perf_counter()
        res = sel_eng.search(q_names, q_seqs, index)
        dt = time.perf_counter() - t0
        st = res["stats"]
        emit(f"bench/search/qps/D{D}", dt * 1e6,
             f"queries={len(q_seqs)};qps={len(q_seqs) / dt:.1f};"
             f"survival={st['survival']};align_calls={st['align_calls']}")

        # recall vs the exhaustive all-pairs oracle (same gates, no seed
        # prefilter): every oracle hit the prefiltered search also found
        got_res = recall_eng.search(q_names, q_seqs, index)
        oracle = recall_eng.search(q_names, q_seqs, index, exhaustive=True)
        for got, want in zip(_hit_set(got_res), _hit_set(oracle)):
            recall_num += len(got & want)
            recall_den += len(want)

    recall = recall_num / max(recall_den, 1)
    emit("bench/search/recall", 0.0,
         f"recall={recall:.4f};oracle_hits={recall_den}")
    return recall


def main(argv=None):
    ap = argparse.ArgumentParser(prog="benchmarks.bench_search")
    ap.add_argument("--smoke", action="store_true",
                    help="small CI-budget matrix; recall must be 1.0")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write emitted rows as JSON to PATH")
    args = ap.parse_args(argv)

    from . import common
    print("name,us_per_call,derived")
    recall = search_matrix(smoke=args.smoke)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(common.ROWS, f, indent=1)
        print(f"# wrote {len(common.ROWS)} rows to {args.json}")
    if args.smoke and recall < 1.0:
        raise SystemExit(f"smoke recall {recall:.4f} < 1.0 — the seed "
                         f"prefilter dropped true hits")


if __name__ == "__main__":
    main()
