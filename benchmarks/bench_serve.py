"""Serve-layer benchmark: coalesced batching vs sequential request serving.

The serve pillar's claim is that merging concurrent align requests into
the engine's pow2 (q_width, t_width) buckets turns per-request dispatch
into a handful of jitted calls. Rows:

  bench/serve/sequential   one ``align_pairs`` call per request (B=1) —
                           what a service without coalescing pays
  bench/serve/coalesced    the same requests submitted to the
                           ``CoalescingAligner`` queue and flushed as
                           merged bucketed batches
  bench/serve/incremental  add-to-MSA against the frozen center vs a
                           full realign of the grown family
  bench/serve/obs_overhead coalesced run with repro.obs enabled vs
                           ``obs.disabled()`` — the < 3% instrumentation
                           guardrail, asserted in-harness

Acceptance (ISSUE 4): coalesced throughput >= 3x sequential on >= 200
mixed-length requests (run without ``--smoke``); the CI smoke uploads
the small matrix as ``BENCH_serve.json``.

  PYTHONPATH=src python -m benchmarks.bench_serve [--smoke] \\
      [--requests N] [--json PATH]
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np

from .common import emit


def _mutate(t, rng, rate=0.03):
    q = t.copy()
    nsub = max(1, int(rate * q.size))
    idx = rng.integers(0, q.size, nsub)
    q[idx] = rng.integers(0, 4, nsub).astype(np.int8)
    return q


def _requests(n, rng, lmin, lmax):
    """n single-query requests (query vs its own reference), mixed lengths."""
    reqs = []
    for _ in range(n):
        L = int(rng.integers(lmin, lmax))
        t = rng.integers(0, 4, L).astype(np.int8)
        reqs.append((_mutate(t, rng), t, L))
    return reqs


def serve_matrix(smoke: bool = False, n_requests: int | None = None):
    from repro.align.bucketing import _pow2_widths
    from repro.core import alphabet as ab
    from repro.core.msa import MSAConfig
    from repro.serve.queue import AlignJob, CoalescingAligner

    n = n_requests or (48 if smoke else 320)
    lmin, lmax = (16, 120) if smoke else (16, 200)
    rng = np.random.default_rng(0)
    cfg = MSAConfig(method="plain")
    engine = cfg.engine()
    gap = ab.DNA.gap_code
    reqs = _requests(n, rng, lmin, lmax)

    def pow2pad(x, w):
        out = np.full((1, w), gap, np.int8)
        out[0, : x.size] = x
        return out

    def run_sequential():
        # an uncoalesced server still pads singles to pow2 buckets — exact
        # per-length shapes would mean one fresh compile per distinct
        # request length, which no serving compile cache survives
        lat = []
        t0 = time.perf_counter()
        for q, t, L in reqs:
            s = time.perf_counter()
            w = int(_pow2_widths([L], 1 << 20, 32)[0])
            lens = np.array([L], np.int32)
            r = engine.align_pairs(pow2pad(q, w), lens, pow2pad(t, w), lens)
            np.asarray(r.a_row)
            lat.append(time.perf_counter() - s)
        return time.perf_counter() - t0, np.sort(np.array(lat))

    def run_coalesced():
        co = CoalescingAligner(max_batch=n, max_wait_ms=1000.0)
        t0 = time.perf_counter()
        futs = [co.submit(AlignJob(Q=q[None, :], qlens=np.array([L], np.int32),
                                   target=t, tlen=L, engine=engine,
                                   engine_key="bench"))
                for q, t, L in reqs]
        for f in futs:
            f.result()
        dt = time.perf_counter() - t0
        stats = co.stats()
        co.close()
        return dt, stats

    # each path runs twice; the first pass compiles every bucket shape it
    # will hit, the second is the timed, compile-free measurement
    run_sequential()
    seq_s, lat = run_sequential()
    emit("bench/serve/sequential", seq_s * 1e6,
         f"n={n};rps={n / seq_s:.0f};"
         f"p50_ms={lat[n // 2] * 1e3:.2f};p95_ms={lat[int(n * .95)] * 1e3:.2f}")

    run_coalesced()
    co_s, stats = run_coalesced()
    speedup = seq_s / co_s
    emit("bench/serve/coalesced", co_s * 1e6,
         f"n={n};rps={n / co_s:.0f};speedup={speedup:.2f}x;"
         f"engine_calls={stats['engine_calls']};batches={stats['batches']}")
    return speedup


def obs_overhead_row(smoke: bool = False, repeats: int = 3):
    """Instrumentation guardrail (ISSUE 8): coalesced throughput with the
    obs layer enabled must be < 3% off ``repro.obs.disabled()`` (plus a
    small absolute floor against timer noise on the short smoke run)."""
    import repro.obs as obs
    from repro.core.msa import MSAConfig
    from repro.serve.queue import AlignJob, CoalescingAligner

    n = 32 if smoke else 128
    rng = np.random.default_rng(3)
    engine = MSAConfig(method="plain").engine()
    reqs = _requests(n, rng, 16, 120 if smoke else 200)

    def run_once():
        co = CoalescingAligner(max_batch=n, max_wait_ms=1000.0)
        t0 = time.perf_counter()
        futs = [co.submit(AlignJob(Q=q[None, :],
                                   qlens=np.array([L], np.int32),
                                   target=t, tlen=L, engine=engine,
                                   engine_key="bench"))
                for q, t, L in reqs]
        for f in futs:
            f.result()
        dt = time.perf_counter() - t0
        co.close()
        return dt

    def median_s():
        times = sorted(run_once() for _ in range(repeats))
        return times[repeats // 2]

    run_once()                           # warm: compile the merged buckets
    with obs.disabled():
        off_s = median_s()
    on_s = median_s()
    ratio = on_s / off_s
    emit("bench/serve/obs_overhead", on_s * 1e6,
         f"n={n};off_us={off_s * 1e6:.1f};ratio={ratio:.3f}")
    if on_s > off_s * 1.03 + 0.025:
        raise SystemExit(
            f"obs overhead guardrail failed: coalesced enabled "
            f"{on_s * 1e3:.1f}ms > disabled {off_s * 1e3:.1f}ms * 1.03 "
            f"+ 25ms")
    return ratio


def incremental_row(smoke: bool = False):
    from repro.core.msa import MSAConfig, center_star_msa
    from repro.serve.incremental import add_to_msa

    n_old, n_new, L = (12, 2, 160) if smoke else (48, 4, 400)
    rng = np.random.default_rng(1)
    base = "".join(rng.choice(list("ACGT"), L))

    def mut(s):
        s = list(s)
        for _ in range(max(2, L // 80)):
            s[rng.integers(0, len(s))] = "ACGT"[rng.integers(0, 4)]
        return "".join(s)

    fam = [base] + [mut(base) for _ in range(n_old - 1)]
    new = [mut(base) for _ in range(n_new)]
    cfg = MSAConfig(method="plain")
    prev = center_star_msa(fam, cfg)                    # parent MSA
    add_to_msa(prev.msa, prev.center_idx, new, cfg)     # warm (compiles)
    center_star_msa(fam + new, cfg)
    t0 = time.perf_counter()
    res = add_to_msa(prev.msa, prev.center_idx, new, cfg)
    inc_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    full = center_star_msa(fam + new, cfg)
    full_s = time.perf_counter() - t0
    identical = bool(np.array_equal(res.msa, full.msa))
    emit("bench/serve/incremental", inc_s * 1e6,
         f"n_old={n_old};n_new={n_new};speedup={full_s / inc_s:.2f}x;"
         f"bit_identical={identical}")


def main(argv=None):
    ap = argparse.ArgumentParser(prog="benchmarks.bench_serve")
    ap.add_argument("--smoke", action="store_true",
                    help="small CI-budget matrix")
    ap.add_argument("--requests", type=int, default=None,
                    help="override the request count")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write emitted rows as JSON to PATH")
    args = ap.parse_args(argv)

    from . import common
    print("name,us_per_call,derived")
    serve_matrix(smoke=args.smoke, n_requests=args.requests)
    incremental_row(smoke=args.smoke)
    obs_overhead_row(smoke=args.smoke)
    if args.json:
        from repro.obs import REGISTRY
        with open(args.json, "w") as f:
            json.dump({"rows": common.ROWS,
                       "metrics": REGISTRY.snapshot()}, f, indent=1)
        print(f"# wrote {len(common.ROWS)} rows to {args.json}")


if __name__ == "__main__":
    main()
