"""Persistent MSA store benchmark: the ``BENCH_store`` artifact.

Drives ``repro.serve.store.MSAStore`` through its three costed paths
(ISSUE 10) and emits one row per path:

  bench/store/ingest        continuous ``add`` throughput — one atomic
                            generation commit per add (incremental merge
                            + ``atomic_save_npz`` + retention GC)
  bench/store/realign_swap  drift-triggered background realign latency:
                            from the drifted add returning to the
                            realigned generation swapping in
  bench/store/restore       cold restart: newest-generation restore from
                            disk (read + fingerprint verification)

  PYTHONPATH=src python -m benchmarks.bench_store [--smoke] [--json PATH]

The artifact is ``{"rows": [...], "metrics": <repro.obs snapshot>}`` —
the store's own counters/histograms (``repro_store_*``) ride along, so
commit/realign/restore latency distributions land in CI trajectories.
"""
from __future__ import annotations

import argparse
import json
import tempfile
import time
from pathlib import Path

from .common import emit


def store_matrix(smoke: bool = False) -> None:
    import numpy as np

    from repro.core.msa import MSAConfig, center_star_msa
    from repro.serve.store import MSAStore

    rng = np.random.default_rng(0)
    n_seed, seq_len, n_adds = (4, 64, 8) if smoke else (8, 128, 32)

    def seq(L):
        return "".join("ACGT"[c] for c in rng.integers(0, 4, L))

    def mutate(s, k=3):
        s = list(s)
        for _ in range(k):
            s[rng.integers(0, len(s))] = "ACGT"[rng.integers(0, 4)]
        return "".join(s)

    cfg = MSAConfig(method="plain")
    base = seq(seq_len)
    fam = [base] + [mutate(base) for _ in range(n_seed - 1)]
    res = center_star_msa(fam, cfg)
    root = Path(tempfile.mkdtemp(prefix="bench_store_")) / "store"

    # ---- ingest: one committed generation per add, substitution-only
    # members so width stays fixed and no realign fires mid-measurement
    store = MSAStore(root, keep=4, drift_threshold=10.0)
    store.create("bench", msa=res.msa, center_idx=res.center_idx,
                 seqs=fam, names=[f"m{i}" for i in range(n_seed)])
    adds = [mutate(base) for _ in range(n_adds)]
    store.add("bench", ["warm"], [adds[0]], cfg)      # compile warm-up
    t0 = time.perf_counter()
    for i, s in enumerate(adds):
        store.add("bench", [f"a{i}"], [s], cfg)
    ingest_s = time.perf_counter() - t0
    entry = store.get("bench")
    emit("bench/store/ingest", ingest_s / n_adds * 1e6,
         f"adds_per_s={n_adds / ingest_s:.1f};generation={entry.generation}"
         f";width={entry.width}")

    # ---- realign swap: an insert-heavy add crosses the drift threshold;
    # measure drifted-add-return -> realigned-generation-swapped-in
    store.drift_threshold = 0.2
    big = base[:8] + seq(max(seq_len // 2, 24)) + base[8:]
    t0 = time.perf_counter()
    drifted, info = store.add("bench", ["big"], [big], cfg)
    assert info["realign_pending"], "drift did not schedule a realign"
    store.wait_realigns(timeout=600)
    swap_s = time.perf_counter() - t0
    swapped = store.get("bench")
    assert swapped.generation == drifted.generation + 1
    emit("bench/store/realign_swap", swap_s * 1e6,
         f"members={len(swapped.names)};width={swapped.width}"
         f";growth_at_trigger={info['growth']}")
    store.close()

    # ---- restore: cold restart over the committed directory
    t0 = time.perf_counter()
    cold = MSAStore(root, keep=4)
    restored = cold.get("bench")
    restore_s = time.perf_counter() - t0
    assert restored.fingerprint == swapped.fingerprint, \
        "restart did not restore the committed generation"
    emit("bench/store/restore", restore_s * 1e6,
         f"generation={restored.generation};bytes={restored.nbytes}")
    cold.close()


def main(argv=None):
    ap = argparse.ArgumentParser(prog="benchmarks.bench_store")
    ap.add_argument("--smoke", action="store_true",
                    help="small family / few adds (the CI smoke step)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the BENCH_store artifact to PATH")
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    store_matrix(smoke=args.smoke)
    if args.json:
        from repro.obs import REGISTRY

        from .common import ROWS
        with open(args.json, "w") as f:
            json.dump({"rows": ROWS, "metrics": REGISTRY.snapshot()}, f,
                      indent=1)
        print(f"# wrote BENCH_store artifact to {args.json}")


if __name__ == "__main__":
    main()
