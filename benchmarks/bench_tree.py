"""Paper Table 5: phylogenetic tree construction time + quality.

Direct NJ vs HPTree-style cluster-merge (the paper's approach), scored by
(a) wall time, (b) JC69 log-likelihood (the paper's metric), (c) normalized
Robinson-Foulds distance to the *known* generating topology — a check the
paper could not do with real data.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import alphabet as ab
from repro.core import cluster, distance, likelihood, nj, treeio
from repro.core.msa import MSAConfig, center_star_msa
from repro.data import SimConfig, simulate_family

from .common import emit, time_host


class _T:
    def __init__(self, children, root):
        self.children, self.root = children, root


def _aligned_family(n, L=256, seed=0):
    """Substitution-only family: equal-length rows == already aligned."""
    fam = simulate_family(SimConfig(n_leaves=n, root_len=L, branch_sub=0.03,
                                    branch_indel=0.0, seed=seed))
    S, _ = ab.encode_batch(fam.seqs, ab.DNA)
    return np.asarray(S)


def backend_matrix(smoke: bool = False):
    """repro.phylo TreeEngine backend x N timing matrix (BENCH_tree rows).

    Every backend runs on the same aligned family per N; ``derived``
    records the effective backend (cluster/auto gate to dense at small N)
    and, for tiled runs, the peak resident distance bytes vs the
    one-row-block-strip budget.
    """
    from repro.phylo import TreeEngine

    sizes = [48, 160] if smoke else [96, 256, 512]
    for n in sizes:
        msa = _aligned_family(n)
        for backend in ("dense", "cluster", "tiled"):
            eng = TreeEngine(gap_code=ab.DNA.gap_code, n_chars=ab.DNA.n_chars,
                             backend=backend, row_block=64, target_cluster=32,
                             seed=0)
            us, res = time_host(eng.build, msa)
            derived = f"effective={res.backend}"
            if res.backend == "tiled":   # strip bound is the tiled contract
                derived += (f";peak_bytes={res.tile_stats['peak_resident_bytes']}"
                            f";strip_bytes={res.tile_stats['row_block_bytes']}")
            emit(f"bench/tree/{backend}_n{n}", us, derived)


def table5_trees():
    fam = simulate_family(SimConfig(n_leaves=96, root_len=512,
                                    branch_sub=0.02, branch_indel=0.0005,
                                    seed=11))
    res = center_star_msa(fam.seqs, MSAConfig(method="kmer", k=10,
                                              max_anchors=96, max_seg=48))
    msa = jnp.asarray(res.msa)
    gap, nch = ab.DNA.gap_code, ab.DNA.n_chars
    gt = _T(fam.children, fam.root)

    # direct NJ (monolithic)
    D = distance.distance_matrix(msa, gap_code=gap, n_chars=nch)
    D.block_until_ready()
    t0 = time.perf_counter()
    D = distance.distance_matrix(msa, gap_code=gap, n_chars=nch)
    tree = nj.neighbor_joining(D, 96)
    jnp.asarray(tree.children).block_until_ready()
    us_direct = (time.perf_counter() - t0) * 1e6
    ll = float(likelihood.log_likelihood(msa, tree.children, tree.blen,
                                         tree.root, gap_code=gap))
    rf = treeio.normalized_rf(_T(np.asarray(tree.children), int(tree.root)),
                              gt, 96)
    emit("table5/direct_nj", us_direct, f"logL={ll:.0f};RF={rf:.3f}")

    # HPTree cluster-merge (the paper's scalable path)
    t0 = time.perf_counter()
    cp = cluster.cluster_phylogeny(res.msa, gap_code=gap, n_chars=nch,
                                   cfg=cluster.ClusterConfig(
                                       target_cluster=24, seed=0))
    us_cluster = (time.perf_counter() - t0) * 1e6
    ll_c = float(likelihood.log_likelihood(
        msa, jnp.asarray(cp.children), jnp.asarray(cp.blen), cp.root,
        gap_code=gap))
    rf_c = treeio.normalized_rf(_T(cp.children, cp.root), gt, 96)
    emit("table5/hptree_cluster", us_cluster,
         f"logL={ll_c:.0f};RF={rf_c:.3f};k={cp.n_clusters}")


def kernel_distance_speed():
    """Pallas distance kernel (interpret) vs jnp oracle on the same MSA —
    correctness-grade timing; the TPU win is architectural (one-hot stays in
    VMEM), quantified in EXPERIMENTS.md §Roofline."""
    from repro.core.distance import match_valid_counts
    from repro.kernels.distance import match_valid_pallas
    rng = np.random.default_rng(0)
    msa = jnp.asarray(rng.integers(0, 6, (128, 512)).astype(np.int8))

    def oracle():
        return match_valid_counts(msa, gap_code=5, n_chars=5)

    oracle()[0].block_until_ready()
    t0 = time.perf_counter()
    oracle()[0].block_until_ready()
    us = (time.perf_counter() - t0) * 1e6
    emit("kernels/distance_oracle_xla", us, "N=128;L=512")


def main():
    table5_trees()
    kernel_distance_speed()
    backend_matrix()


if __name__ == "__main__":
    main()
