"""Multi-start tree search vs the single-start NJ+NNI refiner.

Emits the ``bench/treesearch/*`` rows behind ``BENCH_treesearch.json``:

* ``single_nj_nni_nN``  — the baseline: one NJ start, NNI-only hill
  climb (``TreeEngine refine="ml"``), its final logL in ``derived``
* ``fleet_kK_nN``       — the K-start NNI+SPR fleet
  (``refine="search"``), best logL + per-start finals + move counts
* ``trajectory_rR``     — best-logL-so-far vs cumulative wall clock,
  one row per search round (``us_per_call`` is the cumulative wall
  time, ``derived`` the best logL over all starts up to that round)

The smoke run GATES the paper-facing invariant in-harness: on the
Φ_DNA analogue the multi-start best logL must be >= the single-start
NJ+NNI logL (both under the same model and per-fit budget) — the whole
point of paying for K searches.
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from .common import emit, time_host


def treesearch_matrix(smoke: bool = False):
    """Returns (single_logl, fleet_logl) for the in-harness gate."""
    from repro.core.alphabet import DNA
    from repro.core.msa import MSAConfig, center_star_msa
    from repro.data import phi_dna
    from repro.phylo import TreeEngine

    fam = phi_dna()
    msa = center_star_msa(fam.seqs, MSAConfig(method="kmer")).msa
    n = msa.shape[0]
    steps = 60 if smoke else 150
    rounds = 3 if smoke else 8
    starts = 4
    radius = 2 if smoke else 3
    common = dict(gap_code=DNA.gap_code, n_chars=DNA.n_chars,
                  model="jc69", ml_steps=steps)

    single_eng = TreeEngine(refine="ml", nni_rounds=rounds, **common)
    us, single = time_host(single_eng.build, msa)
    emit(f"bench/treesearch/single_nj_nni_n{n}", us,
         f"logl={single.logl['final']:.2f};n_nni={single.n_nni};"
         f"steps={steps};rounds={rounds}")

    fleet_eng = TreeEngine(refine="search", starts=starts,
                           spr_radius=radius, search_rounds=rounds,
                           **common)
    us, fleet = time_host(fleet_eng.build, msa)
    stats = fleet.search
    finals = [f"{t[-1]:.2f}" for t in stats["trajectories"]]
    emit(f"bench/treesearch/fleet_k{starts}_n{n}", us,
         f"logl={fleet.logl['final']:.2f};best_start={stats['best_start']}"
         f"({stats['start_labels'][stats['best_start']]});"
         f"moves={fleet.n_nni};spr_radius={radius};"
         f"per_start_logl={'/'.join(finals)}")

    # best-logL-so-far vs cumulative wall clock, per round
    traj = np.asarray(stats["trajectories"], np.float64)
    secs = np.asarray(stats["round_seconds"], np.float64)
    cum = 0.0
    for r in range(traj.shape[1]):
        cum += secs[r]
        best = float(np.nanmax(traj[:, :r + 1]))
        emit(f"bench/treesearch/trajectory_r{r}", cum * 1e6,
             f"best_logl={best:.4f};n_active_starts="
             f"{int(np.isfinite(traj[:, r]).sum())}")

    return float(single.logl["final"]), float(fleet.logl["final"])


def check_gate(single_logl: float, fleet_logl: float, tol: float = 1e-3):
    """Multi-start best logL must not fall below the single-start NJ+NNI
    result — returns a list of failure strings (empty = pass)."""
    if fleet_logl < single_logl - tol:
        return [f"fleet best logL {fleet_logl:.4f} < single-start NJ+NNI "
                f"logL {single_logl:.4f} (tol {tol})"]
    return []


def main(argv=None):
    ap = argparse.ArgumentParser(prog="benchmarks.bench_treesearch")
    ap.add_argument("--smoke", action="store_true",
                    help="small CI-budget run (fewer rounds/adam steps)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write emitted rows + metrics snapshot to PATH")
    args = ap.parse_args(argv)

    from . import common
    print("name,us_per_call,derived")
    single_logl, fleet_logl = treesearch_matrix(smoke=args.smoke)
    failures = check_gate(single_logl, fleet_logl)
    if args.json:
        from repro.obs import REGISTRY
        with open(args.json, "w") as f:
            json.dump({"rows": common.ROWS,
                       "metrics": REGISTRY.snapshot()}, f, indent=1)
        print(f"# wrote {len(common.ROWS)} rows to {args.json}")
    if failures:
        raise SystemExit("BENCH_treesearch gate failed:\n  " +
                         "\n  ".join(failures))


if __name__ == "__main__":
    main()
