"""Shared benchmark utilities: wall-clock timing with compile excluded."""
from __future__ import annotations

import time

import jax


def time_call(fn, *args, repeats: int = 3, warmup: int = 1):
    """Median wall time (us) of fn(*args) with jit warmup excluded."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6, out


def time_host(fn, *args, repeats: int = 1):
    """Wall time (us) of a host-level pipeline (includes jit on first call,
    so callers warm up separately when comparing)."""
    t0 = time.perf_counter()
    out = fn(*args)
    dt = time.perf_counter() - t0
    return dt * 1e6, out


ROWS: list[dict] = []       # every emit() lands here; run.py can dump JSON


def emit(name: str, us: float, derived):
    print(f"{name},{us:.1f},{derived}")
    ROWS.append({"name": name, "us_per_call": round(us, 1),
                 "derived": str(derived)})
