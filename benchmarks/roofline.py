"""Roofline analysis: LM dry-run artifacts + alignment-kernel cost models.

Two independent sections share one set of hardware peaks (``Peaks`` — no
hardcoded chip: pass your own numbers; the default is a 256-chip v5e pod):

**LM layer-scan section** (``analyze``/``main``) — rooflines for the
training/serving side from compiled dry-run artifacts. Terms per
(arch x shape):
  compute    = FLOPs/device / peaks.flops      [bf16 MXU peak]
  memory     = bytes/device / peaks.hbm_bw     [HBM bw]
  collective = collective bytes/device / peaks.ici_bw  [ICI per link]
FLOPs/bytes come from ``compiled.cost_analysis()`` of the ROOFLINE lowering
(layer scan unrolled, microbatches=1) because XLA counts while bodies once
regardless of trip count (validated in EXPERIMENTS.md §Roofline). Two inner
scans remain rolled even there — the flash-attention KV-chunk scan and the
SSD chunk scan — so their missing trips are added back analytically from the
exact einsum shapes; everything else is straight from the artifact.
MODEL_FLOPS = 6·N_active·D (train) / 2·N_active·D (inference) gives the
useful-compute ratio.

**Alignment-kernel section** (``sw_forward_cost`` /
``banded_forward_cost`` / ``fused_pairs_cost`` / ``distance_cost`` /
``kernel_rooflines``) — analytic FLOP and HBM-byte models for the
``repro.kernels`` hot path (the HAlign-II map(1) stage). These are exact
functions of the shapes, so ``benchmarks/bench_kernels.py`` can gate
regressions on them deterministically (no wall-clock noise under the CPU
interpreter) and report achieved-vs-peak fractions when a measured wall
time is available. The headline invariant lives here: the fused banded
pairs kernel has NO direction-matrix term in its HBM bytes, so
``fused_pairs_cost(...)["hbm_bytes"] < sw_forward_cost(...)["hbm_bytes"]``
at every default bucket shape.
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Dict, Optional


@dataclasses.dataclass(frozen=True)
class Peaks:
    """Hardware peaks the rooflines are normalized by (per chip/link)."""
    flops: float = 197e12      # bf16 MXU peak, FLOP/s per chip
    hbm_bw: float = 819e9      # HBM bytes/s per chip
    ici_bw: float = 50e9       # ICI bytes/s per link
    chips: int = 256           # pod size the per-device numbers assume


DEFAULT_PEAKS = Peaks()        # 256 x v5e — override, don't edit

# Back-compat module constants (several call sites and docs reference
# these names); derived from the default peaks, not a second source.
PEAK_FLOPS = DEFAULT_PEAKS.flops
HBM_BW = DEFAULT_PEAKS.hbm_bw
ICI_BW = DEFAULT_PEAKS.ici_bw
CHIPS = DEFAULT_PEAKS.chips
KV_CHUNK = 1024            # layers.xla_flash default
SSD_CHUNK = 128            # mamba2.ssd_chunked default


def _counts(cfg):
    attn_layers = sum(1 for i in range(cfg.n_layers)
                      if cfg.layer_kind(i).startswith("attn"))
    mamba_layers = cfg.n_layers - attn_layers if cfg.family in ("ssm", "hybrid") \
        else 0
    return attn_layers, mamba_layers


def attn_flops(cfg, B, Sq, Skv, causal=True):
    """QK^T + PV einsum flops for ONE attention layer, forward."""
    eff = Skv / 2 if (causal and Sq == Skv) else Skv
    if cfg.sliding_window:
        eff = min(eff, cfg.sliding_window)
    return 2 * 2 * B * cfg.n_heads * cfg.head_dim * Sq * eff


def ssd_flops(cfg, B, S):
    """Dominant SSD einsums for ONE mamba layer, forward."""
    Q, st, nh, hp = SSD_CHUNK, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    cb = 2 * B * S * Q * st                       # C_i . B_j per chunk pair
    intra = 2 * B * S * Q * nh * hp               # masked mix
    states = 2 * B * S * st * nh * hp / max(Q, 1) * Q  # B (x dt) outer
    inter = 2 * B * S * nh * hp * st              # C . h
    return cb + intra + states + inter


def model_flops(cfg, shape) -> float:
    """6·N_active·D convention (the §Roofline 'useful compute')."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch      # decode: one token per seq


def scan_corrections(cfg, shape) -> float:
    """Forward flops hidden inside still-rolled inner scans (global)."""
    attn_l, mamba_l = _counts(cfg)
    B, S = shape.global_batch, shape.seq_len
    extra = 0.0
    if shape.kind in ("train", "prefill"):
        trips = max(S // KV_CHUNK, 1)
        a = attn_flops(cfg, B, S, S) * attn_l * (trips - 1) / max(trips, 1)
        m_trips = max(S // SSD_CHUNK, 1)
        m = ssd_flops(cfg, B, S) * mamba_l * (m_trips - 1) / max(m_trips, 1)
        mult = 3.0 if shape.kind == "train" else 1.0   # bwd ~ 2x fwd
        extra = (a + m) * mult
    return extra


def analytic_flops(cfg, shape) -> float:
    """Full analytic step flops (global): matmul 2N·T + attention + SSD,
    x3 bwd, x4/3 remat for train. Validated against unrolled compiles to
    ~15 % (see EXPERIMENTS.md §Roofline)."""
    attn_l, mamba_l = _counts(cfg)
    B, S = shape.global_batch, shape.seq_len
    n = cfg.active_param_count()
    if shape.kind == "decode":
        per_tok = 2.0 * n * B
        cache = attn_l * 4.0 * B * cfg.n_heads * cfg.head_dim * \
            min(S, cfg.sliding_window or S)
        ssm = mamba_l * 4.0 * B * cfg.ssm_heads * cfg.ssm_head_dim * cfg.ssm_state
        return per_tok + cache + ssm
    fwd = 2.0 * n * B * S + attn_l * attn_flops(cfg, B, S, S) \
        + mamba_l * ssd_flops(cfg, B, S)
    if shape.kind == "prefill":
        return fwd
    mult = 4.0 if cfg.remat else 3.0
    return fwd * mult


def min_traffic_bytes(cfg, shape, mu: int,
                      peaks: Peaks = DEFAULT_PEAKS) -> float:
    """Analytic LOWER bound on HBM bytes/device/step (params + optimizer +
    remat-boundary activations + caches; perfect fusion assumed). The XLA
    'bytes accessed' number is the matching UPPER bound (fusion-blind)."""
    CHIPS = peaks.chips
    p_dev = cfg.param_count() / CHIPS
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        t_dev = B * S / CHIPS
        w = p_dev * (2 * 2 * mu      # bf16 param reads, fwd+bwd per micro
                     + 4 + 4         # f32 grad write + read
                     + 16 + 8 + 8)   # adam m,v r/w + master p r/w
        acts = cfg.n_layers * t_dev * cfg.d_model * 2 * 2  # save+restore bf16
        logits = t_dev * cfg.vocab_size * 4 * 2
        return w + acts + logits
    if shape.kind == "prefill":
        t_dev = B * S / CHIPS
        acts = cfg.n_layers * t_dev * cfg.d_model * 2
        cache = cfg.n_layers * t_dev * 2 * cfg.n_kv_heads * cfg.head_dim * 2
        return p_dev * 2 + acts + cache
    # decode: stream the whole cache + params once per token
    W = min(S, cfg.sliding_window or S)
    attn_l = sum(1 for i in range(cfg.n_layers)
                 if cfg.layer_kind(i).startswith("attn"))
    cache = attn_l * (B / 1) * W * 2 * cfg.n_kv_heads * cfg.head_dim * 2 / CHIPS
    ssm_l = cfg.n_layers - attn_l if cfg.family in ("ssm", "hybrid") else 0
    ssm = ssm_l * B * cfg.ssm_heads * cfg.ssm_head_dim * cfg.ssm_state * 4 / CHIPS
    return p_dev * 2 + cache + ssm


def analyze(rec: Dict, cfg, shape,
            peaks: Peaks = DEFAULT_PEAKS) -> Optional[Dict]:
    if "error" in rec or "skipped" in rec:
        return None
    CHIPS = peaks.chips
    # roofline lowerings unroll layers but keep the (homogeneous) microbatch
    # scan: multiply per-step totals by the recorded mu — exact, not an
    # estimate. Records lowered with mu=1 multiply by 1.
    mu = rec.get("microbatches", 1) if shape.kind == "train" else 1
    ng_mu = mu
    source = "hlo"
    if not rec.get("roofline_mode", False):
        # scanned lowering: while bodies counted once. Fall back to the
        # validated analytic flop model; scale collectives by the known
        # layer-scan trips (upper bound for the non-scan remainder).
        import math as _m
        from repro.models.transformer import n_groups as _ng
        ng_mu = mu * max(_ng(cfg), 1)
        source = "analytic"
    if source == "hlo":
        flops_dev = rec["flops_per_device"] * mu
        corrected = flops_dev + scan_corrections(cfg, shape) / CHIPS
        bytes_dev = rec["bytes_accessed_per_device"] * mu
        coll = sum(rec["collective_bytes_per_device"].values()) * mu
    else:
        corrected = analytic_flops(cfg, shape) / CHIPS
        bytes_dev = rec["bytes_accessed_per_device"] * ng_mu
        coll = sum(rec["collective_bytes_per_device"].values()) * ng_mu
    t_c = corrected / peaks.flops
    t_m_hi = bytes_dev / peaks.hbm_bw
    t_m_lo = min_traffic_bytes(cfg, shape, mu, peaks) / peaks.hbm_bw
    t_n = coll / peaks.ici_bw
    # bottleneck judged with the achievable (min-traffic) memory term; the
    # fusion-blind upper bound is reported alongside
    terms = {"compute": t_c, "memory": t_m_lo, "collective": t_n}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    return {
        "arch": rec["arch"], "shape": rec["shape"],
        "source": source,
        "compute_s": t_c, "memory_lo_s": t_m_lo, "memory_hi_s": t_m_hi,
        "collective_s": t_n,
        "bottleneck": bottleneck,
        "model_flops": mf,
        "hlo_flops_global": corrected * CHIPS,
        "useful_ratio": mf / (corrected * CHIPS) if corrected > 0 else 0.0,
        "roofline_fraction": t_c / max(terms.values()) if max(terms.values()) > 0 else 0.0,
    }


# --------------------------------------------------------------------------
# Alignment-kernel rooflines (repro.kernels — the HAlign-II map(1) stage)
# --------------------------------------------------------------------------
#
# Exact analytic models, deterministic in the shapes: FLOPs from cells x
# per-cell op count, HBM bytes from the tensors that actually cross the
# HBM<->VMEM boundary (band state / score rows that stay in VMEM scratch
# are *not* counted — that residency is the whole point of the kernels).

GOTOH_CELL_FLOPS = 14       # M/Ix/Iy updates + dir packing per DP cell
TRACE_STEP_FLOPS = 12       # byte decode + move select per traceback step


def sw_forward_cost(B: int, n: int, m: int, n_chars: int = 6) -> Dict:
    """kernels.sw forward: O(n·m) DP, int8 direction matrix to HBM."""
    cells = B * n * (m + 1)
    return {
        "kernel": "sw_forward", "B": B, "n": n, "m": m,
        "flops": float(cells * GOTOH_CELL_FLOPS),
        # in: a + b int8, sub f32; out: dirs int8 (the dominant term) + out f32
        "hbm_bytes": float(B * n + B * m + n_chars * n_chars * 4
                           + cells + B * 8 * 4),
    }


def banded_forward_cost(B: int, n: int, m: int, band: int) -> Dict:
    """kernels.banded forward: O(n·W) band, band state resident in VMEM."""
    cells = B * n * band
    return {
        "kernel": "banded_forward", "B": B, "n": n, "m": m, "band": band,
        "flops": float(cells * GOTOH_CELL_FLOPS),
        "hbm_bytes": float(B * n + B * m + 6 * 6 * 4 + cells + B * 8 * 4),
    }


def fused_pairs_cost(B: int, n: int, m: int, band: int) -> Dict:
    """kernels.banded fused pairs: forward + traceback in one program.

    No direction-matrix term at all — dirs live and die in VMEM scratch.
    HBM traffic is sequences in, aligned rows + stats out.
    """
    cells = B * n * band
    steps = B * (n + m)
    return {
        "kernel": "fused_pairs", "B": B, "n": n, "m": m, "band": band,
        "flops": float(cells * GOTOH_CELL_FLOPS + steps * TRACE_STEP_FLOPS),
        "hbm_bytes": float(B * n + B * m + 6 * 6 * 4
                           + 2 * B * (n + m) + B * 8 * 4),
    }


def distance_cost(N: int, M: int, L: int, n_chars: int = 4,
                  pack: str = "int8") -> Dict:
    """kernels.distance match/valid: one-hot MXU counting.

    ``vmem_tile_bytes`` is the expanded one-hot operand footprint per grid
    step — the number the int8 packing divides by 4 versus f32; HBM bytes
    are int8 sequences in + count matrices out either way.
    """
    itemsize = 1 if pack == "int8" else 4
    out_itemsize = 4                      # int32 counts / f32 legacy
    flops = 2.0 * N * M * L * (n_chars + 1)   # match (C lanes) + valid dots
    return {
        "kernel": "distance", "N": N, "M": M, "L": L, "pack": pack,
        "flops": float(flops),
        "hbm_bytes": float(N * L + M * L + 2 * N * M * out_itemsize),
        "vmem_tile_bytes": float(2 * 128 * 128 * n_chars * itemsize),
    }


def achieved(cost: Dict, wall_s: float, peaks: Peaks = DEFAULT_PEAKS) -> Dict:
    """Achieved-vs-peak fractions for one measured kernel run (one chip)."""
    if wall_s <= 0:
        return {**cost, "wall_s": wall_s}
    return {
        **cost, "wall_s": wall_s,
        "achieved_flops": cost["flops"] / wall_s,
        "flops_frac_of_peak": cost["flops"] / wall_s / peaks.flops,
        "achieved_hbm_bw": cost["hbm_bytes"] / wall_s,
        "hbm_frac_of_peak": cost["hbm_bytes"] / wall_s / peaks.hbm_bw,
    }


def kernel_rooflines(shapes=None, peaks: Peaks = DEFAULT_PEAKS):
    """Cost-model rows for the default bucket shapes (no execution).

    Each row carries the analytic flops/hbm_bytes plus the arithmetic
    intensity and the peak-bound wall time on ``peaks`` — what
    BENCH_kernels.json records and the CI smoke gate compares.
    """
    if shapes is None:
        # default pow2 bucket shapes the engine actually produces
        shapes = [(64, 128, 128, 16), (64, 256, 256, 32), (32, 512, 512, 64)]
    rows = []
    for B, n, m, W in shapes:
        for cost in (sw_forward_cost(B, n, m),
                     banded_forward_cost(B, n, m, W),
                     fused_pairs_cost(B, n, m, W),
                     distance_cost(B, B, n)):
            ai = cost["flops"] / max(cost["hbm_bytes"], 1.0)
            rows.append({
                **cost,
                "intensity_flops_per_byte": ai,
                "peak_bound_s": max(cost["flops"] / peaks.flops,
                                    cost["hbm_bytes"] / peaks.hbm_bw),
            })
    return rows


ADVICE = {
    "compute": "compute-bound: raise MXU utilization (larger tiles, bf16 "
               "everywhere, fewer remat recomputes)",
    "memory": "HBM-bound: cut activation traffic (fused kernels, smaller "
              "remat policy, bf16 intermediates, flash attention)",
    "collective": "ICI-bound: overlap collectives with compute (collective "
                  "matmul), shard params deeper (FSDP), compress gradients",
}


def main(out="results/roofline.md", peaks: Peaks = DEFAULT_PEAKS):
    from repro.configs import ALL_ARCHS, SHAPES, get_arch, shape_applicable

    recs: Dict = {}
    # roofline-mode lowerings (preferred; trip-exact)
    for p in ("results/roofline_rest.jsonl.head", "results/roofline_rest.jsonl",
              "results/dryrun_roofline.json"):
        pp = Path(p)
        if not pp.exists():
            continue
        if p.endswith(".json"):
            data = json.loads(pp.read_text())
        else:
            data = []
            dec = json.JSONDecoder()
            for line in pp.read_text().splitlines():
                line = line.strip()
                while line.startswith("{"):
                    obj, end = dec.raw_decode(line)
                    data.append(obj)
                    line = line[end:].strip()
        for r in data:
            if "flops_per_device" in r:
                recs[(r["arch"], r["shape"])] = r
    # scanned dry-run as analytic-model fallback
    fb = Path("results/dryrun_all.json")
    if fb.exists():
        for r in json.loads(fb.read_text()):
            if (r.get("mesh") == "pod" and r.get("shape") not in (None, "msa")
                    and "flops_per_device" in r):
                recs.setdefault((r["arch"], r["shape"]), r)

    rows = []
    for arch in ALL_ARCHS:
        cfg = get_arch(arch).config
        for shape_name, shape in SHAPES.items():
            ok, why = shape_applicable(cfg, shape)
            if not ok:
                rows.append({"arch": arch, "shape": shape_name,
                             "skipped": why})
                continue
            rec = recs.get((arch, shape_name))
            if rec is None:
                rows.append({"arch": arch, "shape": shape_name,
                             "skipped": "no dry-run record"})
                continue
            r = analyze(rec, cfg, shape, peaks)
            if r:
                rows.append(r)

    lines = ["| arch | shape | src | compute s | memory s (lo..hi) | "
             "collective s | bottleneck | useful ratio | roofline frac | "
             "next move |",
             "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if "skipped" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | "
                         f"skipped: {r['skipped']} | — | — | — |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['source']} | "
            f"{r['compute_s']:.3e} | {r['memory_lo_s']:.2e}..{r['memory_hi_s']:.2e} | "
            f"{r['collective_s']:.3e} | {r['bottleneck']} | "
            f"{r['useful_ratio']:.2f} | {r['roofline_fraction']:.2f} | "
            f"{ADVICE[r['bottleneck']].split(':')[1].strip()} |")
    Path(out).parent.mkdir(parents=True, exist_ok=True)
    Path(out).write_text("\n".join(lines) + "\n")
    print("\n".join(lines))
    return rows


if __name__ == "__main__":
    main()
