"""Roofline analysis from the compiled dry-run artifacts (§Roofline).

Terms per (arch x shape), single-pod (256 x v5e):
  compute    = FLOPs/device / 197e12        [bf16 MXU peak]
  memory     = bytes/device / 819e9         [HBM bw]
  collective = collective bytes/device / 50e9  [ICI per link]

FLOPs/bytes come from ``compiled.cost_analysis()`` of the ROOFLINE lowering
(layer scan unrolled, microbatches=1) because XLA counts while bodies once
regardless of trip count (validated in EXPERIMENTS.md §Roofline). Two inner
scans remain rolled even there — the flash-attention KV-chunk scan and the
SSD chunk scan — so their missing trips are added back analytically from the
exact einsum shapes (documented below); everything else is straight from the
artifact. MODEL_FLOPS = 6·N_active·D (train) / 2·N_active·D (inference)
gives the useful-compute ratio.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, Optional

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s / chip
ICI_BW = 50e9              # bytes/s / link
CHIPS = 256                # single-pod roofline
KV_CHUNK = 1024            # layers.xla_flash default
SSD_CHUNK = 128            # mamba2.ssd_chunked default


def _counts(cfg):
    attn_layers = sum(1 for i in range(cfg.n_layers)
                      if cfg.layer_kind(i).startswith("attn"))
    mamba_layers = cfg.n_layers - attn_layers if cfg.family in ("ssm", "hybrid") \
        else 0
    return attn_layers, mamba_layers


def attn_flops(cfg, B, Sq, Skv, causal=True):
    """QK^T + PV einsum flops for ONE attention layer, forward."""
    eff = Skv / 2 if (causal and Sq == Skv) else Skv
    if cfg.sliding_window:
        eff = min(eff, cfg.sliding_window)
    return 2 * 2 * B * cfg.n_heads * cfg.head_dim * Sq * eff


def ssd_flops(cfg, B, S):
    """Dominant SSD einsums for ONE mamba layer, forward."""
    Q, st, nh, hp = SSD_CHUNK, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    cb = 2 * B * S * Q * st                       # C_i . B_j per chunk pair
    intra = 2 * B * S * Q * nh * hp               # masked mix
    states = 2 * B * S * st * nh * hp / max(Q, 1) * Q  # B (x dt) outer
    inter = 2 * B * S * nh * hp * st              # C . h
    return cb + intra + states + inter


def model_flops(cfg, shape) -> float:
    """6·N_active·D convention (the §Roofline 'useful compute')."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch      # decode: one token per seq


def scan_corrections(cfg, shape) -> float:
    """Forward flops hidden inside still-rolled inner scans (global)."""
    attn_l, mamba_l = _counts(cfg)
    B, S = shape.global_batch, shape.seq_len
    extra = 0.0
    if shape.kind in ("train", "prefill"):
        trips = max(S // KV_CHUNK, 1)
        a = attn_flops(cfg, B, S, S) * attn_l * (trips - 1) / max(trips, 1)
        m_trips = max(S // SSD_CHUNK, 1)
        m = ssd_flops(cfg, B, S) * mamba_l * (m_trips - 1) / max(m_trips, 1)
        mult = 3.0 if shape.kind == "train" else 1.0   # bwd ~ 2x fwd
        extra = (a + m) * mult
    return extra


def analytic_flops(cfg, shape) -> float:
    """Full analytic step flops (global): matmul 2N·T + attention + SSD,
    x3 bwd, x4/3 remat for train. Validated against unrolled compiles to
    ~15 % (see EXPERIMENTS.md §Roofline)."""
    attn_l, mamba_l = _counts(cfg)
    B, S = shape.global_batch, shape.seq_len
    n = cfg.active_param_count()
    if shape.kind == "decode":
        per_tok = 2.0 * n * B
        cache = attn_l * 4.0 * B * cfg.n_heads * cfg.head_dim * \
            min(S, cfg.sliding_window or S)
        ssm = mamba_l * 4.0 * B * cfg.ssm_heads * cfg.ssm_head_dim * cfg.ssm_state
        return per_tok + cache + ssm
    fwd = 2.0 * n * B * S + attn_l * attn_flops(cfg, B, S, S) \
        + mamba_l * ssd_flops(cfg, B, S)
    if shape.kind == "prefill":
        return fwd
    mult = 4.0 if cfg.remat else 3.0
    return fwd * mult


def min_traffic_bytes(cfg, shape, mu: int) -> float:
    """Analytic LOWER bound on HBM bytes/device/step (params + optimizer +
    remat-boundary activations + caches; perfect fusion assumed). The XLA
    'bytes accessed' number is the matching UPPER bound (fusion-blind)."""
    p_dev = cfg.param_count() / CHIPS
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        t_dev = B * S / CHIPS
        w = p_dev * (2 * 2 * mu      # bf16 param reads, fwd+bwd per micro
                     + 4 + 4         # f32 grad write + read
                     + 16 + 8 + 8)   # adam m,v r/w + master p r/w
        acts = cfg.n_layers * t_dev * cfg.d_model * 2 * 2  # save+restore bf16
        logits = t_dev * cfg.vocab_size * 4 * 2
        return w + acts + logits
    if shape.kind == "prefill":
        t_dev = B * S / CHIPS
        acts = cfg.n_layers * t_dev * cfg.d_model * 2
        cache = cfg.n_layers * t_dev * 2 * cfg.n_kv_heads * cfg.head_dim * 2
        return p_dev * 2 + acts + cache
    # decode: stream the whole cache + params once per token
    W = min(S, cfg.sliding_window or S)
    attn_l = sum(1 for i in range(cfg.n_layers)
                 if cfg.layer_kind(i).startswith("attn"))
    cache = attn_l * (B / 1) * W * 2 * cfg.n_kv_heads * cfg.head_dim * 2 / CHIPS
    ssm_l = cfg.n_layers - attn_l if cfg.family in ("ssm", "hybrid") else 0
    ssm = ssm_l * B * cfg.ssm_heads * cfg.ssm_head_dim * cfg.ssm_state * 4 / CHIPS
    return p_dev * 2 + cache + ssm


def analyze(rec: Dict, cfg, shape) -> Optional[Dict]:
    if "error" in rec or "skipped" in rec:
        return None
    # roofline lowerings unroll layers but keep the (homogeneous) microbatch
    # scan: multiply per-step totals by the recorded mu — exact, not an
    # estimate. Records lowered with mu=1 multiply by 1.
    mu = rec.get("microbatches", 1) if shape.kind == "train" else 1
    ng_mu = mu
    source = "hlo"
    if not rec.get("roofline_mode", False):
        # scanned lowering: while bodies counted once. Fall back to the
        # validated analytic flop model; scale collectives by the known
        # layer-scan trips (upper bound for the non-scan remainder).
        import math as _m
        from repro.models.transformer import n_groups as _ng
        ng_mu = mu * max(_ng(cfg), 1)
        source = "analytic"
    if source == "hlo":
        flops_dev = rec["flops_per_device"] * mu
        corrected = flops_dev + scan_corrections(cfg, shape) / CHIPS
        bytes_dev = rec["bytes_accessed_per_device"] * mu
        coll = sum(rec["collective_bytes_per_device"].values()) * mu
    else:
        corrected = analytic_flops(cfg, shape) / CHIPS
        bytes_dev = rec["bytes_accessed_per_device"] * ng_mu
        coll = sum(rec["collective_bytes_per_device"].values()) * ng_mu
    t_c = corrected / PEAK_FLOPS
    t_m_hi = bytes_dev / HBM_BW
    t_m_lo = min_traffic_bytes(cfg, shape, mu) / HBM_BW
    t_n = coll / ICI_BW
    # bottleneck judged with the achievable (min-traffic) memory term; the
    # fusion-blind upper bound is reported alongside
    terms = {"compute": t_c, "memory": t_m_lo, "collective": t_n}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    return {
        "arch": rec["arch"], "shape": rec["shape"],
        "source": source,
        "compute_s": t_c, "memory_lo_s": t_m_lo, "memory_hi_s": t_m_hi,
        "collective_s": t_n,
        "bottleneck": bottleneck,
        "model_flops": mf,
        "hlo_flops_global": corrected * CHIPS,
        "useful_ratio": mf / (corrected * CHIPS) if corrected > 0 else 0.0,
        "roofline_fraction": t_c / max(terms.values()) if max(terms.values()) > 0 else 0.0,
    }


ADVICE = {
    "compute": "compute-bound: raise MXU utilization (larger tiles, bf16 "
               "everywhere, fewer remat recomputes)",
    "memory": "HBM-bound: cut activation traffic (fused kernels, smaller "
              "remat policy, bf16 intermediates, flash attention)",
    "collective": "ICI-bound: overlap collectives with compute (collective "
                  "matmul), shard params deeper (FSDP), compress gradients",
}


def main(out="results/roofline.md"):
    from repro.configs import ALL_ARCHS, SHAPES, get_arch, shape_applicable

    recs: Dict = {}
    # roofline-mode lowerings (preferred; trip-exact)
    for p in ("results/roofline_rest.jsonl.head", "results/roofline_rest.jsonl",
              "results/dryrun_roofline.json"):
        pp = Path(p)
        if not pp.exists():
            continue
        if p.endswith(".json"):
            data = json.loads(pp.read_text())
        else:
            data = []
            dec = json.JSONDecoder()
            for line in pp.read_text().splitlines():
                line = line.strip()
                while line.startswith("{"):
                    obj, end = dec.raw_decode(line)
                    data.append(obj)
                    line = line[end:].strip()
        for r in data:
            if "flops_per_device" in r:
                recs[(r["arch"], r["shape"])] = r
    # scanned dry-run as analytic-model fallback
    fb = Path("results/dryrun_all.json")
    if fb.exists():
        for r in json.loads(fb.read_text()):
            if (r.get("mesh") == "pod" and r.get("shape") not in (None, "msa")
                    and "flops_per_device" in r):
                recs.setdefault((r["arch"], r["shape"]), r)

    rows = []
    for arch in ALL_ARCHS:
        cfg = get_arch(arch).config
        for shape_name, shape in SHAPES.items():
            ok, why = shape_applicable(cfg, shape)
            if not ok:
                rows.append({"arch": arch, "shape": shape_name,
                             "skipped": why})
                continue
            rec = recs.get((arch, shape_name))
            if rec is None:
                rows.append({"arch": arch, "shape": shape_name,
                             "skipped": "no dry-run record"})
                continue
            r = analyze(rec, cfg, shape)
            if r:
                rows.append(r)

    lines = ["| arch | shape | src | compute s | memory s (lo..hi) | "
             "collective s | bottleneck | useful ratio | roofline frac | "
             "next move |",
             "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if "skipped" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | "
                         f"skipped: {r['skipped']} | — | — | — |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['source']} | "
            f"{r['compute_s']:.3e} | {r['memory_lo_s']:.2e}..{r['memory_hi_s']:.2e} | "
            f"{r['collective_s']:.3e} | {r['bottleneck']} | "
            f"{r['useful_ratio']:.2f} | {r['roofline_fraction']:.2f} | "
            f"{ADVICE[r['bottleneck']].split(':')[1].strip()} |")
    Path(out).parent.mkdir(parents=True, exist_ok=True)
    Path(out).write_text("\n".join(lines) + "\n")
    print("\n".join(lines))
    return rows


if __name__ == "__main__":
    main()
