"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  table2/*    — genome MSA (paper Table 2): plain vs k-mer center star
  table3/*    — RNA MSA (Table 3)
  table4/*    — protein MSA (Table 4): SW vs NW center star
  table5/*    — phylogeny construction (Table 5): NJ vs HPTree cluster-merge
  fig5/*      — memory per device from the dry-run artifacts (Figure 5)
  fig6/*      — per-worker shard scaling (Figure 6)
  bench/msa/* — repro.align backend x method matrix (engine dispatch)
  scaling/*   — O(n) sequence-count scaling
Run the multi-pod dry-run separately: ``python -m repro.launch.dryrun --all``.

``--smoke`` runs the small backend matrices (the CI smoke step: the
repro.align backend x method matrix plus the repro.phylo tree backend x N
matrix); ``--json PATH`` additionally writes every emitted row as JSON,
``--json-tree PATH`` writes just the tree rows, and ``--json-ml PATH``
runs the ML-refinement matrix (``bench_ml``: logL gain + bootstrap
throughput vs the NJ baseline on the Φ_DNA analogue) and writes its
rows, and ``--json-search PATH`` runs the homology-search matrix
(``bench_search``: queries/sec vs DB size, prefilter survival, top-k
recall vs the exhaustive oracle) and writes its rows, and
``--json-kernels PATH`` runs the kernel roofline matrix
(``bench_kernels``: analytic flops/HBM-bytes at the default bucket
shapes plus measured achieved-vs-peak rows) and GATES it against the
recorded baseline (``benchmarks/baselines/BENCH_kernels.json`` — >20%
regression on a gated metric fails the run) — CI uploads
``BENCH_msa.json``, ``BENCH_tree.json``, ``BENCH_ml.json``,
``BENCH_search.json``, and ``BENCH_kernels.json`` as artifacts so every
bench trajectory is tracked per commit (``docs/BENCHMARKS.md`` documents
the artifact schema).
"""
from __future__ import annotations

import argparse
import json


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small subset: the backend matrices only")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write emitted rows as JSON to PATH")
    ap.add_argument("--json-tree", default=None, metavar="PATH",
                    help="also write the tree-stage rows as JSON to PATH")
    ap.add_argument("--json-ml", default=None, metavar="PATH",
                    help="also run the ML-refinement matrix and write its "
                         "rows as JSON to PATH")
    ap.add_argument("--json-search", default=None, metavar="PATH",
                    help="also run the homology-search matrix and write "
                         "its rows as JSON to PATH")
    ap.add_argument("--json-kernels", default=None, metavar="PATH",
                    help="also run the kernel roofline matrix, write its "
                         "rows as JSON to PATH, and gate against the "
                         "recorded baseline")
    args = ap.parse_args()

    from . import common
    print("name,us_per_call,derived")
    if args.smoke:
        from . import bench_msa, bench_tree
        bench_msa.backend_matrix(smoke=True)
        n_msa = len(common.ROWS)
        bench_tree.backend_matrix(smoke=True)
        tree_rows = common.ROWS[n_msa:]
    else:
        from . import bench_msa, bench_scaling, bench_tree
        bench_msa.main()
        n_msa = len(common.ROWS)
        bench_tree.main()
        tree_rows = common.ROWS[n_msa:]
        bench_scaling.main()

    ml_rows = []
    if args.json_ml:
        from . import bench_ml
        n_before = len(common.ROWS)
        bench_ml.ml_matrix(smoke=args.smoke)
        ml_rows = common.ROWS[n_before:]

    search_rows = []
    if args.json_search:
        from . import bench_search
        n_before = len(common.ROWS)
        bench_search.search_matrix(smoke=args.smoke)
        search_rows = common.ROWS[n_before:]

    kernel_failures = []
    kernel_rows = []
    if args.json_kernels:
        from . import bench_kernels
        kernel_rows = bench_kernels.kernel_matrix(smoke=args.smoke)
        kernel_failures = bench_kernels.check_invariants(kernel_rows)
        kernel_failures += bench_kernels.check_against_baseline(kernel_rows)

    if args.json:
        with open(args.json, "w") as f:
            json.dump(common.ROWS, f, indent=1)
        print(f"# wrote {len(common.ROWS)} rows to {args.json}")
    if args.json_tree:
        with open(args.json_tree, "w") as f:
            json.dump(tree_rows, f, indent=1)
        print(f"# wrote {len(tree_rows)} tree rows to {args.json_tree}")
    if args.json_ml:
        with open(args.json_ml, "w") as f:
            json.dump(ml_rows, f, indent=1)
        print(f"# wrote {len(ml_rows)} ml rows to {args.json_ml}")
    if args.json_search:
        with open(args.json_search, "w") as f:
            json.dump(search_rows, f, indent=1)
        print(f"# wrote {len(search_rows)} search rows to "
              f"{args.json_search}")
    if args.json_kernels:
        with open(args.json_kernels, "w") as f:
            json.dump(kernel_rows, f, indent=1)
        print(f"# wrote {len(kernel_rows)} kernel rows to "
              f"{args.json_kernels}")
        if kernel_failures:
            raise SystemExit("BENCH_kernels gate failed:\n  " +
                             "\n  ".join(kernel_failures))


if __name__ == "__main__":
    main()
