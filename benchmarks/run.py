"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  table2/* — genome MSA (paper Table 2): plain vs k-mer center star
  table3/* — RNA MSA (Table 3)
  table4/* — protein MSA (Table 4): SW vs NW center star
  table5/* — phylogeny construction (Table 5): NJ vs HPTree cluster-merge
  fig5/*   — memory per device from the dry-run artifacts (Figure 5)
  fig6/*   — per-worker shard scaling (Figure 6)
  scaling/*— O(n) sequence-count scaling
Run the multi-pod dry-run separately: ``python -m repro.launch.dryrun --all``.
"""
from __future__ import annotations


def main() -> None:
    print("name,us_per_call,derived")
    from . import bench_msa, bench_scaling, bench_tree
    bench_msa.main()
    bench_tree.main()
    bench_scaling.main()


if __name__ == "__main__":
    main()
