"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  table2/*    — genome MSA (paper Table 2): plain vs k-mer center star
  table3/*    — RNA MSA (Table 3)
  table4/*    — protein MSA (Table 4): SW vs NW center star
  table5/*    — phylogeny construction (Table 5): NJ vs HPTree cluster-merge
  fig5/*      — memory per device from the dry-run artifacts (Figure 5)
  fig6/*      — per-worker shard scaling (Figure 6)
  bench/msa/* — repro.align backend x method matrix (engine dispatch)
  scaling/*   — O(n) sequence-count scaling
Run the multi-pod dry-run separately: ``python -m repro.launch.dryrun --all``.

``--smoke`` runs the small backend matrices (the CI smoke step: the
repro.align backend x method matrix plus the repro.phylo tree backend x N
matrix). ``--json <name>[,<name>...]`` selects which benchmark artifacts
to write — names from {``msa``, ``tree``, ``ml``, ``search``,
``kernels``, ``all``} — each landing as ``BENCH_<name>.json`` in
``--out-dir`` (default ``.``). Every artifact is
``{"rows": [...], "metrics": {...}}``: the emitted rows plus the
``repro.obs`` metrics snapshot taken after that suite ran, so bench
trajectories carry the engine's own counters (dispatches, fallbacks,
pad waste) per commit. ``kernels`` additionally GATES the model rows
against the recorded baseline
(``benchmarks/baselines/BENCH_kernels.json`` — >20% regression on a
gated metric fails the run).

A PATH-looking ``--json`` value (contains ``/`` or ends in ``.json``)
keeps the legacy behavior — every emitted row dumped to that path.

The ``msa`` suite also runs the obs-overhead guardrail
(``bench_msa.obs_overhead_row``): instrumentation must cost < 3% on the
backend-matrix path, asserted in-harness (``docs/BENCHMARKS.md``
documents the artifact schema; CI uploads the ``BENCH_*.json`` set).
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

_SUITES = ("msa", "tree", "ml", "search", "kernels")


def _artifact(rows) -> dict:
    """The BENCH_*.json schema: rows + the obs metrics snapshot."""
    from repro.obs import REGISTRY
    return {"rows": rows, "metrics": REGISTRY.snapshot()}


def _write(path: Path, rows, label: str):
    with open(path, "w") as f:
        json.dump(_artifact(rows), f, indent=1)
    print(f"# wrote {len(rows)} {label} rows to {path}")


def parse_json_selector(value):
    """``--json`` value -> (names, legacy_path).

    Suite names (comma-separated) select artifacts; anything that looks
    like a path (has a separator or a .json suffix) is the legacy
    dump-all-rows form.
    """
    if value is None:
        return [], None
    looks_like_path = ("/" in value or value.endswith(".json")
                       or value.endswith(".JSON"))
    names = [n.strip() for n in value.split(",") if n.strip()]
    if not looks_like_path and all(n in _SUITES or n == "all"
                                   for n in names):
        if "all" in names:
            return list(_SUITES), None
        return names, None
    return [], value


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small subset: the backend matrices only")
    ap.add_argument("--json", default=None, metavar="NAMES|PATH",
                    help="comma-separated suites to write as "
                         "BENCH_<name>.json artifacts (msa, tree, ml, "
                         "search, kernels, all); a PATH-looking value "
                         "keeps the legacy dump-every-row behavior")
    ap.add_argument("--out-dir", default=".", metavar="DIR",
                    help="directory for BENCH_<name>.json artifacts")
    args = ap.parse_args()

    names, legacy_all = parse_json_selector(args.json)
    out_dir = Path(args.out_dir)
    if names:
        out_dir.mkdir(parents=True, exist_ok=True)

    def art_path(name: str) -> Path:
        return out_dir / f"BENCH_{name}.json"

    from . import common
    print("name,us_per_call,derived")
    if args.smoke:
        from . import bench_msa, bench_tree
        bench_msa.backend_matrix(smoke=True)
        msa_rows = list(common.ROWS)
        bench_tree.backend_matrix(smoke=True)
        tree_rows = common.ROWS[len(msa_rows):]
    else:
        from . import bench_msa, bench_scaling, bench_tree
        bench_msa.main()
        msa_rows = list(common.ROWS)
        bench_tree.main()
        tree_rows = common.ROWS[len(msa_rows):]
        bench_scaling.main()

    if "msa" in names:
        # the obs-overhead guardrail rides with the msa artifact: the
        # instrumented backend-matrix path must cost < 3% over disabled
        n_before = len(common.ROWS)
        bench_msa.obs_overhead_row(smoke=args.smoke)
        msa_rows = msa_rows + common.ROWS[n_before:]

    ml_rows = []
    if "ml" in names:
        from . import bench_ml
        n_before = len(common.ROWS)
        bench_ml.ml_matrix(smoke=args.smoke)
        ml_rows = common.ROWS[n_before:]

    search_rows = []
    if "search" in names:
        from . import bench_search
        n_before = len(common.ROWS)
        bench_search.search_matrix(smoke=args.smoke)
        search_rows = common.ROWS[n_before:]

    kernel_failures = []
    kernel_rows = []
    if "kernels" in names:
        from . import bench_kernels
        kernel_rows = bench_kernels.kernel_matrix(smoke=args.smoke)
        kernel_failures = bench_kernels.check_invariants(kernel_rows)
        kernel_failures += bench_kernels.check_against_baseline(kernel_rows)

    if legacy_all:
        print("# PATH-valued --json is deprecated; use --json "
              "<suite>[,<suite>] with --out-dir")
        with open(legacy_all, "w") as f:
            json.dump(common.ROWS, f, indent=1)
        print(f"# wrote {len(common.ROWS)} rows to {legacy_all}")
    if "msa" in names:
        _write(art_path("msa"), msa_rows, "msa")
    if "tree" in names:
        _write(art_path("tree"), tree_rows, "tree")
    if "ml" in names:
        _write(art_path("ml"), ml_rows, "ml")
    if "search" in names:
        _write(art_path("search"), search_rows, "search")
    if "kernels" in names:
        _write(art_path("kernels"), kernel_rows, "kernel")
        if kernel_failures:
            raise SystemExit("BENCH_kernels gate failed:\n  " +
                             "\n  ".join(kernel_failures))


if __name__ == "__main__":
    main()
