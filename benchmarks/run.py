"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  table2/*    — genome MSA (paper Table 2): plain vs k-mer center star
  table3/*    — RNA MSA (Table 3)
  table4/*    — protein MSA (Table 4): SW vs NW center star
  table5/*    — phylogeny construction (Table 5): NJ vs HPTree cluster-merge
  fig5/*      — memory per device from the dry-run artifacts (Figure 5)
  fig6/*      — per-worker shard scaling (Figure 6)
  bench/msa/* — repro.align backend x method matrix (engine dispatch)
  scaling/*   — O(n) sequence-count scaling
Run the multi-pod dry-run separately: ``python -m repro.launch.dryrun --all``.

``--smoke`` runs only the small backend matrix (the CI smoke step);
``--json PATH`` additionally writes every emitted row as JSON — CI
uploads ``BENCH_msa.json`` as an artifact so the bench trajectory is
tracked per commit.
"""
from __future__ import annotations

import argparse
import json


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small subset: backend x method matrix only")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write emitted rows as JSON to PATH")
    args = ap.parse_args()

    from . import common
    print("name,us_per_call,derived")
    if args.smoke:
        from . import bench_msa
        bench_msa.backend_matrix(smoke=True)
    else:
        from . import bench_msa, bench_scaling, bench_tree
        bench_msa.main()
        bench_tree.main()
        bench_scaling.main()

    if args.json:
        with open(args.json, "w") as f:
            json.dump(common.ROWS, f, indent=1)
        print(f"# wrote {len(common.ROWS)} rows to {args.json}")


if __name__ == "__main__":
    main()
