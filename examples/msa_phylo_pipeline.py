"""End-to-end driver (paper pipeline): generate a Φ_DNA-style dataset to
FASTA, run the distributed-ready MSA + HPTree cluster-merge phylogeny via the
launcher, inspect the report. This is the example that exercises the public
CLI surface exactly as a cluster run would.

  PYTHONPATH=src python examples/msa_phylo_pipeline.py
"""
import json
import subprocess
import sys
import tempfile
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))

from repro.data import SimConfig, simulate_family, write_fasta  # noqa: E402


def main():
    with tempfile.TemporaryDirectory() as d:
        fam = simulate_family(SimConfig(n_leaves=80, root_len=512,
                                        branch_sub=0.01, branch_indel=0.0008,
                                        seed=4))
        fasta = Path(d) / "family.fasta"
        write_fasta(fasta, fam.names, fam.seqs)
        out = Path(d) / "out"
        cmd = [sys.executable, "-m", "repro.launch.msa_run",
               "--fasta", str(fasta), "--out", str(out),
               "--method", "kmer", "--tree", "cluster"]
        env = {"PYTHONPATH": str(ROOT / "src"), "PATH": "/usr/bin:/bin"}
        import os
        env.update({k: v for k, v in os.environ.items()
                    if k not in env})
        proc = subprocess.run(cmd, env=env, capture_output=True, text=True)
        print(proc.stdout)
        if proc.returncode != 0:
            print(proc.stderr[-2000:])
            raise SystemExit(1)
        report = json.loads((out / "report.json").read_text())
        assert report["n_sequences"] == 80
        nwk = (out / "tree.nwk").read_text()
        print("tree leaves:", nwk.count("seq"), "| aligned.fasta + tree.nwk "
              "+ report.json written")


if __name__ == "__main__":
    main()
