"""Client walk-through of the MSA/phylogeny web service (repro.serve).

Starts the service in-process on a free port (the same server
``python -m repro.launch.serve_msa`` binds), then drives the four
endpoints with plain stdlib HTTP: align a family, hit the cache, insert
two new sequences incrementally against the frozen center, and build a
tree from the cached MSA — printing the coalescing/cache stats each
response carries.

  PYTHONPATH=src python examples/msa_service.py
"""
import json
import sys
import threading
import urllib.request
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import numpy as np

from repro.serve import MSAService, ServiceConfig, serve_http


def post(port, path, obj):
    req = urllib.request.Request(
        f"http://127.0.0.1:{port}{path}", data=json.dumps(obj).encode(),
        headers={"Content-Type": "application/json"})
    with urllib.request.urlopen(req) as r:
        return json.loads(r.read())


def main():
    service = MSAService(ServiceConfig(max_wait_ms=5.0))
    httpd = serve_http(service, "127.0.0.1", 0)
    port = httpd.server_address[1]
    threading.Thread(target=httpd.serve_forever, daemon=True).start()
    print(f"service on 127.0.0.1:{port}")

    rng = np.random.default_rng(0)
    base = "".join(rng.choice(list("ACGT"), 120))

    def mutate(s, n=3):
        s = list(s)
        for _ in range(n):
            s[rng.integers(0, len(s))] = "ACGT"[rng.integers(0, 4)]
        return "".join(s)

    fasta = "".join(f">seq{i}\n{mutate(base)}\n" for i in range(6))

    # 1. align a family (FASTA payload, exactly what msa_run reads)
    r = post(port, "/align", {"fasta": fasta})
    msa_id = r["alignment"]["msa_id"]
    print(f"\n/align: width={r['alignment']['width']} "
          f"cached={r['cached']} path={r['path']} "
          f"elapsed={r['elapsed_ms']:.1f}ms")
    for name, row in zip(r["alignment"]["names"], r["alignment"]["rows"]):
        print(f"  {name:>6} {row}")

    # 2. the same set again -> content-hash cache hit, byte-identical
    r2 = post(port, "/align", {"fasta": fasta})
    print(f"\n/align (repeat): cached={r2['cached']} "
          f"cache_stats={r2['cache']}")

    # 3. incrementally add sequences against the frozen center
    radd = post(port, "/align/add",
                {"msa_id": msa_id,
                 "sequences": [mutate(base), mutate(base, 5)],
                 "names": ["new0", "new1"]})
    print(f"\n/align/add: width={radd['alignment']['width']} "
          f"add={radd['add']}")

    # 4. a tree from the cached MSA (memoized per msa_id + backend)
    t = post(port, "/tree", {"msa_id": msa_id})
    print(f"\n/tree: backend={t['backend']} cached_tree={t['cached_tree']}")
    print(f"  {t['newick']}")
    t2 = post(port, "/tree", {"msa_id": msa_id})
    print(f"/tree (repeat): cached_tree={t2['cached_tree']}")

    with urllib.request.urlopen(f"http://127.0.0.1:{port}/healthz") as h:
        print(f"\n/healthz: {json.loads(h.read())}")

    httpd.shutdown()
    httpd.server_close()
    service.drain()
    print("\ndrained; bye")


if __name__ == "__main__":
    main()
