"""Quickstart: align a small DNA family, build its tree, score everything.

  PYTHONPATH=src python examples/quickstart.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax.numpy as jnp
import numpy as np

from repro.core import alphabet as ab
from repro.core import distance, likelihood, nj, sp_score, treeio
from repro.core.msa import MSAConfig, center_star_msa, decode_msa
from repro.data import SimConfig, simulate_family


def main():
    # 1. simulate a family of similar sequences (known true tree)
    fam = simulate_family(SimConfig(n_leaves=12, root_len=600,
                                    branch_sub=0.02, branch_indel=0.001,
                                    seed=0))
    print(f"{len(fam.seqs)} sequences, lengths "
          f"{min(map(len, fam.seqs))}-{max(map(len, fam.seqs))}")

    # 2. HAlign-II MSA: k-mer anchored center star
    cfg = MSAConfig(method="kmer", k=10, max_anchors=128, max_seg=48)
    res = center_star_msa(fam.seqs, cfg)
    rows = decode_msa(res.msa, cfg)
    print(f"MSA width {res.width} (center = seq{res.center_idx}, "
          f"{res.n_fallback} full-DP fallbacks)")
    for r in rows[:3]:
        print("  " + r[:76] + ("…" if len(r) > 76 else ""))

    # 3. quality: average sum-of-pairs penalty (paper metric, lower better)
    msa = jnp.asarray(res.msa)
    gap, nch = ab.DNA.gap_code, ab.DNA.n_chars
    print(f"avg SP penalty: "
          f"{float(sp_score.avg_sp(msa, gap_code=gap, n_chars=nch)):.1f}")

    # 4. NJ tree + JC69 likelihood + RF vs the true topology
    D = distance.distance_matrix(msa, gap_code=gap, n_chars=nch)
    tree = nj.neighbor_joining(D, len(fam.seqs))
    ll = likelihood.log_likelihood(msa, tree.children, tree.blen, tree.root,
                                   gap_code=gap)

    class T:
        pass
    t, g = T(), T()
    t.children, t.root = np.asarray(tree.children), int(tree.root)
    g.children, g.root = fam.children, fam.root
    rf = treeio.normalized_rf(t, g, len(fam.seqs))
    print(f"NJ tree: logL={float(ll):.1f}, normalized RF vs truth={rf:.3f}")
    print(treeio.to_newick(tree.children, tree.blen, int(tree.root),
                           fam.names))


if __name__ == "__main__":
    main()
