"""Serve a small *language model* with batched requests: prefill + decode
with KV / SSM caches, mixed prompt lengths via position offsets, latency
report. This exercises the LM path (``repro.launch.serve`` /
``train.serve_step``) — for the MSA/phylogeny web service the paper
describes, see ``repro.launch.serve_msa`` and ``examples/msa_service.py``.

  PYTHONPATH=src python examples/serve_lm.py --arch mamba2-130m
"""
import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.models.transformer import init_params
from repro.train.serve_step import make_decode_step, make_prefill_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()

    cfg = get_arch(args.arch).smoke
    if not cfg.has_decode:
        raise SystemExit(f"{args.arch} is encoder-only")
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    max_len = args.prompt_len + args.gen

    prefill = jax.jit(make_prefill_step(cfg, max_len=max_len))
    decode = jax.jit(make_decode_step(cfg))

    toks = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                              cfg.vocab_size)
    logits, cache = prefill(params, {"tokens": toks})
    jax.block_until_ready(logits)

    t0 = time.time()
    logits, cache = prefill(params, {"tokens": toks})
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    out = [jnp.argmax(logits, -1).astype(jnp.int32)]
    pos = jnp.full((args.batch,), args.prompt_len, jnp.int32)
    # warm decode
    _ = decode(params, cache, out[-1], pos)
    t0 = time.time()
    for _ in range(args.gen - 1):
        logits, cache = decode(params, cache, out[-1], pos)
        out.append(jnp.argmax(logits, -1).astype(jnp.int32))
        pos = pos + 1
    jax.block_until_ready(logits)
    t_dec = (time.time() - t0) / max(args.gen - 1, 1)

    print(f"arch={cfg.name} batch={args.batch}")
    print(f"prefill {args.prompt_len} tokens: {t_prefill * 1e3:.1f} ms")
    print(f"decode: {t_dec * 1e3:.2f} ms/token "
          f"({args.batch / t_dec:.0f} tok/s aggregate)")
    gen = jnp.stack(out, 1)
    print("generated (req 0):", gen[0].tolist())


if __name__ == "__main__":
    main()
