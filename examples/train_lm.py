"""Train a ~100M-param LM config for a few hundred steps on synthetic data
with the resilient loop (checkpoints + replay). Uses qwen1.5-0.5b's family at
reduced width so it runs on CPU; pass --full for the real config on a pod.

  PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import argparse
import dataclasses
import sys
import tempfile
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.dist.checkpoint import CheckpointManager
from repro.dist.fault import ResilientLoop
from repro.train.optimizer import AdamWConfig
from repro.train.train_step import init_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    spec = get_arch("qwen1.5-0.5b")
    cfg = spec.config if args.full else dataclasses.replace(
        spec.smoke, n_layers=4, d_model=128, d_ff=384, n_heads=8,
        n_kv_heads=8, head_dim=16, vocab_size=512)
    key = jax.random.PRNGKey(0)
    state = init_state(cfg, key)
    n_params = sum(x.size for x in jax.tree.leaves(state.params))
    print(f"model: {cfg.name} ({n_params:,} params)")

    step = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-3, warmup_steps=20),
                                   microbatches=2))

    # synthetic "language": markov-ish integer stream (learnable structure)
    def batches(s):
        k = jax.random.PRNGKey(s)
        start = jax.random.randint(k, (args.batch, 1), 0, cfg.vocab_size)
        ramp = (start + jnp.arange(args.seq)[None, :] * 7) % cfg.vocab_size
        return {"tokens": ramp, "labels": ramp}

    losses = []

    def run_step(st, b):
        st, m = step(st, b)
        losses.append(float(m["loss"]))
        return st

    with tempfile.TemporaryDirectory() as d:
        loop = ResilientLoop(run_step, CheckpointManager(d, keep=2),
                             ckpt_every=50)

        class B:
            n_steps = args.steps

            def __call__(self, s):
                return batches(s)

        t0 = time.time()
        state, steps = loop.run(state, B())
        dt = time.time() - t0
    print(f"{steps} steps in {dt:.1f}s; loss {losses[0]:.3f} -> "
          f"{losses[-1]:.3f}")
    assert losses[-1] < losses[0] * 0.9, "model failed to learn"
    print("learned the synthetic stream ✓")


if __name__ == "__main__":
    main()
