"""repro.align — the backend-dispatching engine for the map(1) stage.

See ``engine.AlignEngine`` (host API: bucketing + fallback),
``backends`` (the jnp / pallas / banded / banded-pallas primitives and
the BACKENDS registry), ``banded`` (O(n·W) diagonal-band Gotoh; the
native Pallas version lives in ``kernels.banded``), and ``bucketing``
(power-of-two length and band buckets).
"""
from .backends import (BACKENDS, PAIR_BACKENDS, BatchAlignment,  # noqa: F401
                       resolve_backend)
from .engine import AlignEngine, EngineResult, PairsResult  # noqa: F401
