"""The AlignEngine backend protocol and its three implementations.

Every backend is a jit-compatible batched map(1) primitive with one
contract (``BatchAlignment``): align queries ``Q (B, n)`` with lengths
``lens`` against one broadcast target ``b (m,)`` of length ``lb`` and
return gap-padded aligned rows of width ``n + m`` plus per-pair ``ok``
flags (False = the backend's heuristic gave up and the pair needs a
full-DP re-alignment — only the ``banded`` backend ever clears it).

  jnp            the row-scan Gotoh oracle (``core.pairwise``); O(n·m)
                 dirs
  pallas         the ``kernels.sw`` Pallas kernel (compiled on TPU,
                 interpreted elsewhere) + the shared traceback; O(n·m)
                 dirs in HBM, row scores never leave VMEM
  banded         diagonal band as a jnp scan, O(n·W) dirs, per-pair
                 overflow flags
  banded-pallas  the same band as a native Pallas kernel
                 (``kernels.banded``): band state resident in VMEM,
                 wavefront rows, in-kernel overflow flags — bit-identical
                 to ``banded`` by construction (both call
                 ``kernels.banded.ref``); the pairs variant fuses
                 score+traceback so no direction matrix reaches HBM

All four are registered in ``BACKENDS`` so the engine, the shard_map
pipeline, and the benchmarks dispatch by name.

Each backend also has a *pairs* variant (``*_align_pairs``,
``PAIR_BACKENDS``) with per-pair targets ``T (B, m)`` instead of one
broadcast ``b`` — the batch-entry contract that lets
``AlignEngine.align_pairs`` merge pre-encoded requests from many callers
(each with its own center) into one jitted call. ``repro.serve.queue``
is the consumer.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..core import pairwise
from ..kernels.banded.ops import banded_forward_pallas, banded_pairs_fused
from ..kernels.sw.ops import gotoh_forward_pallas
from . import banded as banded_mod


class BatchAlignment(NamedTuple):
    score: jnp.ndarray      # (B,) f32
    a_row: jnp.ndarray      # (B, n+m) int8 gap-padded aligned queries
    b_row: jnp.ndarray      # (B, n+m) int8 gap-padded aligned target
    aln_len: jnp.ndarray    # (B,) i32 valid leading columns
    ok: jnp.ndarray         # (B,) bool; False = needs full-DP fallback


@functools.partial(jax.jit, static_argnames=("gap_open", "gap_extend",
                                             "local", "gap_code"))
def jnp_align_batch(Q, lens, b, lb, sub, *, gap_open, gap_extend,
                    local=False, gap_code=5):
    res = pairwise.align_many_to_one(Q, lens, b, lb, sub, gap_open=gap_open,
                                     gap_extend=gap_extend, local=local,
                                     gap_code=gap_code)
    return BatchAlignment(res.score, res.a_row, res.b_row, res.aln_len,
                          jnp.ones(Q.shape[0], jnp.bool_))


@functools.partial(jax.jit, static_argnames=("gap_open", "gap_extend",
                                             "local", "gap_code",
                                             "block_rows", "interpret"))
def pallas_align_batch(Q, lens, b, lb, sub, *, gap_open, gap_extend,
                       local=False, gap_code=5, block_rows=128,
                       interpret=None):
    B, n = Q.shape
    Bm = jnp.broadcast_to(b[None, :], (B, b.shape[0]))
    lens2 = jnp.stack([lens.astype(jnp.int32),
                       jnp.full((B,), lb, jnp.int32)], axis=1)
    fwd = gotoh_forward_pallas(Q, Bm, lens2, sub, gap_open=gap_open,
                               gap_extend=gap_extend, local=local,
                               block_rows=min(block_rows, max(n, 1)),
                               interpret=interpret)
    a_row, b_row, k = jax.vmap(
        lambda a_, b_, f: pairwise.traceback(a_, b_, f, gap_code))(Q, Bm, fwd)
    return BatchAlignment(fwd.score, a_row, b_row, k,
                          jnp.ones(B, jnp.bool_))


@functools.partial(jax.jit, static_argnames=("gap_open", "gap_extend",
                                             "band", "gap_code"))
def banded_align_batch(Q, lens, b, lb, sub, *, gap_open, gap_extend,
                       band=64, gap_code=5):
    def one(q, lq):
        fwd = banded_mod.banded_forward(q, lq, b, lb, sub, gap_open,
                                        gap_extend, band=band)
        a_row, b_row, k, ok = banded_mod.banded_traceback(
            q, b, fwd, gap_code, band=band)
        return BatchAlignment(fwd.score, a_row, b_row, k, ok)
    return jax.vmap(one)(Q, lens.astype(jnp.int32))


@functools.partial(jax.jit, static_argnames=("gap_open", "gap_extend",
                                             "local", "gap_code"))
def jnp_align_pairs(Q, qlens, T, tlens, sub, *, gap_open, gap_extend,
                    local=False, gap_code=5):
    f = lambda q, lq, t, lt: pairwise.align_pair(
        q, lq, t, lt, sub, gap_open=gap_open, gap_extend=gap_extend,
        local=local, gap_code=gap_code)
    res = jax.vmap(f)(Q, qlens.astype(jnp.int32), T, tlens.astype(jnp.int32))
    return BatchAlignment(res.score, res.a_row, res.b_row, res.aln_len,
                          jnp.ones(Q.shape[0], jnp.bool_))


@functools.partial(jax.jit, static_argnames=("gap_open", "gap_extend",
                                             "local", "gap_code",
                                             "block_rows", "interpret"))
def pallas_align_pairs(Q, qlens, T, tlens, sub, *, gap_open, gap_extend,
                       local=False, gap_code=5, block_rows=128,
                       interpret=None):
    # the kernel already takes a (B, m) target batch — the broadcast path
    # above is just this with T = tile(b); per-pair targets come for free
    B, n = Q.shape
    lens2 = jnp.stack([qlens.astype(jnp.int32), tlens.astype(jnp.int32)],
                      axis=1)
    fwd = gotoh_forward_pallas(Q, T, lens2, sub, gap_open=gap_open,
                               gap_extend=gap_extend, local=local,
                               block_rows=min(block_rows, max(n, 1)),
                               interpret=interpret)
    a_row, b_row, k = jax.vmap(
        lambda a_, b_, f: pairwise.traceback(a_, b_, f, gap_code))(Q, T, fwd)
    return BatchAlignment(fwd.score, a_row, b_row, k,
                          jnp.ones(B, jnp.bool_))


@functools.partial(jax.jit, static_argnames=("gap_open", "gap_extend",
                                             "band", "gap_code"))
def banded_align_pairs(Q, qlens, T, tlens, sub, *, gap_open, gap_extend,
                       band=64, gap_code=5):
    def one(q, lq, t, lt):
        fwd = banded_mod.banded_forward(q, lq, t, lt, sub, gap_open,
                                        gap_extend, band=band)
        a_row, b_row, k, ok = banded_mod.banded_traceback(
            q, t, fwd, gap_code, band=band)
        return BatchAlignment(fwd.score, a_row, b_row, k, ok)
    return jax.vmap(one)(Q, qlens.astype(jnp.int32), T,
                         tlens.astype(jnp.int32))


@functools.partial(jax.jit, static_argnames=("gap_open", "gap_extend",
                                             "band", "gap_code",
                                             "block_rows", "interpret"))
def banded_pallas_align_batch(Q, lens, b, lb, sub, *, gap_open, gap_extend,
                              band=64, gap_code=5, block_rows=128,
                              interpret=None):
    # Forward runs in the kernel (band in VMEM, O(n·W) dirs to HBM);
    # the jnp traceback then walks those dirs exactly like ``banded``.
    B, n = Q.shape
    Bm = jnp.broadcast_to(b[None, :], (B, b.shape[0]))
    lens2 = jnp.stack([lens.astype(jnp.int32),
                       jnp.full((B,), lb, jnp.int32)], axis=1)
    fwd = banded_forward_pallas(Q, Bm, lens2, sub, gap_open=gap_open,
                                gap_extend=gap_extend, band=band,
                                block_rows=min(block_rows, max(n, 1)),
                                interpret=interpret)
    a_row, b_row, k, ok = jax.vmap(
        lambda a_, b_, f: banded_mod.banded_traceback(a_, b_, f, gap_code,
                                                      band=band))(Q, Bm, fwd)
    return BatchAlignment(fwd.score, a_row, b_row, k, ok)


@functools.partial(jax.jit, static_argnames=("gap_open", "gap_extend",
                                             "band", "gap_code",
                                             "interpret"))
def banded_pallas_align_pairs(Q, qlens, T, tlens, sub, *, gap_open,
                              gap_extend, band=64, gap_code=5,
                              interpret=None):
    # Fully fused: score rows AND the traceback band stay in VMEM for the
    # whole bucket; the per-pair direction matrix never reaches HBM.
    lens2 = jnp.stack([qlens.astype(jnp.int32), tlens.astype(jnp.int32)],
                      axis=1)
    score, a_row, b_row, k, ok = banded_pairs_fused(
        Q, T, lens2, sub, gap_open=gap_open, gap_extend=gap_extend,
        band=band, gap_code=gap_code, interpret=interpret)
    return BatchAlignment(score, a_row, b_row, k, ok)


BACKENDS = {
    "jnp": jnp_align_batch,
    "pallas": pallas_align_batch,
    "banded": banded_align_batch,
    "banded-pallas": banded_pallas_align_batch,
}

PAIR_BACKENDS = {
    "jnp": jnp_align_pairs,
    "pallas": pallas_align_pairs,
    "banded": banded_align_pairs,
    "banded-pallas": banded_pallas_align_pairs,
}


def resolve_backend(name: str) -> str:
    """``auto`` → the compiled kernel on TPU, the jnp oracle elsewhere."""
    if name == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "jnp"
    if name not in BACKENDS:
        raise ValueError(f"unknown align backend {name!r}; "
                         f"expected one of {sorted(BACKENDS)} or 'auto'")
    return name
