"""Banded Gotoh DP: O(n·W) direction storage instead of O(n·m).

The full Gotoh forward in ``core.pairwise`` materializes an
(La+1)×(Lb+1) packed-direction matrix per pair — the memory wall for
ultra-long sequences. HAlign-II's inputs are highly similar, so the
optimal path hugs the (0,0)→(la,lb) diagonal; this module keeps only a
width-W band of cells around that diagonal per row.

Band geometry: row ``i`` stores absolute columns ``j ∈ [lo_i, lo_i+W)``
with ``lo_i = floor(i·lb/la) - W//2`` (for ``la == 0`` the band parks on
``j = lb`` so the all-insert traceback start stays addressable). The band
center follows the straight line to ``(la, lb)``, so unequal lengths are
handled by construction and the global end cell ``(la, lb)`` is always at
offset ``W//2``. Cells outside the band are NEG, exactly like the
out-of-matrix boundary of the full DP — with a band wide enough to cover
every column (``W ≥ 2·lb + 2``) the recurrence is bit-identical to
``pairwise.gotoh_forward``.

Band overflow: a clipped band can only *underestimate* scores, and the
returned path need not touch the band edge for a better out-of-band path
to exist — so path-touches-edge alone is not enough. Detection is
forward "edge pressure": a pair is flagged when any live DP row has a
*competitive* cell (within ``margin = max(sub)`` of the row's best) in
an exit zone — offset 0 or the slide-clipped right rim
``o >= W - max(s, 1)`` of the current row, or a previous-row cell about
to be slid out of storage (``o < s``, the bottom-left exit) — i.e. a
near-dominant path is pushing against the band. The traceback
additionally flags walks that touch a band-edge cell with a real
missing neighbour or leave the band, and NEG-degenerate scores (bands
thinner than the length-difference slope).

This is a heuristic (only a full DP can certify optimality), but
empirically it has no escapes where it matters and beyond: on random
*unrelated* 24-mers at band=8 — adversarial for banding — 0/3000
unflagged pairs scored below the full DP across 10 seeds, while similar
families (HAlign's regime) at band=16 flag 0/200 with exact scores.
Flagged pairs are re-aligned with the full DP by the engine — the same
per-pair fallback contract as the k-mer chaining path.

Row 0 and column 0 direction bytes are closed-form (pure gap runs), so
they are never stored and the direction buffer is exactly (n, W) int8.
Global alignment only: the local (Smith-Waterman) start cell can sit
anywhere, which defeats a diagonal band; the engine routes ``local=True``
to the full-DP backends.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..core.pairwise import (NEG, M_ST, IX_ST, IY_ST, FRESH, AlignResult,
                             _pack)


class BandedForward(NamedTuple):
    dirs: jnp.ndarray       # (n, W) int8 packed bytes for DP rows 1..n
    score: jnp.ndarray      # f32 global score at (la, lb)
    start_i: jnp.ndarray    # i32 == la
    start_j: jnp.ndarray    # i32 == lb
    start_state: jnp.ndarray
    edge: jnp.ndarray       # bool: some row's best cell hit the band edge


def band_lo(i, la, lb, band: int):
    """Leftmost absolute column stored for DP row ``i``."""
    c = jnp.where(la == 0, lb, (i * lb) // jnp.maximum(la, 1))
    return (c - band // 2).astype(jnp.int32)


def banded_forward(a, la, b, lb, sub, gap_open, gap_extend, *, band: int):
    """Banded Gotoh forward; mirrors ``pairwise.gotoh_forward`` (global).

    a: (n,) int8 codes, la: actual length; b: (m,) int8, lb; sub: (S,S).
    Returns a BandedForward whose dirs buffer is (n, band) — never the
    full (n+1)×(m+1) matrix.
    """
    n, m = a.shape[0], b.shape[0]
    W = band
    go = jnp.float32(gap_open)
    ge = jnp.float32(gap_extend)
    sub = sub.astype(jnp.float32)
    la = la.astype(jnp.int32)
    lb = lb.astype(jnp.int32)
    offs = jnp.arange(W, dtype=jnp.int32)
    offs_f = offs.astype(jnp.float32)
    mid = W // 2

    # Row 0 boundary in band coordinates.
    lo0 = band_lo(jnp.int32(0), la, lb, W)
    j0 = lo0 + offs
    m0 = jnp.where(j0 == 0, 0.0, NEG)
    ix0 = jnp.full((W,), NEG)
    iy0 = jnp.where((j0 >= 1) & (j0 <= lb),
                    -(go + (j0.astype(jnp.float32) - 1.0) * ge), NEG)
    # End-cell capture init covers la == 0 (offset of j=lb is W//2 there).
    cap0 = jnp.stack([m0[mid], ix0[mid], iy0[mid]])
    h0 = jnp.where((j0 >= 0) & (j0 <= lb), jnp.maximum(m0, iy0), NEG)
    margin = jnp.max(sub)                  # one diagonal step of headroom

    def row_step(carry, inp):
        m_prev, ix_prev, iy_prev, lo_prev, cap, edge, hb_prev = carry
        a_i, i = inp                       # i: 1-based DP row
        lo_i = band_lo(i, la, lb, W)
        s = lo_i - lo_prev                 # band slide (>= 0)
        j = lo_i + offs                    # absolute columns this row

        def shifted(v, sh, fill):
            # value of prev-row vector at current offset o == prev o + sh
            idx = offs + sh
            ok = (idx >= 0) & (idx < W)
            return jnp.where(ok, v[jnp.clip(idx, 0, W - 1)], fill)

        h_prev = jnp.maximum(m_prev, jnp.maximum(ix_prev, iy_prev))
        amax = jnp.where(m_prev >= h_prev, M_ST,
                         jnp.where(ix_prev >= h_prev, IX_ST, IY_ST))
        h_diag = shifted(h_prev, s - 1, NEG)
        amax_diag = shifted(amax.astype(jnp.int32), s - 1, jnp.int32(M_ST))
        m_up = shifted(m_prev, s, NEG)
        ix_up = shifted(ix_prev, s, NEG)

        s_row = sub[a_i.astype(jnp.int32),
                    b[jnp.clip(j - 1, 0, m - 1)].astype(jnp.int32)]
        in_mat = (j >= 1) & (j <= lb)
        m_new = jnp.where(in_mat, h_diag + s_row, NEG)
        dir_m = amax_diag

        ix_open = m_up - go
        ix_ext = ix_up - ge
        ix_new = jnp.where((j >= 0) & (j <= lb),
                           jnp.maximum(ix_open, ix_ext), NEG)
        dir_ix = (ix_ext > ix_open).astype(jnp.int32)

        # Iy running max within the row; band offsets stand in for absolute
        # columns (the lo_i·ge term cancels exactly in f32 integer range).
        cm = jax.lax.cummax(m_new + offs_f * ge)
        iy_new = jnp.concatenate(
            [jnp.full((1,), NEG), cm[:-1] - go - (offs_f[1:] - 1.0) * ge])
        iy_new = jnp.where(in_mat, iy_new, NEG)
        m_left = jnp.concatenate([jnp.full((1,), NEG), m_new[:-1]])
        iy_left = jnp.concatenate([jnp.full((1,), NEG), iy_new[:-1]])
        dir_iy = (iy_left - ge > m_left - go).astype(jnp.int32)

        dirs = _pack(dir_m, dir_ix, dir_iy)

        hit = i == la                      # end cell (la, lb) sits at mid
        cap = jnp.where(hit, jnp.stack([m_new[mid], ix_new[mid],
                                        iy_new[mid]]), cap)

        # Edge pressure: a competitive cell in an exit zone means a
        # near-dominant path is fighting the band — a wider band could
        # beat this alignment, so flag the pair for full-DP fallback.
        live = i <= la
        h_new = jnp.where((j >= 0) & (j <= lb),
                          jnp.maximum(m_new, jnp.maximum(ix_new, iy_new)),
                          NEG)
        hb = jnp.max(h_new)
        zone = (offs == 0) | (offs >= W - jnp.maximum(s, 1))
        comp_cur = jnp.any(zone & (h_new >= hb - margin)) & (hb > NEG / 2)
        # bottom-left exit: previous-row cells slid out of storage this row
        comp_prev = (jnp.any((offs < s) & (h_prev >= hb_prev - margin)) &
                     (hb_prev > NEG / 2))
        edge = edge | (live & (comp_cur | comp_prev))
        hb_prev = jnp.where(live, hb, hb_prev)
        return (m_new, ix_new, iy_new, lo_i, cap, edge, hb_prev), dirs

    rows_i = jnp.arange(1, n + 1, dtype=jnp.int32)
    (_, _, _, _, cap, edge, _), dirs = jax.lax.scan(
        row_step, (m0, ix0, iy0, lo0, cap0, jnp.bool_(False), jnp.max(h0)),
        (a, rows_i))
    st = jnp.argmax(cap).astype(jnp.int32)
    return BandedForward(dirs, cap[st], la, lb, st, edge)


def banded_traceback(a, b, fwd: BandedForward, gap_code: int, *, band: int):
    """Walk the banded directions back to an aligned pair.

    Same output contract as ``pairwise.traceback`` plus an ``ok`` flag:
    False when the path left the band, touched a band edge adjacent to
    real (un-stored) DP cells, or the score is NEG-degenerate.
    """
    n, m = a.shape[0], b.shape[0]
    W = band
    la, lb = fwd.start_i, fwd.start_j
    out_len = n + m
    dirf = fwd.dirs.reshape(-1)

    def step(t, carry):
        i, j, st, done, edge, oob, out_a, out_b, k = carry
        lo_i = band_lo(i, la, lb, W)
        o = j - lo_i
        in_band = (o >= 0) & (o < W) & (i >= 1)
        byte_band = dirf[jnp.clip((i - 1) * W + o, 0, n * W - 1)].astype(
            jnp.int32)
        # Boundary cells are pure gap runs with closed-form directions;
        # they are not stored in the band (and for la==0 / lb==0 the whole
        # walk happens here).
        byte_row0 = FRESH | (jnp.where(j == 1, 0, 1) << 3)
        byte_col0 = M_ST | (jnp.where(i == 1, 0, 1) << 2)
        byte = jnp.where(i == 0, byte_row0,
                         jnp.where(j == 0, byte_col0, byte_band))

        interior = (i > 0) & (j > 0)
        lost = (~done) & interior & (~in_band)
        oob = oob | lost
        # Edge cells whose clipped neighbour would be a real DP cell mean
        # a wider band could score higher: flag for full-DP fallback.
        edge = edge | ((~done) & interior & in_band &
                       ((o == 0) | ((o == W - 1) & (j < lb))))
        done = done | lost

        dir_m = byte & 3
        dir_ix = (byte >> 2) & 1
        dir_iy = (byte >> 3) & 1
        is_m = st == M_ST
        is_ix = st == IX_ST
        ca = jnp.where(is_m | is_ix, a[jnp.maximum(i - 1, 0)],
                       gap_code).astype(jnp.int8)
        cb = jnp.where(is_m | (st == IY_ST), b[jnp.maximum(j - 1, 0)],
                       gap_code).astype(jnp.int8)
        out_a = out_a.at[k].set(jnp.where(done, out_a[k], ca))
        out_b = out_b.at[k].set(jnp.where(done, out_b[k], cb))

        ni = jnp.where(is_m | is_ix, i - 1, i)
        nj = jnp.where(is_m | (st == IY_ST), j - 1, j)
        nst = jnp.where(is_m, dir_m,
                        jnp.where(is_ix, jnp.where(dir_ix == 1, IX_ST, M_ST),
                                  jnp.where(dir_iy == 1, IY_ST, M_ST)))
        ndone = done | ((ni == 0) & (nj == 0))
        k = jnp.where(done, k, k + 1)
        i = jnp.where(done, i, ni)
        j = jnp.where(done, j, nj)
        st = jnp.where(done, st, nst.astype(jnp.int32))
        return (i, j, st, ndone, edge, oob, out_a, out_b, k)

    out_a = jnp.full((out_len,), gap_code, jnp.int8)
    out_b = jnp.full((out_len,), gap_code, jnp.int8)
    init = (fwd.start_i, fwd.start_j, fwd.start_state,
            (fwd.start_i == 0) & (fwd.start_j == 0),
            jnp.bool_(False), jnp.bool_(False), out_a, out_b, jnp.int32(0))
    (_, _, _, _, edge, oob, out_a, out_b, k) = jax.lax.fori_loop(
        0, out_len, step, init)

    ok = (~edge) & (~oob) & (~fwd.edge) & (fwd.score > NEG / 2)

    def unrev(x):
        return jnp.roll(jnp.flip(x), k - out_len)
    return unrev(out_a), unrev(out_b), k, ok


@functools.partial(jax.jit, static_argnames=("gap_open", "gap_extend",
                                             "band", "gap_code"))
def banded_align_pair(a, la, b, lb, sub, *, gap_open, gap_extend, band,
                      gap_code=5):
    """Banded counterpart of ``pairwise.align_pair``; extra ``ok`` output."""
    fwd = banded_forward(a, la, b, lb, sub, gap_open, gap_extend, band=band)
    a_row, b_row, k, ok = banded_traceback(a, b, fwd, gap_code, band=band)
    return AlignResult(fwd.score, a_row, b_row, k, fwd.start_i,
                       fwd.start_j), ok
