"""Banded Gotoh DP: O(n·W) direction storage instead of O(n·m).

The full Gotoh forward in ``core.pairwise`` materializes an
(La+1)×(Lb+1) packed-direction matrix per pair — the memory wall for
ultra-long sequences. HAlign-II's inputs are highly similar, so the
optimal path hugs the (0,0)→(la,lb) diagonal; this module keeps only a
width-W band of cells around that diagonal per row.

Band geometry: row ``i`` stores absolute columns ``j ∈ [lo_i, lo_i+W)``
with ``lo_i = floor(i·lb/la) - W//2`` (for ``la == 0`` the band parks on
``j = lb`` so the all-insert traceback start stays addressable). The band
center follows the straight line to ``(la, lb)``, so unequal lengths are
handled by construction and the global end cell ``(la, lb)`` is always at
offset ``W//2``. Cells outside the band are NEG, exactly like the
out-of-matrix boundary of the full DP — with a band wide enough to cover
every column (``W ≥ 2·lb + 2``) the recurrence is bit-identical to
``pairwise.gotoh_forward``.

Band overflow: a clipped band can only *underestimate* scores, and the
returned path need not touch the band edge for a better out-of-band path
to exist — so path-touches-edge alone is not enough. Detection is
forward "edge pressure": a pair is flagged when any live DP row has a
*competitive* cell (within ``margin = max(sub)`` of the row's best) in
an exit zone — offset 0 or the slide-clipped right rim
``o >= W - max(s, 1)`` of the current row, or a previous-row cell about
to be slid out of storage (``o < s``, the bottom-left exit) — i.e. a
near-dominant path is pushing against the band. The traceback
additionally flags walks that touch a band-edge cell with a real
missing neighbour or leave the band, and NEG-degenerate scores (bands
thinner than the length-difference slope).

This is a heuristic (only a full DP can certify optimality), but
empirically it has no escapes where it matters and beyond: on random
*unrelated* 24-mers at band=8 — adversarial for banding — 0/3000
unflagged pairs scored below the full DP across 10 seeds, while similar
families (HAlign's regime) at band=16 flag 0/200 with exact scores.
Flagged pairs are re-aligned with the full DP by the engine — the same
per-pair fallback contract as the k-mer chaining path.

Row 0 and column 0 direction bytes are closed-form (pure gap runs), so
they are never stored and the direction buffer is exactly (n, W) int8.
Global alignment only: the local (Smith-Waterman) start cell can sit
anywhere, which defeats a diagonal band; the engine routes ``local=True``
to the full-DP backends.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ..core.pairwise import NEG, AlignResult
# The pure band recurrence lives in kernels.banded.ref so the native
# Pallas kernels and this jnp scan call the *same* math (bit-identical
# parity by construction); re-exported here as the historical home.
from ..kernels.banded.ref import (BandedForward, band_lo, band_row_init,
                                  band_row_update, edge_pressure,
                                  trace_step_math)

__all__ = ["BandedForward", "band_lo", "band_row_init", "band_row_update",
           "edge_pressure", "trace_step_math", "banded_forward",
           "banded_traceback", "banded_align_pair"]


def banded_forward(a, la, b, lb, sub, gap_open, gap_extend, *, band: int):
    """Banded Gotoh forward; mirrors ``pairwise.gotoh_forward`` (global).

    a: (n,) int8 codes, la: actual length; b: (m,) int8, lb; sub: (S,S).
    Returns a BandedForward whose dirs buffer is (n, band) — never the
    full (n+1)×(m+1) matrix.
    """
    n = a.shape[0]
    W = band
    go = jnp.float32(gap_open)
    ge = jnp.float32(gap_extend)
    sub = sub.astype(jnp.float32)
    la = la.astype(jnp.int32)
    lb = lb.astype(jnp.int32)
    mid = W // 2

    m0, ix0, iy0, cap0, hb0 = band_row_init(la, lb, go, ge, band=W)
    lo0 = band_lo(jnp.int32(0), la, lb, W)
    margin = jnp.max(sub)                  # one diagonal step of headroom

    def row_step(carry, inp):
        m_prev, ix_prev, iy_prev, lo_prev, cap, edge, hb_prev = carry
        a_i, i = inp                       # i: 1-based DP row
        lo_i = band_lo(i, la, lb, W)
        m_new, ix_new, iy_new, dirs, h_new, h_prev, s = band_row_update(
            m_prev, ix_prev, iy_prev, a_i, b, lo_prev, lo_i, sub, go, ge, lb)

        hit = i == la                      # end cell (la, lb) sits at mid
        cap = jnp.where(hit, jnp.stack([m_new[mid], ix_new[mid],
                                        iy_new[mid]]), cap)

        # Edge pressure: a competitive cell in an exit zone means a
        # near-dominant path is fighting the band — a wider band could
        # beat this alignment, so flag the pair for full-DP fallback.
        live = i <= la
        comp, hb = edge_pressure(h_new, h_prev, hb_prev, s, margin)
        edge = edge | (live & comp)
        hb_prev = jnp.where(live, hb, hb_prev)
        return (m_new, ix_new, iy_new, lo_i, cap, edge, hb_prev), dirs

    rows_i = jnp.arange(1, n + 1, dtype=jnp.int32)
    (_, _, _, _, cap, edge, _), dirs = jax.lax.scan(
        row_step, (m0, ix0, iy0, lo0, cap0, jnp.bool_(False), hb0),
        (a, rows_i))
    st = jnp.argmax(cap).astype(jnp.int32)
    return BandedForward(dirs, cap[st], la, lb, st, edge)


def banded_traceback(a, b, fwd: BandedForward, gap_code: int, *, band: int):
    """Walk the banded directions back to an aligned pair.

    Same output contract as ``pairwise.traceback`` plus an ``ok`` flag:
    False when the path left the band, touched a band edge adjacent to
    real (un-stored) DP cells, or the score is NEG-degenerate.
    """
    n, m = a.shape[0], b.shape[0]
    W = band
    la, lb = fwd.start_i, fwd.start_j
    out_len = n + m
    dirf = fwd.dirs.reshape(-1)

    def step(t, carry):
        i, j, st, done, edge, oob, out_a, out_b, k = carry
        lo_i = band_lo(i, la, lb, W)
        o = j - lo_i
        byte_band = dirf[jnp.clip((i - 1) * W + o, 0, n * W - 1)].astype(
            jnp.int32)
        a_im1 = a[jnp.maximum(i - 1, 0)]
        b_jm1 = b[jnp.maximum(j - 1, 0)]
        ni, nj, nst, done, ndone, lost, edge_hit, ca, cb = trace_step_math(
            i, j, o, st, done, byte_band, a_im1, b_jm1, lb, gap_code, W)
        oob = oob | lost
        edge = edge | edge_hit
        out_a = out_a.at[k].set(jnp.where(done, out_a[k], ca))
        out_b = out_b.at[k].set(jnp.where(done, out_b[k], cb))
        k = jnp.where(done, k, k + 1)
        i = jnp.where(done, i, ni)
        j = jnp.where(done, j, nj)
        st = jnp.where(done, st, nst)
        return (i, j, st, ndone, edge, oob, out_a, out_b, k)

    out_a = jnp.full((out_len,), gap_code, jnp.int8)
    out_b = jnp.full((out_len,), gap_code, jnp.int8)
    init = (fwd.start_i, fwd.start_j, fwd.start_state,
            (fwd.start_i == 0) & (fwd.start_j == 0),
            jnp.bool_(False), jnp.bool_(False), out_a, out_b, jnp.int32(0))
    (_, _, _, _, edge, oob, out_a, out_b, k) = jax.lax.fori_loop(
        0, out_len, step, init)

    ok = (~edge) & (~oob) & (~fwd.edge) & (fwd.score > NEG / 2)

    def unrev(x):
        return jnp.roll(jnp.flip(x), k - out_len)
    return unrev(out_a), unrev(out_b), k, ok


@functools.partial(jax.jit, static_argnames=("gap_open", "gap_extend",
                                             "band", "gap_code"))
def banded_align_pair(a, la, b, lb, sub, *, gap_open, gap_extend, band,
                      gap_code=5):
    """Banded counterpart of ``pairwise.align_pair``; extra ``ok`` output."""
    fwd = banded_forward(a, la, b, lb, sub, gap_open, gap_extend, band=band)
    a_row, b_row, k, ok = banded_traceback(a, b, fwd, gap_code, band=band)
    return AlignResult(fwd.score, a_row, b_row, k, fwd.start_i,
                       fwd.start_j), ok
