"""Length-bucketed batching for the map(1) align-to-center stage.

Padding every query to the global Lmax makes one 10x-long outlier
dominate the whole shard's DP cost (the DP is O(n·m) per pair in the
padded length n). The dispatcher groups queries into power-of-two
length buckets and runs the backend once per bucket at that width, so a
bucket of short reads never pays the outlier's padding. Power-of-two
widths bound the number of distinct compiled shapes at log2(Lmax) —
the standard trade between shape-churn recompiles and padding waste.

Two planners share the pow2 rounding:

  ``bucket_plan``       1D: queries against one broadcast center
                        (``AlignEngine.align_to_center``)
  ``pair_bucket_plan``  2D: per-pair targets, buckets keyed on the
                        (query width, target width) pair — the
                        batch-entry path ``AlignEngine.align_pairs``
                        uses to coalesce requests from many callers
                        (each with its own center) into one jitted
                        call per bucket (``repro.serve.queue``)
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np


def _pow2_widths(lens, Lmax: int, min_bucket: int) -> np.ndarray:
    """Per-item pow2 padded width, clamped to [min(min_bucket, Lmax), Lmax]."""
    w = np.maximum(np.asarray(lens).astype(np.int64), 1)
    w = 1 << np.ceil(np.log2(w)).astype(np.int64)      # next pow2 >= len
    return np.clip(w, min(min_bucket, max(Lmax, 1)), max(Lmax, 1))


def bucket_plan(lens, Lmax: int, *, min_bucket: int = 32
                ) -> List[Tuple[int, np.ndarray]]:
    """Group query indices by power-of-two padded width.

    Returns ``[(width, indices), ...]`` sorted by width; widths are
    clamped to ``[min(min_bucket, Lmax), Lmax]`` so a bucket never
    exceeds the physical batch width and tiny buckets don't fragment.
    """
    lens = np.asarray(lens).astype(np.int64)
    if lens.size == 0:
        return []
    w = _pow2_widths(lens, Lmax, min_bucket)
    plan = []
    for width in np.unique(w):
        plan.append((int(width), np.flatnonzero(w == width)))
    return plan


def pair_bucket_plan(qlens, tlens, Lq: int, Lt: int, *, min_bucket: int = 32
                     ) -> List[Tuple[int, int, np.ndarray]]:
    """Group (query, target) pairs by their pow2 (q_width, t_width) bucket.

    Returns ``[(q_width, t_width, indices), ...]`` sorted by (q_width,
    t_width). The bucket count is bounded at log2(Lq) · log2(Lt) distinct
    compiled shapes regardless of how many callers' requests are merged
    into the batch — the invariant ``repro.serve``'s coalescing tests pin.
    """
    qlens = np.asarray(qlens).astype(np.int64)
    if qlens.size == 0:
        return []
    wq = _pow2_widths(qlens, Lq, min_bucket)
    wt = _pow2_widths(tlens, Lt, min_bucket)
    key = wq * (int(max(Lt, 1)) + 1) + wt          # unique composite key
    plan = []
    for k in np.unique(key):
        idx = np.flatnonzero(key == k)
        plan.append((int(wq[idx[0]]), int(wt[idx[0]]), idx))
    return plan
