"""Length-bucketed batching for the map(1) align-to-center stage.

Padding every query to the global Lmax makes one 10x-long outlier
dominate the whole shard's DP cost (the DP is O(n·m) per pair in the
padded length n). The dispatcher groups queries into power-of-two
length buckets and runs the backend once per bucket at that width, so a
bucket of short reads never pays the outlier's padding. Power-of-two
widths bound the number of distinct compiled shapes at log2(Lmax) —
the standard trade between shape-churn recompiles and padding waste.

Three planners share the pow2 rounding:

  ``bucket_plan``       1D: queries against one broadcast center
                        (``AlignEngine.align_to_center``)
  ``pair_bucket_plan``  2D: per-pair targets, buckets keyed on the
                        (query width, target width) pair — the
                        batch-entry path ``AlignEngine.align_pairs``
                        uses to coalesce requests from many callers
                        (each with its own center) into one jitted
                        call per bucket (``repro.serve.queue``)
  ``band_bucket_plan``  3D: as ``pair_bucket_plan`` but band-aware —
                        buckets additionally keyed on the pow2 band
                        width each pair needs, so banded pairs with the
                        same W share one jitted kernel instance instead
                        of recompiling per distinct length skew
                        (``AlignEngine.align_pairs`` with
                        ``band_policy="adaptive"``)
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np


def _pow2_widths(lens, Lmax: int, min_bucket: int) -> np.ndarray:
    """Per-item pow2 padded width, clamped to [min(min_bucket, Lmax), Lmax]."""
    w = np.maximum(np.asarray(lens).astype(np.int64), 1)
    w = 1 << np.ceil(np.log2(w)).astype(np.int64)      # next pow2 >= len
    return np.clip(w, min(min_bucket, max(Lmax, 1)), max(Lmax, 1))


def bucket_plan(lens, Lmax: int, *, min_bucket: int = 32
                ) -> List[Tuple[int, np.ndarray]]:
    """Group query indices by power-of-two padded width.

    Returns ``[(width, indices), ...]`` sorted by width; widths are
    clamped to ``[min(min_bucket, Lmax), Lmax]`` so a bucket never
    exceeds the physical batch width and tiny buckets don't fragment.
    """
    lens = np.asarray(lens).astype(np.int64)
    if lens.size == 0:
        return []
    w = _pow2_widths(lens, Lmax, min_bucket)
    plan = []
    for width in np.unique(w):
        plan.append((int(width), np.flatnonzero(w == width)))
    return plan


def pair_bucket_plan(qlens, tlens, Lq: int, Lt: int, *, min_bucket: int = 32
                     ) -> List[Tuple[int, int, np.ndarray]]:
    """Group (query, target) pairs by their pow2 (q_width, t_width) bucket.

    Returns ``[(q_width, t_width, indices), ...]`` sorted by (q_width,
    t_width). The bucket count is bounded at log2(Lq) · log2(Lt) distinct
    compiled shapes regardless of how many callers' requests are merged
    into the batch — the invariant ``repro.serve``'s coalescing tests pin.
    """
    qlens = np.asarray(qlens).astype(np.int64)
    if qlens.size == 0:
        return []
    wq = _pow2_widths(qlens, Lq, min_bucket)
    wt = _pow2_widths(tlens, Lt, min_bucket)
    key = wq * (int(max(Lt, 1)) + 1) + wt          # unique composite key
    plan = []
    for k in np.unique(key):
        idx = np.flatnonzero(key == k)
        plan.append((int(wq[idx[0]]), int(wt[idx[0]]), idx))
    return plan


def band_bucket_plan(qlens, tlens, Lq: int, Lt: int, *, band: int,
                     min_bucket: int = 32
                     ) -> List[Tuple[int, int, int, np.ndarray]]:
    """Band-aware pair buckets: ``[(q_width, t_width, W, indices), ...]``.

    The banded backends compile one kernel per (shape, W); a pair whose
    length skew ``|la - lb|`` exceeds the band half-width is guaranteed to
    overflow (the band center line has slope lb/la, so the start or end
    cell falls outside a too-thin band) and would burn a full-DP fallback.
    Each pair therefore gets ``W = next_pow2(|la - lb| + band)`` — the
    engine's configured band as headroom on top of the skew — clamped to
    ``next_pow2(2·t_width + 2)``, the width at which the band provably
    covers every column and the result is bit-identical to the full DP.
    Pairs sharing (q_width, t_width, W) share one jitted kernel instance,
    so the compile count stays bounded by pow2 keys, not by distinct
    skews.
    """
    qlens = np.asarray(qlens).astype(np.int64)
    tlens = np.asarray(tlens).astype(np.int64)
    if qlens.size == 0:
        return []

    def _pow2(x):
        return 1 << np.ceil(np.log2(np.maximum(x, 1))).astype(np.int64)

    wq = _pow2_widths(qlens, Lq, min_bucket)
    wt = _pow2_widths(tlens, Lt, min_bucket)
    need = np.abs(qlens - tlens) + max(int(band), 2)
    W = np.minimum(_pow2(need), _pow2(2 * wt + 2))
    key = (wq * (int(max(Lt, 1)) + 1) + wt) * (int(2 * max(Lt, 1)) + 3) + W
    plan = []
    for k in np.unique(key):
        idx = np.flatnonzero(key == k)
        plan.append((int(wq[idx[0]]), int(wt[idx[0]]), int(W[idx[0]]), idx))
    return plan
