"""Length-bucketed batching for the map(1) align-to-center stage.

Padding every query to the global Lmax makes one 10x-long outlier
dominate the whole shard's DP cost (the DP is O(n·m) per pair in the
padded length n). The dispatcher groups queries into power-of-two
length buckets and runs the backend once per bucket at that width, so a
bucket of short reads never pays the outlier's padding. Power-of-two
widths bound the number of distinct compiled shapes at log2(Lmax) —
the standard trade between shape-churn recompiles and padding waste.
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np


def bucket_plan(lens, Lmax: int, *, min_bucket: int = 32
                ) -> List[Tuple[int, np.ndarray]]:
    """Group query indices by power-of-two padded width.

    Returns ``[(width, indices), ...]`` sorted by width; widths are
    clamped to ``[min(min_bucket, Lmax), Lmax]`` so a bucket never
    exceeds the physical batch width and tiny buckets don't fragment.
    """
    lens = np.asarray(lens).astype(np.int64)
    if lens.size == 0:
        return []
    w = np.maximum(lens, 1)
    w = 1 << np.ceil(np.log2(w)).astype(np.int64)      # next pow2 >= len
    w = np.clip(w, min(min_bucket, max(Lmax, 1)), max(Lmax, 1))
    plan = []
    for width in np.unique(w):
        plan.append((int(width), np.flatnonzero(w == width)))
    return plan
