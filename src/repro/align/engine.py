"""AlignEngine: the single entry point for HAlign-II's map(1) stage.

The three historical alignment paths (the jnp scan oracle, the Pallas SW
kernel, and the k-mer fallback re-alignment) dispatch through this
engine. It owns:

  * backend selection (``jnp`` | ``pallas`` | ``banded`` |
    ``banded-pallas``, ``auto`` resolves per platform — see
    ``backends.resolve_backend``),
  * length-bucketed batching (``bucketing.bucket_plan``): each bucket
    runs at its own power-of-two width instead of the global Lmax; with
    ``band_policy="adaptive"`` the pairs path additionally buckets on
    the pow2 band width each pair's length skew needs
    (``bucketing.band_bucket_plan``), so banded kernels compile once
    per W instead of overflowing thin bands into full-DP fallbacks,
  * the per-pair full-DP fallback shared by the banded backends
    (band overflow) and the k-mer chaining path (chain failure) — the
    merge happens device-side, no host round-trip of the row buffers.

``batch_fn`` exposes the raw jit-compatible backend primitive for use
inside jitted pipelines (``dist.mapreduce`` calls it under shard_map,
where host-side bucketing and fallback control flow are impossible).

Two host batch APIs:

  ``align_to_center``  one broadcast target — the MSA map(1) stage
  ``align_pairs``      per-pair targets — the batch-entry API that lets
                       ``repro.serve`` coalesce pre-encoded requests from
                       many callers (each with its own center) into pow2
                       (q_width, t_width) buckets, one jitted call per
                       bucket (``PairsResult.n_calls`` reports how many)
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax.numpy as jnp
import numpy as np

from . import backends, bucketing
from ..obs import metrics as _obs

_M_CALLS = _obs.counter(
    "repro_align_calls_total",
    "backend invocations (buckets + fallback batches)", ("api", "backend"))
_M_PAIRS = _obs.counter(
    "repro_align_pairs_total", "pairs aligned", ("api", "backend"))
_M_FALLBACK = _obs.counter(
    "repro_align_fallback_pairs_total",
    "pairs re-aligned with full DP after band overflow", ("backend",))
_M_CELLS = _obs.counter(
    "repro_align_cells_total", "useful DP cells dispatched", ("api",))
_M_PAD_CELLS = _obs.counter(
    "repro_align_pad_cells_total", "padding DP cells dispatched", ("api",))
_G_PAD_WASTE = _obs.gauge(
    "repro_align_pad_waste_ratio",
    "padding fraction of the last dispatch's DP area", ("api",))


def _record_dispatch(api: str, backend: str, n_calls: int, n_pairs: int,
                     real_cells: Optional[int],
                     padded_cells: Optional[int]) -> None:
    _M_CALLS.labels(api=api, backend=backend).inc(n_calls)
    _M_PAIRS.labels(api=api, backend=backend).inc(n_pairs)
    if real_cells is None or padded_cells is None or padded_cells <= 0:
        return
    _M_CELLS.labels(api=api).inc(real_cells)
    _M_PAD_CELLS.labels(api=api).inc(max(padded_cells - real_cells, 0))
    _G_PAD_WASTE.labels(api=api).set(1.0 - real_cells / padded_cells)


class EngineResult(NamedTuple):
    score: jnp.ndarray      # (B,) f32
    a_row: jnp.ndarray      # (B, P) int8 gap-padded aligned queries
    b_row: jnp.ndarray      # (B, P) int8 aligned target rows
    aln_len: jnp.ndarray    # (B,) i32
    n_fallback: int         # pairs re-aligned with full DP (banded only)


class PairsResult(NamedTuple):
    score: jnp.ndarray      # (B,) f32
    a_row: jnp.ndarray      # (B, P) int8 gap-padded aligned queries
    b_row: jnp.ndarray      # (B, P) int8 aligned per-pair targets
    aln_len: jnp.ndarray    # (B,) i32
    n_fallback: int         # pairs re-aligned with full DP (banded only)
    n_calls: int            # backend invocations (buckets + fallbacks) —
                            # the coalescing metric repro.serve reports


def _pad_cols(x, width: int, fill):
    if x.shape[-1] >= width:
        return x
    cfg = [(0, 0)] * (x.ndim - 1) + [(0, width - x.shape[-1])]
    return jnp.pad(x, cfg, constant_values=fill)


@dataclasses.dataclass(frozen=True)
class AlignEngine:
    """One configured map(1) engine; construction is cheap, jit caches are
    module-level (keyed on shapes + the static params below), so building
    an engine per MSA call does not recompile."""
    sub: jnp.ndarray
    gap_open: int
    gap_extend: int
    gap_code: int = 5
    backend: str = "auto"
    band: int = 64
    band_policy: str = "fixed"   # "fixed" | "adaptive" (pairs path only)
    local: bool = False
    block_rows: int = 128
    interpret: Optional[bool] = None
    bucket: bool = True
    min_bucket: int = 32

    def __post_init__(self):
        object.__setattr__(self, "backend",
                           backends.resolve_backend(self.backend))
        if self._is_banded and self.local:
            # a diagonal band cannot host an anywhere-start local path
            object.__setattr__(self, "backend", "jnp")
        if self.band_policy not in ("fixed", "adaptive"):
            raise ValueError(f"unknown band_policy {self.band_policy!r}; "
                             "expected 'fixed' or 'adaptive'")

    @property
    def _is_banded(self) -> bool:
        return self.backend in ("banded", "banded-pallas")

    def batch_fn(self, *, local: Optional[bool] = None):
        """(Q, lens, b, lb) -> BatchAlignment, safe inside jit/shard_map.

        ``local`` overrides the engine's local mode for this primitive
        (the k-mer fallback is always global even under a local engine);
        a local override still routes ``banded`` to ``jnp``.
        """
        be = self.backend
        loc = self.local if local is None else local
        if be in ("banded", "banded-pallas") and loc:
            be = "jnp"

        def fn(Q, lens, b, lb):
            if be == "pallas":
                return backends.pallas_align_batch(
                    Q, lens, b, lb, self.sub, gap_open=self.gap_open,
                    gap_extend=self.gap_extend, local=loc,
                    gap_code=self.gap_code, block_rows=self.block_rows,
                    interpret=self.interpret)
            if be == "banded":
                return backends.banded_align_batch(
                    Q, lens, b, lb, self.sub, gap_open=self.gap_open,
                    gap_extend=self.gap_extend, band=self.band,
                    gap_code=self.gap_code)
            if be == "banded-pallas":
                return backends.banded_pallas_align_batch(
                    Q, lens, b, lb, self.sub, gap_open=self.gap_open,
                    gap_extend=self.gap_extend, band=self.band,
                    gap_code=self.gap_code, block_rows=self.block_rows,
                    interpret=self.interpret)
            return backends.jnp_align_batch(
                Q, lens, b, lb, self.sub, gap_open=self.gap_open,
                gap_extend=self.gap_extend, local=loc,
                gap_code=self.gap_code)
        return fn

    def _full_dp_fn(self):
        """The full-DP global primitive used for per-pair fallbacks."""
        def fn(Q, lens, b, lb):
            if self.backend == "pallas":
                return backends.pallas_align_batch(
                    Q, lens, b, lb, self.sub, gap_open=self.gap_open,
                    gap_extend=self.gap_extend, local=False,
                    gap_code=self.gap_code, block_rows=self.block_rows,
                    interpret=self.interpret)
            return backends.jnp_align_batch(
                Q, lens, b, lb, self.sub, gap_open=self.gap_open,
                gap_extend=self.gap_extend, local=False,
                gap_code=self.gap_code)
        return fn

    # ------------------------------------------------------------- host API

    def align_to_center(self, Q, lens, b, lb) -> EngineResult:
        """Bucketed, fallback-handling map(1): every query against ``b``.

        Q: (B, Lmax) int8, lens: (B,), b: (m,), lb scalar. Output rows are
        (B, Lmax + m) — trailing (gap,gap) columns are dead padding the
        center-star assembly ignores.
        """
        Q = jnp.asarray(Q)
        lens = jnp.asarray(lens, jnp.int32)
        b = jnp.asarray(b)
        B, Lmax = Q.shape
        m = b.shape[0]
        P = Lmax + m
        fn = self.batch_fn()

        if not self.bucket or B == 0:
            _record_dispatch("to_center", self.backend, 1 if B else 0, B,
                             None, None)
            out = fn(Q, lens, b, lb)
            return self._apply_fallback(out, Q, lens, b, lb, P)

        lens_np = np.asarray(lens)
        real_cells = int(lens_np.sum()) * m
        plan = bucketing.bucket_plan(lens_np, Lmax,
                                     min_bucket=self.min_bucket)
        padded_cells = sum(width * len(idx) for width, idx in plan) * m
        _record_dispatch("to_center", self.backend, len(plan), B,
                         real_cells, padded_cells)
        if len(plan) == 1:
            width, _ = plan[0]
            out = fn(Q[:, :width], lens, b, lb)
            return self._apply_fallback(out, Q, lens, b, lb, P)

        score = jnp.zeros((B,), jnp.float32)
        a_rows = jnp.full((B, P), self.gap_code, jnp.int8)
        b_rows = jnp.full((B, P), self.gap_code, jnp.int8)
        aln_len = jnp.zeros((B,), jnp.int32)
        ok = np.ones((B,), bool)
        for width, idx in plan:
            ix = jnp.asarray(idx)
            out = fn(Q[ix, :width], lens[ix], b, lb)
            score = score.at[ix].set(out.score)
            a_rows = a_rows.at[ix].set(_pad_cols(out.a_row, P, self.gap_code))
            b_rows = b_rows.at[ix].set(_pad_cols(out.b_row, P, self.gap_code))
            aln_len = aln_len.at[ix].set(out.aln_len)
            ok[idx] = np.asarray(out.ok)
        merged = backends.BatchAlignment(score, a_rows, b_rows, aln_len,
                                         jnp.asarray(ok))
        return self._apply_fallback(merged, Q, lens, b, lb, P)

    def _apply_fallback(self, out: backends.BatchAlignment, Q, lens, b, lb,
                        P: int) -> EngineResult:
        """Re-align pairs the backend flagged (band overflow) with full DP."""
        bad = np.flatnonzero(~np.asarray(out.ok))
        score = out.score
        a_rows = _pad_cols(out.a_row, P, self.gap_code)
        b_rows = _pad_cols(out.b_row, P, self.gap_code)
        aln_len = out.aln_len
        if len(bad):
            _M_FALLBACK.labels(backend=self.backend).inc(len(bad))
            _M_CALLS.labels(api="to_center", backend=self.backend).inc()
            ix = jnp.asarray(bad)
            res = self._full_dp_fn()(Q[ix], lens[ix], b, lb)
            score = score.at[ix].set(res.score)
            a_rows = a_rows.at[ix].set(_pad_cols(res.a_row, P, self.gap_code))
            b_rows = b_rows.at[ix].set(_pad_cols(res.b_row, P, self.gap_code))
            aln_len = aln_len.at[ix].set(res.aln_len)
        return EngineResult(score, a_rows, b_rows, aln_len, len(bad))

    def pairs_fn(self, *, local: Optional[bool] = None,
                 band: Optional[int] = None):
        """(Q, qlens, T, tlens) -> BatchAlignment with per-pair targets.

        The batch-entry primitive: every row carries its own target, so a
        single jitted call can serve pre-encoded requests from many
        callers — each request's center becomes that row's target
        (``repro.serve.queue`` builds such batches). Safe inside
        jit/shard_map; ``local`` overrides as in ``batch_fn``; ``band``
        overrides the engine band for one primitive (the adaptive band
        planner builds one pairs_fn per bucket W).
        """
        be = self.backend
        loc = self.local if local is None else local
        if be in ("banded", "banded-pallas") and loc:
            be = "jnp"
        W = self.band if band is None else band

        def fn(Q, qlens, T, tlens):
            if be == "pallas":
                return backends.pallas_align_pairs(
                    Q, qlens, T, tlens, self.sub, gap_open=self.gap_open,
                    gap_extend=self.gap_extend, local=loc,
                    gap_code=self.gap_code, block_rows=self.block_rows,
                    interpret=self.interpret)
            if be == "banded":
                return backends.banded_align_pairs(
                    Q, qlens, T, tlens, self.sub, gap_open=self.gap_open,
                    gap_extend=self.gap_extend, band=W,
                    gap_code=self.gap_code)
            if be == "banded-pallas":
                return backends.banded_pallas_align_pairs(
                    Q, qlens, T, tlens, self.sub, gap_open=self.gap_open,
                    gap_extend=self.gap_extend, band=W,
                    gap_code=self.gap_code, interpret=self.interpret)
            return backends.jnp_align_pairs(
                Q, qlens, T, tlens, self.sub, gap_open=self.gap_open,
                gap_extend=self.gap_extend, local=loc,
                gap_code=self.gap_code)
        return fn

    def _full_dp_pairs_fn(self):
        """Full-DP global pairs primitive for per-pair fallbacks."""
        def fn(Q, qlens, T, tlens):
            if self.backend == "pallas":
                return backends.pallas_align_pairs(
                    Q, qlens, T, tlens, self.sub, gap_open=self.gap_open,
                    gap_extend=self.gap_extend, local=False,
                    gap_code=self.gap_code, block_rows=self.block_rows,
                    interpret=self.interpret)
            return backends.jnp_align_pairs(
                Q, qlens, T, tlens, self.sub, gap_open=self.gap_open,
                gap_extend=self.gap_extend, local=False,
                gap_code=self.gap_code)
        return fn

    def align_pairs(self, Q, qlens, T, tlens) -> PairsResult:
        """Bucketed batch-entry map(1): row i of ``Q`` against row i of ``T``.

        Q: (B, Lq) int8, T: (B, Lt) int8, qlens/tlens: (B,). Pairs are
        grouped into pow2 (q_width, t_width) buckets
        (``bucketing.pair_bucket_plan``) so one jitted call per bucket
        serves every caller whose request landed in it; output rows are
        (B, Lq + Lt) with trailing (gap, gap) dead padding. ``n_calls``
        counts backend invocations — the coalescing win is B requests
        serviced in <= log2(Lq)·log2(Lt) calls.
        """
        Q = jnp.asarray(Q)
        T = jnp.asarray(T)
        qlens = jnp.asarray(qlens, jnp.int32)
        tlens = jnp.asarray(tlens, jnp.int32)
        B, Lq = Q.shape
        Lt = T.shape[1]
        P = Lq + Lt
        if B == 0:
            z = jnp.zeros((0,), jnp.float32)
            r = jnp.zeros((0, P), jnp.int8)
            return PairsResult(z, r, r, jnp.zeros((0,), jnp.int32), 0, 0)
        fn = self.pairs_fn()

        if not self.bucket:
            _record_dispatch("pairs", self.backend, 1, B, None, None)
            out = fn(Q, qlens, T, tlens)
            return self._apply_pairs_fallback(out, Q, qlens, T, tlens, P,
                                              n_calls=1)

        qlens_np = np.asarray(qlens)
        tlens_np = np.asarray(tlens)
        real_cells = int((qlens_np.astype(np.int64)
                          * tlens_np.astype(np.int64)).sum())

        if self.band_policy == "adaptive" and self._is_banded:
            # Band-aware buckets: pairs sharing (wq, wt, W) share one
            # jitted kernel instance; skewed pairs get a band wide enough
            # to not overflow instead of a guaranteed full-DP fallback.
            plan = bucketing.band_bucket_plan(
                qlens_np, tlens_np, Lq, Lt,
                band=self.band, min_bucket=self.min_bucket)
            _record_dispatch(
                "pairs", self.backend, len(plan), B, real_cells,
                sum(wq * wt * len(idx) for wq, wt, _, idx in plan))
            score = jnp.zeros((B,), jnp.float32)
            a_rows = jnp.full((B, P), self.gap_code, jnp.int8)
            b_rows = jnp.full((B, P), self.gap_code, jnp.int8)
            aln_len = jnp.zeros((B,), jnp.int32)
            ok = np.ones((B,), bool)
            for wq, wt, W, idx in plan:
                ix = jnp.asarray(idx)
                out = self.pairs_fn(band=W)(Q[ix, :wq], qlens[ix],
                                            T[ix, :wt], tlens[ix])
                score = score.at[ix].set(out.score)
                a_rows = a_rows.at[ix].set(
                    _pad_cols(out.a_row, P, self.gap_code))
                b_rows = b_rows.at[ix].set(
                    _pad_cols(out.b_row, P, self.gap_code))
                aln_len = aln_len.at[ix].set(out.aln_len)
                ok[idx] = np.asarray(out.ok)
            merged = backends.BatchAlignment(score, a_rows, b_rows, aln_len,
                                             jnp.asarray(ok))
            return self._apply_pairs_fallback(merged, Q, qlens, T, tlens, P,
                                              n_calls=len(plan))

        plan = bucketing.pair_bucket_plan(qlens_np, tlens_np, Lq, Lt,
                                          min_bucket=self.min_bucket)
        _record_dispatch("pairs", self.backend, len(plan), B, real_cells,
                         sum(wq * wt * len(idx) for wq, wt, idx in plan))
        if len(plan) == 1:
            wq, wt, _ = plan[0]
            out = fn(Q[:, :wq], qlens, T[:, :wt], tlens)
            return self._apply_pairs_fallback(out, Q, qlens, T, tlens, P,
                                              n_calls=1)

        score = jnp.zeros((B,), jnp.float32)
        a_rows = jnp.full((B, P), self.gap_code, jnp.int8)
        b_rows = jnp.full((B, P), self.gap_code, jnp.int8)
        aln_len = jnp.zeros((B,), jnp.int32)
        ok = np.ones((B,), bool)
        for wq, wt, idx in plan:
            ix = jnp.asarray(idx)
            out = fn(Q[ix, :wq], qlens[ix], T[ix, :wt], tlens[ix])
            score = score.at[ix].set(out.score)
            a_rows = a_rows.at[ix].set(_pad_cols(out.a_row, P, self.gap_code))
            b_rows = b_rows.at[ix].set(_pad_cols(out.b_row, P, self.gap_code))
            aln_len = aln_len.at[ix].set(out.aln_len)
            ok[idx] = np.asarray(out.ok)
        merged = backends.BatchAlignment(score, a_rows, b_rows, aln_len,
                                         jnp.asarray(ok))
        return self._apply_pairs_fallback(merged, Q, qlens, T, tlens, P,
                                          n_calls=len(plan))

    def _apply_pairs_fallback(self, out: backends.BatchAlignment, Q, qlens,
                              T, tlens, P: int, *, n_calls: int
                              ) -> PairsResult:
        """Full-DP re-alignment of pairs the backend flagged (band overflow)."""
        bad = np.flatnonzero(~np.asarray(out.ok))
        score = out.score
        a_rows = _pad_cols(out.a_row, P, self.gap_code)
        b_rows = _pad_cols(out.b_row, P, self.gap_code)
        aln_len = out.aln_len
        if len(bad):
            _M_FALLBACK.labels(backend=self.backend).inc(len(bad))
            _M_CALLS.labels(api="pairs", backend=self.backend).inc()
            ix = jnp.asarray(bad)
            res = self._full_dp_pairs_fn()(Q[ix], qlens[ix], T[ix], tlens[ix])
            score = score.at[ix].set(res.score)
            a_rows = a_rows.at[ix].set(_pad_cols(res.a_row, P, self.gap_code))
            b_rows = b_rows.at[ix].set(_pad_cols(res.b_row, P, self.gap_code))
            aln_len = aln_len.at[ix].set(res.aln_len)
            n_calls += 1
        return PairsResult(score, a_rows, b_rows, aln_len, len(bad), n_calls)

    def realign_failed(self, Q, lens, b, lb, a_rows, b_rows, ok):
        """Full-DP re-alignment of k-mer chain failures, merged device-side.

        This replaces the old host-numpy round-trip in ``core.msa``: the
        assembled k-mer rows stay on device; only the (B,) ok flags cross
        to host to pick the failed subset.

        Returns (a_rows, b_rows, n_fallback); widths grow to fit the DP
        rows if needed.
        """
        bad = np.flatnonzero(~np.asarray(ok))
        if len(bad) == 0:
            return jnp.asarray(a_rows), jnp.asarray(b_rows), 0
        Q = jnp.asarray(Q)
        lens = jnp.asarray(lens, jnp.int32)
        ix = jnp.asarray(bad)
        # the k-mer assembly is global, so its fallback must be too — even
        # under a local (Smith-Waterman) engine
        eng = (self if not self.local
               else dataclasses.replace(self, local=False))
        res = eng.align_to_center(Q[ix], lens[ix], b, lb)
        P = max(int(a_rows.shape[1]), int(res.a_row.shape[1]))
        a_rows = _pad_cols(jnp.asarray(a_rows), P, self.gap_code)
        b_rows = _pad_cols(jnp.asarray(b_rows), P, self.gap_code)
        a_rows = a_rows.at[ix].set(_pad_cols(res.a_row, P, self.gap_code))
        b_rows = b_rows.at[ix].set(_pad_cols(res.b_row, P, self.gap_code))
        return a_rows, b_rows, len(bad)
