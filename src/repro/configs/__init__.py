"""Assigned-architecture registry. Import side-effects register each arch."""
from . import (gemma_2b, qwen1_5_0_5b, llama3_2_1b, h2o_danube3_4b,  # noqa: F401
               jamba_1_5_large, mamba2_130m, kimi_k2, moonshot_v1_16b,  # noqa: F401
               qwen2_vl_2b, hubert_xlarge)  # noqa: F401
from .base import (ArchSpec, ModelConfig, ShapeSpec, SHAPES, get_arch,  # noqa: F401
                   shape_applicable)

ALL_ARCHS = [
    "gemma-2b", "qwen1.5-0.5b", "llama3.2-1b", "h2o-danube-3-4b",
    "jamba-1.5-large-398b", "mamba2-130m", "kimi-k2-1t-a32b",
    "moonshot-v1-16b-a3b", "qwen2-vl-2b", "hubert-xlarge",
]
