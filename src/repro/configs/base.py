"""Config schema shared by every architecture + the shape/arch registries.

One frozen dataclass covers the whole zoo (dense / MoE / SSM / hybrid / VLM /
audio); family-specific fields are zero/empty when unused. Every assigned
architecture file under repro/configs instantiates exactly one ModelConfig
plus its reduced smoke-test variant.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # --- features
    mlp: str = "swiglu"              # swiglu | geglu
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    m_rope: bool = False             # 3-section multimodal RoPE (qwen2-vl)
    m_rope_sections: Tuple[int, int, int] = (16, 24, 24)  # t/h/w halves
    sliding_window: int = 0          # >0 => SWA
    causal: bool = True              # False => encoder-only
    embed_input: bool = True         # False => input is precomputed embeddings
    tie_embeddings: bool = False
    scale_embeds: bool = False       # gemma: x *= sqrt(d_model)
    rms_eps: float = 1e-6
    # --- MoE
    n_experts: int = 0
    experts_per_token: int = 0
    moe_period: int = 1              # MoE every k-th layer (jamba: 2)
    first_dense: int = 0             # leading dense layers (kimi: 1)
    d_ff_dense: int = 0              # dense-layer FF width when mixed (kimi)
    capacity_factor: float = 1.25
    # --- SSM
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    d_conv: int = 4
    attn_period: int = 0             # hybrid: 1 attention layer per group of k
    # --- training defaults
    remat: bool = True
    remat_policy: str = "dots"       # nothing | dots (save matmul outputs;
                                     # §Perf iter 5: -15% flops, same memory)
    # roofline mode: unroll the layer scan so XLA cost_analysis (which counts
    # while bodies ONCE) sees every layer's flops/bytes/collectives
    unroll_layers: bool = False

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def is_hybrid(self) -> bool:
        return self.family == "hybrid"

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k (SSM/hybrid/sliding-window)."""
        return self.family in ("ssm", "hybrid") or self.sliding_window > 0

    @property
    def has_decode(self) -> bool:
        return self.causal

    def param_count(self) -> int:
        """Analytic parameter count (drives 6ND roofline numbers)."""
        D, V = self.d_model, self.vocab_size
        emb = V * D if self.embed_input else 0
        head = 0 if self.tie_embeddings else D * V
        per_attn = D * (self.n_heads + 2 * self.n_kv_heads) * self.head_dim \
            + self.n_heads * self.head_dim * D
        gate_mult = 3 if self.mlp in ("swiglu", "geglu") else 2
        def mlp_p(ff): return gate_mult * D * ff
        per_moe = self.n_experts * mlp_p(self.d_ff) + D * self.n_experts
        total = emb + head + 2 * D  # final norm + small extras
        for i in range(self.n_layers):
            kind = self.layer_kind(i)
            total += 2 * D  # norms
            if kind in ("attn", "attn_moe"):
                total += per_attn
            if kind in ("mamba", "mamba_moe"):
                di, st, nh = self.d_inner, self.ssm_state, self.ssm_heads
                total += D * (2 * di + 2 * st + nh) + self.d_conv * (di + 2 * st) \
                    + 3 * nh + di + di * D
            if kind.endswith("_moe") or kind == "moe":
                total += per_moe
            elif kind in ("attn", "mamba", "dense"):
                ff = self.d_ff_dense or self.d_ff
                total += mlp_p(ff)
        return total

    def active_param_count(self) -> int:
        """Per-token active params (MoE: top-k experts only)."""
        if self.n_experts == 0:
            return self.param_count()
        full = self.param_count()
        gate_mult = 3 if self.mlp in ("swiglu", "geglu") else 2
        n_moe_layers = sum(1 for i in range(self.n_layers)
                           if "moe" in self.layer_kind(i))
        moe_all = n_moe_layers * self.n_experts * gate_mult * self.d_model * self.d_ff
        moe_active = n_moe_layers * self.experts_per_token * gate_mult \
            * self.d_model * self.d_ff
        return full - moe_all + moe_active

    def layer_kind(self, i: int) -> str:
        """Kind of layer i: attn | mamba | moe-variants | dense FF pairing."""
        if self.family == "ssm":
            return "mamba"
        if self.family == "hybrid":
            pos = i % self.attn_period if self.attn_period else 1
            mixer = "attn" if pos == self.attn_period - 1 else "mamba"
            moe = (self.n_experts > 0 and i % self.moe_period == self.moe_period - 1)
            return f"{mixer}_moe" if moe else mixer
        if self.n_experts > 0:
            if i < self.first_dense:
                return "attn"
            return "attn_moe"
        return "attn"


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str                 # train | prefill | decode
    seq_len: int
    global_batch: int
    microbatches: int = 1     # gradient-accumulation steps (train only)


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """Skip rules from the assignment (documented in DESIGN.md)."""
    if shape.kind == "decode" and not cfg.has_decode:
        return False, "encoder-only: no decode step"
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "pure full-attention arch: long_500k needs sub-quadratic"
    return True, ""


_REGISTRY: Dict[str, "ArchSpec"] = {}


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    config: ModelConfig
    smoke: ModelConfig            # reduced same-family config for CPU tests
    microbatch_overrides: Dict[str, int] = dataclasses.field(default_factory=dict)


def register(arch_id: str, spec: ArchSpec):
    _REGISTRY[arch_id] = spec
    return spec


def get_arch(arch_id: str) -> ArchSpec:
    if arch_id not in _REGISTRY:
        # import side-effect registration
        from . import ALL_ARCHS  # noqa: F401
    return _REGISTRY[arch_id]


def list_archs():
    from . import ALL_ARCHS
    return list(ALL_ARCHS)
