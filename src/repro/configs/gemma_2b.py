"""gemma-2b [arXiv:2403.08295]: 18L d=2048 8H MQA(kv=1) head_dim=256,
GeGLU d_ff=16384, vocab 256000, tied embeddings, embedding scaling."""
from .base import ArchSpec, ModelConfig, register

CONFIG = ModelConfig(
    name="gemma-2b", family="dense", n_layers=18, d_model=2048,
    n_heads=8, n_kv_heads=1, head_dim=256, d_ff=16384, vocab_size=256000,
    mlp="geglu", tie_embeddings=True, scale_embeds=True, rope_theta=10000.0,
)

SMOKE = ModelConfig(
    name="gemma-2b-smoke", family="dense", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=1, head_dim=16, d_ff=128, vocab_size=128,
    mlp="geglu", tie_embeddings=True, scale_embeds=True,
)

register("gemma-2b", ArchSpec(CONFIG, SMOKE,
                              microbatch_overrides={"train_4k": 8}))
