"""h2o-danube-3-4b [arXiv:2401.16818 family]: 24L d=3840 32H GQA(kv=8)
hd=120, d_ff=10240, vocab 32000, sliding-window attention (llama+mistral
mix). SWA makes it long_500k-eligible with a windowed KV cache."""
from .base import ArchSpec, ModelConfig, register

CONFIG = ModelConfig(
    name="h2o-danube-3-4b", family="dense", n_layers=24, d_model=3840,
    n_heads=32, n_kv_heads=8, head_dim=120, d_ff=10240, vocab_size=32000,
    sliding_window=4096, rope_theta=10000.0,
)

SMOKE = ModelConfig(
    name="h2o-danube-3-4b-smoke", family="dense", n_layers=2, d_model=64,
    n_heads=8, n_kv_heads=2, head_dim=8, d_ff=160, vocab_size=128,
    sliding_window=32,
)

register("h2o-danube-3-4b", ArchSpec(CONFIG, SMOKE,
                                     microbatch_overrides={"train_4k": 8}))
