"""hubert-xlarge [arXiv:2106.07447]: encoder-only, 48L d=1280 16H MHA hd=80,
d_ff=5120, 504 cluster targets. The conv waveform frontend is a stub per the
assignment: input_specs() provides precomputed frame embeddings (B, S, d).
Encoder-only => no decode shapes (documented skip)."""
from .base import ArchSpec, ModelConfig, register

CONFIG = ModelConfig(
    name="hubert-xlarge", family="audio", n_layers=48, d_model=1280,
    n_heads=16, n_kv_heads=16, head_dim=80, d_ff=5120, vocab_size=504,
    causal=False, embed_input=False,
)

SMOKE = ModelConfig(
    name="hubert-smoke", family="audio", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=4, head_dim=16, d_ff=128, vocab_size=32,
    causal=False, embed_input=False,
)

register("hubert-xlarge", ArchSpec(CONFIG, SMOKE,
                                   microbatch_overrides={"train_4k": 4}))
