"""jamba-1.5-large-398b [arXiv:2403.19887]: 72L d=8192, Mamba:attention 7:1
interleave (1 attn per 8-layer group), 64H GQA(kv=8) hd=128, MoE 16e top-2
every other layer, d_ff=24576/expert, vocab 65536, ssm_state=128."""
from .base import ArchSpec, ModelConfig, register

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid", n_layers=72, d_model=8192,
    n_heads=64, n_kv_heads=8, head_dim=128, d_ff=24576, vocab_size=65536,
    n_experts=16, experts_per_token=2, moe_period=2, attn_period=8,
    ssm_state=128, ssm_expand=2, ssm_head_dim=64, d_conv=4,
)

SMOKE = ModelConfig(
    name="jamba-smoke", family="hybrid", n_layers=4, d_model=64,
    n_heads=4, n_kv_heads=2, head_dim=16, d_ff=96, vocab_size=128,
    n_experts=4, experts_per_token=2, moe_period=2, attn_period=4,
    ssm_state=16, ssm_expand=2, ssm_head_dim=16, d_conv=4,
)

register("jamba-1.5-large-398b",
         ArchSpec(CONFIG, SMOKE, microbatch_overrides={"train_4k": 16}))
