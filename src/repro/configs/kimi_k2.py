"""kimi-k2-1t-a32b [arXiv:2501 / Kimi K2 paper-table]: 61L d=7168 64H
GQA(kv=8) hd=112, MoE 384e top-8 d_ff=2048/expert, first layer dense
(d_ff 18432), vocab 163840 — the trillion-parameter stress test."""
from .base import ArchSpec, ModelConfig, register

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe", n_layers=61, d_model=7168,
    n_heads=64, n_kv_heads=8, head_dim=112, d_ff=2048, vocab_size=163840,
    n_experts=384, experts_per_token=8, first_dense=1, d_ff_dense=18432,
    capacity_factor=1.25,
)

SMOKE = ModelConfig(
    name="kimi-k2-smoke", family="moe", n_layers=3, d_model=64,
    n_heads=4, n_kv_heads=2, head_dim=16, d_ff=32, vocab_size=128,
    n_experts=8, experts_per_token=2, first_dense=1, d_ff_dense=96,
)

register("kimi-k2-1t-a32b",
         ArchSpec(CONFIG, SMOKE, microbatch_overrides={"train_4k": 32,
                                                       "prefill_32k": 1}))
