"""llama3.2-1b [hf:meta-llama/Llama-3.2-1B]: 16L d=2048 32H GQA(kv=8) hd=64,
d_ff=8192 SwiGLU, vocab 128256."""
from .base import ArchSpec, ModelConfig, register

CONFIG = ModelConfig(
    name="llama3.2-1b", family="dense", n_layers=16, d_model=2048,
    n_heads=32, n_kv_heads=8, head_dim=64, d_ff=8192, vocab_size=128256,
    rope_theta=500000.0, tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="llama3.2-1b-smoke", family="dense", n_layers=2, d_model=64,
    n_heads=8, n_kv_heads=2, head_dim=8, d_ff=192, vocab_size=128,
    tie_embeddings=True,
)

register("llama3.2-1b", ArchSpec(CONFIG, SMOKE,
                                 microbatch_overrides={"train_4k": 4}))
