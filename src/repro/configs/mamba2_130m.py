"""mamba2-130m [arXiv:2405.21060]: 24L d=768 attention-free SSD,
ssm_state=128, expand=2 (d_inner 1536, 24 heads @ hd 64), vocab 50280."""
from .base import ArchSpec, ModelConfig, register

CONFIG = ModelConfig(
    name="mamba2-130m", family="ssm", n_layers=24, d_model=768,
    n_heads=0, n_kv_heads=0, head_dim=0, d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_expand=2, ssm_head_dim=64, d_conv=4,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="mamba2-smoke", family="ssm", n_layers=2, d_model=64,
    n_heads=0, n_kv_heads=0, head_dim=0, d_ff=0, vocab_size=128,
    ssm_state=16, ssm_expand=2, ssm_head_dim=16, d_conv=4,
    tie_embeddings=True,
)

register("mamba2-130m", ArchSpec(CONFIG, SMOKE,
                                 microbatch_overrides={"train_4k": 2}))
