"""moonshot-v1-16b-a3b [hf:moonshotai/Moonlight-16B-A3B]: 48L d=2048 16H
MHA(kv=16) hd=128, MoE 64e top-6 d_ff=1408/expert, vocab 163840."""
from .base import ArchSpec, ModelConfig, register

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b", family="moe", n_layers=48, d_model=2048,
    n_heads=16, n_kv_heads=16, head_dim=128, d_ff=1408, vocab_size=163840,
    n_experts=64, experts_per_token=6, capacity_factor=1.25,
)

SMOKE = ModelConfig(
    name="moonshot-smoke", family="moe", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=4, head_dim=16, d_ff=32, vocab_size=128,
    n_experts=8, experts_per_token=2,
)

register("moonshot-v1-16b-a3b",
         ArchSpec(CONFIG, SMOKE, microbatch_overrides={"train_4k": 16}))
