"""qwen1.5-0.5b [hf:Qwen/Qwen1.5-0.5B]: 24L d=1024 16H MHA(kv=16) hd=64,
d_ff=2816 SwiGLU, vocab 151936, QKV bias, tied embeddings."""
from .base import ArchSpec, ModelConfig, register

CONFIG = ModelConfig(
    name="qwen1.5-0.5b", family="dense", n_layers=24, d_model=1024,
    n_heads=16, n_kv_heads=16, head_dim=64, d_ff=2816, vocab_size=151936,
    qkv_bias=True, tie_embeddings=True, rope_theta=1e6,
)

SMOKE = ModelConfig(
    name="qwen1.5-0.5b-smoke", family="dense", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=4, head_dim=16, d_ff=96, vocab_size=128,
    qkv_bias=True, tie_embeddings=True,
)

register("qwen1.5-0.5b", ArchSpec(CONFIG, SMOKE,
                                  microbatch_overrides={"train_4k": 4}))
