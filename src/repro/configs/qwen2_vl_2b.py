"""qwen2-vl-2b [arXiv:2409.12191]: 28L d=1536 12H GQA(kv=2) hd=128,
d_ff=8960, vocab 151936, M-RoPE (t/h/w sections). The vision frontend is a
stub per the assignment: input_specs() provides precomputed patch embeddings
(B, S, d_model) + 3D position ids."""
from .base import ArchSpec, ModelConfig, register

CONFIG = ModelConfig(
    name="qwen2-vl-2b", family="vlm", n_layers=28, d_model=1536,
    n_heads=12, n_kv_heads=2, head_dim=128, d_ff=8960, vocab_size=151936,
    m_rope=True, m_rope_sections=(16, 24, 24), embed_input=False,
    rope_theta=1e6,
)

SMOKE = ModelConfig(
    name="qwen2-vl-smoke", family="vlm", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, head_dim=16, d_ff=96, vocab_size=128,
    m_rope=True, m_rope_sections=(2, 3, 3), embed_input=False,
)

register("qwen2-vl-2b", ArchSpec(CONFIG, SMOKE,
                                 microbatch_overrides={"train_4k": 4}))
