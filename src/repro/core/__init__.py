"""HAlign-II core: center-star MSA, k-mer index, NJ phylogeny, metrics."""
from . import alphabet, centerstar, cluster, distance, kmer_index  # noqa: F401
from . import likelihood, msa, nj, pairwise, sp_score, treeio  # noqa: F401
from .msa import MSAConfig, MSAResult, center_star_msa, decode_msa  # noqa: F401
