"""Sequence alphabets and scoring matrices.

Encodings are dense int8 codes so sequences live in ``(N, L) int8`` device
arrays (the JAX analogue of HAlign-II's RDD partitions of strings). The gap
code doubles as the pad code: a padded tail is indistinguishable from
trailing gaps, which is exactly the semantics center-star MSA wants.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax.numpy as jnp
import numpy as np

_DNA_CHARS = "ACGTN"
_PROTEIN_CHARS = "ARNDCQEGHILKMFPSTWYVX"

# BLOSUM62, rows/cols in _PROTEIN_CHARS order (20 AAs + X), standard values.
_BLOSUM62 = np.array([
    #  A   R   N   D   C   Q   E   G   H   I   L   K   M   F   P   S   T   W   Y   V   X
    [  4, -1, -2, -2,  0, -1, -1,  0, -2, -1, -1, -1, -1, -2, -1,  1,  0, -3, -2,  0,  0],  # A
    [ -1,  5,  0, -2, -3,  1,  0, -2,  0, -3, -2,  2, -1, -3, -2, -1, -1, -3, -2, -3, -1],  # R
    [ -2,  0,  6,  1, -3,  0,  0,  0,  1, -3, -3,  0, -2, -3, -2,  1,  0, -4, -2, -3, -1],  # N
    [ -2, -2,  1,  6, -3,  0,  2, -1, -1, -3, -4, -1, -3, -3, -1,  0, -1, -4, -3, -3, -1],  # D
    [  0, -3, -3, -3,  9, -3, -4, -3, -3, -1, -1, -3, -1, -2, -3, -1, -1, -2, -2, -1, -2],  # C
    [ -1,  1,  0,  0, -3,  5,  2, -2,  0, -3, -2,  1,  0, -3, -1,  0, -1, -2, -1, -2, -1],  # Q
    [ -1,  0,  0,  2, -4,  2,  5, -2,  0, -3, -3,  1, -2, -3, -1,  0, -1, -3, -2, -2, -1],  # E
    [  0, -2,  0, -1, -3, -2, -2,  6, -2, -4, -4, -2, -3, -3, -2,  0, -2, -2, -3, -3, -1],  # G
    [ -2,  0,  1, -1, -3,  0,  0, -2,  8, -3, -3, -1, -2, -1, -2, -1, -2, -2,  2, -3, -1],  # H
    [ -1, -3, -3, -3, -1, -3, -3, -4, -3,  4,  2, -3,  1,  0, -3, -2, -1, -3, -1,  3, -1],  # I
    [ -1, -2, -3, -4, -1, -2, -3, -4, -3,  2,  4, -2,  2,  0, -3, -2, -1, -2, -1,  1, -1],  # L
    [ -1,  2,  0, -1, -3,  1,  1, -2, -1, -3, -2,  5, -1, -3, -1,  0, -1, -3, -2, -2, -1],  # K
    [ -1, -1, -2, -3, -1,  0, -2, -3, -2,  1,  2, -1,  5,  0, -2, -1, -1, -1, -1,  1, -1],  # M
    [ -2, -3, -3, -3, -2, -3, -3, -3, -1,  0,  0, -3,  0,  6, -4, -2, -2,  1,  3, -1, -1],  # F
    [ -1, -2, -2, -1, -3, -1, -1, -2, -2, -3, -3, -1, -2, -4,  7, -1, -1, -4, -3, -2, -2],  # P
    [  1, -1,  1,  0, -1,  0,  0,  0, -1, -2, -2,  0, -1, -2, -1,  4,  1, -3, -2, -2,  0],  # S
    [  0, -1,  0, -1, -1, -1, -1, -2, -2, -1, -1, -1, -1, -2, -1,  1,  5, -2, -2,  0,  0],  # T
    [ -3, -3, -4, -4, -2, -2, -3, -2, -2, -3, -2, -3, -1,  1, -4, -3, -2, 11,  2, -3, -2],  # W
    [ -2, -2, -2, -3, -2, -1, -2, -3,  2, -1, -1, -2, -1,  3, -3, -2, -2,  2,  7, -1, -1],  # Y
    [  0, -3, -3, -3, -1, -2, -2, -3, -3,  3,  1, -2,  1, -1, -2, -2,  0, -3, -1,  4, -1],  # V
    [  0, -1, -1, -1, -2, -1, -1, -1, -1, -1, -1, -1, -1, -1, -2,  0,  0, -2, -1, -1, -1],  # X
], dtype=np.int32)


@dataclasses.dataclass(frozen=True)
class Alphabet:
    """A biological alphabet with dense int8 codes.

    Codes ``0..n_chars-1`` are real symbols, ``gap_code`` (== ``n_chars``)
    is the gap/pad code. ``size`` includes the gap row so scoring matrices
    can be indexed by any code without bounds games (gap rows score 0 — the
    DP never legitimately scores a gap through the substitution matrix).
    """
    name: str
    chars: str

    @property
    def n_chars(self) -> int:
        return len(self.chars)

    @property
    def gap_code(self) -> int:
        return len(self.chars)

    @property
    def size(self) -> int:
        return len(self.chars) + 1

    @property
    def char_to_code(self) -> Dict[str, int]:
        return {c: i for i, c in enumerate(self.chars)}

    def encode(self, seq: str) -> np.ndarray:
        lut = self.char_to_code
        unknown = self.unknown_code
        return np.array([lut.get(c, unknown) for c in seq.upper().replace("-", "")],
                        dtype=np.int8)

    def encode_aligned(self, seq: str) -> np.ndarray:
        """Encode keeping '-' as gap_code (for pre-aligned input)."""
        lut = dict(self.char_to_code)
        lut["-"] = self.gap_code
        unknown = self.unknown_code
        return np.array([lut.get(c, unknown) for c in seq.upper()], dtype=np.int8)

    def decode(self, codes) -> str:
        table = self.chars + "-"
        return "".join(table[int(c)] for c in np.asarray(codes))

    @property
    def unknown_code(self) -> int:
        # 'N' for DNA, 'X' for protein: the last real symbol by convention.
        return len(self.chars) - 1


DNA = Alphabet("dna", _DNA_CHARS)
RNA = Alphabet("rna", _DNA_CHARS)  # U encoded via T by upstream replace
PROTEIN = Alphabet("protein", _PROTEIN_CHARS)


def dna_matrix(match: int = 2, mismatch: int = -1) -> jnp.ndarray:
    """Simple match/mismatch matrix for DNA/RNA; N scores 0 vs anything."""
    n = DNA.size
    m = np.full((n, n), mismatch, dtype=np.int32)
    np.fill_diagonal(m, match)
    m[DNA.unknown_code, :] = 0
    m[:, DNA.unknown_code] = 0
    m[DNA.gap_code, :] = 0
    m[:, DNA.gap_code] = 0
    return jnp.asarray(m)


def blosum62() -> jnp.ndarray:
    n = PROTEIN.size
    m = np.zeros((n, n), dtype=np.int32)
    m[: PROTEIN.n_chars, : PROTEIN.n_chars] = _BLOSUM62
    return jnp.asarray(m)


def encode_batch(seqs, alphabet: Alphabet, pad_to: int | None = None):
    """Encode a list of strings into a padded ``(N, L) int8`` array + lengths.

    Padding uses the gap code (trailing-gap semantics).
    """
    enc = [alphabet.encode(s) for s in seqs]
    lens = np.array([len(e) for e in enc], dtype=np.int32)
    L = int(pad_to if pad_to is not None else (max(lens) if len(lens) else 0))
    out = np.full((len(enc), L), alphabet.gap_code, dtype=np.int8)
    for i, e in enumerate(enc):
        out[i, : len(e)] = e[:L]
    return jnp.asarray(out), jnp.asarray(lens)
