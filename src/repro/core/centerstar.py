"""Center-star MSA assembly: the paper's two MapReduce stages, vectorized.

Stage map(1): every sequence is pairwise-aligned to the broadcast center
(``pairwise.align_many_to_one`` or the k-mer path). Stage reduce(1): the
per-pair insert-space profiles are merged with an elementwise ``max`` — on a
mesh this is literally one ``pmax``. Stage map(2): every pairwise alignment
is re-emitted padded to the merged profile. This module implements the
profile extraction, the merge, and the final row construction, all shape-
static and vmap/shard_map friendly.

Conventions: aligned pairs are (a_row, b_row) int8 with gap_code for gaps
*and* padding; columns where both rows are gaps are dead padding and are
ignored (the k-mer assembly path produces interior dead columns by design).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def _cpos_and_masks(a_row, b_row, gap_code):
    """Per-column center index + insertion mask for one aligned pair."""
    ischar_b = b_row != gap_code
    ins = (b_row == gap_code) & (a_row != gap_code)   # real insertion into center
    # number of center chars strictly before column t (exclusive cumsum)
    cpos = jnp.cumsum(ischar_b.astype(jnp.int32)) - ischar_b.astype(jnp.int32)
    return ischar_b, ins, cpos


def gap_profiles(a_rows, b_rows, *, gap_code: int, num_slots: int):
    """Insert-space profiles g[i, j] = #gaps pair i inserts before center char j.

    a_rows/b_rows: (N, P) int8 aligned pairs (b = center). num_slots must be
    >= lc + 1 (slot lc counts gaps after the last center char).
    """
    def one(a_row, b_row):
        _, ins, cpos = _cpos_and_masks(a_row, b_row, gap_code)
        seg = jnp.clip(cpos, 0, num_slots - 1)
        return jax.ops.segment_sum(ins.astype(jnp.int32), seg, num_segments=num_slots)
    return jax.vmap(one)(a_rows, b_rows)


def merge_profiles(g):
    """reduce(1): merged center profile = columnwise max over pairs."""
    return jnp.max(g, axis=0)


def msa_width(G, lc: int) -> int:
    """Final MSA width (host-side; G concrete)."""
    return int(lc) + int(jnp.sum(G))


@functools.partial(jax.jit, static_argnames=("gap_code", "out_len"))
def build_rows(a_rows, b_rows, G, *, gap_code: int, out_len: int):
    """map(2): place each sequence's chars into the merged-profile frame.

    Layout: for center char j, columns [col(j)-G[j], col(j)) are its insertion
    block (right-packed) and col(j) = j + cumsum(G)[j] holds the char itself.
    """
    cumG = jnp.cumsum(G)                       # inclusive
    col_of = jnp.arange(G.shape[0]) + cumG     # col(j), defined for j in [0, lc]

    def one(a_row, b_row):
        P = a_row.shape[0]
        ischar_b, ins, cpos = _cpos_and_masks(a_row, b_row, gap_code)
        j = jnp.clip(cpos, 0, G.shape[0] - 1)
        # rank of each insertion within its run (contiguity not required)
        cumins = jnp.cumsum(ins.astype(jnp.int32))
        g_here = jax.ops.segment_sum(ins.astype(jnp.int32), j,
                                     num_segments=G.shape[0])
        last_char_idx = jax.lax.cummax(
            jnp.where(ischar_b, jnp.arange(P), -1))
        base = jnp.where(last_char_idx >= 0,
                         cumins[jnp.maximum(last_char_idx, 0)], 0)
        o = cumins - 1 - base
        tgt_char = col_of[j]
        tgt_ins = col_of[j] - g_here[j] + o
        target = jnp.where(ischar_b, tgt_char, jnp.where(ins, tgt_ins, out_len))
        target = jnp.where(a_row != gap_code, target, out_len)  # only place real chars
        row = jnp.full((out_len,), gap_code, jnp.int8)
        return row.at[target].set(a_row, mode="drop")

    return jax.vmap(one)(a_rows, b_rows)


@functools.partial(jax.jit, static_argnames=("gap_code", "out_len"))
def center_msa_row(center, lc, G, *, gap_code: int, out_len: int):
    """The center sequence's own row in the merged frame."""
    cumG = jnp.cumsum(G)
    col_of = jnp.arange(G.shape[0]) + cumG
    idx = jnp.arange(center.shape[0])
    target = jnp.where((idx < lc), col_of[jnp.clip(idx, 0, G.shape[0] - 1)], out_len)
    row = jnp.full((out_len,), gap_code, jnp.int8)
    return row.at[target].set(center, mode="drop")


def drop_dead_columns(msa, gap_code: int):
    """Remove all-gap columns (host-side utility; returns a new array)."""
    import numpy as np
    msa = np.asarray(msa)
    keep = ~(msa == gap_code).all(axis=0)
    return msa[:, keep]
