"""HPTree-style cluster-then-merge phylogeny (paper Fig. 4).

Stages, mirroring the paper: (1) random-sample ~10% of sequences; (2) pick k
medoids among the sample (farthest-point greedy over the sampled distance
matrix); (3) assign every sequence to its nearest medoid — one (N, k) MXU
cross-distance; (4) rebalance oversized clusters by spilling overflow to the
next-nearest medoid with room; (5) NJ per cluster, batched with vmap over
padded distance matrices; (6) NJ skeleton over the medoids and stitch the
cluster subtrees into the final tree.

Steps 3 and 5 are the distributed hot paths (shard rows of the cross-distance
/ clusters over the mesh); steps 2/4/6 are O(sample^2)-small host logic.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from . import distance as dist
from . import nj as nj_mod
from . import treeio


@dataclasses.dataclass(frozen=True)
class ClusterConfig:
    sample_frac: float = 0.10
    min_sample: int = 8
    target_cluster: int = 64       # desired leaves per cluster
    balance_factor: float = 1.5    # cap = balance_factor * N/k
    seed: int = 0
    correct: bool = True           # JC69 correction


class ClusterPhylogeny(NamedTuple):
    children: np.ndarray
    blen: np.ndarray
    root: int
    assignments: np.ndarray        # (N,) cluster id
    medoids: np.ndarray            # (k,) global row index of each medoid
    n_clusters: int


def farthest_point_medoids(Ds: np.ndarray, k: int) -> np.ndarray:
    """Greedy k-center over a sampled distance matrix (host, O(k * m)).

    ``repro.phylo.tiles.greedy_k_center`` is the streamed equivalent (same
    picks, no (m, m) matrix) used by the tiled pipeline.
    """
    m = Ds.shape[0]
    first = int(np.argmax(Ds.sum(axis=1)))
    chosen = [first]
    mind = Ds[first].copy()
    for _ in range(1, min(k, m)):
        nxt = int(np.argmax(mind))
        chosen.append(nxt)
        mind = np.minimum(mind, Ds[nxt])
    return np.asarray(chosen)


def rebalance(assign: np.ndarray, xdist: np.ndarray, cap: int) -> np.ndarray:
    """Spill overflow members to the next-nearest cluster with room.

    Shared host logic: the dense path below and the tiled pipeline
    (``repro.phylo.pipeline``) both run their assignments through it.
    """
    assign = assign.copy()
    k = xdist.shape[1]
    order = np.argsort(xdist[np.arange(len(assign)), assign])[::-1]  # worst first
    counts = np.bincount(assign, minlength=k)
    pref = np.argsort(xdist, axis=1)
    for i in order:
        c = assign[i]
        if counts[c] <= cap:
            continue
        for alt in pref[i]:
            if alt != c and counts[alt] < cap:
                counts[c] -= 1
                counts[alt] += 1
                assign[i] = alt
                break
    return assign


def cluster_phylogeny(msa, *, gap_code: int, n_chars: int,
                      cfg: ClusterConfig = ClusterConfig()) -> ClusterPhylogeny:
    msa = jnp.asarray(msa)
    N = msa.shape[0]
    rng = np.random.default_rng(cfg.seed)

    if N <= max(cfg.target_cluster, cfg.min_sample) * 2:
        # small problem: one monolithic NJ
        D = dist.distance_matrix(msa, gap_code=gap_code, n_chars=n_chars,
                                 correct=cfg.correct)
        tree = nj_mod.neighbor_joining(D, N)
        return ClusterPhylogeny(np.asarray(tree.children), np.asarray(tree.blen),
                                int(tree.root), np.zeros(N, np.int32),
                                np.arange(min(1, N)), 1)

    # (1)-(2): sample + medoids
    m = max(cfg.min_sample, int(N * cfg.sample_frac))
    sample = np.sort(rng.choice(N, size=min(m, N), replace=False))
    Ds = np.asarray(dist.distance_matrix(msa[jnp.asarray(sample)],
                                         gap_code=gap_code, n_chars=n_chars,
                                         correct=cfg.correct))
    k = max(2, int(np.ceil(N / cfg.target_cluster)))
    med_local = farthest_point_medoids(Ds, k)
    medoids = sample[med_local]
    k = len(medoids)

    # (3): assign all sequences to nearest medoid
    xdist = np.asarray(dist.cross_distance(msa, msa[jnp.asarray(medoids)],
                                           gap_code=gap_code, n_chars=n_chars,
                                           correct=cfg.correct))
    assign = np.argmin(xdist, axis=1)

    # (4): rebalance (paper: split/merge until balanced; we cap + spill)
    cap = max(3, int(np.ceil(cfg.balance_factor * N / k)))
    assign = rebalance(assign, xdist, cap)

    # (5): per-cluster NJ, vmapped over padded distance matrices
    members = [np.flatnonzero(assign == c) for c in range(k)]
    cap_sz = max(max(len(mm) for mm in members), 3)
    Dpad = np.zeros((k, cap_sz, cap_sz), np.float32)
    sizes = np.zeros((k,), np.int32)
    for c, mm in enumerate(members):
        if len(mm) == 0:
            sizes[c] = 1
            continue
        sub = np.asarray(dist.distance_matrix(msa[jnp.asarray(mm)],
                                              gap_code=gap_code,
                                              n_chars=n_chars,
                                              correct=cfg.correct))
        Dpad[c, : len(mm), : len(mm)] = sub
        sizes[c] = len(mm)
    trees = nj_mod.nj_batch(jnp.asarray(Dpad), jnp.asarray(sizes))

    # (6): skeleton over medoids + stitch
    Dm = np.asarray(dist.distance_matrix(msa[jnp.asarray(medoids)],
                                         gap_code=gap_code, n_chars=n_chars,
                                         correct=cfg.correct))
    skel = nj_mod.neighbor_joining(jnp.asarray(Dm), k)
    cluster_trees = [(np.asarray(trees.children[c]), np.asarray(trees.blen[c]),
                      int(trees.root[c]), int(sizes[c])) for c in range(k)]
    members_nonempty = [mm if len(mm) else np.asarray([medoids[c]])
                        for c, mm in enumerate(members)]
    children, blen, root = treeio.stitch_cluster_trees(
        np.asarray(skel.children), np.asarray(skel.blen), int(skel.root),
        cluster_trees, members_nonempty)
    return ClusterPhylogeny(children, blen, root, assign.astype(np.int32),
                            medoids, k)
