"""Pairwise distance matrices from MSA results.

The N x N p-distance over aligned columns is the compute hot-spot of the
phylogeny pipeline — HAlign-II distributes it over the cluster; we turn it
into MXU work: per-symbol one-hot matmuls accumulated over column chunks
(never materializing the full (N, L*C) one-hot). The Pallas kernel in
``repro.kernels.distance`` fuses the one-hot construction into the matmul
tiles; this module is the XLA/jnp oracle with the same chunking.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("gap_code", "n_chars", "chunk"))
def match_valid_counts(msa, other=None, *, gap_code: int, n_chars: int,
                       chunk: int = 512):
    """Returns (match, valid): per-pair counts of equal non-gap columns and
    both-non-gap columns, via chunked one-hot matmuls (f32 exact for counts
    < 2^24). With ``other`` given, computes the (N, M) cross counts instead
    (used for medoid assignment in the HPTree clustering stage)."""
    N, L = msa.shape
    sym = other is None
    other = msa if sym else other
    M = other.shape[0]
    pad = (-L) % chunk
    msa = jnp.pad(msa, ((0, 0), (0, pad)), constant_values=gap_code)
    other = jnp.pad(other, ((0, 0), (0, pad)), constant_values=gap_code)
    nchunks = (L + pad) // chunk
    chunks_a = msa.reshape(N, nchunks, chunk).transpose(1, 0, 2)
    chunks_b = other.reshape(M, nchunks, chunk).transpose(1, 0, 2)

    def onehot(blk):
        oh = (blk[:, :, None] == jnp.arange(n_chars)[None, None, :])
        oh = (oh & (blk[:, :, None] != gap_code)).astype(jnp.float32)
        return oh.reshape(blk.shape[0], -1)

    def body(carry, blks):
        match, valid = carry
        ba, bb = blks
        na = ((ba != gap_code) & (ba < n_chars)).astype(jnp.float32)
        nb = ((bb != gap_code) & (bb < n_chars)).astype(jnp.float32)
        valid = valid + na @ nb.T
        match = match + onehot(ba) @ onehot(bb).T
        return (match, valid), None

    z = jnp.zeros((N, M), jnp.float32)
    (match, valid), _ = jax.lax.scan(body, (z, z), (chunks_a, chunks_b))
    return match, valid


def p_distance(msa, *, gap_code: int, n_chars: int, chunk: int = 512):
    match, valid = match_valid_counts(msa, gap_code=gap_code, n_chars=n_chars,
                                      chunk=chunk)
    p = 1.0 - match / jnp.maximum(valid, 1.0)
    return jnp.where(valid > 0, p, 0.75)   # saturated when no overlap


def jc69_distance(p):
    """Jukes-Cantor correction d = -3/4 ln(1 - 4/3 p), clipped to stay finite."""
    x = jnp.clip(1.0 - 4.0 / 3.0 * p, 1e-6, 1.0)
    return -0.75 * jnp.log(x)


def counts_to_distance(match, valid, *, correct: bool = True):
    """JC69 (or raw p) distances from (match, valid) count blocks.

    The shared tail of the dense, cross, and tiled paths — counts are exact
    integers in f32, so any block decomposition that feeds this produces
    bitwise-identical distances (the ``repro.phylo.tiles`` invariant).
    """
    p = 1.0 - match / jnp.maximum(valid, 1.0)
    p = jnp.where(valid > 0, p, 0.75)   # saturated when no overlap
    return jc69_distance(p) if correct else p


def distance_matrix(msa, *, gap_code: int, n_chars: int, correct: bool = True,
                    chunk: int = 512):
    match, valid = match_valid_counts(msa, gap_code=gap_code, n_chars=n_chars,
                                      chunk=chunk)
    d = counts_to_distance(match, valid, correct=correct)
    d = (d + d.T) / 2.0
    return d * (1.0 - jnp.eye(d.shape[0]))


def cross_distance(msa, other, *, gap_code: int, n_chars: int,
                   correct: bool = True, chunk: int = 512):
    """(N, M) distances between two row sets (medoid assignment, tiles)."""
    match, valid = match_valid_counts(msa, other, gap_code=gap_code,
                                      n_chars=n_chars, chunk=chunk)
    return counts_to_distance(match, valid, correct=correct)
