"""Trie tree -> k-mer index: the TPU-native adaptation of HAlign's trie.

The paper indexes the center sequence with a trie so common substrings with
every other sequence are found in O(1) per position; DP then runs only on the
unmatched inter-anchor segments. Tries are pointer-chasing structures; on a
TPU the same contract is met by a dense integer table: every length-k window
of the center is encoded as a base-4 integer and scattered (min = first
occurrence) into a 4^k table. Queries compute their own rolling codes, probe
the table with one gather, and greedily chain monotone hits into anchors.
Asymptotics match the trie (O(m) build, O(1) probe); the constant factors are
vector loads instead of cache-missing pointer walks.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

EMPTY = jnp.int32(2**30)


class Anchors(NamedTuple):
    q_pos: jnp.ndarray    # (A,) i32 anchor start in query
    c_pos: jnp.ndarray    # (A,) i32 anchor start in center
    count: jnp.ndarray    # i32 number of accepted anchors
    ok: jnp.ndarray       # bool: every inter-anchor/tail segment <= max_seg


def kmer_codes(seq, length, k: int):
    """Rolling base-4 codes; invalid windows (N/gap or beyond length) -> -1.

    A buffer shorter than ``k`` has no windows at all — the result is the
    empty (0,) code array, never a negative-size slice (degenerate inputs:
    fragments below the k-mer width, empty queries). All-ambiguous windows
    (N / gap codes >= 4) are invalid like any other.
    """
    n = seq.shape[0]
    if n < k:                       # static shape: no length-k window exists
        return jnp.full((0,), -1, jnp.int32)
    windows = jnp.stack([seq[i: n - k + 1 + i] for i in range(k)], axis=1)
    windows = windows.astype(jnp.int32)
    powers = jnp.array([4**i for i in range(k)], dtype=jnp.int32)
    codes = windows @ powers
    valid = jnp.all(windows < 4, axis=1)
    valid &= jnp.arange(n - k + 1) <= (length - k)
    return jnp.where(valid, codes, -1)


@functools.partial(jax.jit, static_argnames=("k", "r"))
def build_center_index(center, lc, *, k: int, r: int = 4):
    """(4^k, r) i32 table: code -> first r positions in center (EMPTY pad).

    r > 1 matters for repetitive sequences: greedy chaining needs the first
    occurrence *at or after* the current chain end, not the global first.
    This is the dense-array equivalent of a trie node holding a position list.
    """
    codes = kmer_codes(center, lc, k)
    pos = jnp.arange(codes.shape[0], dtype=jnp.int32)
    idx = jnp.where(codes >= 0, codes, 4**k)  # invalid -> dropped
    cols = []
    floor = jnp.full((4**k,), -1, jnp.int32)
    for _ in range(r):
        tbl = jnp.full((4**k,), EMPTY, jnp.int32)
        live = jnp.where(codes >= 0, pos > floor[jnp.clip(codes, 0)], False)
        tbl = tbl.at[jnp.where(live, idx, 4**k)].min(pos, mode="drop")
        cols.append(tbl)
        floor = tbl
    return jnp.stack(cols, axis=1)


@functools.partial(jax.jit, static_argnames=("k", "stride", "max_anchors", "max_seg"))
def chain_anchors(q, lq, table, lc, *, k: int, stride: int, max_anchors: int,
                  max_seg: int):
    """Greedy monotone chaining of k-mer hits (the trie-walk equivalent).

    Accept hit (t, c) iff it extends the chain (t >= q_end, c >= c_end) and
    the inter-anchor segments it closes are both <= max_seg. ``ok`` is False
    when the final tail exceeds max_seg or no anchor coverage was achieved —
    the MSA driver then falls back to full DP for that pair.
    """
    codes = kmer_codes(q, lq, k)
    if codes.shape[0] == 0:
        # query buffer below the k-mer width: no windows, so no chain —
        # the pair is still ok when the whole rectangle fits one full-DP
        # segment (same predicate the scan's tail check would apply)
        ok = (lq <= max_seg) & (lc <= max_seg)
        zeros = jnp.zeros((max_anchors,), jnp.int32)
        return Anchors(zeros, zeros, jnp.int32(0), ok)
    cand = jnp.where(codes[:, None] >= 0, table[jnp.clip(codes, 0)], EMPTY)
    t_steps = jnp.arange(0, codes.shape[0], stride)

    def step(carry, t):
        q_end, c_end, cnt, aq, ac = carry
        # first center occurrence at or after the chain end (trie walk with
        # position list); EMPTY if none of the stored r occurrences qualify
        cs = cand[t]
        c = jnp.min(jnp.where(cs >= c_end, cs, EMPTY))
        seg_q = t - q_end
        seg_c = c - c_end
        accept = ((c != EMPTY) & (t >= q_end) & (c >= c_end)
                  & (seg_q <= max_seg) & (seg_c <= max_seg)
                  & (cnt < max_anchors) & (t + k <= lq) & (c + k <= lc))
        aq = jnp.where(accept, aq.at[cnt].set(t), aq)
        ac = jnp.where(accept, ac.at[cnt].set(c), ac)
        q_end = jnp.where(accept, t + k, q_end)
        c_end = jnp.where(accept, c + k, c_end)
        cnt = jnp.where(accept, cnt + 1, cnt)
        return (q_end, c_end, cnt, aq, ac), None

    aq0 = jnp.zeros((max_anchors,), jnp.int32)
    ac0 = jnp.zeros((max_anchors,), jnp.int32)
    (q_end, c_end, cnt, aq, ac), _ = jax.lax.scan(
        step, (jnp.int32(0), jnp.int32(0), jnp.int32(0), aq0, ac0), t_steps)
    tail_ok = ((lq - q_end) <= max_seg) & ((lc - c_end) <= max_seg)
    # cnt == 0 is still a usable chain when the whole pair fits one DP
    # segment (short queries, fragments below the k-mer width): the
    # assembly aligns the single [0,lq)x[0,lc) segment with full DP, which
    # is exactly what the driver's fallback would do. Only flag fallback
    # when zero anchors leave a segment over budget.
    ok = tail_ok & ((cnt > 0) | ((lq <= max_seg) & (lc <= max_seg)))
    return Anchors(aq, ac, cnt, ok)


def segment_bounds(anchors: Anchors, lq, lc, *, k: int):
    """Start/length of the A+1 inter-anchor segments in query and center."""
    A = anchors.q_pos.shape[0]
    s = jnp.arange(A + 1)
    prev_q_end = jnp.where(s == 0, 0, anchors.q_pos[jnp.clip(s - 1, 0)] + k)
    prev_c_end = jnp.where(s == 0, 0, anchors.c_pos[jnp.clip(s - 1, 0)] + k)
    next_q = jnp.where(s < anchors.count, anchors.q_pos[jnp.clip(s, 0, A - 1)], lq)
    next_c = jnp.where(s < anchors.count, anchors.c_pos[jnp.clip(s, 0, A - 1)], lc)
    live = s <= anchors.count                    # segments past the tail are empty
    q_len = jnp.where(live, jnp.maximum(next_q - prev_q_end, 0), 0)
    c_len = jnp.where(live, jnp.maximum(next_c - prev_c_end, 0), 0)
    q_start = jnp.where(live, prev_q_end, 0)
    c_start = jnp.where(live, prev_c_end, 0)
    return q_start, q_len, c_start, c_len
