"""JC69 log-likelihood of an MSA given a tree (Felsenstein pruning).

The paper evaluates phylogeny quality by maximum-likelihood value; we provide
the vectorized evaluator: partial likelihoods for all sites at once, a scan
over internal nodes in topological order (NJ emits children-before-parents by
construction), with per-node rescaling against underflow. Used by
benchmarks/bench_tree.py to score NJ and HPTree trees like the paper's
Table 5 commentary (logL ~ -2.19e7 for their DNA set).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def jc69_transition(t):
    """4x4 JC69 transition matrix for branch length t (expected subs/site)."""
    e = jnp.exp(-4.0 * jnp.maximum(t, 1e-8) / 3.0)
    same = 0.25 + 0.75 * e
    diff = 0.25 - 0.25 * e
    return diff[..., None, None] * jnp.ones((4, 4)) + \
        (same - diff)[..., None, None] * jnp.eye(4)


@functools.partial(jax.jit, static_argnames=("gap_code",))
def log_likelihood(msa, children, blen, root, *, gap_code: int):
    """JC69 logL; gap/N columns contribute uninformative all-ones partials.

    msa: (N, L) int8 with codes A,C,G,T = 0..3; children (M, 2); blen (M, 2).
    """
    N, L = msa.shape
    M = children.shape[0]
    codes = msa.astype(jnp.int32)
    leaf_part = jnp.where((codes[..., None] == jnp.arange(4)) |
                          (codes[..., None] >= 4), 1.0, 0.0)  # (N, L, 4)

    parts = jnp.zeros((M, L, 4), jnp.float32)
    parts = parts.at[:N].set(leaf_part)
    scales = jnp.zeros((M, L), jnp.float32)

    def body(node, carry):
        parts, scales = carry
        c0 = children[node, 0]
        c1 = children[node, 1]
        is_internal = c0 >= 0
        p0 = jc69_transition(blen[node, 0])
        p1 = jc69_transition(blen[node, 1])
        l0 = parts[jnp.maximum(c0, 0)]
        l1 = parts[jnp.maximum(c1, 0)]
        part = (l0 @ p0.T) * (l1 @ p1.T)
        m = jnp.maximum(jnp.max(part, axis=-1, keepdims=True), 1e-30)
        part = part / m
        sc = (scales[jnp.maximum(c0, 0)] + scales[jnp.maximum(c1, 0)]
              + jnp.log(m[..., 0]))
        parts = jnp.where(is_internal, parts.at[node].set(part), parts)
        scales = jnp.where(is_internal, scales.at[node].set(sc), scales)
        return parts, scales

    parts, scales = jax.lax.fori_loop(N, M, body, (parts, scales))
    site_l = jnp.sum(0.25 * parts[root], axis=-1)
    return jnp.sum(jnp.log(jnp.maximum(site_l, 1e-30)) + scales[root])
