"""Log-likelihood of an MSA given a tree (Felsenstein pruning).

The paper evaluates phylogeny quality by maximum-likelihood value; this
module provides the vectorized evaluators: partial likelihoods for all
sites at once, a scan over internal nodes in topological order (NJ emits
children-before-parents by construction), with per-node rescaling against
underflow. Two entry points:

* ``log_likelihood`` — the JC69 closed-form special case over raw MSA
  columns (what ``--tree-ll`` and benchmarks/bench_tree.py report, like
  the paper's Table 5 commentary: logL ~ -2.19e7 for their DNA set).
* ``pruning_log_likelihood`` — the general reversible-model evaluator
  over compressed site patterns: the model arrives pre-decomposed
  (``repro.phylo.models``), the internal-node processing order is an
  explicit array (so NNI candidates score under one vmap without
  renumbering), and site-chunk checkpointing bounds reverse-mode memory.
  This is the function ``repro.phylo.ml`` autodiffs for branch-length
  optimization and vmaps for topology search.

``compress_patterns`` collapses identical alignment columns to (pattern,
count) pairs — logL becomes a weighted sum over unique patterns, and a
nonparametric bootstrap replicate is just a reweighting of the counts.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np


def jc69_transition(t):
    """4x4 JC69 transition matrix for branch length t (expected subs/site).

    Exact at t == 0: ``exp(0) == 1`` makes the off-diagonal exactly zero
    and the diagonal exactly one, so a true zero-length branch is the
    identity (no probability leaks off the diagonal). Positivity clamps
    live in the ML optimizer's softplus parameterization
    (``repro.phylo.ml``), not here.
    """
    e = jnp.exp(-4.0 * jnp.maximum(t, 0.0) / 3.0)
    same = 0.25 + 0.75 * e
    diff = 0.25 - 0.25 * e
    return diff[..., None, None] * jnp.ones((4, 4)) + \
        (same - diff)[..., None, None] * jnp.eye(4)


@functools.partial(jax.jit, static_argnames=("gap_code",))
def log_likelihood(msa, children, blen, root, *, gap_code: int):
    """JC69 logL; gap/N columns contribute uninformative all-ones partials.

    msa: (N, L) int8 with codes A,C,G,T = 0..3; children (M, 2); blen (M, 2).
    """
    N, L = msa.shape
    M = children.shape[0]
    codes = msa.astype(jnp.int32)
    leaf_part = jnp.where((codes[..., None] == jnp.arange(4)) |
                          (codes[..., None] >= 4), 1.0, 0.0)  # (N, L, 4)

    parts = jnp.zeros((M, L, 4), jnp.float32)
    parts = parts.at[:N].set(leaf_part)
    scales = jnp.zeros((M, L), jnp.float32)

    def body(node, carry):
        parts, scales = carry
        c0 = children[node, 0]
        c1 = children[node, 1]
        is_internal = c0 >= 0
        p0 = jc69_transition(blen[node, 0])
        p1 = jc69_transition(blen[node, 1])
        l0 = parts[jnp.maximum(c0, 0)]
        l1 = parts[jnp.maximum(c1, 0)]
        part = (l0 @ p0.T) * (l1 @ p1.T)
        m = jnp.maximum(jnp.max(part, axis=-1, keepdims=True), 1e-30)
        part = part / m
        sc = (scales[jnp.maximum(c0, 0)] + scales[jnp.maximum(c1, 0)]
              + jnp.log(m[..., 0]))
        parts = jnp.where(is_internal, parts.at[node].set(part), parts)
        scales = jnp.where(is_internal, scales.at[node].set(sc), scales)
        return parts, scales

    parts, scales = jax.lax.fori_loop(N, M, body, (parts, scales))
    site_l = jnp.sum(0.25 * parts[root], axis=-1)
    return jnp.sum(jnp.log(jnp.maximum(site_l, 1e-30)) + scales[root])


def compress_patterns(msa):
    """(N, L) alignment -> ``(patterns (N, P) int8, weights (P,) f32)``.

    Site-pattern compression: identical columns collapse to one pattern
    with a multiplicity. Every downstream likelihood is a weighted sum
    over the P unique patterns (P << L for the paper's highly similar
    families), and a bootstrap replicate of the L sites is a multinomial
    reweighting of ``weights`` — no column gather, no new patterns.
    """
    cols, counts = np.unique(np.asarray(msa).T, axis=0, return_counts=True)
    return (np.ascontiguousarray(cols.T).astype(np.int8),
            counts.astype(np.float32))


def _transition_from_decomp(lam, U, sp, t):
    """P(t) = diag(1/sp) U diag(exp(lam t)) U^T diag(sp) for one branch.

    Negative t floors at 0 (identity), same convention as
    ``jc69_transition`` — NJ emits slightly negative lengths, and exp of
    a positive lam*|t| would put diagonal probabilities above 1.
    """
    inner = (U * jnp.exp(lam * jnp.maximum(t, 0.0))[None, :]) @ U.T
    return jnp.maximum(inner * (sp[None, :] / sp[:, None]), 0.0)


@functools.partial(jax.jit, static_argnames=("site_chunk",))
def pruning_log_likelihood(patterns, weights, children, blen, order, root,
                           lam, U, sp, pi, *, site_chunk: int = 0):
    """General reversible-model pruning logL over compressed site patterns.

    patterns: (N, P) int8, codes 0..3 = A,C,G,T, codes >= 4 (N/gap) give
    uninformative all-ones partials; weights: (P,) pattern multiplicities.
    children/blen: (M, 2) tree arrays; ``order``: (M - N,) internal-node
    processing order — any topological sort works, which is what lets NNI
    candidates (whose swapped arrays are no longer index-sorted) score in
    one vmapped call. The substitution model arrives eigendecomposed
    (``repro.phylo.models.decompose``): lam/U the eigensystem of the
    pi-symmetrized rate matrix, sp = sqrt(pi).

    Differentiable in blen and the decomposition (the branch-length path
    is ``exp(lam * t)`` — no eigh in the gradient when the model is
    fixed). ``site_chunk > 0`` evaluates the patterns in checkpointed
    chunks: reverse-mode saves the scan carry per internal node, so peak
    backward memory drops from O(M^2 * P) to O(M^2 * site_chunk).
    """
    N, P = patterns.shape
    M = children.shape[0]

    def chunk_ll(pat, w):
        codes = pat.astype(jnp.int32)
        leaf = jnp.where((codes[..., None] == jnp.arange(4)) |
                         (codes[..., None] >= 4), 1.0, 0.0)   # (N, p, 4)
        parts0 = jnp.zeros((M,) + leaf.shape[1:], jnp.float32).at[:N].set(leaf)
        scales0 = jnp.zeros((M, leaf.shape[1]), jnp.float32)

        def step(carry, node):
            parts, scales = carry
            c0 = children[node, 0]
            c1 = children[node, 1]
            p0 = _transition_from_decomp(lam, U, sp, blen[node, 0])
            p1 = _transition_from_decomp(lam, U, sp, blen[node, 1])
            part = (parts[c0] @ p0.T) * (parts[c1] @ p1.T)
            m = jnp.maximum(jnp.max(part, axis=-1, keepdims=True), 1e-30)
            sc = scales[c0] + scales[c1] + jnp.log(m[..., 0])
            return (parts.at[node].set(part / m),
                    scales.at[node].set(sc)), None

        (parts, scales), _ = jax.lax.scan(step, (parts0, scales0), order)
        site_l = jnp.sum(pi * parts[root], axis=-1)
        return jnp.sum(w * (jnp.log(jnp.maximum(site_l, 1e-30))
                            + scales[root]))

    if site_chunk <= 0 or P <= site_chunk:
        return chunk_ll(patterns, weights)
    pad = (-P) % site_chunk
    # padded patterns are all-N (uninformative, site likelihood 1) with
    # weight 0, so they contribute exactly nothing
    pat = jnp.pad(patterns, ((0, 0), (0, pad)), constant_values=4)
    w = jnp.pad(weights, (0, pad))
    n_chunks = (P + pad) // site_chunk
    pat_c = pat.reshape(N, n_chunks, site_chunk).transpose(1, 0, 2)
    w_c = w.reshape(n_chunks, site_chunk)
    lls = jax.lax.map(jax.checkpoint(lambda args: chunk_ll(*args)),
                      (pat_c, w_c))
    return jnp.sum(lls)
