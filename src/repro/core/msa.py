"""High-level MSA driver: HAlign-II's pipeline as host-orchestrated jitted stages.

Pipeline (paper Fig. 3):
  1. pick the center sequence (first, or most-shared-kmers sample heuristic)
  2. map(1): align every sequence to the broadcast center
       - 'sw' / 'plain': Gotoh DP through ``repro.align.AlignEngine``
         (backend-dispatched: jnp scan / Pallas kernel / banded,
         length-bucketed batching)
       - 'kmer': chain k-mer anchors, DP only on inter-anchor segments
         (trie-accelerated path; per-pair fallback through the engine
         when chaining fails, e.g. diverged sequences)
  3. reduce(1): merge insert-space profiles (columnwise max)
  4. map(2): rebuild every row in the merged frame

The distributed version runs the same jitted stages under shard_map with the
center replicated: ``repro.dist.mapreduce.distributed_center_star`` is the
jitted pipeline, ``repro.dist.mapreduce.msa_over_mesh`` the host driver, and
``repro.launch.msa_run --dist`` the CLI entry. This module is the
single-host reference and the building block both reuse.
"""
from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import alphabet as ab
from . import centerstar, kmer_index, pairwise
from ..obs import trace as _trace


@dataclasses.dataclass(frozen=True)
class MSAConfig:
    alphabet: str = "dna"            # dna | rna | protein
    method: str = "kmer"             # kmer | plain | sw
    match: int = 2
    mismatch: int = -1
    gap_open: int = 3
    gap_extend: int = 1
    k: int = 11                      # k-mer width (trie depth equivalent)
    stride: int = 1                  # query probe stride
    max_anchors: int = 256
    max_seg: int = 64                # inter-anchor DP budget
    center: str = "first"            # first | sampled
    local: bool = False              # Smith-Waterman local stage-1 alignment
    backend: str = "auto"            # map(1) DP: auto | jnp | pallas |
                                     #   banded | banded-pallas
    band: int = 64                   # band width for the banded backends
    bucket: bool = True              # length-bucketed batching in map(1)

    def alpha(self) -> ab.Alphabet:
        return {"dna": ab.DNA, "rna": ab.RNA, "protein": ab.PROTEIN}[self.alphabet]

    def matrix(self) -> jnp.ndarray:
        if self.alphabet == "protein":
            return ab.blosum62().astype(jnp.float32)
        return ab.dna_matrix(self.match, self.mismatch).astype(jnp.float32)

    def engine(self, *, bucket: Optional[bool] = None):
        """The configured ``repro.align.AlignEngine`` for this MSA run."""
        from ..align import AlignEngine
        return AlignEngine(self.matrix(), gap_open=self.gap_open,
                           gap_extend=self.gap_extend,
                           gap_code=self.alpha().gap_code,
                           backend=self.backend, band=self.band,
                           local=self.local,
                           bucket=self.bucket if bucket is None else bucket)


class MSAResult(NamedTuple):
    msa: np.ndarray          # (N, L) int8 aligned rows, original order
    center_idx: int
    n_fallback: int          # pairs that fell back to full DP (kmer chain
                             # failure or banded-DP band overflow)
    width: int
    center_mode: str = "first"   # effective center selection ('first'|'sampled')


# ---------------------------------------------------------------- k-mer path

@functools.partial(jax.jit, static_argnames=("k", "stride", "max_anchors",
                                             "max_seg", "gap_open",
                                             "gap_extend", "gap_code"))
def kmer_align_batch(Q, lens, center, lc, table, sub, *, k, stride,
                     max_anchors, max_seg, gap_open, gap_extend, gap_code):
    """Anchor-chained alignment of a batch of queries against the center.

    Returns (a_rows, b_rows) in a fixed assembly buffer plus per-pair ok flags.
    Dead (gap,gap) columns are interior padding, ignored downstream.
    """
    A = max_anchors
    blk = 2 * max_seg
    kbuf = (A + 1) * blk + A * k + blk

    def one(q, lq):
        anch = kmer_index.chain_anchors(q, lq, table, lc, k=k, stride=stride,
                                        max_anchors=A, max_seg=max_seg)
        qs, qlen, cs, clen = kmer_index.segment_bounds(anch, lq, lc, k=k)

        def get_seg(seq, start, length, width):
            # pad before slicing so end-of-sequence segments stay aligned
            seqp = jnp.concatenate(
                [seq, jnp.full((width,), gap_code, seq.dtype)])
            s = jax.lax.dynamic_slice(seqp, (jnp.clip(start, 0, seq.shape[0]),),
                                      (width,))
            mask = jnp.arange(width) < length
            return jnp.where(mask, s, gap_code).astype(jnp.int8)

        seg_q = jax.vmap(lambda s, l: get_seg(q, s, l, max_seg))(qs, qlen)
        seg_c = jax.vmap(lambda s, l: get_seg(center, s, l, max_seg))(cs, clen)

        aln = jax.vmap(lambda a, la, b, lb: pairwise.align_pair(
            a, la, b, lb, sub, gap_open=gap_open, gap_extend=gap_extend,
            local=False, gap_code=gap_code))(seg_q, qlen, seg_c, clen)

        # anchor blocks: exact k-length matches, padded to blk
        def anchor_block(aq, ac):
            qa = get_seg(q, aq, jnp.int32(k), blk)
            ca = get_seg(center, ac, jnp.int32(k), blk)
            return qa, ca
        anch_a, anch_b = jax.vmap(anchor_block)(anch.q_pos, anch.c_pos)
        anch_live = jnp.arange(A) < anch.count
        anch_len = jnp.where(anch_live, k, 0)

        # interleave: seg0, anch0, seg1, anch1, ..., seg_A
        blocks_a = jnp.zeros((2 * A + 1, blk), jnp.int8)
        blocks_b = jnp.zeros((2 * A + 1, blk), jnp.int8)
        blocks_a = blocks_a.at[0::2].set(aln.a_row[:, :blk])
        blocks_b = blocks_b.at[0::2].set(aln.b_row[:, :blk])
        blocks_a = blocks_a.at[1::2].set(anch_a)
        blocks_b = blocks_b.at[1::2].set(anch_b)
        seg_live = jnp.arange(A + 1) <= anch.count
        seg_len = jnp.where(seg_live, aln.aln_len, 0)
        lens_u = jnp.zeros((2 * A + 1,), jnp.int32)
        lens_u = lens_u.at[0::2].set(seg_len)
        lens_u = lens_u.at[1::2].set(anch_len)

        buf_a = jnp.full((kbuf,), gap_code, jnp.int8)
        buf_b = jnp.full((kbuf,), gap_code, jnp.int8)

        def put(u, carry):
            ba, bb, off = carry
            ba = jax.lax.dynamic_update_slice(ba, blocks_a[u], (off,))
            bb = jax.lax.dynamic_update_slice(bb, blocks_b[u], (off,))
            return ba, bb, off + lens_u[u]
        buf_a, buf_b, _ = jax.lax.fori_loop(0, 2 * A + 1, put, (buf_a, buf_b, jnp.int32(0)))
        return buf_a, buf_b, anch.ok

    return jax.vmap(one)(Q, lens)


# ------------------------------------------------------------------- driver

def encode_for_msa(seqs: Sequence[str], cfg: MSAConfig):
    """Normalize (RNA U->T) and encode a string batch for ``cfg``'s alphabet.

    Shared by this host driver and ``repro.dist.mapreduce.msa_over_mesh`` so
    the two pipelines can never diverge on preprocessing.
    """
    return ab.encode_batch(
        [s.replace("U", "T").replace("u", "t")
         if cfg.alphabet == "rna" else s for s in seqs], cfg.alpha())


def map1_align_to_center(Q, qlens, center, lc, cfg: MSAConfig, engine=None):
    """The map(1) stage on its own: a query batch against a frozen center.

    Returns ``(a_rows, b_rows, n_fallback)`` — the per-pair aligned rows
    every downstream consumer (``assemble_center_star`` here, the
    incremental add-to-MSA path in ``repro.serve.incremental``) feeds to
    the reduce(1)/map(2) assembly. Kept separate from ``center_star_msa``
    so incremental alignment of *new* sequences runs the exact same code
    path as a full realign — the bit-identity the serve tests pin depends
    on it.
    """
    gap = cfg.alpha().gap_code
    sub = cfg.matrix()
    engine = cfg.engine() if engine is None else engine
    if cfg.method == "kmer":
        table = kmer_index.build_center_index(center, lc, k=cfg.k)
        a_rows, b_rows, ok = kmer_align_batch(
            Q, qlens, center, lc, table, sub, k=cfg.k, stride=cfg.stride,
            max_anchors=cfg.max_anchors, max_seg=cfg.max_seg,
            gap_open=cfg.gap_open, gap_extend=cfg.gap_extend, gap_code=gap)
        # chain failures re-align through the engine; rows stay on device
        return engine.realign_failed(Q, qlens, center, lc, a_rows, b_rows, ok)
    res = engine.align_to_center(Q, qlens, center, lc)
    return res.a_row, res.b_row, res.n_fallback


def assemble_center_star(a_rows, b_rows, center, lc, *, others, cidx: int,
                         n_total: int, gap: int):
    """reduce(1) + map(2): merge insert profiles, rebuild rows, place center.

    ``a_rows``/``b_rows`` are the map(1) pair alignments for the ``others``
    rows (any width — dead (gap, gap) columns are ignored). Returns
    ``(msa, width)`` with rows in original order. Shared by
    ``center_star_msa`` and the coalesced request path in
    ``repro.serve.service`` (which obtains the pair alignments through
    ``AlignEngine.align_pairs`` batched across callers).
    """
    num_slots = int(center.shape[0]) + 1
    g = centerstar.gap_profiles(a_rows, b_rows,
                                gap_code=gap, num_slots=num_slots)
    G = centerstar.merge_profiles(g)
    width = centerstar.msa_width(G, int(lc))

    rows = centerstar.build_rows(a_rows, b_rows, G,
                                 gap_code=gap, out_len=width)
    crow = centerstar.center_msa_row(center, lc, G, gap_code=gap,
                                     out_len=width)

    msa = np.full((n_total, width), gap, np.int8)
    msa[np.asarray(others)] = np.asarray(rows)
    msa[cidx] = np.asarray(crow)
    return msa, width


def center_star_msa(seqs: Sequence[str] | np.ndarray,
                    cfg: MSAConfig,
                    lens: Optional[np.ndarray] = None) -> MSAResult:
    alpha = cfg.alpha()
    gap = alpha.gap_code
    if isinstance(seqs, (list, tuple)):
        with _trace.span("encode", n=len(seqs)):
            S, lens = encode_for_msa(seqs, cfg)
    else:
        S = jnp.asarray(seqs)
        lens = jnp.asarray(lens)
    N, Lmax = S.shape
    if N < 2:
        # center selection never runs; the effective mode is trivially first
        return MSAResult(np.asarray(S), 0, 0, Lmax, "first")

    with _trace.span("center", n=int(N), mode=cfg.center):
        cidx, center_mode = _select_center(S, lens, cfg)
        center = S[cidx]
        lc = lens[cidx]
        others = np.array([i for i in range(N) if i != cidx])
        Q, qlens = S[jnp.asarray(others)], lens[jnp.asarray(others)]

    with _trace.span("map1", n=int(N) - 1, method=cfg.method,
                     backend=cfg.backend) as sp:
        a_rows, b_rows, n_fallback = map1_align_to_center(
            Q, qlens, center, lc, cfg)
        if sp is not None:
            # async dispatch would otherwise bill the DP to "assemble"
            jax.block_until_ready((a_rows, b_rows))
    with _trace.span("assemble", n=int(N)):
        msa, width = assemble_center_star(a_rows, b_rows, center, lc,
                                          others=others, cidx=int(cidx),
                                          n_total=N, gap=gap)
    return MSAResult(msa, int(cidx), n_fallback, width, center_mode)


def _select_center(S, lens, cfg: MSAConfig) -> tuple[int, str]:
    """Pick the center row; returns (index, effective mode).

    ``center='sampled'`` needs the k-mer index, which only exists for
    nucleotide alphabets — for proteins the request silently downgraded
    before; now it warns and reports ``center_mode='first'`` in MSAResult.
    """
    if cfg.center == "first" or S.shape[0] <= 2:
        return 0, "first"
    if cfg.alphabet == "protein":
        warnings.warn(
            "center='sampled' is unsupported for protein alphabets (no "
            "k-mer index); falling back to center='first'", stacklevel=2)
        return 0, "first"
    # 'sampled': index sequence 0, pick the sequence sharing the most k-mers —
    # the paper's "contains the most segments among all sequences" heuristic.
    table = kmer_index.build_center_index(S[0], lens[0], k=cfg.k)

    @jax.jit
    def hits(q, lq):
        codes = kmer_index.kmer_codes(q, lq, cfg.k)
        cand = table[jnp.clip(codes, 0), 0]          # first occurrence column
        return jnp.sum((codes >= 0) & (cand != kmer_index.EMPTY))
    h = jax.vmap(hits)(S, lens)
    return int(jnp.argmax(h)), "sampled"


def decode_msa(msa: np.ndarray, cfg: MSAConfig) -> list[str]:
    alpha = cfg.alpha()
    return [alpha.decode(r) for r in np.asarray(msa)]
