"""Neighbor-Joining (Saitou & Nei 1987) — the paper's tree builder, vectorized.

Classic NJ is a pointer-heavy agglomerative loop; the TPU formulation keeps a
fixed (S, S) distance matrix with an active-slot mask and runs S-2 merge
iterations under ``lax.fori_loop``, each a fully vectorized O(S^2) Q-matrix +
argmin. Supports padded inputs (``size`` <= S) so clusters of different sizes
vmap together — that is exactly what HPTree's per-cluster parallel NJ needs.

Tree representation (shared with treeio/likelihood):
  nodes 0..size-1 are leaves; size..2*size-2 are internal, created in merge
  order (so children always have smaller ids -> arrays are topologically
  sorted for the pruning likelihood). children: (2S-1, 2) i32 (-1 for leaf),
  blen: (2S-1, 2) f32 edge lengths to each child.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

INF = jnp.float32(1e30)


class Tree(NamedTuple):
    children: jnp.ndarray   # (2S-1, 2) i32
    blen: jnp.ndarray       # (2S-1, 2) f32
    root: jnp.ndarray       # i32 = 2*size-2
    n_leaves: jnp.ndarray   # i32


@functools.partial(jax.jit, static_argnames=())
def neighbor_joining(D, size) -> Tree:
    """NJ over the leading ``size`` slots of the (S, S) distance matrix."""
    S = D.shape[0]
    size = jnp.asarray(size, jnp.int32)
    eye = jnp.eye(S, dtype=bool)

    def body(t, carry):
        D, active, node_id, children, blen = carry
        do = t < size - 2
        actf = active.astype(jnp.float32)
        pair = actf[:, None] * actf[None, :]
        na = jnp.sum(actf)
        R = jnp.sum(D * pair, axis=1)
        Q = (na - 2.0) * D - R[:, None] - R[None, :]
        Qm = jnp.where((pair > 0) & ~eye, Q, INF)
        idx = jnp.argmin(Qm.reshape(-1))
        i, j = idx // S, idx % S
        dij = D[i, j]
        denom = 2.0 * jnp.maximum(na - 2.0, 1.0)
        li = 0.5 * dij + (R[i] - R[j]) / denom
        lj = dij - li
        new_id = size + t
        drow = 0.5 * (D[i, :] + D[j, :] - dij)
        D2 = D.at[i, :].set(drow).at[:, i].set(drow).at[i, i].set(0.0)
        ch2 = children.at[new_id].set(jnp.stack([node_id[i], node_id[j]]))
        bl2 = blen.at[new_id].set(jnp.stack([li, lj]))
        nid2 = node_id.at[i].set(new_id)
        act2 = active.at[j].set(False)
        keep = lambda new, old: jnp.where(do, new, old)
        return (keep(D2, D), keep(act2, active), keep(nid2, node_id),
                keep(ch2, children), keep(bl2, blen))

    active0 = jnp.arange(S) < size
    node_id0 = jnp.arange(S, dtype=jnp.int32)
    children0 = jnp.full((2 * S - 1, 2), -1, jnp.int32)
    blen0 = jnp.zeros((2 * S - 1, 2), jnp.float32)
    D, active, node_id, children, blen = jax.lax.fori_loop(
        0, S - 2, body, (D, active0, node_id0, children0, blen0))

    # join the two surviving nodes at the root
    order = jnp.argsort(jnp.where(active, jnp.arange(S), S))
    a, b = order[0], order[1]
    root = 2 * size - 2
    half = D[a, b] / 2.0
    children = children.at[root].set(jnp.stack([node_id[a], node_id[b]]))
    blen = blen.at[root].set(jnp.stack([half, half]))
    return Tree(children, blen, root.astype(jnp.int32), size)


def nj_batch(Ds, sizes) -> Tree:
    """vmapped NJ over padded per-cluster distance matrices (HPTree stage)."""
    return jax.vmap(neighbor_joining)(Ds, sizes)


def host_tree(tree: Tree):
    """Device ``Tree`` -> ``(children, blen, root)`` numpy triple.

    The hand-off point between the device-side builders and the host-side
    consumers (treeio stitch/newick, the launchers, ``repro.phylo``).
    """
    return np.asarray(tree.children), np.asarray(tree.blen), int(tree.root)
