"""Pairwise alignment: Needleman-Wunsch / Smith-Waterman with affine gaps (Gotoh).

This is the paper's Eq. (1)-(2) engine, vectorized the TPU way: the classic
cell-by-cell DP is re-expressed as a scan over rows where every in-row
dependency is either elementwise (M, Ix) or a running max (Iy via cummax), so
each row is one fused vector op. The Pallas kernel in ``repro.kernels.sw``
implements the same recurrences with explicit VMEM tiling; this module is the
jnp oracle and the small-problem workhorse.

State convention (shared with the kernel and the traceback):
  M  = 0  a[i-1] aligned to b[j-1]            (diagonal move)
  IX = 1  a[i-1] aligned to a gap in b        (up move, consumes a)
  IY = 2  b[j-1] aligned to a gap in a        (left move, consumes b)
  FRESH = 3  local-alignment fresh start / origin marker

Direction byte = dirM | dirIx << 2 | dirIy << 3, where
  dirM  in {0,1,2,3}: which state the diagonal max came from (3 = fresh)
  dirIx in {0,1}: 0 = opened from M above, 1 = extended Ix above
  dirIy in {0,1}: 0 = opened from M left,  1 = extended Iy left

All scores are integer-valued float32 (exact up to 2^24), NEG = -1e7.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

NEG = -1.0e7
M_ST, IX_ST, IY_ST, FRESH = 0, 1, 2, 3


class AlignResult(NamedTuple):
    score: jnp.ndarray      # f32 scalar
    a_row: jnp.ndarray      # (La+Lb,) int8 aligned a with gaps (gap-padded)
    b_row: jnp.ndarray      # (La+Lb,) int8 aligned b with gaps
    aln_len: jnp.ndarray    # i32 scalar: number of valid leading columns
    start_i: jnp.ndarray    # i32: row where traceback started (local end in a)
    start_j: jnp.ndarray    # i32


class ForwardResult(NamedTuple):
    dirs: jnp.ndarray       # (La+1, Lb+1) int8 packed direction bytes
    score: jnp.ndarray      # f32
    start_i: jnp.ndarray
    start_j: jnp.ndarray
    start_state: jnp.ndarray


def _pack(dir_m, dir_ix, dir_iy):
    return (dir_m | (dir_ix << 2) | (dir_iy << 3)).astype(jnp.int8)


def gotoh_forward(a, la, b, lb, sub, gap_open, gap_extend, *, local=False):
    """Fill the DP, returning packed directions + traceback start.

    a: (n,) int8 codes, la: actual length; b: (m,) int8, lb; sub: (S,S) f32.
    """
    n, m = a.shape[0], b.shape[0]
    go = jnp.float32(gap_open)
    ge = jnp.float32(gap_extend)
    sub = sub.astype(jnp.float32)
    jcol = jnp.arange(m + 1, dtype=jnp.float32)
    col_valid = jnp.arange(m + 1) <= lb

    # Row 0 boundary.
    m0 = jnp.full((m + 1,), NEG).at[0].set(0.0)
    ix0 = jnp.full((m + 1,), NEG)
    iy0 = jnp.where(jnp.arange(m + 1) >= 1, -(go + (jcol - 1.0) * ge), NEG)
    dir_iy0 = jnp.where(jnp.arange(m + 1) == 1, 0, 1)
    dirs0 = _pack(jnp.full((m + 1,), FRESH, jnp.int32), jnp.zeros((m + 1,), jnp.int32), dir_iy0)

    def row_step(carry, a_i):
        m_prev, ix_prev, iy_prev, at_la_m, at_la_ix, at_la_iy, best, i = carry
        i = i + 1
        s_row = sub[a_i.astype(jnp.int32), b.astype(jnp.int32)]       # (m,)
        s_full = jnp.concatenate([jnp.zeros((1,), jnp.float32), s_row])

        h_prev = jnp.maximum(m_prev, jnp.maximum(ix_prev, iy_prev))
        amax = jnp.where(m_prev >= h_prev, M_ST,
                         jnp.where(ix_prev >= h_prev, IX_ST, IY_ST))
        h_diag = jnp.concatenate([jnp.full((1,), NEG), h_prev[:-1]])
        amax_diag = jnp.concatenate([jnp.full((1,), M_ST, amax.dtype), amax[:-1]])

        m_new = h_diag + s_full
        dir_m = amax_diag
        if local:
            # Starting fresh (empty prefix, value 0) beats extending whenever
            # the incoming diagonal is <= 0; ties prefer fresh so traceback
            # stops at zero-valued cells (score-consistency).
            fresh = h_diag <= 0.0
            m_new = jnp.where(fresh, s_full, m_new)
            dir_m = jnp.where(fresh, FRESH, dir_m)
        m_new = m_new.at[0].set(NEG)

        ix_open = m_prev - go
        ix_ext = ix_prev - ge
        ix_new = jnp.maximum(ix_open, ix_ext)
        dir_ix = (ix_ext > ix_open).astype(jnp.int32)

        # Iy via running max:  Iy[j] = -go-(j-1)ge + max_{k<=j-1}(M[k]+k*ge)
        cm = jax.lax.cummax(m_new + jcol * ge)
        iy_new = jnp.concatenate([jnp.full((1,), NEG),
                                  cm[:-1] - go - (jcol[1:] - 1.0) * ge])
        m_left = jnp.concatenate([jnp.full((1,), NEG), m_new[:-1]])
        iy_left = jnp.concatenate([jnp.full((1,), NEG), iy_new[:-1]])
        dir_iy = (iy_left - ge > m_left - go).astype(jnp.int32)

        dirs = _pack(dir_m.astype(jnp.int32), dir_ix, dir_iy)

        # Capture the row i == la for global traceback start.
        hit = (i == la)
        at_la_m = jnp.where(hit, m_new, at_la_m)
        at_la_ix = jnp.where(hit, ix_new, at_la_ix)
        at_la_iy = jnp.where(hit, iy_new, at_la_iy)

        # Track the best local cell (M state only), masked to valid region.
        row_masked = jnp.where(col_valid & (i <= la), m_new, NEG)
        j_best = jnp.argmax(row_masked)
        v_best = row_masked[j_best]
        best_v, best_i, best_j = best
        upd = v_best > best_v
        best = (jnp.where(upd, v_best, best_v),
                jnp.where(upd, i, best_i),
                jnp.where(upd, j_best.astype(jnp.int32), best_j))

        return (m_new, ix_new, iy_new, at_la_m, at_la_ix, at_la_iy, best, i), dirs

    best0 = (jnp.float32(NEG), jnp.int32(0), jnp.int32(0))
    init = (m0, ix0, iy0, m0, ix0, iy0, best0, jnp.int32(0))
    (_, _, _, fm, fx, fy, best, _), dir_rows = jax.lax.scan(row_step, init, a)
    dirs = jnp.concatenate([dirs0[None], dir_rows], axis=0)

    if local:
        score, bi, bj = best
        return ForwardResult(dirs, score, bi, bj, jnp.int32(M_ST))
    end_scores = jnp.stack([fm[lb], fx[lb], fy[lb]])
    st = jnp.argmax(end_scores).astype(jnp.int32)
    return ForwardResult(dirs, end_scores[st], la.astype(jnp.int32),
                         lb.astype(jnp.int32), st)


def traceback(a, b, fwd: ForwardResult, gap_code: int):
    """Walk packed directions back to an aligned pair (gap-padded rows)."""
    n, m = a.shape[0], b.shape[0]
    out_len = n + m
    dirf = fwd.dirs.reshape(-1)

    def step(t, carry):
        i, j, st, done, out_a, out_b, k = carry
        byte = dirf[i * (m + 1) + j].astype(jnp.int32)
        dir_m = byte & 3
        dir_ix = (byte >> 2) & 1
        dir_iy = (byte >> 3) & 1

        is_m = (st == M_ST)
        is_ix = (st == IX_ST)
        # emit characters for this step
        ca = jnp.where(is_m | is_ix, a[jnp.maximum(i - 1, 0)], gap_code).astype(jnp.int8)
        cb = jnp.where(is_m | (st == IY_ST), b[jnp.maximum(j - 1, 0)], gap_code).astype(jnp.int8)
        # O(1) in-place-friendly writes: when done, rewrite the existing value.
        out_a = out_a.at[k].set(jnp.where(done, out_a[k], ca))
        out_b = out_b.at[k].set(jnp.where(done, out_b[k], cb))

        ni = jnp.where(is_m | is_ix, i - 1, i)
        nj = jnp.where(is_m | (st == IY_ST), j - 1, j)
        nst = jnp.where(is_m, dir_m,
                        jnp.where(is_ix, jnp.where(dir_ix == 1, IX_ST, M_ST),
                                  jnp.where(dir_iy == 1, IY_ST, M_ST)))
        fresh_stop = is_m & (dir_m == FRESH)
        ndone = done | fresh_stop | ((ni == 0) & (nj == 0))
        k = jnp.where(done, k, k + 1)
        i = jnp.where(done, i, ni)
        j = jnp.where(done, j, nj)
        st = jnp.where(done, st, nst.astype(jnp.int32))
        return (i, j, st, ndone, out_a, out_b, k)

    out_a = jnp.full((out_len,), gap_code, jnp.int8)
    out_b = jnp.full((out_len,), gap_code, jnp.int8)
    init = (fwd.start_i, fwd.start_j, fwd.start_state,
            (fwd.start_i == 0) & (fwd.start_j == 0),
            out_a, out_b, jnp.int32(0))
    i, j, st, done, out_a, out_b, k = jax.lax.fori_loop(0, out_len, step, init)

    # The walk emitted columns in reverse; un-reverse the first k entries.
    def unrev(x):
        return jnp.roll(jnp.flip(x), k - out_len)
    return unrev(out_a), unrev(out_b), k


@functools.partial(jax.jit, static_argnames=("gap_open", "gap_extend", "local", "gap_code"))
def align_pair(a, la, b, lb, sub, *, gap_open, gap_extend, local=False, gap_code=5):
    """Align one pair; returns AlignResult with gap-padded aligned rows."""
    fwd = gotoh_forward(a, la, b, lb, sub, gap_open, gap_extend, local=local)
    a_row, b_row, k = traceback(a, b, fwd, gap_code)
    return AlignResult(fwd.score, a_row, b_row, k, fwd.start_i, fwd.start_j)


@functools.partial(jax.jit, static_argnames=("gap_open", "gap_extend", "local", "gap_code"))
def align_many_to_one(A, lens, b, lb, sub, *, gap_open, gap_extend,
                      local=False, gap_code=5):
    """vmap of align_pair over queries A (N, La) against one target b.

    This is HAlign-II's map(1) stage: the center sequence b is the broadcast
    variable, every worker aligns its shard of A against it.
    """
    f = lambda a, la: align_pair(a, la, b, lb, sub, gap_open=gap_open,
                                 gap_extend=gap_extend, local=local,
                                 gap_code=gap_code)
    return jax.vmap(f)(A, lens)


def score_only(a, la, b, lb, sub, *, gap_open, gap_extend, local=False):
    """Alignment score without materializing directions (O(m) memory)."""
    fwd = gotoh_forward(a, la, b, lb, sub, gap_open, gap_extend, local=local)
    return fwd.score
