"""Progressive MSA baseline (MUSCLE/ClustalW family) — the paper's Table 2-4
comparison class, implemented so HAlign-II has an in-repo baseline:

  1. guide tree: k-mer composition sketches -> cosine distances -> UPGMA
     (MUSCLE's draft-tree stage)
  2. progressive alignment up the tree: profile-profile Needleman-Wunsch
     (linear gaps), column score = f_a^T S f_b — one (La, Lb) MXU matmul per
     merge, DP + packed traceback like the pairwise engine.

Quality beats center-star on diverged families (every merge is optimal
w.r.t. profiles) at O(N) DP passes over growing profiles — the classic
accuracy/scalability trade the paper's tables show.
"""
from __future__ import annotations

import functools
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from . import alphabet as ab
from .msa import MSAConfig, MSAResult

NEG = -1.0e7


def kmer_sketch(S, lens, *, n_chars: int, k: int = 4):
    """(N, n_chars^k) L2-normalized k-mer composition vectors."""
    N, L = S.shape
    powers = jnp.array([n_chars ** i for i in range(k)], jnp.int32)
    windows = jnp.stack([S[:, i: L - k + 1 + i] for i in range(k)], axis=-1)
    codes = (windows.astype(jnp.int32) * powers).sum(-1)
    valid = (windows < n_chars).all(-1) & \
        (jnp.arange(L - k + 1)[None, :] < (lens - k + 1)[:, None])
    codes = jnp.where(valid, codes, n_chars ** k)

    def hist(c):
        return jnp.zeros((n_chars ** k,), jnp.float32).at[c].add(
            1.0, mode="drop")
    H = jax.vmap(hist)(codes)
    return H / jnp.maximum(jnp.linalg.norm(H, axis=1, keepdims=True), 1e-9)


def upgma(D: np.ndarray):
    """Host UPGMA; returns merge list [(a, b, new_id)] with leaf ids 0..N-1."""
    N = D.shape[0]
    D = D.copy().astype(np.float64)
    np.fill_diagonal(D, np.inf)
    active = {i: 1 for i in range(N)}   # id -> cluster size
    idx = {i: i for i in range(N)}      # id -> row in D
    merges = []
    nxt = N
    rows = list(range(N))
    for _ in range(N - 1):
        ids = list(active)
        sub = np.array([[D[idx[a], idx[b]] if a != b else np.inf
                         for b in ids] for a in ids])
        i, j = np.unravel_index(np.argmin(sub), sub.shape)
        a, b = ids[i], ids[j]
        sa, sb = active[a], active[b]
        ra, rb = idx[a], idx[b]
        newrow = (D[ra] * sa + D[rb] * sb) / (sa + sb)
        D[ra] = newrow
        D[:, ra] = newrow
        D[ra, ra] = np.inf
        merges.append((a, b, nxt))
        del active[a], active[b]
        active[nxt] = sa + sb
        idx[nxt] = ra
        nxt += 1
    return merges


@functools.partial(jax.jit, static_argnames=("gap_pen",))
def profile_align_dirs(pa, pb, sub, *, gap_pen: float):
    """Linear-gap NW over profiles; returns (dirs (La+1, Lb+1) i8, score)."""
    La, C = pa.shape
    Lb = pb.shape[0]
    S = pa @ sub @ pb.T                               # (La, Lb) column scores
    # linear gaps: H[i,j] = max(H[i-1,j-1]+S, H[i-1,j]-g, H[i,j-1]-g)
    g = jnp.float32(gap_pen)

    def row_step(h_prev, s_row):
        # up = H[i-1,j] - g  (vector); diag needs shift; left via cummax:
        # H[i,j] = max(up[j], diag[j], max_k<=j-1 (H[i,k]) - (j-k) g)
        up = h_prev - g
        diag = jnp.concatenate([jnp.full((1,), NEG),
                                h_prev[:-1] + s_row])
        m = jnp.maximum(up, diag)
        jj = jnp.arange(m.shape[0], dtype=jnp.float32)
        cm = jax.lax.cummax(m + jj * g)
        h = jnp.maximum(m, jnp.concatenate(
            [jnp.full((1,), NEG), cm[:-1] - g - (jj[1:] - 1.0) * g]))
        left = jnp.concatenate([jnp.full((1,), NEG), h[:-1] - g])
        dirs = jnp.where(h == diag, 0, jnp.where(h == up, 1, 2)).astype(jnp.int8)
        return h, dirs

    h0 = -g * jnp.arange(Lb + 1, dtype=jnp.float32)
    hN, dir_rows = jax.lax.scan(row_step, h0, S)
    dirs0 = jnp.full((1, Lb + 1), 2, jnp.int8).at[0, 0].set(0)
    dirs = jnp.concatenate([dirs0, dir_rows], axis=0)
    return dirs, hN[Lb]


def _traceback_host(dirs: np.ndarray, La: int, Lb: int):
    i, j = La, Lb
    cols_a, cols_b = [], []
    while i > 0 or j > 0:
        d = dirs[i, j]
        if i > 0 and j > 0 and d == 0:
            i -= 1
            j -= 1
            cols_a.append(i)
            cols_b.append(j)
        elif i > 0 and (d == 1 or j == 0):
            i -= 1
            cols_a.append(i)
            cols_b.append(-1)
        else:
            j -= 1
            cols_a.append(-1)
            cols_b.append(j)
    return cols_a[::-1], cols_b[::-1]


def _expand(rows: np.ndarray, cols: List[int], gap: int) -> np.ndarray:
    out = np.full((rows.shape[0], len(cols)), gap, rows.dtype)
    for t, c in enumerate(cols):
        if c >= 0:
            out[:, t] = rows[:, c]
    return out


def progressive_msa(seqs, cfg: MSAConfig) -> MSAResult:
    alpha = cfg.alpha()
    gap = alpha.gap_code
    S, lens = ab.encode_batch(seqs, alpha)
    N = len(seqs)
    if N < 2:
        return MSAResult(np.asarray(S), 0, 0, S.shape[1])
    sub = cfg.matrix().astype(jnp.float32)[: alpha.n_chars, : alpha.n_chars]

    sk = kmer_sketch(S, lens, n_chars=alpha.n_chars,
                     k=3 if alpha.n_chars > 5 else 4)
    Dm = np.asarray(1.0 - sk @ sk.T)
    merges = upgma(Dm)

    # cluster id -> (rows array (n, L), member leaf ids)
    groups = {i: (np.asarray(S[i: i + 1, : int(lens[i])]), [i])
              for i in range(N)}
    gap_pen = float(cfg.gap_open)

    def profile(rows):
        oh = (rows[:, :, None] == np.arange(alpha.n_chars)).astype(np.float32)
        return jnp.asarray(oh.mean(axis=0))

    for a, b, new in merges:
        ra, ma = groups.pop(a)
        rb, mb = groups.pop(b)
        pa, pb = profile(ra), profile(rb)
        dirs, _ = profile_align_dirs(pa, pb, sub, gap_pen=gap_pen)
        ca, cb = _traceback_host(np.asarray(dirs), pa.shape[0], pb.shape[0])
        rows = np.concatenate([_expand(ra, ca, gap), _expand(rb, cb, gap)])
        groups[new] = (rows, ma + mb)

    rows, members = groups.popitem()[1]
    msa = np.empty_like(rows)
    msa[np.asarray(members)] = rows
    return MSAResult(msa, 0, 0, rows.shape[1])
