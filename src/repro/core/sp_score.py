"""Sum-of-pairs scoring — the paper's MSA quality metric.

Per the paper (and the HAlign papers it builds on): comparing two rows
column-by-column costs 1 when two residues differ, 2 when a residue faces an
inserted space, 0 otherwise; SP is the sum over all rows pairs, avg SP is
SP / #pairs. Lower is better (it is a penalty). O(N^2 L) done as chunked
one-hot matmuls so an ultra-large MSA scores in MXU time.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .distance import match_valid_counts


@functools.partial(jax.jit, static_argnames=("gap_code", "n_chars", "chunk"))
def sp_pair_matrix(msa, *, gap_code: int, n_chars: int, chunk: int = 512):
    """(N, N) matrix of pairwise column costs (mismatch=1, half-gap=2)."""
    N, L = msa.shape
    match, valid = match_valid_counts(msa, gap_code=gap_code, n_chars=n_chars,
                                      chunk=chunk)
    mismatch = valid - match
    nongap = (msa != gap_code).astype(jnp.float32)
    gap = 1.0 - nongap
    half_gap = gap @ nongap.T + nongap @ gap.T
    return mismatch + 2.0 * half_gap


def sp_score(msa, *, gap_code: int, n_chars: int, chunk: int = 512):
    """Total SP penalty over all unordered row pairs."""
    M = sp_pair_matrix(msa, gap_code=gap_code, n_chars=n_chars, chunk=chunk)
    return (jnp.sum(M) - jnp.sum(jnp.diag(M))) / 2.0


def avg_sp(msa, *, gap_code: int, n_chars: int, chunk: int = 512):
    n = msa.shape[0]
    pairs = n * (n - 1) / 2.0
    return sp_score(msa, gap_code=gap_code, n_chars=n_chars, chunk=chunk) / pairs
