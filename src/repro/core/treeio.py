"""Tree utilities: Newick export, bipartitions, Robinson-Foulds distance,
and host-side stitching of HPTree cluster subtrees. Host code by design —
trees leave the device as small arrays and these run once per analysis."""
from __future__ import annotations

from typing import Optional, Sequence, Set, FrozenSet

import numpy as np


def to_newick(children: np.ndarray, blen: np.ndarray, root: int,
              names: Optional[Sequence[str]] = None,
              support: Optional[np.ndarray] = None) -> str:
    """Newick string via iterative postorder (matching ``leaf_sets``) —
    NJ can emit caterpillar-deep trees that blow Python's recursion limit
    around ~1000 leaves.

    ``support`` (optional, per-node) emits bootstrap support as internal
    node labels — ``(a:0.1,b:0.2)0.97:0.3`` — for every node with a
    finite entry (``repro.phylo.ml.split_support`` leaves leaves, the
    root, and trivial splits NaN, the standard convention).
    """
    children = np.asarray(children)
    blen = np.asarray(blen)
    if support is not None:
        support = np.asarray(support)
    frag: dict[int, str] = {}
    stack = [(int(root), False)]
    while stack:
        node, seen = stack.pop()
        c = children[node]
        if c[0] < 0:
            frag[node] = names[node] if names else f"t{node}"
        elif not seen:
            stack.append((node, True))
            stack.append((int(c[0]), False))
            stack.append((int(c[1]), False))
        else:
            left = f"{frag.pop(int(c[0]))}:{float(blen[node, 0]):.6f}"
            right = f"{frag.pop(int(c[1]))}:{float(blen[node, 1]):.6f}"
            label = ""
            if support is not None and np.isfinite(support[node]):
                label = f"{float(support[node]):.2f}"
            frag[node] = f"({left},{right}){label}"
    return frag[int(root)] + ";"


def leaf_sets(children: np.ndarray, root: int, n_leaves: int):
    """Per-node frozenset of descendant leaves (iterative postorder)."""
    children = np.asarray(children)
    memo: dict[int, FrozenSet[int]] = {}
    stack = [(int(root), False)]
    while stack:
        node, seen = stack.pop()
        c = children[node]
        if c[0] < 0:
            memo[node] = frozenset([node])
            continue
        if not seen:
            stack.append((node, True))
            stack.append((int(c[0]), False))
            stack.append((int(c[1]), False))
        else:
            memo[node] = memo[int(c[0])] | memo[int(c[1])]
    return memo


def canonical_split(s: FrozenSet[int], all_leaves: FrozenSet[int]
                    ) -> FrozenSet[int]:
    """The canonical side of a bipartition (smaller set, sorted tiebreak).

    Shared by ``bipartitions`` and the bootstrap support tally
    (``repro.phylo.ml.split_support``) — both must canonicalize
    identically or support lookups silently miss.
    """
    return min(s, all_leaves - s, key=lambda x: (len(x), sorted(x)))


def bipartitions(children: np.ndarray, root: int, n_leaves: int) -> Set[FrozenSet[int]]:
    """Non-trivial splits of the (implicitly unrooted) tree."""
    memo = leaf_sets(children, root, n_leaves)
    all_leaves = frozenset(range(n_leaves))
    splits = set()
    for node, s in memo.items():
        if node == root:
            continue
        if 1 < len(s) < n_leaves - 1:
            splits.add(canonical_split(s, all_leaves))
    return splits


def rf_distance(tree_a, tree_b, n_leaves: int) -> int:
    """Robinson-Foulds distance between two trees over the same leaf ids."""
    sa = bipartitions(np.asarray(tree_a.children), int(tree_a.root), n_leaves)
    sb = bipartitions(np.asarray(tree_b.children), int(tree_b.root), n_leaves)
    return len(sa ^ sb)


def normalized_rf(tree_a, tree_b, n_leaves: int) -> float:
    rf = rf_distance(tree_a, tree_b, n_leaves)
    denom = 2.0 * max(n_leaves - 3, 1)
    return rf / denom


def stitch_cluster_trees(skeleton_children, skeleton_blen, skeleton_root,
                         cluster_trees, cluster_members):
    """Replace skeleton leaf c with cluster c's subtree (HPTree merge step).

    cluster_trees: list of (children, blen, root, size) in *local* leaf ids;
    cluster_members: list of arrays mapping local leaf id -> global leaf id.
    Returns (children, blen, root) in global ids.
    """
    skeleton_children = np.asarray(skeleton_children)
    skeleton_blen = np.asarray(skeleton_blen)
    n_global = sum(len(m) for m in cluster_members)
    # allocate: global leaves, then every cluster's internals, then skeleton's
    children_out = []
    blen_out = []
    next_id = n_global

    def alloc():
        nonlocal next_id
        children_out.append([-1, -1])
        blen_out.append([0.0, 0.0])
        next_id += 1
        return next_id - 1

    def copy_tree(ch, bl, root, leaf_id):
        """Re-emit the subtree at ``root`` into the global arrays, mapping
        leaf ``n`` through ``leaf_id``. Iterative postorder — cluster and
        skeleton NJ trees can be caterpillar-deep (same hazard as
        ``to_newick``)."""
        mapped: dict[int, int] = {}
        stack = [(int(root), False)]
        while stack:
            node, seen = stack.pop()
            c = ch[node]
            if c[0] < 0:
                mapped[node] = leaf_id(node)
            elif not seen:
                stack.append((node, True))
                stack.append((int(c[1]), False))   # c0 pops (and allocs) first,
                stack.append((int(c[0]), False))   # matching the old recursion
            else:
                nid = alloc()
                children_out[nid - n_global] = [mapped[int(c[0])],
                                                mapped[int(c[1])]]
                blen_out[nid - n_global] = [float(bl[node, 0]),
                                            float(bl[node, 1])]
                mapped[node] = nid
        return mapped[int(root)]

    cluster_root_global = []
    for (ch, bl, root, size), members in zip(cluster_trees, cluster_members):
        ch, bl = np.asarray(ch), np.asarray(bl)
        if int(size) == 1:
            cluster_root_global.append(int(members[0]))
        else:
            cluster_root_global.append(
                copy_tree(ch, bl, root, lambda n: int(members[n])))

    root = copy_tree(skeleton_children, skeleton_blen, skeleton_root,
                     lambda n: cluster_root_global[n])
    children = np.full((next_id, 2), -1, np.int32)
    blen = np.zeros((next_id, 2), np.float32)
    if children_out:
        children[n_global:] = np.asarray(children_out, np.int32)
        blen[n_global:] = np.asarray(blen_out, np.float32)
    return children, blen, root
