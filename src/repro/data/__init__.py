from .fasta import iter_fasta, read_fasta, write_fasta
from .datasets import SimConfig, simulate_family, phi_dna, phi_rna, phi_protein
