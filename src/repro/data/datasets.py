"""Synthetic sequence families with known phylogeny.

The paper's datasets (human mitochondrial genomes, 16S rRNA, BAliBASE R10)
are not shippable here, so we simulate statistically similar families: a
random ancestor evolved along a random binary tree with JC69 substitutions
and occasional indels. Crucially this gives a *ground-truth tree*, letting us
score reconstructed phylogenies by Robinson-Foulds distance — a stronger
check than the paper's likelihood-only comparison. Scale knobs mirror the
paper's Φ_DNA / Φ_RNA / Φ_Protein: length ~16.5k similar genomes, ~1.4k
moderately diverged RNA, 19-4895 diverged proteins.
"""
from __future__ import annotations

import dataclasses
from typing import List, NamedTuple

import numpy as np

_DNA = np.array(list("ACGT"))
_AA = np.array(list("ARNDCQEGHILKMFPSTWYV"))


@dataclasses.dataclass(frozen=True)
class SimConfig:
    n_leaves: int = 16
    root_len: int = 1024
    alphabet: str = "dna"          # dna | protein
    branch_sub: float = 0.01       # expected substitutions/site/branch
    branch_indel: float = 0.0005   # expected indels/site/branch
    indel_len_mean: float = 2.0
    seed: int = 0
    len_jitter: float = 0.0        # fractional leaf-length variation


class SimFamily(NamedTuple):
    names: List[str]
    seqs: List[str]
    children: np.ndarray    # ground-truth tree (leaves 0..n-1)
    blen: np.ndarray
    root: int


def _random_topology(n: int, rng) -> tuple[np.ndarray, np.ndarray, int]:
    """Random binary tree via sequential random joins; NJ-style arrays."""
    children = np.full((2 * n - 1, 2), -1, np.int32)
    blen = np.zeros((2 * n - 1, 2), np.float32)
    active = list(range(n))
    nxt = n
    while len(active) > 1:
        i, j = rng.choice(len(active), size=2, replace=False)
        a, b = active[i], active[j]
        children[nxt] = (a, b)
        blen[nxt] = rng.exponential(1.0, size=2)
        for x in sorted([i, j], reverse=True):
            active.pop(x)
        active.append(nxt)
        nxt += 1
    return children[:nxt], blen[:nxt], nxt - 1


def _evolve(seq: np.ndarray, t_sub: float, t_indel: float, cfg: SimConfig, rng):
    chars = _DNA if cfg.alphabet == "dna" else _AA
    n = len(seq)
    # JC69-like substitutions
    p_sub = 1.0 - np.exp(-t_sub)
    mask = rng.random(n) < p_sub
    seq = seq.copy()
    if mask.any():
        seq[mask] = chars[rng.integers(0, len(chars), mask.sum())]
    # indels
    n_indel = rng.poisson(t_indel * n)
    for _ in range(n_indel):
        pos = rng.integers(0, max(len(seq), 1))
        ln = max(1, rng.poisson(cfg.indel_len_mean))
        if rng.random() < 0.5 and len(seq) > ln + 2:
            seq = np.concatenate([seq[:pos], seq[pos + ln:]])
        else:
            ins = chars[rng.integers(0, len(chars), ln)]
            seq = np.concatenate([seq[:pos], ins, seq[pos:]])
    return seq


def simulate_family(cfg: SimConfig) -> SimFamily:
    rng = np.random.default_rng(cfg.seed)
    chars = _DNA if cfg.alphabet == "dna" else _AA
    children, blen, root = _random_topology(cfg.n_leaves, rng)
    root_seq = chars[rng.integers(0, len(chars), cfg.root_len)]
    seqs: dict[int, np.ndarray] = {}

    def rec(node: int, seq: np.ndarray):
        c = children[node]
        if c[0] < 0:
            seqs[node] = seq
            return
        for ci, t in ((int(c[0]), blen[node, 0]), (int(c[1]), blen[node, 1])):
            rec(ci, _evolve(seq, t * cfg.branch_sub, t * cfg.branch_indel, cfg, rng))

    rec(root, root_seq)
    names = [f"seq{i}" for i in range(cfg.n_leaves)]
    out = ["".join(seqs[i]) for i in range(cfg.n_leaves)]
    return SimFamily(names, out, children, blen, root)


def phi_dna(scale: int = 1, seed: int = 0) -> SimFamily:
    """Φ_DNA analogue: highly similar 'mitochondrial' genomes (scaled)."""
    return simulate_family(SimConfig(n_leaves=16 * scale, root_len=2048,
                                     branch_sub=0.002, branch_indel=0.0002,
                                     seed=seed))


def phi_rna(scale: int = 1, seed: int = 1) -> SimFamily:
    """Φ_RNA analogue: ~1.4k-length moderately diverged sequences."""
    return simulate_family(SimConfig(n_leaves=24 * scale, root_len=1440,
                                     branch_sub=0.01, branch_indel=0.001,
                                     seed=seed))


def phi_protein(scale: int = 1, seed: int = 2) -> SimFamily:
    """Φ_Protein analogue: diverged proteins, variable length."""
    return simulate_family(SimConfig(n_leaves=16 * scale, root_len=459,
                                     alphabet="protein", branch_sub=0.05,
                                     branch_indel=0.002, seed=seed))
