"""Minimal, robust FASTA reader/writer (the system's HDFS stand-in)."""
from __future__ import annotations

from pathlib import Path
from typing import Iterable, List, Tuple


def read_fasta(path) -> Tuple[List[str], List[str]]:
    names, seqs = [], []
    cur: list[str] = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            if line.startswith(">"):
                if cur:
                    seqs.append("".join(cur))
                    cur = []
                names.append(line[1:].split()[0])
            else:
                cur.append(line)
    if cur:
        seqs.append("".join(cur))
    if len(names) != len(seqs):
        raise ValueError(f"malformed FASTA {path}: {len(names)} headers, "
                         f"{len(seqs)} sequences")
    return names, seqs


def write_fasta(path, names: Iterable[str], seqs: Iterable[str], width: int = 80):
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    with open(path, "w") as f:
        for n, s in zip(names, seqs):
            f.write(f">{n}\n")
            for i in range(0, len(s), width):
                f.write(s[i: i + width] + "\n")
