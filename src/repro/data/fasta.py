"""Minimal, robust FASTA reader/writer (the system's HDFS stand-in).

``iter_fasta`` is the streaming core: one ``(name, sequence)`` record at a
time from a path or any line-iterable (an open file, ``io.StringIO`` over
an HTTP upload body — ``repro.serve`` parses request payloads through it
so an upload is never materialized twice). ``read_fasta`` is the
list-building wrapper every launcher uses.

Records are normalized on the way in:

  * CRLF / stray ``\\r`` line endings are stripped (files written on
    Windows or pasted through HTTP bodies arrive as ``\\r\\n`` even when
    the stream wasn't opened in universal-newline mode),
  * sequence characters are uppercased (lowercase soft-masked residues
    otherwise leak into encoding, where only uppercase codes exist),
  * ``.`` gap characters become ``-``,
  * anything outside letters / ``-`` / ``*`` raises ``ValueError`` with
    the offending record named. IUPAC ambiguity codes (R, Y, S, W, ...)
    are letters and pass through — the alphabet encoder maps codes
    outside its table to the unknown symbol (N / X).
"""
from __future__ import annotations

import re
from pathlib import Path
from typing import Iterable, Iterator, List, Tuple

_BAD_CHARS = re.compile(r"[^A-Z\-*]")


def _normalize_seq(chunks: List[str], name: str) -> str:
    seq = "".join(chunks).upper().replace(".", "-")
    bad = _BAD_CHARS.search(seq)
    if bad:
        raise ValueError(
            f"invalid character {bad.group()!r} in sequence {name!r}")
    return seq


def iter_fasta(source) -> Iterator[Tuple[str, str]]:
    """Stream ``(name, normalized_sequence)`` records from ``source``.

    ``source`` is a path (opened and closed here) or any iterable of
    lines (already-open file, ``io.StringIO``, a list of strings).
    """
    if isinstance(source, (str, Path)):
        with open(source) as f:
            yield from _iter_lines(f, str(source))
    else:
        yield from _iter_lines(source, "<stream>")


def _iter_lines(lines, label: str) -> Iterator[Tuple[str, str]]:
    name = None
    cur: List[str] = []
    for line in lines:
        line = line.strip()
        if not line:
            continue
        if line.startswith(">"):
            if name is not None:
                yield name, _normalize_seq(cur, name)
            cur = []
            name = line[1:].split()[0] if len(line) > 1 else ""
        else:
            if name is None:
                raise ValueError(
                    f"malformed FASTA {label}: sequence data before the "
                    f"first '>' header")
            cur.append(line.replace(" ", "").replace("\t", ""))
    if name is not None:
        yield name, _normalize_seq(cur, name)


def read_fasta(path) -> Tuple[List[str], List[str]]:
    names, seqs = [], []
    for name, seq in iter_fasta(path):
        names.append(name)
        seqs.append(seq)
    return names, seqs


def write_fasta(path, names: Iterable[str], seqs: Iterable[str], width: int = 80):
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    with open(path, "w") as f:
        for n, s in zip(names, seqs):
            f.write(f">{n}\n")
            for i in range(0, len(s), width):
                f.write(s[i: i + width] + "\n")
