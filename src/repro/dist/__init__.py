"""Distributed runtime: the paper's Spark layer, in JAX terms.

HAlign-II delegates distribution to Spark: sequences become an RDD of
shards, map(1) aligns each shard against the broadcast center, reduce(1)
merges insert-space profiles, map(2) re-emits rows in the merged frame,
and Spark supplies checkpointing, replication, and straggler recovery for
free. This package is that layer for a JAX mesh:

  sharding.py          named-axis helpers + the versioned shard_map import
  mapreduce.py         shard_map map/reduce over sequence shards (Fig. 3)
  collectives.py       overlap-friendly collectives (all-gather/matmul)
  grad_compression.py  int8 quantized psum-mean with error feedback
  checkpoint.py        async atomic checkpoints with retention
  fault.py             shard replication plan + failure-replay step loop

Everything here runs unchanged on one CPU device (tests), a forced
multi-device host platform (tests/test_multidevice.py), or a real pod.
"""
from . import checkpoint, collectives, fault, grad_compression, mapreduce, sharding
from .sharding import shard_map

__all__ = ["checkpoint", "collectives", "fault", "grad_compression",
           "mapreduce", "sharding", "shard_map"]
