"""Async atomic checkpoints with retention — the durability half of Spark.

One ``step_<N>.npz`` file per step holding the tree's leaves in flatten
order. Writes go to a temp file in the same directory and are
``os.replace``d into place, so a crash mid-write never corrupts the latest
step. ``async_write=True`` moves the file IO to a background thread (the
device->host transfer still happens in ``save`` so the caller may mutate
the live tree immediately after). Retention keeps the newest ``keep``
steps. ``restore`` walks newest-to-oldest past unreadable/mismatched files
— a corrupt latest step costs one checkpoint interval, not the run.
"""
from __future__ import annotations

import os
import uuid
import warnings
from concurrent.futures import Future, ThreadPoolExecutor
from pathlib import Path
from typing import List, Optional

import jax
import numpy as np

from ..obs import metrics as _obs

_PREFIX = "step_"
_SUFFIX = ".npz"

_H_WRITE = _obs.histogram("repro_checkpoint_write_seconds",
                          "serialize + atomic replace per checkpoint")
_C_WRITES = _obs.counter("repro_checkpoint_writes_total",
                         "checkpoints written")
_C_BYTES = _obs.counter("repro_checkpoint_bytes_total",
                        "checkpoint bytes written")
_C_RESTORES = _obs.counter("repro_checkpoint_restores_total",
                           "successful checkpoint restores")


def atomic_save_npz(path, arrays: dict, *, _hook=None):
    """Crash-safe npz write: temp file in the target directory, then one
    ``os.replace``. The durability primitive ``CheckpointManager`` builds
    on, exported for single-artifact consumers (``repro.search`` persists
    its ``SearchIndex`` through it so a crash mid-save never corrupts an
    index a fleet of workers is about to load; ``repro.serve.store``
    commits MSA generations through it).

    ``_hook(label)`` is a fault-injection seam for crash-atomicity tests:
    it is called at ``save.serialize`` (nothing written yet),
    ``save.pre-replace`` (temp complete, final untouched) and
    ``save.post-replace`` (final replaced). A hook that raises models a
    crash at that point; the temp file is always cleaned up, the final
    file is either the old bytes or the new bytes, never a mix.
    """
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    tmp = path.parent / f".tmp-{uuid.uuid4().hex}"
    try:
        if _hook is not None:
            _hook("save.serialize")
        with open(tmp, "wb") as f:
            np.savez(f, **arrays)
        if _hook is not None:
            _hook("save.pre-replace")
        os.replace(tmp, path)
        if _hook is not None:
            _hook("save.post-replace")
    finally:
        tmp.unlink(missing_ok=True)


class CheckpointManager:
    def __init__(self, directory, keep: Optional[int] = None,
                 async_write: bool = False):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_write = async_write
        self._pool = ThreadPoolExecutor(max_workers=1) if async_write else None
        self._pending: List[Future] = []

    # ------------------------------------------------------------- inventory

    def _path(self, step: int) -> Path:
        return self.dir / f"{_PREFIX}{step:010d}{_SUFFIX}"

    def all_steps(self) -> List[int]:
        steps = []
        for p in self.dir.glob(f"{_PREFIX}*{_SUFFIX}"):
            try:
                steps.append(int(p.name[len(_PREFIX):-len(_SUFFIX)]))
            except ValueError:
                continue
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # ------------------------------------------------------------------ save

    def save(self, step: int, tree, block: bool = False):
        """Checkpoint ``tree`` as ``step``. Returns after the device->host
        copy; the file write is backgrounded unless ``block`` or sync mode."""
        host = [np.asarray(x) for x in jax.tree.leaves(tree)]
        if self.async_write and not block:
            self._pending.append(self._pool.submit(self._write, step, host))
        else:
            self._write(step, host)

    def _write(self, step: int, host_leaves):
        import time
        t0 = time.perf_counter()
        final = self._path(step)
        tmp = self.dir / f".tmp-{uuid.uuid4().hex}"
        with open(tmp, "wb") as f:
            np.savez(f, **{f"leaf_{i}": a for i, a in enumerate(host_leaves)})
        nbytes = tmp.stat().st_size
        os.replace(tmp, final)
        _H_WRITE.observe(time.perf_counter() - t0)
        _C_WRITES.inc()
        _C_BYTES.inc(nbytes)
        self._gc()

    def _gc(self):
        if self.keep is None:
            return
        steps = self.all_steps()
        for s in steps[:max(len(steps) - self.keep, 0)]:
            try:
                self._path(s).unlink()
            except FileNotFoundError:
                pass

    def wait(self):
        """Block until every async save has hit disk (raises their errors)."""
        pending, self._pending = self._pending, []
        for f in pending:
            f.result()

    # --------------------------------------------------------------- restore

    def restore(self, like, shardings=None, step: Optional[int] = None):
        """Load into the structure of ``like``; returns ``(tree, step)``.

        ``shardings``: optional tree of ``jax.sharding.Sharding`` matching
        ``like`` — leaves are ``device_put`` with them, which is what makes
        restore elastic across mesh shapes (save on 4x2, restore on 8x1).
        With ``step=None`` the newest readable checkpoint wins; unreadable
        or structurally mismatched files are skipped with a warning.
        """
        self.wait()
        leaves, treedef = jax.tree.flatten(like)
        candidates = [step] if step is not None else self.all_steps()[::-1]
        for s in candidates:
            host = self._read(s, shapes=[np.shape(x) for x in leaves],
                              strict=step is not None)
            if host is None:
                continue
            if shardings is not None:
                sh_leaves = treedef.flatten_up_to(shardings)
                out = [jax.device_put(h, d) for h, d in zip(host, sh_leaves)]
            else:
                out = [jax.numpy.asarray(h) for h in host]
            _C_RESTORES.inc()
            return jax.tree.unflatten(treedef, out), s
        raise FileNotFoundError(
            f"no restorable checkpoint in {self.dir} "
            f"(requested step={step}, present={self.all_steps()})")

    def _read(self, step: int, *, shapes, strict: bool):
        path = self._path(step)
        try:
            with np.load(path) as z:
                host = [z[f"leaf_{i}"] for i in range(len(z.files))]
        except Exception as e:
            if strict:
                raise
            warnings.warn(f"skipping unreadable checkpoint {path}: {e!r}")
            return None
        msg = None
        if len(host) != len(shapes):
            msg = (f"checkpoint {path} has {len(host)} leaves, "
                   f"restore target has {len(shapes)}")
        else:
            for i, (h, shp) in enumerate(zip(host, shapes)):
                if tuple(h.shape) != tuple(shp):
                    msg = (f"checkpoint {path} leaf {i} has shape {h.shape}, "
                           f"restore target expects {shp}")
                    break
        if msg is not None:
            if strict:
                raise ValueError(msg)
            warnings.warn("skipping: " + msg)
            return None
        return host
