"""Overlap-friendly collectives for shard_map code.

These are the communication patterns the train/serve steps lean on, written
so compute and communication interleave: instead of one bulk all-gather
followed by one big matmul, the ring variants move one shard per step with
``ppermute`` while the matmul for the shard already on-device runs. XLA's
latency-hiding scheduler can then overlap the permute of step s+1 with the
matmul of step s. All helpers are shard_map-internal (they take the axis
*name*); axis sizes resolve statically via ``psum(1, axis)``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def axis_size(axis_name: str) -> int:
    """Static size of a named mesh axis, usable inside shard_map."""
    return int(jax.lax.psum(1, axis_name))


def ring_all_gather(x, axis_name: str, *, tiled_axis: int = 0):
    """All-gather via a ring of ppermutes (overlappable, bandwidth-optimal).

    Device i contributes its shard; the result concatenates all shards along
    ``tiled_axis`` in axis-index order, replicated on every device.
    """
    n = axis_size(axis_name)
    if n == 1:
        return x
    idx = jax.lax.axis_index(axis_name)
    perm = [(i, (i + 1) % n) for i in range(n)]
    chunk = x.shape[tiled_axis]
    shape = list(x.shape)
    shape[tiled_axis] = chunk * n
    out = jnp.zeros(shape, x.dtype)
    cur = x
    for s in range(n):
        src = (idx - s) % n                       # owner of the shard we hold
        start = [0] * out.ndim
        start[tiled_axis] = src * chunk
        out = jax.lax.dynamic_update_slice(out, cur, tuple(start))
        if s < n - 1:
            cur = jax.lax.ppermute(cur, axis_name, perm)
    return out


def ag_matmul_overlap(x, w, axis_name: str):
    """``x @ all_gather(w)`` with the gather decomposed into a matmul ring.

    ``w`` is column-sharded over ``axis_name`` (spec P(None, axis)); ``x`` is
    replicated. Each ring step multiplies the weight shard currently
    on-device into its column block of the output while the next shard is in
    flight — the all-gather/matmul overlap pattern. Returns the full
    (x.shape[0], w_cols * n) product on every device.
    """
    n = axis_size(axis_name)
    if n == 1:
        return jnp.matmul(x, w)
    idx = jax.lax.axis_index(axis_name)
    perm = [(i, (i + 1) % n) for i in range(n)]
    cols = w.shape[-1]
    dt = jnp.result_type(x.dtype, w.dtype)
    out = jnp.zeros(x.shape[:-1] + (cols * n,), dt)
    w_cur = w
    for s in range(n):
        src = (idx - s) % n
        block = jnp.matmul(x, w_cur).astype(dt)
        start = (0,) * (out.ndim - 1) + (src * cols,)
        out = jax.lax.dynamic_update_slice(out, block, start)
        if s < n - 1:
            w_cur = jax.lax.ppermute(w_cur, axis_name, perm)
    return out


def psum_scatter_mean(x, axis_name: str, *, tiled_axis: int = 0):
    """Mean-reduce then keep only this device's shard (reduce-scatter)."""
    n = axis_size(axis_name)
    y = jax.lax.psum_scatter(x, axis_name, scatter_dimension=tiled_axis,
                             tiled=True)
    return y / n
