"""Failure recovery: the scheduler work Spark does for HAlign-II.

Two pieces:

``BackupShardPlan`` — static replication plan mapping every sequence shard
to ``replication`` hosts (primary first, ring successors after), plus the
reassignment table used when a host dies: each affected shard moves to its
first surviving owner, so recovery is a table lookup, not a reshuffle.

``ResilientLoop`` — the deterministic replay loop around a step function:
checkpoint every ``ckpt_every`` steps, and on ``StepFailure`` (preemption,
injected fault, collective timeout surfaced by the caller) restore the
newest checkpoint and replay forward. Steps are pure functions of
``(state, batch(step))``, so replay reproduces the exact trajectory —
failures cost wall-clock, never correctness.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

from ..obs import metrics as _obs
from .checkpoint import CheckpointManager

_C_STEPS = _obs.counter("repro_resilient_steps_total",
                        "steps completed by ResilientLoop")
_C_FAILURES = _obs.counter("repro_resilient_failures_total",
                           "StepFailures caught by ResilientLoop")
_C_REPLAYS = _obs.counter("repro_resilient_replays_total",
                          "restore-and-replay recoveries")


class StepFailure(RuntimeError):
    """A step failed in a way that warrants checkpoint replay."""


@dataclasses.dataclass(frozen=True)
class BackupShardPlan:
    """shard s lives on hosts (s, s+1, ..., s+replication-1) mod n_hosts.

    ``n_shards`` defaults to one shard per host; pass it explicitly when
    the data is split finer than the host count.
    """
    n_hosts: int
    replication: int
    n_shards: Optional[int] = None

    def __post_init__(self):
        if not 1 <= self.replication <= self.n_hosts:
            raise ValueError(
                f"replication {self.replication} not in [1, {self.n_hosts}]")
        if self.n_shards is None:
            object.__setattr__(self, "n_shards", self.n_hosts)

    def owners(self, shard: int) -> List[int]:
        """Hosts holding ``shard``; owners[0] is the primary."""
        return [(shard + j) % self.n_hosts for j in range(self.replication)]

    @staticmethod
    def _dead_set(dead) -> frozenset:
        """Accept a single host id or any iterable of them (cascades)."""
        if isinstance(dead, int):
            return frozenset((dead,))
        return frozenset(int(h) for h in dead)

    def takeover(self, dead, shard: int) -> Optional[int]:
        """First surviving owner of ``shard`` when ``dead`` fails.

        ``dead`` is one host id or an iterable of them (a cascading
        failure where the backup owners may be dead too); ``None`` means
        every replica of the shard is gone.
        """
        dead = self._dead_set(dead)
        for h in self.owners(shard):
            if h not in dead:
                return h
        return None

    def reassignment(self, dead) -> Dict[int, int]:
        """shard -> takeover host, for every shard the dead hosts held.

        ``dead`` is one host id or an iterable (cascading failures);
        shards whose every replica died are absent from the table — the
        caller must re-ingest those, not look them up.
        """
        dead = self._dead_set(dead)
        out = {}
        for s in range(self.n_shards):
            if dead & set(self.owners(s)):
                t = self.takeover(dead, s)
                if t is not None:
                    out[s] = t
        return out


class ResilientLoop:
    """Checkpointed step loop with deterministic failure replay.

    ``step_fn(state, batch) -> state`` must be pure in its inputs;
    ``batches`` provides ``n_steps`` and ``batches(step) -> batch``.
    ``failure_hook(step)`` (tests, chaos injection) runs before each step
    and may raise ``StepFailure``. ``state_shardings`` (a tree of
    ``jax.sharding.Sharding`` matching the state) is forwarded to every
    restore so replayed/resumed state lands back on the mesh instead of
    unsharded on one device.
    """

    def __init__(self, step_fn: Callable, ckpt: CheckpointManager, *,
                 ckpt_every: int = 100,
                 failure_hook: Optional[Callable[[int], None]] = None,
                 max_failures: Optional[int] = None,
                 state_shardings=None):
        self.step_fn = step_fn
        self.ckpt = ckpt
        self.ckpt_every = ckpt_every
        self.failure_hook = failure_hook
        self.max_failures = max_failures
        self.state_shardings = state_shardings

    def run(self, state, batches, *, resume: bool = False):
        """Run to ``batches.n_steps``; returns ``(state, steps_completed)``."""
        n_steps = int(batches.n_steps)
        step = 0
        if resume and self.ckpt.all_steps():
            state, step = self.ckpt.restore(state,
                                            shardings=self.state_shardings)
        failures = 0
        while step < n_steps:
            if self.ckpt_every and step % self.ckpt_every == 0:
                self.ckpt.save(step, state)
            try:
                if self.failure_hook is not None:
                    self.failure_hook(step)
                state = self.step_fn(state, batches(step))
                step += 1
                _C_STEPS.inc()
            except StepFailure:
                failures += 1
                _C_FAILURES.inc()
                if self.max_failures is not None and failures > self.max_failures:
                    raise
                self.ckpt.wait()        # an async save may be in flight
                if not self.ckpt.all_steps():
                    raise
                state, step = self.ckpt.restore(
                    state, shardings=self.state_shardings)
                _C_REPLAYS.inc()
        if self.ckpt_every and self.ckpt.latest_step() != step:
            self.ckpt.save(step, state)      # final state must be durable
        self.ckpt.wait()
        return state, step
