"""Quantized gradient reduction with error feedback.

The data-parallel psum is the bandwidth bill of distributed training; int8
quantization cuts it 4x vs f32. The residual each step is carried in an
error-feedback buffer and added back before the next quantization, so the
bias of rounding does not accumulate (1-bit-Adam / EF-SGD style — the
compressed mean converges to the true mean over steps).

Protocol per leaf, inside shard_map over the data axis:
  scale = pmax(max|g + ef|) / 127          (one scalar collective)
  q     = round((g + ef) / scale)  int8
  mean  = reduce(q) * scale / n            (see below)
  ef'   = (g + ef) - q * scale             (local residual, no comm)

Wire strategy for the reduce: an int8 all_gather moves (n-1)*S bytes per
device versus ~8S for a ring f32 allreduce, so gathering int8 wins for
axis sizes up to ``_GATHER_MAX`` and we fall back to an int32 psum beyond
that (no bandwidth win at large n without a requantizing ring, which XLA
cannot express; the quantization itself still pays for 4x smaller
*checkpoint/offload* traffic and keeps the error-feedback contract).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_QMAX = 127.0
_GATHER_MAX = 8      # largest axis where int8 all_gather beats f32 allreduce


def init_ef(tree):
    """Zero error-feedback buffers matching a gradient tree (f32)."""
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), tree)


def compressed_psum_mean(g, axis_name: str, ef):
    """One leaf: int8-quantized psum-mean. Returns (mean, new_ef)."""
    v = g.astype(jnp.float32) + ef
    scale = jax.lax.pmax(jnp.max(jnp.abs(v)), axis_name) / _QMAX
    scale = jnp.maximum(scale, jnp.float32(1e-30))
    q = jnp.clip(jnp.round(v / scale), -_QMAX, _QMAX).astype(jnp.int8)
    n = jax.lax.psum(1, axis_name)
    if n <= _GATHER_MAX:
        # int8 stays int8 on the wire; accumulate locally in int32
        gathered = jax.lax.all_gather(q, axis_name)
        total = jnp.sum(gathered.astype(jnp.int32), axis=0)
    else:
        total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    mean = total.astype(jnp.float32) * (scale / n)
    new_ef = v - q.astype(jnp.float32) * scale
    return mean.astype(g.dtype), new_ef


def tree_compressed_psum_mean(grads, axis_name: str, ef):
    """Whole-tree compressed psum-mean. Returns (mean_tree, new_ef_tree)."""
    leaves, treedef = jax.tree.flatten(grads)
    ef_leaves = treedef.flatten_up_to(ef)
    pairs = [compressed_psum_mean(g, axis_name, e)
             for g, e in zip(leaves, ef_leaves)]
    return (jax.tree.unflatten(treedef, [m for m, _ in pairs]),
            jax.tree.unflatten(treedef, [e for _, e in pairs]))
