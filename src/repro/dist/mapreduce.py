"""Shard-mapped center-star MSA: the paper's Fig. 3 pipeline on a mesh.

Spark terms -> mesh terms:

  RDD of sequence shards     leading-dim sharding over the 'data' axis
  broadcast(center, index)   replicated operands (PartitionSpec())
  map(1)  align-to-center    jitted ``core.msa.kmer_align_batch`` /
                             a ``repro.align`` backend primitive per shard
                             (jnp scan, Pallas SW kernel, or banded DP —
                             jnp or native Pallas)
  reduce(1) merge profiles   local columnwise max, then one ``pmax``
  map(2)  re-emit rows       ``core.centerstar.build_rows`` per shard

``distributed_center_star`` builds the whole pipeline as ONE jitted
function so XLA fuses the stages and the only cross-device traffic is the
(num_slots,) int32 profile pmax — the paper's observation that center-star
reduces to an embarrassingly parallel map plus a tiny reduction.

Shard-count bookkeeping: shard_map needs the sequence count to divide the
data-axis size; ``pad_rows`` adds empty-query rows (length 0) that align to
all-gap rows and contribute nothing to the merged profile, and
``unpad_rows`` drops them again.

Consumers: ``launch/msa_run --dist`` (batch CLI), ``repro.serve`` (the
web service routes requests of >= ``dist_threshold`` sequences through
``msa_over_mesh`` and shard-maps ``/tree`` distance strips through
``distance_strip_over_mesh`` / ``nearest_anchor_over_mesh`` on the same
mesh), ``repro.phylo.ml`` (ML bootstrap replicates fan out through
``bootstrap_over_mesh``), ``repro.phylo.treesearch`` (the K-start
NNI+SPR fleet scores its candidate block through
``treesearch_over_mesh``), and ``launch/dryrun`` (512-device
lower+compile sweeps).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from ..align import AlignEngine
from ..align.engine import _pad_cols
from ..core import centerstar
from ..core import msa as msa_mod
from ..obs import metrics as _obs
from ..obs import trace as _trace
from . import sharding as sh

_C_MAP_CALLS = _obs.counter("repro_dist_map_calls_total",
                            "host-side mesh pipeline invocations", ("stage",))


def pad_rows(x, multiple_of: int, fill=0):
    """Pad the leading dim up to a multiple of ``multiple_of``.

    Returns (padded, original_n). For query batches pass ``fill=0`` (a valid
    alphabet code) and pad the matching ``lens`` with 0 so padded rows align
    as empty queries.
    """
    import numpy as np
    x = np.asarray(x)
    n = x.shape[0]
    rem = (-n) % multiple_of
    if rem == 0:
        return x, n
    pad = np.full((rem,) + x.shape[1:], fill, x.dtype)
    return np.concatenate([x, pad], axis=0), n


def unpad_rows(x, n: int):
    """Drop the rows ``pad_rows`` added."""
    return x[:n]


def _chunked(f, n_chunks: int, *arrs):
    """Run ``f`` over ``n_chunks`` sequential slices of the leading dim.

    Bounds per-device temp memory (the DP direction matrices live only for
    one chunk); the chunk loop is a lax.map so it stays inside jit.
    """
    if n_chunks <= 1:
        return f(*arrs)
    resh = tuple(a.reshape((n_chunks, a.shape[0] // n_chunks) + a.shape[1:])
                 for a in arrs)
    out = jax.lax.map(lambda xs: f(*xs), resh)
    return jax.tree.map(lambda o: o.reshape((-1,) + o.shape[2:]), out)


def distributed_center_star(mesh: Mesh, *, method: str, sub, gap_code: int,
                            out_len: int, num_slots: int, gap_open: int,
                            gap_extend: int, k: int = 11, stride: int = 1,
                            max_anchors: int = 256, max_seg: int = 64,
                            map_chunks: int = 1, data_axis: str = "data",
                            fallback: str = "dp", local: bool = False,
                            backend: str = "auto", band: int = 64):
    """Build the jitted distributed pipeline for one problem geometry.

    Returns ``fn(Q, lens, center, lc, table)`` (``table`` only for
    ``method='kmer'``) -> ``(rows, G)`` where ``rows`` is (N, out_len) int8
    sharded over ``data_axis`` and ``G`` the merged (num_slots,) insert
    profile, replicated. Inputs are placed with ``sharding.shard_rows`` /
    ``sharding.broadcast``; N must divide the data-axis size (``pad_rows``).

    ``backend`` picks the map(1) DP primitive from the ``repro.align``
    registry (jnp scan / Pallas SW kernel / banded O(n·band) DP as a jnp
    scan or the native ``banded-pallas`` wavefront kernel). The banded
    backends accept their result in-graph without the host driver's
    per-pair overflow fallback — re-aligning in-graph would materialize
    the full direction matrix for every pair, exactly what banding is
    there to avoid; size the band for the workload instead.

    ``fallback='dp'`` re-aligns pairs whose k-mer chaining failed with the
    full Gotoh DP in-graph (matches the host driver exactly);
    ``fallback='none'`` skips that second pass — the right trade at the
    ultra-large benchmark sizes where chain failures are rare and the DP
    lowering dominates compile time.
    """
    if method not in ("kmer", "plain", "sw"):
        raise ValueError(f"unknown method {method!r}")
    sub = jnp.asarray(sub, jnp.float32)
    engine = AlignEngine(sub, gap_open=gap_open, gap_extend=gap_extend,
                         gap_code=gap_code, backend=backend, band=band,
                         local=local, bucket=False)

    def _map1_dp(Q, lens, center, lc, *, dp_local=local):
        res = engine.batch_fn(local=dp_local)(Q, lens, center, lc)
        return res.a_row, res.b_row

    def _map1_kmer(Q, lens, center, lc, table):
        a_rows, b_rows, ok = msa_mod.kmer_align_batch(
            Q, lens, center, lc, table, sub, k=k, stride=stride,
            max_anchors=max_anchors, max_seg=max_seg, gap_open=gap_open,
            gap_extend=gap_extend, gap_code=gap_code)
        if fallback == "dp":
            # the kmer assembly is global; its fallback must be too
            da, db = _map1_dp(Q, lens, center, lc, dp_local=False)
            width = max(a_rows.shape[-1], da.shape[-1])
            a_rows = jnp.where(ok[:, None], _pad_cols(a_rows, width, gap_code),
                               _pad_cols(da, width, gap_code))
            b_rows = jnp.where(ok[:, None], _pad_cols(b_rows, width, gap_code),
                               _pad_cols(db, width, gap_code))
        return a_rows, b_rows

    def _shard_fn(*operands):
        if method == "kmer":
            Q, lens, center, lc, table = operands
            a_rows, b_rows = _chunked(
                lambda q, l: _map1_kmer(q, l, center, lc, table),
                map_chunks, Q, lens)
        else:
            Q, lens, center, lc = operands
            a_rows, b_rows = _chunked(
                lambda q, l: _map1_dp(q, l, center, lc), map_chunks, Q, lens)
        g = centerstar.gap_profiles(a_rows, b_rows, gap_code=gap_code,
                                    num_slots=num_slots)
        G = jax.lax.pmax(jnp.max(g, axis=0), data_axis)          # reduce(1)
        rows = _chunked(
            lambda a, b: centerstar.build_rows(a, b, G, gap_code=gap_code,
                                               out_len=out_len),
            map_chunks, a_rows, b_rows)
        return rows, G

    row2 = P(data_axis, None)
    row1 = P(data_axis)
    if method == "kmer":
        in_specs = (row2, row1, P(), P(), P())
    else:
        in_specs = (row2, row1, P(), P())
    fn = sh.shard_map(_shard_fn, mesh, in_specs=in_specs,
                      out_specs=(row2, P()), check_vma=False)
    return jax.jit(fn)


def distance_strip_over_mesh(mesh: Mesh, *, gap_code: int, n_chars: int,
                             correct: bool = True, data_axis: str = "data"):
    """Tree-stage hook: jitted ``fn(rows_blk, S) -> (rb, N)`` distance strip.

    The phylogeny analogue of the MSA map stage: ``S`` is the full aligned
    row set sharded over ``data_axis`` (place once with
    ``sharding.shard_rows``; pad with ``pad_rows`` first), ``rows_blk`` a
    replicated (row_block, L) block. Each device computes
    ``cross_distance(rows_blk, its shard)`` — a row-block x column-block
    tile — and the strip comes back concatenated over the column dim
    (out spec ``P(None, data_axis)``). ``repro.phylo.tiles.TileContext``
    streams these strips so no host holds more than one.
    """
    from ..core import distance as dist_mod

    def _strip(blk, S):
        return dist_mod.cross_distance(blk, S, gap_code=gap_code,
                                       n_chars=n_chars, correct=correct)

    fn = sh.shard_map(_strip, mesh, in_specs=(P(), P(data_axis, None)),
                      out_specs=P(None, data_axis), check_vma=False)
    return jax.jit(fn)


def nearest_anchor_over_mesh(mesh: Mesh, *, gap_code: int, n_chars: int,
                             correct: bool = True, data_axis: str = "data"):
    """Tree-stage hook: jitted ``fn(S, anchors) -> (N, k)`` distances.

    The assignment stage of the tiled HPTree pipeline: ``S`` is the full
    row set sharded over ``data_axis``, ``anchors`` the k medoid rows
    replicated — each device computes its rows' distances to every medoid
    (the transpose of ``distance_strip_over_mesh``'s tiling, chosen
    because k << N so sharding the long axis is the one that balances).
    """
    from ..core import distance as dist_mod

    def _nearest(S, A):
        return dist_mod.cross_distance(S, A, gap_code=gap_code,
                                       n_chars=n_chars, correct=correct)

    fn = sh.shard_map(_nearest, mesh, in_specs=(P(data_axis, None), P()),
                      out_specs=P(data_axis, None), check_vma=False)
    return jax.jit(fn)


def bootstrap_over_mesh(mesh: Mesh, *, gap_code: int, n_chars: int,
                        correct: bool = True, data_axis: str = "data"):
    """Tree-stage hook: shard ML bootstrap replicates over the mesh.

    Returns jitted ``fn(patterns, W) -> (children (B, 2N-1, 2), blen)``.
    ``W`` is the (B, P) replicate site-weight matrix sharded over
    ``data_axis`` (pad B with ``pad_rows`` first — all-zero padding rows
    produce saturated-distance throwaway trees that ``unpad_rows``
    drops); ``patterns`` is the compressed site-pattern matrix,
    replicated. Each device runs weighted-distance + vmapped NJ for its
    replicates (``repro.phylo.ml.replicate_trees``) — embarrassingly
    parallel, and per-replicate math is independent of the partitioning,
    so a fixed seed is bit-reproducible across mesh shapes.
    """
    from ..phylo import ml as ml_mod

    def _rep(patterns, W):
        return ml_mod.replicate_trees(patterns, W, gap_code=gap_code,
                                      n_chars=n_chars, correct=correct)

    fn = sh.shard_map(_rep, mesh, in_specs=(P(), P(data_axis, None)),
                      out_specs=(P(data_axis, None, None),
                                 P(data_axis, None, None)),
                      check_vma=False)
    return jax.jit(fn)


def treesearch_over_mesh(mesh: Mesh, *, model: str, site_chunk: int = 2048,
                         data_axis: str = "data"):
    """Tree-stage hook: shard K-start tree-search candidate scoring.

    Returns jitted ``fn(patterns, weights, children_k, blen_k, order_k,
    params_k) -> (K, C) logL``. The per-search candidate blocks
    (``(K, C, 2N-1, 2)`` children/blen, ``(K, C, N-1)`` orders) and the
    per-search model parameters shard over ``data_axis`` (pad K with
    ``pad_rows`` first — all-zero padding rows score garbage trees that
    ``unpad_rows`` drops); the compressed site patterns and weights are
    replicated. Each device runs ``repro.phylo.treesearch.score_fleet``
    for its searches — per-(search, candidate) math is independent of
    the partitioning, so a fixed seed is bit-reproducible across mesh
    shapes (the same invariant ``bootstrap_over_mesh`` holds).
    """
    from ..phylo import treesearch as ts_mod

    def _score(patterns, weights, ch_k, bl_k, od_k, pr_k):
        return ts_mod.score_fleet(patterns, weights, ch_k, bl_k, od_k, pr_k,
                                  model=model, site_chunk=site_chunk)

    fn = sh.shard_map(_score, mesh,
                      in_specs=(P(), P(),
                                P(data_axis, None, None, None),
                                P(data_axis, None, None, None),
                                P(data_axis, None, None),
                                P(data_axis, None)),
                      out_specs=P(data_axis, None), check_vma=False)
    return jax.jit(fn)


def search_over_mesh(mesh: Mesh, *, k: int, stride: int = 1,
                     max_anchors: int = 32, max_seg: int = 1 << 20,
                     data_axis: str = "data"):
    """Search-stage hook: jitted seeding prefilter over a sharded DB.

    Returns ``fn(Q, qlens, dblens, tables) -> (B, D) anchor counts``.
    The per-sequence k-mer tables (not the rows — seeding only probes
    tables) are sharded over ``data_axis`` (place with
    ``sharding.shard_rows``; pad D with ``pad_rows`` first), the query
    batch is replicated — each device chains anchors for every
    (query, local DB row) pair and the count matrix comes back
    concatenated over the DB dim (out spec ``P(None, data_axis)``). Counts are per-pair integers independent of
    the partitioning, so results are bit-identical across mesh shapes —
    the invariant ``repro.search`` builds its mesh/host equivalence on.
    The candidate *rescoring* stays a host concern: the surviving pair
    set re-enters ``AlignEngine.align_pairs`` (pow2-bucketed), identical
    on every mesh because the surviving set is.
    """
    from ..search.engine import seed_counts_batch

    def _seed(Q, qlens, dblens, tables):
        return seed_counts_batch(Q, qlens, dblens, tables, k=k,
                                 stride=stride, max_anchors=max_anchors,
                                 max_seg=max_seg)

    fn = sh.shard_map(_seed, mesh,
                      in_specs=(P(), P(), P(data_axis),
                                P(data_axis, None, None)),
                      out_specs=P(None, data_axis), check_vma=False)
    return jax.jit(fn)


def center_row(center, lc, G, *, gap_code: int, out_len: int):
    """The broadcast center's own row in the merged frame (host-side wrap)."""
    return centerstar.center_msa_row(center, lc, G, gap_code=gap_code,
                                     out_len=out_len)


def msa_over_mesh(seqs, cfg, mesh: Mesh, *, data_axis: str = "data",
                  map_chunks: int = 1, out_pad: int = 64):
    """Host driver: ``core.msa.center_star_msa`` semantics over a mesh.

    Handles everything the jitted pipeline cannot: center selection,
    padding the query count to the shard count, placing operands
    (``shard_rows``/``broadcast``), appending the center's own row, and
    trimming to the realized width. ``cfg`` is a ``core.msa.MSAConfig``.
    Returns a ``core.msa.MSAResult`` (``n_fallback=-1``: per-pair fallback
    counts are not tracked across shards).
    """
    import numpy as np

    from ..core import kmer_index

    alpha = cfg.alpha()
    gap = alpha.gap_code
    S, lens = msa_mod.encode_for_msa(seqs, cfg)
    N, Lmax = S.shape
    if N < 2:
        return msa_mod.MSAResult(np.asarray(S), 0, 0, Lmax, "first")
    with _trace.span("center", n=int(N), mode=cfg.center, dist=True):
        cidx, center_mode = msa_mod._select_center(S, lens, cfg)
    center, lc = S[cidx], lens[cidx]
    others = np.array([i for i in range(N) if i != cidx])
    n_shards = sh.axis_size(mesh, data_axis)
    # per-shard row count must also divide map_chunks for _chunked's reshape
    Q, n_q = pad_rows(np.asarray(S)[others], n_shards * map_chunks)
    qlens, _ = pad_rows(np.asarray(lens)[others], n_shards * map_chunks)

    out_len = 2 * Lmax + out_pad
    num_slots = int(center.shape[0]) + 1
    _C_MAP_CALLS.labels(stage="msa").inc()
    with _trace.span("map1", n=int(N) - 1, method=cfg.method,
                     backend=cfg.backend, dist=True, n_shards=n_shards,
                     shard_rows=Q.shape[0] // n_shards,
                     map_chunks=map_chunks) as sp:
        fn = distributed_center_star(
            mesh, method=cfg.method, sub=cfg.matrix(), gap_code=gap,
            out_len=out_len, num_slots=num_slots, gap_open=cfg.gap_open,
            gap_extend=cfg.gap_extend, k=cfg.k, stride=cfg.stride,
            max_anchors=cfg.max_anchors, max_seg=cfg.max_seg,
            map_chunks=map_chunks, data_axis=data_axis, local=cfg.local,
            backend=cfg.backend, band=cfg.band)
        operands = [sh.shard_rows(Q, mesh, data_axis),
                    sh.shard_rows(qlens, mesh, data_axis),
                    sh.broadcast(center, mesh), jnp.int32(lc)]
        if cfg.method == "kmer":
            operands.append(sh.broadcast(
                kmer_index.build_center_index(center, lc, k=cfg.k), mesh))
        rows, G = fn(*operands)
        if sp is not None:
            jax.block_until_ready((rows, G))

    with _trace.span("assemble", n=int(N), dist=True):
        width = centerstar.msa_width(G, int(lc))
        if width > out_len:
            raise ValueError(
                f"merged width {width} exceeds out_len {out_len}; rerun "
                f"with a larger out_pad (sequences too diverged for 2*Lmax)")
        crow = center_row(center, lc, G, gap_code=gap, out_len=out_len)
        msa = np.full((N, out_len), gap, np.int8)
        msa[others] = unpad_rows(np.asarray(rows), n_q)
        msa[cidx] = np.asarray(crow)
    return msa_mod.MSAResult(msa[:, :width], int(cidx), -1, width,
                             center_mode)
