"""Named-axis sharding helpers shared by the planner, mapreduce, and tests.

Also home of the repo's single ``shard_map`` import: jax moved shard_map
from ``jax.experimental.shard_map`` (kwarg ``check_rep``) to ``jax.shard_map``
(kwarg ``check_vma``); the wrapper below accepts either keyword and forwards
to whichever implementation the installed jax provides. Import it from here
(or ``repro.dist``) instead of from jax directly.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:                                      # jax >= 0.6 style
    from jax import shard_map as _shard_map
    _NEW_API = True
except ImportError:                       # jax 0.4/0.5 style
    from jax.experimental.shard_map import shard_map as _shard_map
    _NEW_API = False

Axes = Union[str, Tuple[str, ...], None]


def shard_map(f, mesh, in_specs, out_specs, **kwargs):
    """Version-portable shard_map. ``check_vma``/``check_rep`` both accepted."""
    check = kwargs.pop("check_vma", kwargs.pop("check_rep", None))
    if check is not None:
        kwargs["check_vma" if _NEW_API else "check_rep"] = check
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kwargs)


def axis_size(mesh: Mesh, axes: Axes) -> int:
    """Product of the mesh extents of ``axes`` (str, tuple, or None)."""
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def maybe(mesh: Mesh, dim: int, axes: Axes) -> Axes:
    """``axes`` if ``dim`` divides over them, else None (replicate)."""
    if axes is None or (not isinstance(axes, str) and len(axes) == 0):
        return None
    return axes if dim % axis_size(mesh, axes) == 0 else None


def first_fit(mesh: Mesh, dim: int, *candidates: Axes) -> Axes:
    """First candidate axis (group) that divides ``dim``; None replicates.

    ``first_fit(mesh, d, "model", ("pod", "data"), None)`` expresses the
    planner's preference order in one call.
    """
    for cand in candidates:
        if cand is None:
            return None
        if dim % axis_size(mesh, cand) == 0:
            return cand
    return None


def row_spec(ndim: int, axis: Axes = "data") -> P:
    """PartitionSpec sharding only the leading dim over ``axis``."""
    return P(axis, *([None] * (ndim - 1)))


def shard_rows(x, mesh: Mesh, axis: Axes = "data"):
    """Place ``x`` with its leading dim sharded over ``axis``.

    The leading extent must divide the axis size — pad first with
    ``mapreduce.pad_rows`` when it does not.
    """
    x = jax.numpy.asarray(x)
    n = axis_size(mesh, axis)
    if x.shape[0] % n != 0:
        raise ValueError(
            f"leading dim {x.shape[0]} does not divide axis {axis!r} "
            f"(size {n}); pad with repro.dist.mapreduce.pad_rows first")
    return jax.device_put(x, NamedSharding(mesh, row_spec(x.ndim, axis)))


def broadcast(x, mesh: Mesh):
    """Replicate ``x`` on every device of the mesh (Spark's broadcast var)."""
    return jax.device_put(jax.numpy.asarray(x), NamedSharding(mesh, P()))
