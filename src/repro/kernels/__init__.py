"""Pallas kernels (SW/Gotoh, distance, flash attention) + shared helpers."""
from __future__ import annotations

import jax


def default_interpret(platform: str | None = None) -> bool:
    """Platform-aware default for ``pallas_call(interpret=...)``.

    The kernels in this package target the TPU backend; everywhere else
    (CPU CI, local dev) they run under the Pallas interpreter. Callers that
    pass ``interpret=None`` get this resolution; an explicit bool always
    wins (e.g. to force interpret-mode debugging on TPU).
    """
    p = platform or jax.default_backend()
    return p != "tpu"


from . import sw, distance, flash_attention  # noqa: E402,F401
