"""Pallas kernels (SW/Gotoh, banded Gotoh, distance, flash attention) +
shared helpers (`default_interpret`, `kernel_call`)."""
from __future__ import annotations

import jax
from jax.experimental import pallas as pl


def default_interpret(platform: str | None = None) -> bool:
    """Platform-aware default for ``pallas_call(interpret=...)``.

    The kernels in this package target the TPU backend; everywhere else
    (CPU CI, local dev) they run under the Pallas interpreter. Callers that
    pass ``interpret=None`` get this resolution; an explicit bool always
    wins (e.g. to force interpret-mode debugging on TPU).
    """
    p = platform or jax.default_backend()
    return p != "tpu"


def kernel_call(kernel_fn, *, interpret: bool | None = None, **pallas_kwargs):
    """``pl.pallas_call`` with the package's interpret resolution built in.

    Every ops-layer wrapper used to re-implement the same dance
    (``default_interpret() if interpret is None else interpret``); this is
    the one shared spelling. All other kwargs pass through to
    ``pl.pallas_call`` untouched, and the return value is the usual
    callable to apply to the kernel operands.
    """
    if interpret is None:
        interpret = default_interpret()
    return pl.pallas_call(kernel_fn, interpret=interpret, **pallas_kwargs)


from . import sw, banded, distance, flash_attention  # noqa: E402,F401
