from . import sw, distance, flash_attention  # noqa: F401
