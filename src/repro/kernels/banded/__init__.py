"""Banded Gotoh as native Pallas kernels: VMEM-resident (n, W) band,
anti-diagonal wavefront rows, in-kernel overflow flags, and a fused
score+traceback path for coalesced pairs. ``ref`` holds the pure shared
recurrence that keeps these bit-identical to the jnp scan in
``align.banded``."""
from __future__ import annotations

from . import ref  # noqa: F401
from .ops import banded_forward_pallas, banded_pairs_fused  # noqa: F401
