"""Pallas TPU kernels: banded Gotoh forward + fused score-and-traceback.

Two kernels over the same width-W band recurrence (``ref.band_row_update``
— the function the jnp scan in ``align.banded`` also calls, which is what
makes parity bit-identical rather than approximate):

``_fwd_kernel`` — batch path. grid = (batch, row_blocks); the three band
state vectors (M/Ix/Iy, each (W,) f32) live in VMEM scratch persisting
across the sequential row-block dimension, rows advance as an
anti-diagonal wavefront (all W band cells of a row are elementwise or
cummax work on the VPU lanes), and HBM traffic per DP row is one (W,)
int8 direction slab — O(n·W) instead of the SW kernel's O(n·m). The
edge-pressure overflow detector runs in-kernel on the same row state, so
the ``AlignEngine`` fallback contract needs no extra pass.

``_fused_kernel`` — coalesced ``align_pairs`` path. grid = (batch,); one
program owns a whole pair: the forward loop writes direction bytes into a
(n, W) int8 VMEM scratch, then the traceback walks that scratch in the
same program. The direction matrix never exists in HBM at all — per pair
the kernel moves only the sequences in and (score row, two gapped rows)
out, which is the strictly-fewer-HBM-bytes claim BENCH_kernels checks.

TPU layout notes: W is a pow2 (band plans clamp to pow2; 128-lane tiles
want W >= 128 for full lane use, smaller W still vectorizes via sublane
packing); the band state is 3·W·4 B + (8,) stats, and the fused scratch
adds n·W int8 — at n = 4096, W = 64 that is ~256 KiB, inside one core's
VMEM. Under ``interpret=True`` (CPU CI) the same kernels run on the
Pallas interpreter; scalar gathers and dynamic stores are exact there,
just not fast — see docs/KERNELS.md for the caveats.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .. import kernel_call
from ...core.pairwise import NEG
from .ref import (band_lo, band_row_init, band_row_update, edge_pressure,
                  trace_step_math)

# stat scratch slots (f32): end-cell capture per Gotoh state, overflow
# flag, and the previous live row's best score for edge pressure.
_CAP_M, _CAP_IX, _CAP_IY, _EDGE, _HB = 0, 1, 2, 3, 4


def _fwd_kernel(a_ref, b_ref, lens_ref, sub_ref, dirs_ref, out_ref,
                mp, xp, yp, stat, *, band: int, block_rows: int,
                gap_open: float, gap_extend: float):
    W = band
    mid = W // 2
    rb = pl.program_id(1)
    n_rb = pl.num_programs(1)
    la = lens_ref[0, 0]
    lb = lens_ref[0, 1]
    b_row = b_ref[0, :]
    sub = sub_ref[:]
    go = jnp.float32(gap_open)
    ge = jnp.float32(gap_extend)
    margin = jnp.max(sub)

    @pl.when(rb == 0)
    def _init():
        m0, ix0, iy0, cap0, hb0 = band_row_init(la, lb, go, ge, band=W)
        mp[:] = m0
        xp[:] = ix0
        yp[:] = iy0
        stat[_CAP_M] = cap0[0]
        stat[_CAP_IX] = cap0[1]
        stat[_CAP_IY] = cap0[2]
        stat[_EDGE] = 0.0
        stat[_HB] = hb0
        stat[5:] = jnp.zeros((3,), jnp.float32)

    def row(l, _):
        r = rb * block_rows + l + 1          # DP row index (1-based)
        a_i = a_ref[0, l]
        lo_prev = band_lo(r - 1, la, lb, W)
        lo_i = band_lo(r, la, lb, W)
        m_new, ix_new, iy_new, dirs, h_new, h_prev, s = band_row_update(
            mp[:], xp[:], yp[:], a_i, b_row, lo_prev, lo_i, sub, go, ge, lb)
        dirs_ref[0, l, :] = dirs
        # State advances unconditionally (the jnp scan does the same);
        # rows past la only touch the dead padding tail.
        mp[:] = m_new
        xp[:] = ix_new
        yp[:] = iy_new

        hit = r == la                        # end cell (la, lb) sits at mid
        stat[_CAP_M] = jnp.where(hit, m_new[mid], stat[_CAP_M])
        stat[_CAP_IX] = jnp.where(hit, ix_new[mid], stat[_CAP_IX])
        stat[_CAP_IY] = jnp.where(hit, iy_new[mid], stat[_CAP_IY])

        live = r <= la
        comp, hb = edge_pressure(h_new, h_prev, stat[_HB], s, margin)
        stat[_EDGE] = jnp.where(live & comp, 1.0, stat[_EDGE])
        stat[_HB] = jnp.where(live, hb, stat[_HB])
        return 0

    jax.lax.fori_loop(0, block_rows, row, 0)

    @pl.when(rb == n_rb - 1)
    def _fin():
        ends = jnp.stack([stat[_CAP_M], stat[_CAP_IX], stat[_CAP_IY]])
        st = jnp.argmax(ends)
        out_ref[0, 0] = ends[st]
        out_ref[0, 1] = la.astype(jnp.float32)
        out_ref[0, 2] = lb.astype(jnp.float32)
        out_ref[0, 3] = st.astype(jnp.float32)
        out_ref[0, 4] = stat[_EDGE]
        out_ref[0, 5:] = jnp.zeros((3,), jnp.float32)


def banded_forward_kernel(a, b, lens, sub, *, gap_open: float,
                          gap_extend: float, band: int,
                          block_rows: int = 128,
                          interpret: bool | None = None):
    """a: (B, n) int8 (n % block_rows == 0), b: (B, m), lens: (B, 2) i32.

    Returns dirs (B, n, band) int8 (DP rows 1..n) and out (B, 8) f32
    [score, la, lb, start_state, edge, 0*3].
    """
    B, n = a.shape
    m = b.shape[1]
    assert n % block_rows == 0, (n, block_rows)
    grid = (B, n // block_rows)
    kern = functools.partial(_fwd_kernel, band=band, block_rows=block_rows,
                             gap_open=gap_open, gap_extend=gap_extend)
    return kernel_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_rows), lambda b_, r: (b_, r)),
            pl.BlockSpec((1, m), lambda b_, r: (b_, 0)),
            pl.BlockSpec((1, 2), lambda b_, r: (b_, 0)),
            pl.BlockSpec(sub.shape, lambda b_, r: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_rows, band), lambda b_, r: (b_, r, 0)),
            pl.BlockSpec((1, 8), lambda b_, r: (b_, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, n, band), jnp.int8),
            jax.ShapeDtypeStruct((B, 8), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((band,), jnp.float32),
            pltpu.VMEM((band,), jnp.float32),
            pltpu.VMEM((band,), jnp.float32),
            pltpu.VMEM((8,), jnp.float32),
        ],
        interpret=interpret,
    )(a, b, lens, sub)


def _fused_kernel(a_ref, b_ref, lens_ref, sub_ref, out_ref, ar_ref, br_ref,
                  dirs_s, *, band: int, gap_open: float, gap_extend: float,
                  gap_code: int):
    W = band
    mid = W // 2
    n = a_ref.shape[1]
    m = b_ref.shape[1]
    out_len = n + m
    la = lens_ref[0, 0]
    lb = lens_ref[0, 1]
    b_row = b_ref[0, :]
    sub = sub_ref[:]
    go = jnp.float32(gap_open)
    ge = jnp.float32(gap_extend)
    margin = jnp.max(sub)

    # ---- forward: band state as loop carry, dirs into VMEM scratch ----
    m0, ix0, iy0, cap0, hb0 = band_row_init(la, lb, go, ge, band=W)

    def fwd_row(l, carry):
        m_prev, ix_prev, iy_prev, cap, edge, hb_prev = carry
        r = l + 1
        a_i = a_ref[0, l]
        lo_prev = band_lo(r - 1, la, lb, W)
        lo_i = band_lo(r, la, lb, W)
        m_new, ix_new, iy_new, dirs, h_new, h_prev, s = band_row_update(
            m_prev, ix_prev, iy_prev, a_i, b_row, lo_prev, lo_i, sub,
            go, ge, lb)
        pl.store(dirs_s, (pl.dslice(l, 1), slice(None)), dirs[None, :])
        hit = r == la
        cap = jnp.where(hit, jnp.stack([m_new[mid], ix_new[mid],
                                        iy_new[mid]]), cap)
        live = r <= la
        comp, hb = edge_pressure(h_new, h_prev, hb_prev, s, margin)
        edge = edge | (live & comp)
        hb_prev = jnp.where(live, hb, hb_prev)
        return (m_new, ix_new, iy_new, cap, edge, hb_prev)

    (_, _, _, cap, edge_fwd, _) = jax.lax.fori_loop(
        0, n, fwd_row, (m0, ix0, iy0, cap0, jnp.bool_(False), hb0))
    st0 = jnp.argmax(cap).astype(jnp.int32)
    score = cap[st0]

    # ---- traceback: walk the VMEM band, never touching HBM dirs ----
    dirf = dirs_s[:].reshape(-1)

    def tb_step(t, carry):
        i, j, st, done, edge, oob, out_a, out_b, k = carry
        lo_i = band_lo(i, la, lb, W)
        o = j - lo_i
        byte_band = dirf[jnp.clip((i - 1) * W + o, 0, n * W - 1)].astype(
            jnp.int32)
        a_im1 = a_ref[0, jnp.maximum(i - 1, 0)]
        b_jm1 = b_ref[0, jnp.maximum(j - 1, 0)]
        ni, nj, nst, done, ndone, lost, edge_hit, ca, cb = trace_step_math(
            i, j, o, st, done, byte_band, a_im1, b_jm1, lb, gap_code, W)
        oob = oob | lost
        edge = edge | edge_hit
        out_a = out_a.at[k].set(jnp.where(done, out_a[k], ca))
        out_b = out_b.at[k].set(jnp.where(done, out_b[k], cb))
        k = jnp.where(done, k, k + 1)
        i = jnp.where(done, i, ni)
        j = jnp.where(done, j, nj)
        st = jnp.where(done, st, nst)
        return (i, j, st, ndone, edge, oob, out_a, out_b, k)

    out_a = jnp.full((out_len,), gap_code, jnp.int8)
    out_b = jnp.full((out_len,), gap_code, jnp.int8)
    init = (la, lb, st0, (la == 0) & (lb == 0),
            jnp.bool_(False), jnp.bool_(False), out_a, out_b, jnp.int32(0))
    (_, _, _, _, edge, oob, out_a, out_b, k) = jax.lax.fori_loop(
        0, out_len, tb_step, init)

    ok = (~edge) & (~oob) & (~edge_fwd) & (score > NEG / 2)
    ar_ref[0, :] = jnp.roll(jnp.flip(out_a), k - out_len)
    br_ref[0, :] = jnp.roll(jnp.flip(out_b), k - out_len)
    out_ref[0, 0] = score
    out_ref[0, 1] = la.astype(jnp.float32)
    out_ref[0, 2] = lb.astype(jnp.float32)
    out_ref[0, 3] = st0.astype(jnp.float32)
    out_ref[0, 4] = k.astype(jnp.float32)
    out_ref[0, 5] = ok.astype(jnp.float32)
    out_ref[0, 6] = edge_fwd.astype(jnp.float32)
    out_ref[0, 7] = 0.0


def banded_fused_kernel(a, b, lens, sub, *, gap_open: float,
                        gap_extend: float, band: int, gap_code: int = 5,
                        interpret: bool | None = None):
    """Fused banded score+traceback. a: (B, n) int8, b: (B, m), lens (B, 2).

    Returns out (B, 8) f32 [score, la, lb, st, aln_len, ok, edge, 0] and
    a_row/b_row (B, n+m) int8 — no direction matrix ever reaches HBM.
    """
    B, n = a.shape
    m = b.shape[1]
    kern = functools.partial(_fused_kernel, band=band, gap_open=gap_open,
                             gap_extend=gap_extend, gap_code=gap_code)
    return kernel_call(
        kern,
        grid=(B,),
        in_specs=[
            pl.BlockSpec((1, n), lambda b_: (b_, 0)),
            pl.BlockSpec((1, m), lambda b_: (b_, 0)),
            pl.BlockSpec((1, 2), lambda b_: (b_, 0)),
            pl.BlockSpec(sub.shape, lambda b_: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 8), lambda b_: (b_, 0)),
            pl.BlockSpec((1, n + m), lambda b_: (b_, 0)),
            pl.BlockSpec((1, n + m), lambda b_: (b_, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, 8), jnp.float32),
            jax.ShapeDtypeStruct((B, n + m), jnp.int8),
            jax.ShapeDtypeStruct((B, n + m), jnp.int8),
        ],
        scratch_shapes=[
            pltpu.VMEM((n, band), jnp.int8),
        ],
        interpret=interpret,
    )(a, b, lens, sub)
