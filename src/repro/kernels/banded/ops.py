"""jit'd public wrappers for the banded Gotoh Pallas kernels.

``banded_forward_pallas`` pads the query axis to the row-block size and
returns a batched ``BandedForward`` — drop-in for vmapped
``align.banded.banded_forward`` (the jnp traceback then consumes the HBM
dirs exactly as before). ``banded_pairs_fused`` is the whole map(1) in
one kernel: scores, gapped rows, lengths, and the ok flag come back with
no direction matrix ever materialized in HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .banded_kernel import banded_forward_kernel, banded_fused_kernel
from .ref import BandedForward


@functools.partial(jax.jit, static_argnames=("gap_open", "gap_extend",
                                             "band", "block_rows",
                                             "interpret"))
def banded_forward_pallas(a, b, lens, sub, *, gap_open, gap_extend, band,
                          block_rows: int = 128,
                          interpret: bool | None = None) -> BandedForward:
    """Batched banded forward. a: (B, n) int8, b: (B, m), lens: (B, 2) i32.

    Returns BandedForward with batched leaves: dirs (B, n, band) int8,
    score/edge (B,), start_* (B,) i32. ``interpret=None`` resolves
    platform-aware (compiled on TPU, interpreter elsewhere).
    """
    B, n = a.shape
    npad = (-n) % block_rows
    a = jnp.pad(a, ((0, 0), (0, npad)))
    dirs, out = banded_forward_kernel(
        a, b, lens, sub.astype(jnp.float32), gap_open=float(gap_open),
        gap_extend=float(gap_extend), band=band, block_rows=block_rows,
        interpret=interpret)
    return BandedForward(dirs[:, :n, :], out[:, 0],
                         out[:, 1].astype(jnp.int32),
                         out[:, 2].astype(jnp.int32),
                         out[:, 3].astype(jnp.int32),
                         out[:, 4] > 0.5)


@functools.partial(jax.jit, static_argnames=("gap_open", "gap_extend",
                                             "band", "gap_code",
                                             "interpret"))
def banded_pairs_fused(a, b, lens, sub, *, gap_open, gap_extend, band,
                       gap_code: int = 5, interpret: bool | None = None):
    """Fused banded score+traceback for a coalesced pairs bucket.

    a: (B, n) int8, b: (B, m) int8, lens: (B, 2) i32. Returns
    (score (B,) f32, a_row (B, n+m) int8, b_row (B, n+m) int8,
    aln_len (B,) i32, ok (B,) bool) — the BatchAlignment field order.
    """
    out, a_row, b_row = banded_fused_kernel(
        a, b, lens, sub.astype(jnp.float32), gap_open=float(gap_open),
        gap_extend=float(gap_extend), band=band, gap_code=gap_code,
        interpret=interpret)
    return (out[:, 0], a_row, b_row, out[:, 4].astype(jnp.int32),
            out[:, 5] > 0.5)
