"""The banded Gotoh recurrence as pure shared math.

These functions are THE band recurrence: ``align.banded`` scans them on
the jnp path and ``banded_kernel``/``fused_kernel`` call them per row
with VMEM-resident state, so the two implementations are bit-identical
by construction (same op order, same dtypes, same NEG boundary). They
depend only on ``core.pairwise`` constants — no align imports — so the
kernel package never cycles back into the backend registry.

Band geometry and the edge-pressure overflow heuristic are documented in
``align/banded.py`` (the module docstring is the spec) and
``docs/KERNELS.md`` (the kernel-schedule view).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from ...core.pairwise import NEG, M_ST, IX_ST, IY_ST, FRESH, _pack


class BandedForward(NamedTuple):
    dirs: jnp.ndarray       # (n, W) int8 packed bytes for DP rows 1..n
    score: jnp.ndarray      # f32 global score at (la, lb)
    start_i: jnp.ndarray    # i32 == la
    start_j: jnp.ndarray    # i32 == lb
    start_state: jnp.ndarray
    edge: jnp.ndarray       # bool: some row's best cell hit the band edge


def band_lo(i, la, lb, band: int):
    """Leftmost absolute column stored for DP row ``i``."""
    c = jnp.where(la == 0, lb, (i * lb) // jnp.maximum(la, 1))
    return (c - band // 2).astype(jnp.int32)


def band_row_init(la, lb, go, ge, *, band: int):
    """Row-0 band state (m0, ix0, iy0), end-cell capture, and row best."""
    W = band
    offs = jnp.arange(W, dtype=jnp.int32)
    mid = W // 2
    lo0 = band_lo(jnp.int32(0), la, lb, W)
    j0 = lo0 + offs
    m0 = jnp.where(j0 == 0, 0.0, NEG)
    ix0 = jnp.full((W,), NEG)
    iy0 = jnp.where((j0 >= 1) & (j0 <= lb),
                    -(go + (j0.astype(jnp.float32) - 1.0) * ge), NEG)
    # End-cell capture init covers la == 0 (offset of j=lb is W//2 there).
    cap0 = jnp.stack([m0[mid], ix0[mid], iy0[mid]])
    h0 = jnp.where((j0 >= 0) & (j0 <= lb), jnp.maximum(m0, iy0), NEG)
    return m0, ix0, iy0, cap0, jnp.max(h0)


def band_row_update(m_prev, ix_prev, iy_prev, a_i, b, lo_prev, lo_i,
                    sub, go, ge, lb):
    """One banded Gotoh DP row — the pure recurrence.

    Within a row every dependency is elementwise or a running max (Iy
    via cummax), so the W band cells advance together as one
    anti-diagonal wavefront on the vector lanes.

    Returns (m_new, ix_new, iy_new, dirs, h_new, h_prev, s) where
    ``h_new``/``h_prev``/``s`` feed the edge-pressure detector.
    """
    W = m_prev.shape[0]
    m = b.shape[0]
    offs = jnp.arange(W, dtype=jnp.int32)
    offs_f = offs.astype(jnp.float32)
    s = lo_i - lo_prev                 # band slide (>= 0)
    j = lo_i + offs                    # absolute columns this row

    def shifted(v, sh, fill):
        # value of prev-row vector at current offset o == prev o + sh
        idx = offs + sh
        ok = (idx >= 0) & (idx < W)
        return jnp.where(ok, v[jnp.clip(idx, 0, W - 1)], fill)

    h_prev = jnp.maximum(m_prev, jnp.maximum(ix_prev, iy_prev))
    amax = jnp.where(m_prev >= h_prev, M_ST,
                     jnp.where(ix_prev >= h_prev, IX_ST, IY_ST))
    h_diag = shifted(h_prev, s - 1, NEG)
    amax_diag = shifted(amax.astype(jnp.int32), s - 1, jnp.int32(M_ST))
    m_up = shifted(m_prev, s, NEG)
    ix_up = shifted(ix_prev, s, NEG)

    s_row = sub[a_i.astype(jnp.int32),
                b[jnp.clip(j - 1, 0, m - 1)].astype(jnp.int32)]
    in_mat = (j >= 1) & (j <= lb)
    m_new = jnp.where(in_mat, h_diag + s_row, NEG)
    dir_m = amax_diag

    ix_open = m_up - go
    ix_ext = ix_up - ge
    ix_new = jnp.where((j >= 0) & (j <= lb),
                       jnp.maximum(ix_open, ix_ext), NEG)
    dir_ix = (ix_ext > ix_open).astype(jnp.int32)

    # Iy running max within the row; band offsets stand in for absolute
    # columns (the lo_i·ge term cancels exactly in f32 integer range).
    cm = jax.lax.cummax(m_new + offs_f * ge)
    iy_new = jnp.concatenate(
        [jnp.full((1,), NEG), cm[:-1] - go - (offs_f[1:] - 1.0) * ge])
    iy_new = jnp.where(in_mat, iy_new, NEG)
    m_left = jnp.concatenate([jnp.full((1,), NEG), m_new[:-1]])
    iy_left = jnp.concatenate([jnp.full((1,), NEG), iy_new[:-1]])
    dir_iy = (iy_left - ge > m_left - go).astype(jnp.int32)

    dirs = _pack(dir_m, dir_ix, dir_iy)
    h_new = jnp.where((j >= 0) & (j <= lb),
                      jnp.maximum(m_new, jnp.maximum(ix_new, iy_new)),
                      NEG)
    return m_new, ix_new, iy_new, dirs, h_new, h_prev, s


def edge_pressure(h_new, h_prev, hb_prev, s, margin):
    """Band-overflow detector for one row (see ``align/banded.py``).

    A competitive cell (within ``margin`` of the row best) in an exit
    zone — offset 0, the slide-clipped right rim, or a previous-row cell
    about to slide out of storage — means a near-dominant path is
    fighting the band. Returns (comp, hb): flag this row + the row best.
    """
    W = h_new.shape[0]
    offs = jnp.arange(W, dtype=jnp.int32)
    hb = jnp.max(h_new)
    zone = (offs == 0) | (offs >= W - jnp.maximum(s, 1))
    comp_cur = jnp.any(zone & (h_new >= hb - margin)) & (hb > NEG / 2)
    # bottom-left exit: previous-row cells slid out of storage this row
    comp_prev = (jnp.any((offs < s) & (h_prev >= hb_prev - margin)) &
                 (hb_prev > NEG / 2))
    return comp_cur | comp_prev, hb


def trace_step_math(i, j, o, st, done, byte_band, a_im1, b_jm1, lb,
                    gap_code: int, band: int):
    """One traceback step — the pure walk logic.

    The caller fetches the band direction byte and the two sequence
    characters (HBM dirs on the jnp path, VMEM dirs in the fused
    kernel); this function decides the move. Returns
    (ni, nj, nst, done, ndone, lost, edge_hit, ca, cb) where ``done`` is
    the post-``lost`` write gate for this step and ``ndone`` the carry.
    """
    W = band
    in_band = (o >= 0) & (o < W) & (i >= 1)
    # Boundary cells are pure gap runs with closed-form directions;
    # they are not stored in the band (and for la==0 / lb==0 the whole
    # walk happens here).
    byte_row0 = FRESH | (jnp.where(j == 1, 0, 1) << 3)
    byte_col0 = M_ST | (jnp.where(i == 1, 0, 1) << 2)
    byte = jnp.where(i == 0, byte_row0,
                     jnp.where(j == 0, byte_col0, byte_band))

    interior = (i > 0) & (j > 0)
    lost = (~done) & interior & (~in_band)
    # Edge cells whose clipped neighbour would be a real DP cell mean
    # a wider band could score higher: flag for full-DP fallback.
    edge_hit = ((~done) & interior & in_band &
                ((o == 0) | ((o == W - 1) & (j < lb))))
    done = done | lost

    dir_m = byte & 3
    dir_ix = (byte >> 2) & 1
    dir_iy = (byte >> 3) & 1
    is_m = st == M_ST
    is_ix = st == IX_ST
    ca = jnp.where(is_m | is_ix, a_im1, gap_code).astype(jnp.int8)
    cb = jnp.where(is_m | (st == IY_ST), b_jm1, gap_code).astype(jnp.int8)

    ni = jnp.where(is_m | is_ix, i - 1, i)
    nj = jnp.where(is_m | (st == IY_ST), j - 1, j)
    nst = jnp.where(is_m, dir_m,
                    jnp.where(is_ix, jnp.where(dir_ix == 1, IX_ST, M_ST),
                              jnp.where(dir_iy == 1, IY_ST, M_ST)))
    ndone = done | ((ni == 0) & (nj == 0))
    return ni, nj, nst.astype(jnp.int32), done, ndone, lost, edge_hit, ca, cb
