from .ops import match_valid_pallas, distance_matrix_pallas  # noqa: F401
from . import ref  # noqa: F401
