"""Pallas TPU kernel: pairwise match/valid counts via on-the-fly one-hot MXU.

The NJ distance matrix needs, for every row pair (i, j) of the MSA, the
number of equal non-gap columns (match) and both-non-gap columns (valid).
Done naively this is an O(N^2 L) byte-compare loop; expressed as
one-hot(X) @ one-hot(X)^T it is MXU work — but materializing the one-hot in
HBM would multiply sequence bytes by 4*|alphabet|. This kernel builds the
one-hot tiles in VMEM from the int8 tiles at use time, so HBM traffic stays
int8 while the MXU does the counting.

Tiling: grid (N/BN, N/BN, L/BL); A-tile (BN, BL) int8 and B-tile (BN, BL)
int8 expand to (BN, BL*C) f32 in VMEM (~BN*BL*C*4 B; 128*128*8*4 = 512 KiB
for C=8 — fits) and accumulate two (BN, BN) f32 outputs over the L/BL
reduction dimension (last grid dim = sequential on TPU, accumulation in the
output block is the standard Pallas matmul pattern). MXU dims: BN=128 rows,
BL*C a multiple of 128 lanes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(a_ref, b_ref, match_ref, valid_ref, *, n_chars: int, gap_code: int):
    lk = pl.program_id(2)

    @pl.when(lk == 0)
    def _():
        match_ref[:, :] = jnp.zeros_like(match_ref)
        valid_ref[:, :] = jnp.zeros_like(valid_ref)

    a = a_ref[:, :]
    b = b_ref[:, :]

    def onehot(x):
        oh = (x[:, :, None] == jax.lax.broadcasted_iota(jnp.int8, (1, 1, n_chars), 2))
        oh &= (x[:, :, None] != gap_code)
        return oh.astype(jnp.float32).reshape(x.shape[0], -1)

    na = ((a != gap_code) & (a < n_chars)).astype(jnp.float32)
    nb = ((b != gap_code) & (b < n_chars)).astype(jnp.float32)
    valid_ref[:, :] += jax.lax.dot_general(
        na, nb, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)
    match_ref[:, :] += jax.lax.dot_general(
        onehot(a), onehot(b), (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)


def match_valid_kernel(msa_a, msa_b, *, n_chars: int, gap_code: int,
                       bn: int = 128, bl: int = 128, interpret: bool = True):
    """msa_a: (N, L) int8, msa_b: (M, L) int8 (pad N/M to bn, L to bl).

    Returns match (N, M) f32 and valid (N, M) f32.
    """
    N, L = msa_a.shape
    M = msa_b.shape[0]
    assert N % bn == 0 and M % bn == 0 and L % bl == 0, (N, M, L, bn, bl)
    grid = (N // bn, M // bn, L // bl)
    kern = functools.partial(_kernel, n_chars=n_chars, gap_code=gap_code)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bl), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bl), lambda i, j, k: (j, k)),
        ],
        out_specs=[
            pl.BlockSpec((bn, bn), lambda i, j, k: (i, j)),
            pl.BlockSpec((bn, bn), lambda i, j, k: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N, M), jnp.float32),
            jax.ShapeDtypeStruct((N, M), jnp.float32),
        ],
        interpret=interpret,
    )(msa_a, msa_b)
