"""Pallas TPU kernel: pairwise match/valid counts via on-the-fly one-hot MXU.

The NJ distance matrix needs, for every row pair (i, j) of the MSA, the
number of equal non-gap columns (match) and both-non-gap columns (valid).
Done naively this is an O(N^2 L) byte-compare loop; expressed as
one-hot(X) @ one-hot(X)^T it is MXU work — but materializing the one-hot in
HBM would multiply sequence bytes by 4*|alphabet|. This kernel builds the
one-hot tiles in VMEM from the int8 tiles at use time, so HBM traffic stays
int8 while the MXU does the counting.

Profile packing (``pack``): the default ``"int8"`` keeps the one-hot tiles
as int8 operands of an int32-accumulating dot — 4× fewer VMEM bytes per
expanded tile than the legacy ``"f32"`` path (BN*BL*C bytes instead of
BN*BL*C*4; 128*128*8 = 128 KiB at C=8) and the layout the MXU's integer
path wants. Counts are exact small integers either way, so the f32 results
the ops layer returns are bit-identical between packings.

Tiling: grid (N/BN, N/BN, L/BL); A-tile (BN, BL) int8 and B-tile (BN, BL)
int8 expand to (BN, BL*C) in VMEM and accumulate two (BN, BN) outputs over
the L/BL reduction dimension (last grid dim = sequential on TPU,
accumulation in the output block is the standard Pallas matmul pattern).
MXU dims: BN=128 rows, BL*C a multiple of 128 lanes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import kernel_call


def _kernel(a_ref, b_ref, match_ref, valid_ref, *, n_chars: int,
            gap_code: int, pack: str):
    lk = pl.program_id(2)
    op_t = jnp.int8 if pack == "int8" else jnp.float32
    acc_t = jnp.int32 if pack == "int8" else jnp.float32

    @pl.when(lk == 0)
    def _():
        match_ref[:, :] = jnp.zeros_like(match_ref)
        valid_ref[:, :] = jnp.zeros_like(valid_ref)

    a = a_ref[:, :]
    b = b_ref[:, :]

    def onehot(x):
        oh = (x[:, :, None] == jax.lax.broadcasted_iota(jnp.int8, (1, 1, n_chars), 2))
        oh &= (x[:, :, None] != gap_code)
        return oh.astype(op_t).reshape(x.shape[0], -1)

    na = ((a != gap_code) & (a < n_chars)).astype(op_t)
    nb = ((b != gap_code) & (b < n_chars)).astype(op_t)
    valid_ref[:, :] += jax.lax.dot_general(
        na, nb, (((1,), (1,)), ((), ())), preferred_element_type=acc_t)
    match_ref[:, :] += jax.lax.dot_general(
        onehot(a), onehot(b), (((1,), (1,)), ((), ())),
        preferred_element_type=acc_t)


def match_valid_kernel(msa_a, msa_b, *, n_chars: int, gap_code: int,
                       bn: int = 128, bl: int = 128, pack: str = "int8",
                       interpret: bool | None = None):
    """msa_a: (N, L) int8, msa_b: (M, L) int8 (pad N/M to bn, L to bl).

    Returns match (N, M) and valid (N, M) — int32 counts under
    ``pack="int8"``, f32 under the legacy ``pack="f32"``.
    """
    N, L = msa_a.shape
    M = msa_b.shape[0]
    assert N % bn == 0 and M % bn == 0 and L % bl == 0, (N, M, L, bn, bl)
    assert pack in ("int8", "f32"), pack
    acc_t = jnp.int32 if pack == "int8" else jnp.float32
    grid = (N // bn, M // bn, L // bl)
    kern = functools.partial(_kernel, n_chars=n_chars, gap_code=gap_code,
                             pack=pack)
    return kernel_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bl), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bl), lambda i, j, k: (j, k)),
        ],
        out_specs=[
            pl.BlockSpec((bn, bn), lambda i, j, k: (i, j)),
            pl.BlockSpec((bn, bn), lambda i, j, k: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N, M), acc_t),
            jax.ShapeDtypeStruct((N, M), acc_t),
        ],
        interpret=interpret,
    )(msa_a, msa_b)
