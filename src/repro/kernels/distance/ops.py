"""jit'd wrapper: pad, call the kernel, crop, and a full distance_matrix."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ...core.distance import jc69_distance
from .distance_kernel import match_valid_kernel


@functools.partial(jax.jit, static_argnames=("n_chars", "gap_code", "bn", "bl",
                                             "pack", "interpret"))
def match_valid_pallas(msa_a, msa_b, *, n_chars: int, gap_code: int,
                       bn: int = 128, bl: int = 128, pack: str = "int8",
                       interpret: bool | None = None):
    """Match/valid counts as f32. ``pack="int8"`` (default) runs the
    kernel with int8 one-hot operands and int32 accumulation — counts are
    exact integers either way, so both packings are bit-identical."""
    N, L = msa_a.shape
    M = msa_b.shape[0]
    pn, pm, pl_ = (-N) % bn, (-M) % bn, (-L) % bl
    a = jnp.pad(msa_a, ((0, pn), (0, pl_)), constant_values=gap_code)
    b = jnp.pad(msa_b, ((0, pm), (0, pl_)), constant_values=gap_code)
    match, valid = match_valid_kernel(a, b, n_chars=n_chars, gap_code=gap_code,
                                      bn=bn, bl=bl, pack=pack,
                                      interpret=interpret)
    return (match[:N, :M].astype(jnp.float32),
            valid[:N, :M].astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("n_chars", "gap_code", "correct",
                                             "bn", "bl", "pack", "interpret"))
def distance_matrix_pallas(msa, *, n_chars: int, gap_code: int,
                           correct: bool = True, bn: int = 128, bl: int = 128,
                           pack: str = "int8",
                           interpret: bool | None = None):
    match, valid = match_valid_pallas(msa, msa, n_chars=n_chars,
                                      gap_code=gap_code, bn=bn, bl=bl,
                                      pack=pack, interpret=interpret)
    p = 1.0 - match / jnp.maximum(valid, 1.0)
    p = jnp.where(valid > 0, p, 0.75)
    d = jc69_distance(p) if correct else p
    d = (d + d.T) / 2.0
    return d * (1.0 - jnp.eye(d.shape[0]))
