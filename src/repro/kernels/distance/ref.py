"""Pure-jnp oracle for the distance kernel (delegates to core.distance)."""
from __future__ import annotations

from ...core.distance import match_valid_counts


def match_valid_ref(msa_a, msa_b, *, n_chars: int, gap_code: int):
    return match_valid_counts(msa_a, msa_b, gap_code=gap_code, n_chars=n_chars)
