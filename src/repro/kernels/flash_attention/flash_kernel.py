"""Pallas TPU kernel: blocked online-softmax attention (FlashAttention-2
schedule), causal + sliding-window, GQA via head-index mapping.

This is the perf-critical layer of the LM workloads (prefill_32k is
attention-dominated). Grid = (batch, q_head, q_blocks, kv_blocks); the kv
dimension is innermost (sequential on TPU), with the running max m, sum l and
accumulator acc living in VMEM scratch across kv steps. Q/K/V tiles are
(BQ, D) / (BK, D); scores (BQ, BK) stay in VMEM/VREGs. GQA never gathers:
the K/V BlockSpec index_map divides the q-head index by the group size, so a
KV head's tiles are streamed once per q-head group.

Masking: causal and sliding-window are applied as position masks inside the
tile; fully-masked tiles are skipped via the grid's kv upper bound being
conservative (we still iterate but @pl.when(skip) avoids the FLOPs on TPU;
interpret mode computes them — correctness identical).

VMEM at BQ=BK=128, D=128: q/k/v tiles 3*64 KiB + acc 64 KiB + scores 64 KiB
— well under budget; block sizes are the hillclimb's knobs.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .. import kernel_call

NEG_INF = -1.0e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            bq: int, bk: int, scale: float, causal: bool, window: int):
    qb = pl.program_id(2)
    kb = pl.program_id(3)
    n_kb = pl.num_programs(3)

    @pl.when(kb == 0)
    def _():
        m_scr[:] = jnp.full_like(m_scr, NEG_INF)
        l_scr[:] = jnp.zeros_like(l_scr)
        acc_scr[:, :] = jnp.zeros_like(acc_scr)

    q_pos = qb * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = kb * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= q_pos >= k_pos
    if window > 0:
        mask &= (q_pos - k_pos) < window

    # block-level skip: with causal masking, kv blocks strictly above the
    # diagonal contribute nothing
    run = True
    if causal:
        run = (kb * bk) <= (qb * bq + bq - 1)

    @pl.when(run)
    def _():
        q = q_ref[0, 0, :, :]
        k = k_ref[0, 0, :, :]
        v = v_ref[0, 0, :, :]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * scale
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[:]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[:] = l_scr[:] * alpha + jnp.sum(p, axis=1)
        acc_scr[:, :] = acc_scr[:, :] * alpha[:, None] + jax.lax.dot_general(
            p, v.astype(jnp.float32), (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[:] = m_new

    @pl.when(kb == n_kb - 1)
    def _():
        l = jnp.maximum(l_scr[:], 1e-30)
        o_ref[0, 0, :, :] = (acc_scr[:, :] / l[:, None]).astype(o_ref.dtype)


def flash_attention_kernel(q, k, v, *, scale: float, causal: bool,
                           window: int = 0, bq: int = 128, bk: int = 128,
                           interpret: bool | None = None):
    """q: (B, H, S, D), k/v: (B, KH, S, D) with H % KH == 0. S % bq == 0."""
    B, H, S, D = q.shape
    KH = k.shape[1]
    assert H % KH == 0 and S % bq == 0 and S % bk == 0
    group = H // KH
    grid = (B, H, S // bq, S // bk)
    kern = functools.partial(_kernel, bq=bq, bk=bk, scale=scale,
                             causal=causal, window=window)
    return kernel_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h // group, j, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j: (b, h // group, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
