"""jit'd wrapper with recompute-based VJP (forward = Pallas kernel).

Training uses jax.custom_vjp: forward runs the kernel; backward recomputes
attention with the jnp reference (memory-cheap forward, standard backward).
A fused flash backward kernel is a known further optimization and is listed
in EXPERIMENTS.md §Perf as future work for the TPU target.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .flash_kernel import flash_attention_kernel
from .ref import attention_ref


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def flash_attention(q, k, v, scale, causal=True, window=0, bq=128, bk=128,
                    interpret=None):
    return flash_attention_kernel(q, k, v, scale=scale, causal=causal,
                                  window=window, bq=bq, bk=bk,
                                  interpret=interpret)


def _fwd(q, k, v, scale, causal, window, bq, bk, interpret):
    out = flash_attention(q, k, v, scale, causal, window, bq, bk, interpret)
    return out, (q, k, v)


def _bwd(scale, causal, window, bq, bk, interpret, res, g):
    q, k, v = res
    def f(q, k, v):
        return attention_ref(q, k, v, scale=scale, causal=causal, window=window)
    _, vjp = jax.vjp(f, q, k, v)
    return vjp(g)


flash_attention.defvjp(_fwd, _bwd)
