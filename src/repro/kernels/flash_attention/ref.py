"""Pure-jnp oracle: materialized-scores attention with the same masking."""
from __future__ import annotations

import jax.numpy as jnp


def attention_ref(q, k, v, *, scale: float, causal: bool, window: int = 0):
    B, H, S, D = q.shape
    KH = k.shape[1]
    group = H // KH
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    qp = jnp.arange(S)[:, None]
    kp = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= qp >= kp
    if window > 0:
        mask &= (qp - kp) < window
    s = jnp.where(mask, s, -1e30)
    p = jnp.exp(s - jnp.max(s, -1, keepdims=True))
    p = p / jnp.maximum(jnp.sum(p, -1, keepdims=True), 1e-30)
    p = jnp.where(mask, p, 0.0)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)
