from .ops import gotoh_forward_pallas  # noqa: F401
from . import ref  # noqa: F401
