"""jit'd public wrapper for the SW/Gotoh kernel: padding, boundary row,
and a drop-in replacement for pairwise.gotoh_forward in batch form."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ...core.pairwise import ForwardResult
from . import ref as _ref
from .sw_kernel import gotoh_forward_kernel


@functools.partial(jax.jit, static_argnames=("gap_open", "gap_extend", "local",
                                             "block_rows", "interpret"))
def gotoh_forward_pallas(a, b, lens, sub, *, gap_open, gap_extend,
                         local=False, block_rows: int = 128,
                         interpret: bool | None = None) -> ForwardResult:
    """Batched forward with the kernel; returns ForwardResult with the
    boundary row prepended so core.pairwise.traceback consumes it directly.

    a: (B, n) int8, b: (B, m) int8, lens: (B, 2) i32 [[la, lb], ...].
    ``interpret=None`` resolves platform-aware (compiled on TPU) inside
    the shared ``kernels.kernel_call`` wrapper.
    """
    B, n = a.shape
    m = b.shape[1]
    npad = (-n) % block_rows
    a = jnp.pad(a, ((0, 0), (0, npad)))
    dirs_body, out = gotoh_forward_kernel(
        a, b, lens, sub.astype(jnp.float32), gap_open=float(gap_open),
        gap_extend=float(gap_extend), local=local, block_rows=block_rows,
        interpret=interpret)
    dirs_body = dirs_body[:, :n, :]
    row0 = _ref.boundary_row(m, lens[:, 1])
    dirs = jnp.concatenate([jnp.broadcast_to(row0, (B, 1, m + 1)), dirs_body],
                           axis=1)
    return ForwardResult(dirs, out[:, 0], out[:, 1].astype(jnp.int32),
                         out[:, 2].astype(jnp.int32),
                         out[:, 3].astype(jnp.int32))
