"""Pure-jnp oracle for the SW/Gotoh Pallas kernel: the row-scan forward from
repro.core.pairwise, reshaped to the kernel's output contract."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ...core import pairwise


def gotoh_forward_ref(a, b, lens, sub, *, gap_open: float, gap_extend: float,
                      local: bool):
    """Same contract as sw_kernel.gotoh_forward_kernel."""
    def one(a_i, b_i, l_i):
        fwd = pairwise.gotoh_forward(a_i, l_i[0], b_i, l_i[1], sub,
                                     gap_open, gap_extend, local=local)
        out = jnp.stack([fwd.score, fwd.start_i.astype(jnp.float32),
                         fwd.start_j.astype(jnp.float32),
                         fwd.start_state.astype(jnp.float32),
                         0.0, 0.0, 0.0, 0.0])
        return fwd.dirs[1:], out      # body rows only (kernel omits row 0)

    return jax.vmap(one)(a, b, lens)


def boundary_row(m: int, lb, *, gap_code_unused=None):
    """Packed direction row 0 (constant given lb): FRESH | open-from-M at j=1."""
    from ...core.pairwise import FRESH
    dir_iy0 = jnp.where(jnp.arange(m + 1) == 1, 0, 1)
    row0 = (jnp.full((m + 1,), FRESH, jnp.int32) | (dir_iy0 << 3)).astype(jnp.int8)
    return row0
