"""Pallas TPU kernel: batched Gotoh DP forward (scores + packed directions).

TPU adaptation of the paper's Smith-Waterman engine. The 2D DP is blocked by
query rows: grid = (batch, row_blocks); the kernel keeps the previous DP row
(M/Ix/Iy, each (m+1,) f32) in VMEM scratch that persists across the
sequential row-block grid dimension, so HBM traffic is exactly one int8
direction row per DP row (the score rows never leave VMEM). Within a row the
horizontal affine-gap recurrence Iy[j] = max(M[j-1]-go, Iy[j-1]-ge) is
re-expressed as a running max (cummax) over M[k]+k*ge — the same trick as the
jnp oracle — so every row is pure vector work on the VPU with no
sequential-in-j loop.

Layout notes for the TPU target: columns (m+1) should be padded to a
multiple of 128 (lane width) by ops.py; direction rows are int8 (packed
2+1+1 bits); scratch is 3*(m+1)*4B + capture (3,(m+1)) + best (8,) — for
m = 4k this is ~115 KiB, comfortably inside one core's VMEM alongside the
(block_rows, m+1) int8 output tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .. import kernel_call
from ...core.pairwise import NEG, M_ST, IX_ST, IY_ST, FRESH


def _row_update(m_prev, ix_prev, iy_prev, a_i, b_row, sub, go, ge, jcol,
                local: bool):
    """One DP row; mirrors pairwise.row_step (shared semantics, VMEM refs)."""
    mcols = b_row.shape[0] + 1
    s_row = sub[a_i.astype(jnp.int32), b_row.astype(jnp.int32)]
    s_full = jnp.concatenate([jnp.zeros((1,), jnp.float32), s_row])

    h_prev = jnp.maximum(m_prev, jnp.maximum(ix_prev, iy_prev))
    amax = jnp.where(m_prev >= h_prev, M_ST,
                     jnp.where(ix_prev >= h_prev, IX_ST, IY_ST))
    h_diag = jnp.concatenate([jnp.full((1,), NEG, jnp.float32), h_prev[:-1]])
    amax_diag = jnp.concatenate([jnp.full((1,), M_ST, amax.dtype), amax[:-1]])

    m_new = h_diag + s_full
    dir_m = amax_diag
    if local:
        fresh = h_diag <= 0.0
        m_new = jnp.where(fresh, s_full, m_new)
        dir_m = jnp.where(fresh, FRESH, dir_m)
    m_new = m_new.at[0].set(NEG)

    ix_open = m_prev - go
    ix_ext = ix_prev - ge
    ix_new = jnp.maximum(ix_open, ix_ext)
    dir_ix = (ix_ext > ix_open).astype(jnp.int32)

    cm = jax.lax.cummax(m_new + jcol * ge)
    iy_new = jnp.concatenate(
        [jnp.full((1,), NEG, jnp.float32), cm[:-1] - go - (jcol[1:] - 1.0) * ge])
    m_left = jnp.concatenate([jnp.full((1,), NEG, jnp.float32), m_new[:-1]])
    iy_left = jnp.concatenate([jnp.full((1,), NEG, jnp.float32), iy_new[:-1]])
    dir_iy = (iy_left - ge > m_left - go).astype(jnp.int32)

    packed = (dir_m.astype(jnp.int32) | (dir_ix << 2) | (dir_iy << 3)).astype(jnp.int8)
    return m_new, ix_new, iy_new, packed


def _kernel(a_ref, b_ref, lens_ref, sub_ref, dirs_ref, out_ref,
            mp, xp, yp, cap, best, *, block_rows: int, local: bool,
            gap_open: float, gap_extend: float):
    rb = pl.program_id(1)
    n_rb = pl.num_programs(1)
    la = lens_ref[0, 0]
    lb = lens_ref[0, 1]
    b_row = b_ref[0, :]
    mcols = b_row.shape[0] + 1
    sub = sub_ref[:]
    go = jnp.float32(gap_open)
    ge = jnp.float32(gap_extend)
    jcol = jnp.arange(mcols, dtype=jnp.float32)
    col_ok = jnp.arange(mcols) <= lb

    @pl.when(rb == 0)
    def _init():
        m0 = jnp.full((mcols,), NEG, jnp.float32).at[0].set(0.0)
        ix0 = jnp.full((mcols,), NEG, jnp.float32)
        iy0 = jnp.where(jnp.arange(mcols) >= 1, -(go + (jcol - 1.0) * ge), NEG)
        mp[:] = m0
        xp[:] = ix0
        yp[:] = iy0
        cap[0, :] = m0
        cap[1, :] = ix0
        cap[2, :] = iy0
        best[:] = jnp.where(jnp.arange(8) == 0, jnp.float32(NEG), 0.0)

    def row(l, _):
        r = rb * block_rows + l + 1          # DP row index (1-based)
        a_i = a_ref[0, l]
        m_new, ix_new, iy_new, packed = _row_update(
            mp[:], xp[:], yp[:], a_i, b_row, sub, go, ge, jcol, local)
        dirs_ref[0, l, :] = packed
        live = r <= la
        mp[:] = jnp.where(live, m_new, mp[:])
        xp[:] = jnp.where(live, ix_new, xp[:])
        yp[:] = jnp.where(live, iy_new, yp[:])
        hit = r == la
        cap[0, :] = jnp.where(hit, m_new, cap[0, :])
        cap[1, :] = jnp.where(hit, ix_new, cap[1, :])
        cap[2, :] = jnp.where(hit, iy_new, cap[2, :])
        if local:
            row_masked = jnp.where(col_ok & live, m_new, NEG)
            jb = jnp.argmax(row_masked)
            vb = row_masked[jb]
            upd = vb > best[0]
            best[0] = jnp.where(upd, vb, best[0])
            best[1] = jnp.where(upd, r.astype(jnp.float32), best[1])
            best[2] = jnp.where(upd, jb.astype(jnp.float32), best[2])
        return 0

    jax.lax.fori_loop(0, block_rows, row, 0)

    @pl.when(rb == n_rb - 1)
    def _fin():
        if local:
            out_ref[0, 0] = best[0]
            out_ref[0, 1] = best[1]
            out_ref[0, 2] = best[2]
            out_ref[0, 3] = jnp.float32(M_ST)
        else:
            ends = jnp.stack([cap[0, lb], cap[1, lb], cap[2, lb]])
            st = jnp.argmax(ends)
            out_ref[0, 0] = ends[st]
            out_ref[0, 1] = la.astype(jnp.float32)
            out_ref[0, 2] = lb.astype(jnp.float32)
            out_ref[0, 3] = st.astype(jnp.float32)
        out_ref[0, 4:] = jnp.zeros((4,), jnp.float32)


def gotoh_forward_kernel(a, b, lens, sub, *, gap_open: float,
                         gap_extend: float, local: bool,
                         block_rows: int = 128,
                         interpret: bool | None = None):
    """a: (B, n) int8 (n % block_rows == 0), b: (B, m), lens: (B, 2) i32.

    Returns dirs_body (B, n, m+1) int8 (DP rows 1..n) and out (B, 8) f32
    [score, start_i, start_j, start_state, 0*4].
    """
    B, n = a.shape
    m = b.shape[1]
    assert n % block_rows == 0, (n, block_rows)
    grid = (B, n // block_rows)
    kern = functools.partial(_kernel, block_rows=block_rows, local=local,
                             gap_open=gap_open, gap_extend=gap_extend)
    return kernel_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_rows), lambda b_, r: (b_, r)),
            pl.BlockSpec((1, m), lambda b_, r: (b_, 0)),
            pl.BlockSpec((1, 2), lambda b_, r: (b_, 0)),
            pl.BlockSpec(sub.shape, lambda b_, r: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_rows, m + 1), lambda b_, r: (b_, r, 0)),
            pl.BlockSpec((1, 8), lambda b_, r: (b_, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, n, m + 1), jnp.int8),
            jax.ShapeDtypeStruct((B, 8), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((m + 1,), jnp.float32),
            pltpu.VMEM((m + 1,), jnp.float32),
            pltpu.VMEM((m + 1,), jnp.float32),
            pltpu.VMEM((3, m + 1), jnp.float32),
            pltpu.VMEM((8,), jnp.float32),
        ],
        interpret=interpret,
    )(a, b, lens, sub)
