"""Generate docs/CLI.md from the launchers' own argparse definitions.

The reference is generated once (``python -m repro.launch.cli_docs``) and
committed; ``tests/test_docs.py`` regenerates it in memory and fails when
a flag changed without the doc — the drift check the CI docs job runs.
Width is pinned via COLUMNS so the rendering is terminal-independent.
"""
from __future__ import annotations

import importlib
import os
from pathlib import Path

# module name -> parser factory attribute (all expose build_parser())
CLIS = [
    "repro.launch.msa_run",
    "repro.launch.tree_run",
    "repro.launch.search_run",
    "repro.launch.serve_msa",
    "repro.launch.serve",
    "repro.launch.train",
]

HEADER = """\
# CLI reference

Generated from each launcher's `argparse` definition by
`PYTHONPATH=src python -m repro.launch.cli_docs` — do not edit by hand;
`tests/test_docs.py::test_cli_reference_not_drifted` fails when a flag
changes without regenerating. The architecture behind these commands is
mapped in [ARCHITECTURE.md](ARCHITECTURE.md).
"""


def render() -> str:
    old = os.environ.get("COLUMNS")
    os.environ["COLUMNS"] = "79"            # argparse help wraps on this
    try:
        parts = [HEADER]
        for mod_name in CLIS:
            mod = importlib.import_module(mod_name)
            helptext = mod.build_parser().format_help().rstrip()
            parts.append(f"\n## `python -m {mod_name}`\n\n"
                         f"```text\n{helptext}\n```\n")
        return "".join(parts)
    finally:
        if old is None:
            os.environ.pop("COLUMNS", None)
        else:
            os.environ["COLUMNS"] = old


def main():
    out = Path(__file__).resolve().parents[3] / "docs" / "CLI.md"
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(render())
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
