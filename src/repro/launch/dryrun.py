import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch x shape) on the production
meshes, record memory_analysis / cost_analysis / collective bytes.

Usage:
  python -m repro.launch.dryrun --arch gemma-2b --shape train_4k --mesh pod
  python -m repro.launch.dryrun --all --mesh both --out results/dryrun
  python -m repro.launch.dryrun --msa halign-dna-1000x --mesh multipod

The FIRST TWO LINES of this file force 512 host platform devices before any
jax initialization — do not import repro.launch.dryrun from code that needs
the real device count.
"""
import argparse
import json
import re
import time
from pathlib import Path

import jax

from ..configs import ALL_ARCHS, SHAPES, get_arch, shape_applicable
from .mesh import make_production_mesh
from .steps import MSA_CELLS, build_msa_step, build_step, microbatches_for

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(r"\b(pred|s8|u8|s16|u16|bf16|f16|s32|u32|f32|s64|u64|f64)"
                       r"\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str):
    """Sum operand bytes of every collective op in the (post-SPMD) HLO.

    Returns (totals_by_op, counts_by_op, per_computation_totals). HLO prints
    each while body ONCE regardless of trip count, so per-computation totals
    let benchmarks/roofline.py apply the known scan multipliers
    (microbatches x layer groups) — see EXPERIMENTS.md §Roofline for the
    validation of that correction against an unrolled compile.
    """
    out = {op: 0 for op in COLLECTIVE_OPS}
    counts = {op: 0 for op in COLLECTIVE_OPS}
    per_comp = {}
    comp = "<entry>"
    for line in hlo_text.splitlines():
        ls = line.strip()
        if (ls.startswith("%") or ls.startswith("ENTRY")) and ls.endswith("{"):
            comp = ls.split()[0].lstrip("%").split("(")[0].rstrip(".")
        for op in COLLECTIVE_OPS:
            if f" {op}(" in line or f" {op}-start(" in line:
                try:
                    operands = line.split("(", 1)[1]
                except IndexError:
                    continue
                b = sum(_shape_bytes(m.group(1), m.group(2))
                        for m in _SHAPE_RE.finditer(operands))
                out[op] += b
                counts[op] += 1
                per_comp.setdefault(comp, 0)
                per_comp[comp] += b
                break
    return out, counts, per_comp


def run_cell(arch: str, shape: str, mesh_kind: str, verbose: bool = True,
             roofline: bool = False):
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    cfg = get_arch(arch).config
    ok, why = shape_applicable(cfg, SHAPES[shape])
    if not ok:
        return {"arch": arch, "shape": shape, "mesh": mesh_kind,
                "skipped": why}
    t0 = time.time()
    with mesh:
        jitted, args = build_step(arch, shape, mesh, roofline=roofline)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        hlo = compiled.as_text()
    coll, coll_n, coll_comp = collective_bytes(hlo)
    rec = {
        "arch": arch, "shape": shape, "mesh": mesh_kind,
        "roofline_mode": roofline,
        "microbatches": (microbatches_for(arch, shape, mesh)
                         if SHAPES[shape].kind == "train" else 1),
        "flops_per_device": float(cost.get("flops", -1)) if cost else -1,
        "bytes_accessed_per_device": float(cost.get("bytes accessed", -1))
        if cost else -1,
        "collective_bytes_per_device": coll,
        "collective_counts": coll_n,
        "collective_bytes_by_computation": coll_comp,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
    }
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes"):
        if mem is not None and hasattr(mem, attr):
            rec[attr] = int(getattr(mem, attr))
    if verbose:
        print(json.dumps(rec))
    return rec


def run_msa_cell(cell: str, mesh_kind: str, verbose: bool = True):
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    t0 = time.time()
    with mesh:
        fn, args = build_msa_step(cell, mesh)
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        hlo = compiled.as_text()
    coll, coll_n, coll_comp = collective_bytes(hlo)
    rec = {
        "arch": cell, "shape": "msa", "mesh": mesh_kind,
        "flops_per_device": float(cost.get("flops", -1)) if cost else -1,
        "bytes_accessed_per_device": float(cost.get("bytes accessed", -1))
        if cost else -1,
        "collective_bytes_per_device": coll,
        "collective_counts": coll_n,
        "collective_bytes_by_computation": coll_comp,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
    }
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes"):
        if mem is not None and hasattr(mem, attr):
            rec[attr] = int(getattr(mem, attr))
    if verbose:
        print(json.dumps(rec))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--msa", default=None, choices=list(MSA_CELLS) + [None])
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--roofline", action="store_true",
                    help="unroll layer scans so cost_analysis counts every "
                         "layer (single-pod roofline lowering)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]
    results = []
    if args.msa:
        for mk in meshes:
            results.append(run_msa_cell(args.msa, mk))
    elif args.all:
        for arch in ALL_ARCHS:
            for shape in SHAPES:
                for mk in meshes:
                    try:
                        results.append(run_cell(arch, shape, mk,
                                                roofline=args.roofline))
                    except Exception as e:  # a failure here is a bug: record it
                        results.append({"arch": arch, "shape": shape,
                                        "mesh": mk, "error": repr(e)})
                        print(f"FAIL {arch} {shape} {mk}: {e!r}")
        for cell in MSA_CELLS:
            for mk in meshes:
                try:
                    results.append(run_msa_cell(cell, mk))
                except Exception as e:
                    results.append({"arch": cell, "shape": "msa", "mesh": mk,
                                    "error": repr(e)})
                    print(f"FAIL {cell} {mk}: {e!r}")
    else:
        for mk in meshes:
            results.append(run_cell(args.arch, args.shape, mk,
                                    roofline=args.roofline))

    if args.out:
        out = Path(args.out)
        out.parent.mkdir(parents=True, exist_ok=True)
        with open(out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"wrote {len(results)} records to {out}")


if __name__ == "__main__":
    main()
