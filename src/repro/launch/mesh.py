"""Production meshes. Importing this module never touches jax device state —
meshes are built inside functions only (dryrun.py sets the 512-device
XLA_FLAGS before any jax import)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 v5e pod (data, model) or 2 pods = 512 chips (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devices)} — run under "
            f"launch/dryrun.py which forces 512 host devices")
    import numpy as np
    dev = np.asarray(devices[:n]).reshape(shape)
    return jax.sharding.Mesh(dev, axes)


def mesh_from_arg(arg=None):
    """CLI ``--mesh DxM`` string -> local (data, model) mesh.

    ``None`` (flag omitted) uses all visible devices x 1. Shared by the
    msa_run / tree_run launchers.
    """
    if arg:
        try:
            d, m = (int(x) for x in arg.split("x"))
        except ValueError:
            raise ValueError(f"--mesh expects DxM (e.g. 4x1), got {arg!r}")
    else:
        d, m = len(jax.devices()), 1
    return make_local_mesh((d, m), ("data", "model"))


def make_local_mesh(shape=(1, 1), axes=("data", "model")):
    """Small mesh over however many real devices exist (tests, examples)."""
    import numpy as np
    n = 1
    for s in shape:
        n *= s
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devices)} — use a "
            f"smaller --mesh or force host devices via XLA_FLAGS="
            f"--xla_force_host_platform_device_count={n}")
    dev = np.asarray(devices[:n]).reshape(shape)
    return jax.sharding.Mesh(dev, axes)
