"""Distributed MSA launcher: FASTA in, aligned FASTA + tree out.

Runs the Spark-pattern pipeline on whatever mesh the process sees (one CPU
device here; a real pod under jax.distributed). The same jitted stages are
what dryrun.py lowers for 512 devices.

  PYTHONPATH=src python -m repro.launch.msa_run --fasta in.fa --out out/ \
      --method kmer --tree cluster [--backend banded --band 128] \
      [--dist] [--mesh 4x1]

``--dist`` routes the alignment through ``repro.dist.mapreduce`` (shard_map
over the data axis — identical math, Spark-style execution); the default
path is the single-host driver in ``repro.core.msa``. ``--backend`` picks
the map(1) DP primitive from the ``repro.align`` registry (``auto`` =
Pallas kernel on TPU, jnp scan elsewhere; ``banded`` = O(n·band) memory).
``--tree`` picks the ``repro.phylo.TreeEngine`` backend for the phylogeny
stage (``nj`` = dense; ``tiled`` composes with ``--dist`` by shard-mapping
the distance strips over the same mesh; ``ml`` = auto backend plus
maximum-likelihood refinement — autodiff branch lengths, BIC model
selection, vmapped NNI); ``repro.launch.tree_run`` rebuilds a tree from
an already-aligned FASTA without redoing the MSA (and exposes the full
``--refine``/``--model``/``--bootstrap`` surface).

Flags:
  --fasta               input FASTA (required)
  --out                 output directory (aligned.fasta, tree.nwk,
                        report.json); default msa_out
  --method              kmer | plain | sw map(1) path (kmer = the paper's
                        trie-accelerated anchor chaining)
  --alphabet            dna | rna | protein (picks encoding + matrix;
                        protein uses BLOSUM62, gap_open 11)
  --tree                nj | cluster | tiled | auto | ml | none tree
                        backend (ml = auto backend + ML refinement)
  --cluster-threshold   N at or below which cluster/auto fall back to
                        dense NJ
  --tree-ll             record the tree's JC69 log-likelihood (DNA/RNA)
  --k                   k-mer width for the kmer method / sampled center
  --backend / --band    map(1) DP backend registry + band width
  --dist / --mesh       run the shard_map pipeline over a DxM mesh
  --trace-out           write the run's span tree as Chrome-trace JSON
  --metrics-out         write the final metrics snapshot as JSON

``docs/CLI.md`` holds the generated ``--help`` reference for every
launcher (kept in sync by ``tests/test_docs.py``).
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax.numpy as jnp


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="repro.launch.msa_run",
        description="distributed MSA launcher: FASTA in, aligned FASTA + "
                    "tree out")
    ap.add_argument("--fasta", required=True)
    ap.add_argument("--out", default="msa_out")
    ap.add_argument("--method", default="kmer",
                    choices=["kmer", "plain", "sw"])
    ap.add_argument("--alphabet", default="dna",
                    choices=["dna", "rna", "protein"])
    ap.add_argument("--tree", default="nj",
                    choices=["nj", "cluster", "tiled", "auto", "ml", "none"],
                    help="tree backend (repro.phylo registry; nj = dense; "
                         "ml = auto backend + ML refinement)")
    ap.add_argument("--cluster-threshold", type=int, default=64,
                    help="N at or below which cluster/auto tree backends "
                         "fall back to dense NJ")
    ap.add_argument("--tree-ll", action="store_true",
                    help="record the tree's JC69 log-likelihood in the "
                         "report (DNA/RNA only)")
    ap.add_argument("--k", type=int, default=11)
    ap.add_argument("--backend", default="auto",
                    choices=["auto", "jnp", "pallas", "banded",
                             "banded-pallas"],
                    help="map(1) DP backend (repro.align registry)")
    ap.add_argument("--band", type=int, default=64,
                    help="band width for the banded backends (O(n*band) "
                         "direction memory; overflows fall back per pair)")
    ap.add_argument("--dist", action="store_true",
                    help="run the shard_map pipeline (repro.dist.mapreduce)")
    ap.add_argument("--mesh", default=None,
                    help="data x model for --dist, e.g. 4x1; default: all "
                         "visible devices x 1")
    from ..obs import export as obs_export
    obs_export.add_output_args(ap)
    return ap


def main(argv=None):
    parser = build_parser()
    args = parser.parse_args(argv)
    if args.tree == "ml" and args.alphabet == "protein":
        parser.error("--tree ml needs a nucleotide alphabet (the 4-state "
                     "likelihood); use --tree cluster/tiled for protein")
    from ..obs import export as obs_export
    from ..obs import trace as _trace
    with _trace.request_trace(), _trace.span("msa_run", fasta=args.fasta):
        _run(args)
    obs_export.write_outputs(args)


def _run(args):
    from ..obs import trace as _trace
    with _trace.span("load"):
        from ..core import alphabet as ab
        from ..core import likelihood, sp_score
        from ..core.msa import MSAConfig, center_star_msa, decode_msa
        from ..data import read_fasta, write_fasta
        names, seqs = read_fasta(args.fasta)

    alpha = {"dna": ab.DNA, "rna": ab.RNA, "protein": ab.PROTEIN}[args.alphabet]
    cfg = MSAConfig(method=args.method, alphabet=args.alphabet, k=args.k,
                    gap_open=11 if args.alphabet == "protein" else 3,
                    backend=args.backend, band=args.band)
    mesh = None
    if args.dist:
        from .mesh import mesh_from_arg
        mesh = mesh_from_arg(args.mesh)
    t0 = time.time()
    if args.dist:
        from ..dist import mapreduce
        res = mapreduce.msa_over_mesh(seqs, cfg, mesh)
    else:
        res = center_star_msa(seqs, cfg)
    t_msa = time.time() - t0
    out = Path(args.out)
    with _trace.span("write", out=str(out)):
        out.mkdir(parents=True, exist_ok=True)
        write_fasta(out / "aligned.fasta", names, decode_msa(res.msa, cfg))

    with _trace.span("score"):
        msa = jnp.asarray(res.msa)
        sp = float(sp_score.avg_sp(msa, gap_code=alpha.gap_code,
                                   n_chars=alpha.n_chars))
    from ..align import resolve_backend
    report = {"n_sequences": len(seqs), "width": res.width,
              "center": names[res.center_idx],
              "center_mode": res.center_mode,
              "backend": resolve_backend(args.backend),
              "avg_sp_penalty": sp,
              # null under --dist: per-pair fallbacks aren't tracked there
              "kmer_fallbacks": res.n_fallback if res.n_fallback >= 0 else None,
              "msa_seconds": t_msa}

    if args.tree != "none":
        from ..phylo import TreeEngine
        t0 = time.time()
        backend = {"nj": "dense", "ml": "auto"}.get(args.tree, args.tree)
        engine = TreeEngine(gap_code=alpha.gap_code, n_chars=alpha.n_chars,
                            correct=args.alphabet != "protein",
                            backend=backend,
                            cluster_threshold=args.cluster_threshold,
                            mesh=mesh,
                            refine="ml" if args.tree == "ml" else "none")
        tree_res = engine.build(res.msa)
        report["tree_seconds"] = time.time() - t0
        report["tree_backend"] = tree_res.backend
        if tree_res.logl is not None:
            report["tree_model"] = tree_res.model
            report["tree_logl"] = tree_res.logl
        if tree_res.tile_stats is not None:
            report["tile_stats"] = tree_res.tile_stats
        nwk = tree_res.newick(names)
        with _trace.span("write", artifact="tree.nwk"):
            (out / "tree.nwk").write_text(nwk + "\n")
        if args.tree_ll and args.alphabet != "protein":
            report["log_likelihood"] = float(likelihood.log_likelihood(
                msa, jnp.asarray(tree_res.children),
                jnp.asarray(tree_res.blen), tree_res.root,
                gap_code=alpha.gap_code))

    with _trace.span("report"):
        (out / "report.json").write_text(json.dumps(report, indent=1))
    print(json.dumps(report, indent=1))


if __name__ == "__main__":
    main()
