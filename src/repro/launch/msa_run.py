"""Distributed MSA launcher: FASTA in, aligned FASTA + tree out.

Runs the Spark-pattern pipeline on whatever mesh the process sees (one CPU
device here; a real pod under jax.distributed). The same jitted stages are
what dryrun.py lowers for 512 devices.

  PYTHONPATH=src python -m repro.launch.msa_run --fasta in.fa --out out/ \
      --method kmer --tree cluster [--backend banded --band 128] \
      [--dist] [--mesh 4x1]

``--dist`` routes the alignment through ``repro.dist.mapreduce`` (shard_map
over the data axis — identical math, Spark-style execution); the default
path is the single-host driver in ``repro.core.msa``. ``--backend`` picks
the map(1) DP primitive from the ``repro.align`` registry (``auto`` =
Pallas kernel on TPU, jnp scan elsewhere; ``banded`` = O(n·band) memory).
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fasta", required=True)
    ap.add_argument("--out", default="msa_out")
    ap.add_argument("--method", default="kmer",
                    choices=["kmer", "plain", "sw"])
    ap.add_argument("--alphabet", default="dna",
                    choices=["dna", "rna", "protein"])
    ap.add_argument("--tree", default="nj", choices=["nj", "cluster", "none"])
    ap.add_argument("--k", type=int, default=11)
    ap.add_argument("--backend", default="auto",
                    choices=["auto", "jnp", "pallas", "banded"],
                    help="map(1) DP backend (repro.align registry)")
    ap.add_argument("--band", type=int, default=64,
                    help="band width for --backend banded (O(n*band) "
                         "direction memory; overflows fall back per pair)")
    ap.add_argument("--dist", action="store_true",
                    help="run the shard_map pipeline (repro.dist.mapreduce)")
    ap.add_argument("--mesh", default=None,
                    help="data x model for --dist, e.g. 4x1; default: all "
                         "visible devices x 1")
    args = ap.parse_args()

    from ..core import alphabet as ab
    from ..core import cluster as cl
    from ..core import distance, likelihood, nj, sp_score, treeio
    from ..core.msa import MSAConfig, center_star_msa, decode_msa
    from ..data import read_fasta, write_fasta

    names, seqs = read_fasta(args.fasta)
    alpha = {"dna": ab.DNA, "rna": ab.RNA, "protein": ab.PROTEIN}[args.alphabet]
    cfg = MSAConfig(method=args.method, alphabet=args.alphabet, k=args.k,
                    gap_open=11 if args.alphabet == "protein" else 3,
                    backend=args.backend, band=args.band)
    t0 = time.time()
    if args.dist:
        from ..dist import mapreduce
        from .mesh import make_local_mesh
        if args.mesh:
            d, m = (int(x) for x in args.mesh.split("x"))
        else:
            d, m = len(jax.devices()), 1
        mesh = make_local_mesh((d, m), ("data", "model"))
        res = mapreduce.msa_over_mesh(seqs, cfg, mesh)
    else:
        res = center_star_msa(seqs, cfg)
    t_msa = time.time() - t0
    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    write_fasta(out / "aligned.fasta", names, decode_msa(res.msa, cfg))

    msa = jnp.asarray(res.msa)
    sp = float(sp_score.avg_sp(msa, gap_code=alpha.gap_code,
                               n_chars=alpha.n_chars))
    from ..align import resolve_backend
    report = {"n_sequences": len(seqs), "width": res.width,
              "center": names[res.center_idx],
              "center_mode": res.center_mode,
              "backend": resolve_backend(args.backend),
              "avg_sp_penalty": sp,
              # null under --dist: per-pair fallbacks aren't tracked there
              "kmer_fallbacks": res.n_fallback if res.n_fallback >= 0 else None,
              "msa_seconds": t_msa}

    if args.tree != "none":
        t0 = time.time()
        if args.tree == "cluster" and len(seqs) > 64:
            cp = cl.cluster_phylogeny(res.msa, gap_code=alpha.gap_code,
                                      n_chars=alpha.n_chars)
            children, blen, root = cp.children, cp.blen, cp.root
        else:
            D = distance.distance_matrix(msa, gap_code=alpha.gap_code,
                                         n_chars=alpha.n_chars,
                                         correct=args.alphabet != "protein")
            tr = nj.neighbor_joining(D, len(seqs))
            children, blen, root = (np.asarray(tr.children),
                                    np.asarray(tr.blen), int(tr.root))
        report["tree_seconds"] = time.time() - t0
        nwk = treeio.to_newick(children, blen, root, names)
        (out / "tree.nwk").write_text(nwk + "\n")
        if args.alphabet != "protein":
            report["log_likelihood"] = float(likelihood.log_likelihood(
                msa, jnp.asarray(children), jnp.asarray(blen), root,
                gap_code=alpha.gap_code))

    (out / "report.json").write_text(json.dumps(report, indent=1))
    print(json.dumps(report, indent=1))


if __name__ == "__main__":
    main()
