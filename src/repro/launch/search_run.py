"""Homology search launcher: query FASTA vs database FASTA -> top-k hits,
optionally chained all the way to a supported tree per query family.

The front door of the search -> align -> tree pipeline (docs/SEARCH.md):

  PYTHONPATH=src python -m repro.launch.search_run \\
      --db db.fasta --query q.fasta --out search_out/ \\
      [--index db.idx.npz] [--max-hits 10 --max-evalue 1e-3] \\
      [--dist --mesh 2x1] [--pipeline --bootstrap 25]

Writes ``hits.json`` (per-query top-k with bit scores / e-values /
coverage) and ``report.json``; with ``--pipeline`` each query family
(query + its hit sequences) is center-star aligned and treed, yielding
``family_<i>_<query>/aligned.fasta`` + ``tree.nwk`` — with
``--bootstrap`` the Newick carries per-edge support labels.

Flags:
  --db                  database FASTA (required unless --index exists)
  --query               query FASTA (required)
  --index               index artifact path: loaded when present,
                        otherwise built from --db and saved atomically
  --out                 output directory; default search_out
  --alphabet            dna | rna (base-4 k-mer seeding)
  --seed-k              seeding k-mer width (index build; 4^k * r i32
                        table per DB sequence)
  --min-anchors         seed prefilter: chained anchors required to
                        reach the DP rescoring stage
  --max-hits            per-query top-k
  --min-coverage        aligned-column coverage of the query required
  --max-evalue          Karlin-Altschul e-value gate
  --score               local (Smith-Waterman) | global rescoring
  --backend / --band    repro.align DP backend registry + band width
  --exhaustive          skip the prefilter, rescore every pair (oracle)
  --dist / --mesh       shard the seeding stage over a DxM mesh
  --pipeline            chain search -> align -> tree per query family
  --bootstrap           bootstrap replicates for family-tree support
                        (0 = unrefined NJ tree)
  --ml-steps            adam steps per ML fit (pipeline trees)
  --seed                bootstrap / ML seed
  --trace-out           write the run's span tree as Chrome-trace JSON
  --metrics-out         write the final metrics snapshot as JSON
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="repro.launch.search_run",
        description="query-vs-database homology search; --pipeline chains "
                    "search -> align -> tree per query family")
    ap.add_argument("--db", default=None,
                    help="database FASTA (required unless --index exists)")
    ap.add_argument("--query", required=True, help="query FASTA")
    ap.add_argument("--index", default=None,
                    help="index artifact: loaded when present, else built "
                         "from --db and saved atomically")
    ap.add_argument("--out", default="search_out")
    ap.add_argument("--alphabet", default="dna", choices=["dna", "rna"])
    ap.add_argument("--seed-k", type=int, default=6,
                    help="seeding k-mer width (4^k * r int32 per DB seq)")
    ap.add_argument("--min-anchors", type=int, default=1,
                    help="chained anchors required to survive the "
                         "prefilter")
    ap.add_argument("--max-hits", type=int, default=10,
                    help="per-query top-k")
    ap.add_argument("--min-coverage", type=float, default=0.0,
                    help="aligned-column coverage of the query required")
    ap.add_argument("--max-evalue", type=float, default=10.0,
                    help="Karlin-Altschul e-value gate")
    ap.add_argument("--score", default="local",
                    choices=["local", "global"],
                    help="rescoring mode: local Smith-Waterman or global "
                         "Gotoh")
    ap.add_argument("--backend", default="auto",
                    choices=["auto", "jnp", "pallas", "banded",
                             "banded-pallas"],
                    help="rescoring DP backend (repro.align registry)")
    ap.add_argument("--band", type=int, default=64,
                    help="band width for the banded backends")
    ap.add_argument("--exhaustive", action="store_true",
                    help="skip the seed prefilter and rescore every "
                         "(query, DB) pair — the recall oracle")
    ap.add_argument("--dist", action="store_true",
                    help="shard the seeding stage over the mesh "
                         "(repro.dist.mapreduce.search_over_mesh)")
    ap.add_argument("--mesh", default=None,
                    help="data x model mesh, e.g. 2x1; with --dist alone: "
                         "all visible devices x 1")
    ap.add_argument("--pipeline", action="store_true",
                    help="center-star align + tree each query family "
                         "(query + its hits)")
    ap.add_argument("--bootstrap", type=int, default=0,
                    help="bootstrap replicates for family-tree support "
                         "labels (0 = unrefined NJ tree)")
    ap.add_argument("--ml-steps", type=int, default=60,
                    help="adam steps per ML fit for --bootstrap trees")
    ap.add_argument("--seed", type=int, default=0,
                    help="bootstrap / ML seed")
    from ..obs import export as obs_export
    obs_export.add_output_args(ap)
    return ap


def _safe_name(name: str) -> str:
    return "".join(c if c.isalnum() or c in "._-" else "_"
                   for c in name)[:40] or "query"


def main(argv=None):
    parser = build_parser()
    args = parser.parse_args(argv)
    from ..obs import export as obs_export
    from ..obs import trace as _trace
    with _trace.request_trace(), _trace.span("search_run", query=args.query):
        _run(args, parser)
    obs_export.write_outputs(args)


def _run(args, parser):
    from ..obs import trace as _trace
    from ..data import read_fasta, write_fasta
    from ..search import SearchConfig, SearchEngine, SearchIndex

    mesh = None
    if args.dist or args.mesh is not None:
        from .mesh import mesh_from_arg
        mesh = mesh_from_arg(args.mesh)

    cfg = SearchConfig(alphabet=args.alphabet, k=args.seed_k,
                       min_anchors=args.min_anchors,
                       max_hits=args.max_hits,
                       min_coverage=args.min_coverage,
                       max_evalue=args.max_evalue,
                       local=args.score == "local",
                       backend=args.backend, band=args.band)
    engine = SearchEngine(cfg, mesh=mesh)

    t0 = time.time()
    with _trace.span("index"):
        index_path = Path(args.index) if args.index else None
        if index_path is not None and index_path.exists():
            index = SearchIndex.load(index_path)
            if index.k != args.seed_k or index.alphabet != args.alphabet:
                parser.error(
                    f"index {index_path} was built with k={index.k} "
                    f"alphabet={index.alphabet}; rebuild it (delete the "
                    f"file) or pass matching --seed-k/--alphabet")
            index_built = False
        else:
            if args.db is None:
                parser.error("--db is required when --index is absent or "
                             "does not exist yet")
            db_names, db_seqs = read_fasta(args.db)
            index = engine.build_index(db_names, db_seqs)
            if index_path is not None:
                index.save(index_path)
            index_built = True
    t_index = time.time() - t0

    q_names, q_seqs = read_fasta(args.query)
    t0 = time.time()
    with _trace.span("search", n_queries=len(q_seqs)):
        result = engine.search(q_names, q_seqs, index,
                               exhaustive=args.exhaustive)
    t_search = time.time() - t0

    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    (out / "hits.json").write_text(json.dumps(result, indent=1))

    report = {
        "n_queries": len(q_seqs),
        "db_seqs": index.n_seqs, "db_residues": index.db_residues,
        "seed_k": index.k, "index_built": index_built,
        "stats": result["stats"],
        "index_seconds": t_index, "search_seconds": t_search,
        "queries_per_second": (len(q_seqs) / t_search
                               if t_search > 0 else None)}

    if args.pipeline:
        with _trace.span("pipeline", n_queries=len(q_seqs)):
            report["families"] = _run_pipeline(args, out, index, result,
                                               q_names, q_seqs, mesh,
                                               write_fasta)

    (out / "report.json").write_text(json.dumps(report, indent=1))
    print(json.dumps(report, indent=1))


def _run_pipeline(args, out: Path, index, result, q_names, q_seqs, mesh,
                  write_fasta):
    """search -> align -> tree: one family (query + hits) per query."""
    from ..core import alphabet as ab
    from ..core.msa import MSAConfig, center_star_msa, decode_msa
    from ..phylo import TreeEngine

    alpha = {"dna": ab.DNA, "rna": ab.RNA}[args.alphabet]
    msa_cfg = MSAConfig(method="plain", alphabet=args.alphabet,
                        backend=args.backend, band=args.band)
    families = []
    for i, q in enumerate(result["queries"]):
        fam_dir = out / f"family_{i:03d}_{_safe_name(q['name'])}"
        names = [q["name"]] + [h["target"] for h in q["hits"]]
        seqs = [q_seqs[i]] + [_db_seq(index, h["db_idx"], alpha)
                              for h in q["hits"]]
        info = {"query": q["name"], "n_members": len(seqs),
                "dir": fam_dir.name}
        if len(seqs) < 3:
            info["skipped"] = "family needs >= 3 members for a tree"
            families.append(info)
            continue
        fam_dir.mkdir(parents=True, exist_ok=True)
        res = center_star_msa(seqs, msa_cfg)
        write_fasta(fam_dir / "aligned.fasta", names,
                    decode_msa(res.msa, msa_cfg))
        refine = "ml" if args.bootstrap > 0 and len(seqs) >= 4 else "none"
        engine = TreeEngine(gap_code=alpha.gap_code, n_chars=alpha.n_chars,
                            backend="dense", mesh=mesh, refine=refine,
                            bootstrap=args.bootstrap if refine == "ml" else 0,
                            ml_steps=args.ml_steps, seed=args.seed)
        tree = engine.build(res.msa)
        (fam_dir / "tree.nwk").write_text(tree.newick(names) + "\n")
        info.update(width=res.width, tree_backend=tree.backend,
                    refine=refine)
        if tree.support is not None:
            import numpy as np
            finite = tree.support[np.isfinite(tree.support)]
            info["mean_support"] = (round(float(finite.mean()), 4)
                                    if finite.size else None)
        families.append(info)
    return families


def _db_seq(index, db_idx: int, alpha) -> str:
    row = index.S[db_idx][: int(index.lens[db_idx])]
    return alpha.decode(row)


if __name__ == "__main__":
    main()
