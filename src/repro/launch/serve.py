"""LM serving launcher: batched prefill + decode loop with KV/SSM caches.

This is the *language-model* serving path (one-shot benchmark of the
``train.serve_step`` prefill/decode builders) — the MSA/phylogeny web
service lives in ``repro.launch.serve_msa``.

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-130m --smoke \
      --batch 4 --prompt-len 32 --gen 16

Flags:
  --arch          reference architecture name (repro.configs registry)
  --batch         concurrent decode sequences
  --prompt-len    prefill length (tokens)
  --gen           tokens to generate per sequence
  --smoke         use the reduced smoke config (CPU-friendly)
"""
from __future__ import annotations

import argparse
import time


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="repro.launch.serve",
        description="LM serving benchmark: batched prefill + decode with "
                    "KV/SSM caches (MSA service: repro.launch.serve_msa)")
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--smoke", action="store_true")
    return ap


def main():
    args = build_parser().parse_args()

    import jax
    import jax.numpy as jnp

    from ..configs import get_arch
    from ..models.transformer import init_params
    from ..train.serve_step import make_decode_step, make_prefill_step

    spec = get_arch(args.arch)
    cfg = spec.smoke if args.smoke else spec.config
    if not cfg.has_decode:
        raise SystemExit(f"{args.arch} is encoder-only; no decode")
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    max_len = args.prompt_len + args.gen

    prefill = jax.jit(make_prefill_step(cfg, max_len=max_len))
    decode = jax.jit(make_decode_step(cfg))

    toks = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                              cfg.vocab_size)
    t0 = time.time()
    logits, cache = prefill(params, {"tokens": toks})
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    out = [jnp.argmax(logits, -1).astype(jnp.int32)]
    pos = jnp.full((args.batch,), args.prompt_len, jnp.int32)
    t0 = time.time()
    for _ in range(args.gen - 1):
        logits, cache = decode(params, cache, out[-1], pos)
        out.append(jnp.argmax(logits, -1).astype(jnp.int32))
        pos = pos + 1
    jax.block_until_ready(logits)
    t_decode = time.time() - t0
    gen = jnp.stack(out, 1)
    print(f"prefill {args.batch}x{args.prompt_len}: {t_prefill * 1e3:.1f} ms; "
          f"decode {args.gen - 1} steps: "
          f"{t_decode / max(args.gen - 1, 1) * 1e3:.1f} ms/tok")
    print("sample tokens:", gen[0][:10].tolist())


if __name__ == "__main__":
    main()
