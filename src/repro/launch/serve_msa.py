"""MSA/phylogeny web service launcher: the paper's web-server pillar.

  PYTHONPATH=src python -m repro.launch.serve_msa --port 8642 \\
      [--method plain --backend auto] [--dist --mesh 4x1]

Serves ``repro.serve.MSAService`` over stdlib HTTP/JSON:

  POST /align      {"fasta": ">a\\nACGT..."} or {"sequences": [...],
                   "names": [...]} -> aligned rows + msa_id; with
                   ?name=... (or "name" in the body) and --store-dir:
                   create/load a persistent named alignment
  POST /align/add  {"msa_id": ..., "fasta"/"sequences": ...} ->
                   incremental insertion against the frozen center;
                   {"name": ...} ingests into the store (one atomic
                   generation per add, background realign past drift)
  POST /tree       {"msa_id": ...}, {"name": ...} or sequences -> Newick
  POST /search     query sequences -> per-query top-k database hits
                   (needs --search-db / --search-index)
  GET  /healthz    liveness + cache / coalescing-queue stats
  GET  /metrics    Prometheus text exposition of the repro.obs registry
  GET  /statusz    human-readable status page (config, queues, spans)

Flags:
  --host/--port         bind address (default 127.0.0.1:8642)
  --alphabet            dna | rna | protein (server-wide engine config)
  --method              plain | sw | kmer map(1) path; kmer requests run
                        uncoalesced (per-center index)
  --backend/--band      repro.align DP backend registry + band width
  --k/--center          k-mer width / center selection policy
  --max-batch           coalescing: flush a merged batch at this many pairs
  --max-wait-ms         coalescing: max time a request waits for company
  --cache-mb            result-cache byte budget (content-hash LRU)
  --drift-threshold     /align/add width growth past which a full realign
                        replaces the incremental merge (named alignments:
                        cumulative growth scheduling a background realign)
  --store-dir           persistent MSAStore root enabling named
                        alignments that survive restarts
  --store-keep          generation files retained per named alignment
  --store-realign       background (realign + atomic swap) | never
  --tree-backend        repro.phylo registry default for /tree
  --tree-refine         none | ml default /tree refinement (requests can
                        override per call with {"refine": "ml"})
  --tree-model          substitution model for refine=ml (auto = BIC)
  --tree-bootstrap      default bootstrap replicate count for refine=ml
  --tree-seed           default bootstrap/ML seed (part of the tree
                        cache fingerprint)
  --cluster-threshold   N at or below which cluster/auto trees go dense
  --search-db           database FASTA enabling POST /search
  --search-index        search-index artifact: loaded when present, else
                        built from --search-db and saved atomically
  --search-k            seeding k-mer width for --search-db index builds
  --dist/--mesh         shard requests of >= --dist-threshold sequences
                        over the mesh (repro.dist.mapreduce) and shard-map
                        /tree distance strips over it
  --verbose             log one line per HTTP request
  --trace-out           on exit, write the span tree as Chrome-trace JSON
  --metrics-out         on exit, write the final metrics snapshot as JSON

SIGINT/SIGTERM drain gracefully: the listener stops, in-flight requests
finish, and the coalescing queue flushes before exit.
"""
from __future__ import annotations

import argparse
import signal


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="repro.launch.serve_msa",
        description="MSA/phylogeny web service over the repro engines")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=8642)
    ap.add_argument("--alphabet", default="dna",
                    choices=["dna", "rna", "protein"])
    ap.add_argument("--method", default="plain",
                    choices=["plain", "sw", "kmer"],
                    help="map(1) path; kmer requests run uncoalesced")
    ap.add_argument("--backend", default="auto",
                    choices=["auto", "jnp", "pallas", "banded",
                             "banded-pallas"],
                    help="map(1) DP backend (repro.align registry)")
    ap.add_argument("--band", type=int, default=64,
                    help="band width for the banded backends")
    ap.add_argument("--k", type=int, default=11, help="k-mer width")
    ap.add_argument("--center", default="first",
                    choices=["first", "sampled"],
                    help="center selection policy")
    ap.add_argument("--max-batch", type=int, default=256,
                    help="coalescing: flush at this many merged pairs")
    ap.add_argument("--max-wait-ms", type=float, default=5.0,
                    help="coalescing: max wait for request company")
    ap.add_argument("--cache-mb", type=int, default=256,
                    help="result cache byte budget (MiB)")
    ap.add_argument("--drift-threshold", type=float, default=0.25,
                    help="align/add relative width growth forcing a full "
                         "realign (for named alignments: the cumulative "
                         "growth that schedules a background realign)")
    ap.add_argument("--store-dir", default=None,
                    help="persistent MSA store root: enables named "
                         "alignments (/align?name=...) with atomic "
                         "generation commits surviving restarts")
    ap.add_argument("--store-keep", type=int, default=4,
                    help="generation files retained per named alignment")
    ap.add_argument("--store-realign", default="background",
                    choices=["background", "never"],
                    help="drift response for named alignments: realign on "
                         "a worker thread and swap atomically, or never")
    ap.add_argument("--tree-backend", default="auto",
                    choices=["auto", "dense", "tiled", "cluster"],
                    help="default /tree backend (repro.phylo registry)")
    ap.add_argument("--tree-refine", default="none",
                    choices=["none", "ml"],
                    help="default /tree refinement (requests can override "
                         "with {'refine': 'ml'})")
    ap.add_argument("--tree-model", default="auto",
                    choices=["auto", "jc69", "k80", "hky85", "gtr"],
                    help="substitution model for refine=ml (auto = BIC)")
    ap.add_argument("--tree-bootstrap", type=int, default=0,
                    help="default bootstrap replicates (requires "
                         "refine=ml; requests without it get a 400)")
    ap.add_argument("--tree-seed", type=int, default=0,
                    help="default bootstrap/ML seed (requests can "
                         "override with {'seed': N})")
    ap.add_argument("--cluster-threshold", type=int, default=64,
                    help="N at or below which cluster/auto trees go dense")
    ap.add_argument("--search-db", default=None,
                    help="database FASTA enabling POST /search")
    ap.add_argument("--search-index", default=None,
                    help="search-index artifact: loaded when present, "
                         "else built from --search-db and saved")
    ap.add_argument("--search-k", type=int, default=6,
                    help="seeding k-mer width for --search-db builds")
    ap.add_argument("--dist", action="store_true",
                    help="route large requests through repro.dist.mapreduce")
    ap.add_argument("--mesh", default=None,
                    help="data x model for --dist, e.g. 4x1; default: all "
                         "visible devices x 1")
    ap.add_argument("--dist-threshold", type=int, default=512,
                    help="with --dist: sequence count at which a request "
                         "goes over the mesh")
    ap.add_argument("--verbose", action="store_true",
                    help="log one line per HTTP request")
    from ..obs import export as obs_export
    obs_export.add_output_args(ap)
    return ap


def main(argv=None):
    parser = build_parser()
    args = parser.parse_args(argv)
    if args.tree_bootstrap > 0 and args.tree_refine != "ml":
        parser.error("--tree-bootstrap requires --tree-refine ml "
                     "(otherwise every plain /tree request would 400)")

    from ..serve import MSAService, ServiceConfig, serve_http

    mesh = None
    if args.dist:
        from .mesh import mesh_from_arg
        mesh = mesh_from_arg(args.mesh)

    search_index = None
    if args.search_db or args.search_index:
        if args.alphabet == "protein":
            parser.error("--search-db needs a nucleotide --alphabet "
                         "(base-4 k-mer seeding)")
        from pathlib import Path

        from ..search import SearchIndex
        idx_path = Path(args.search_index) if args.search_index else None
        if idx_path is not None and idx_path.exists():
            search_index = SearchIndex.load(idx_path)
        else:
            if not args.search_db:
                parser.error(f"--search-index {idx_path} does not exist; "
                             f"pass --search-db to build it")
            from ..data import read_fasta
            db_names, db_seqs = read_fasta(args.search_db)
            search_index = SearchIndex.build(db_names, db_seqs,
                                             k=args.search_k,
                                             alphabet=args.alphabet)
            if idx_path is not None:
                search_index.save(idx_path)

    service = MSAService(ServiceConfig(
        alphabet=args.alphabet, method=args.method, backend=args.backend,
        band=args.band, k=args.k, center=args.center,
        max_batch=args.max_batch, max_wait_ms=args.max_wait_ms,
        cache_bytes=args.cache_mb << 20,
        drift_threshold=args.drift_threshold,
        store_dir=args.store_dir, store_keep=args.store_keep,
        store_realign=args.store_realign,
        tree_backend=args.tree_backend,
        tree_refine=args.tree_refine,
        tree_model=args.tree_model,
        tree_bootstrap=args.tree_bootstrap,
        tree_seed=args.tree_seed,
        cluster_threshold=args.cluster_threshold,
        mesh=mesh, dist_threshold=args.dist_threshold,
        search_index=search_index))
    httpd = serve_http(service, args.host, args.port, verbose=args.verbose)

    def _shutdown(signum, frame):
        # runs on the main thread; shutdown() must come from another
        # thread, so just flip the flag serve_forever polls
        raise KeyboardInterrupt

    signal.signal(signal.SIGTERM, _shutdown)
    store_note = ""
    if service.store is not None:
        restored = service.store.names()
        store_note = (f" store={args.store_dir}"
                      f"[{len(restored)} named alignment(s)]")
    print(f"serving MSA/phylogeny on http://{args.host}:{args.port} "
          f"(alphabet={args.alphabet} method={args.method} "
          f"backend={service.engine.backend}"
          f"{' mesh' if mesh is not None else ''}"
          f"{f' search_db={search_index.n_seqs}' if search_index else ''}"
          f"{store_note})"
          f" — Ctrl-C drains")
    try:
        httpd.serve_forever()
    except KeyboardInterrupt:
        pass
    print("draining: finishing in-flight requests ...")
    httpd.server_close()          # waits for handler threads
    service.drain()               # flush the coalescing queue
    from ..obs import export as obs_export
    obs_export.write_outputs(args)
    print("drained; bye")


if __name__ == "__main__":
    main()
