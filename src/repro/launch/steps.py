"""Step builders for the dry-run and launchers: per (arch x shape x mesh),
produce the jitted step function plus ShapeDtypeStruct stand-ins for every
input (weak-type-correct, shardable, zero allocation).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs import SHAPES, get_arch, shape_applicable
from ..models import sharding_plan as sp
from ..models.transformer import init_cache, init_params
from ..train import optimizer as opt
from ..train.optimizer import AdamWConfig
from ..train.serve_step import make_decode_step, make_prefill_step
from ..train.train_step import TrainState, init_state, make_train_step


def _sds(tree):
    return jax.tree.map(lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def input_specs(arch_id: str, shape_name: str) -> Dict[str, Any]:
    """ShapeDtypeStructs for the model inputs of this (arch, shape) cell."""
    cfg = get_arch(arch_id).config
    shape = SHAPES[shape_name]
    B, S = shape.global_batch, shape.seq_len
    specs: Dict[str, Any] = {}
    if shape.kind in ("train", "prefill"):
        if cfg.embed_input:
            specs["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        else:
            specs["embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model),
                                                   jnp.bfloat16)
        if cfg.m_rope:
            specs["pos3"] = jax.ShapeDtypeStruct((3, B, S), jnp.int32)
        if shape.kind == "train":
            specs["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    else:  # decode
        if cfg.embed_input:
            specs["token"] = jax.ShapeDtypeStruct((B,), jnp.int32)
        else:
            specs["token"] = jax.ShapeDtypeStruct((B, cfg.d_model), jnp.bfloat16)
        specs["pos"] = jax.ShapeDtypeStruct((B,), jnp.int32)
    return specs


def microbatches_for(arch_id: str, shape_name: str, mesh) -> int:
    spec = get_arch(arch_id)
    mu = spec.microbatch_overrides.get(shape_name, 1)
    shape = SHAPES[shape_name]
    dp = sp._dp_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    while mu > 1 and (shape.global_batch // mu) % dp_size != 0:
        mu //= 2
    return max(mu, 1)


def build_step(arch_id: str, shape_name: str, mesh, *,
               adamw: AdamWConfig = AdamWConfig(), roofline: bool = False):
    """Returns (jitted_fn, args_tuple_of_SDS, out_shardings_info).

    roofline=True unrolls the layer scan and forces microbatches=1 so
    cost_analysis / collective parses count every layer exactly once per
    step; benchmarks/roofline.py multiplies back the microbatch factor.
    """
    import dataclasses as _dc
    spec = get_arch(arch_id)
    cfg = spec.config
    if roofline:
        cfg = _dc.replace(cfg, unroll_layers=True)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        raise ValueError(f"{arch_id} x {shape_name} skipped: {why}")

    key = jax.random.PRNGKey(0)
    B = shape.global_batch
    batch_sds = input_specs(arch_id, shape_name)
    shard_fns = sp.make_shard_fns(cfg, mesh, B)

    params_shape = jax.eval_shape(functools.partial(init_params, cfg), key)
    pspecs = sp.params_pspecs(params_shape, mesh)
    psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                       is_leaf=lambda x: isinstance(x, P))

    if shape.kind == "train":
        # roofline keeps the production microbatch count: the micro-scan body
        # (counted once by cost_analysis) is homogeneous, so benchmarks/
        # roofline.py multiplies the step totals by mu exactly.
        mu = microbatches_for(arch_id, shape_name, mesh)
        state_shape = jax.eval_shape(functools.partial(init_state, cfg), key)
        state_sh = TrainState(
            params=psh,
            opt=opt.OptState(m=psh, v=psh,
                             count=NamedSharding(mesh, P())),
            step=NamedSharding(mesh, P()))
        bspecs = sp.batch_pspecs(cfg, "train", B, mesh, batch_sds)
        bsh = {k: NamedSharding(mesh, v) for k, v in bspecs.items()}
        fn = make_train_step(cfg, adamw, microbatches=mu, shard_fns=shard_fns,
                             grad_shardings=psh)
        jitted = jax.jit(fn, in_shardings=(state_sh, bsh),
                         out_shardings=(state_sh, None))
        return jitted, (_sds(state_shape), batch_sds)

    if shape.kind == "prefill":
        bspecs = sp.batch_pspecs(cfg, "prefill", B, mesh, batch_sds)
        bsh = {k: NamedSharding(mesh, v) for k, v in bspecs.items()}
        cache_shape = jax.eval_shape(
            functools.partial(init_cache, cfg, B, shape.seq_len))
        csh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                           sp.cache_pspecs(cfg, cache_shape, B, mesh),
                           is_leaf=lambda x: isinstance(x, P))
        fn = make_prefill_step(cfg, shard_fns=shard_fns, max_len=shape.seq_len)
        if not cfg.has_decode:
            # encoder: full forward, no cache output
            from ..models.transformer import apply_model

            def enc_fn(params, batch):
                logits, _, _ = apply_model(params, cfg, batch,
                                           shard_fns=shard_fns)
                return logits
            jitted = jax.jit(enc_fn, in_shardings=(psh, bsh),
                             out_shardings=None)
        else:
            jitted = jax.jit(fn, in_shardings=(psh, bsh),
                             out_shardings=(None, csh))
        return jitted, (params_shape, batch_sds)

    # decode
    cache_shape = jax.eval_shape(
        functools.partial(init_cache, cfg, B, shape.seq_len))
    csh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                       sp.cache_pspecs(cfg, cache_shape, B, mesh),
                       is_leaf=lambda x: isinstance(x, P))
    dp = sp._dp_axes(mesh)
    dp_size = 1
    for a in dp:
        dp_size *= mesh.shape[a]
    tok_ax = dp if B % dp_size == 0 else None
    tok_sh = NamedSharding(mesh, P(tok_ax)) if cfg.embed_input else \
        NamedSharding(mesh, P(tok_ax, None))
    pos_sh = NamedSharding(mesh, P(tok_ax))
    fn = make_decode_step(cfg, shard_fns=shard_fns)
    jitted = jax.jit(fn, in_shardings=(psh, csh, tok_sh, pos_sh),
                     out_shardings=(None, csh))
    sds = input_specs(arch_id, shape_name)
    return jitted, (params_shape, _sds(cache_shape), sds["token"], sds["pos"])


# --------------------------------------------------------- MSA (paper) cells

MSA_CELLS = {
    # name: (N sequences, padded length, method, alphabet, k, map_chunks)
    "halign-dna-1000x": (671744, 16576, "kmer", "dna", 11, 1),
    "halign-rna-large": (1011712, 1600, "kmer", "dna", 11, 1),
    "halign-protein-100x": (1789952, 512, "sw", "protein", 0, 1),
    # §Perf variants: local shard processed in sequential chunks to bound
    # per-device temp memory (before/after recorded in EXPERIMENTS.md)
    "halign-dna-1000x-chunked": (671744, 16576, "kmer", "dna", 11, 8),
    "halign-protein-100x-chunked": (1789952, 512, "sw", "protein", 0, 8),
}


def build_msa_step(cell: str, mesh):
    """Lower the distributed center-star MSA (the paper's own workload)."""
    import jax.numpy as jnp

    from ..core import alphabet as ab
    from ..dist import mapreduce

    N, L, method, alpha_name, k, map_chunks = MSA_CELLS[cell]
    alpha = ab.PROTEIN if alpha_name == "protein" else ab.DNA
    sub = (ab.blosum62() if alpha_name == "protein"
           else ab.dna_matrix()).astype(jnp.float32)
    out_len = L + 4096
    fn = mapreduce.distributed_center_star(
        mesh, method=method, sub=sub, gap_code=alpha.gap_code,
        out_len=out_len, num_slots=L + 1,
        gap_open=11 if alpha_name == "protein" else 3, gap_extend=1,
        k=k or 11, max_anchors=256, max_seg=64, map_chunks=map_chunks)
    Q = jax.ShapeDtypeStruct((N, L), jnp.int8)
    lens = jax.ShapeDtypeStruct((N,), jnp.int32)
    center = jax.ShapeDtypeStruct((L,), jnp.int8)
    lc = jax.ShapeDtypeStruct((), jnp.int32)
    if method == "kmer":
        table = jax.ShapeDtypeStruct((4 ** (k or 11), 4), jnp.int32)
        return fn, (Q, lens, center, lc, table)
    return fn, (Q, lens, center, lc)
