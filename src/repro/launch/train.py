"""LM training launcher with the full resilience stack: sharded state,
microbatched steps, async atomic checkpoints, failure replay, elastic
restore. Scaled to whatever devices exist (1 CPU here; a pod in prod).

  PYTHONPATH=src python -m repro.launch.train --arch gemma-2b --steps 100 \
      --batch 8 --seq 128 --ckpt-dir ckpt/ [--smoke] [--resume]

Flags:
  --arch          reference architecture name (repro.configs registry)
  --steps         optimizer steps to run
  --batch/--seq   global batch size / sequence length
  --micro         microbatch count (gradient accumulation)
  --lr            AdamW learning rate
  --ckpt-dir      checkpoint directory (enables async atomic saves)
  --ckpt-every    save cadence in steps
  --smoke         reduced smoke config (CPU-friendly)
  --mesh          data x model device mesh, e.g. 4x2
  --resume        restore the newest checkpoint in --ckpt-dir first
"""
from __future__ import annotations

import argparse
import functools
import time


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="repro.launch.train",
        description="LM training with the full resilience stack")
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--micro", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config (CPU-friendly)")
    ap.add_argument("--mesh", default="1x1",
                    help="data x model, e.g. 4x2 (needs that many devices)")
    ap.add_argument("--resume", action="store_true",
                    help="restore the newest checkpoint in --ckpt-dir first")
    return ap


def main():
    ap = build_parser()
    args = ap.parse_args()
    if args.resume and not args.ckpt_dir:
        ap.error("--resume requires --ckpt-dir")

    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from ..configs import get_arch
    from ..dist.checkpoint import CheckpointManager
    from ..dist.fault import ResilientLoop
    from ..launch.mesh import make_local_mesh
    from ..models import sharding_plan as sp
    from ..train import optimizer as opt
    from ..train.optimizer import AdamWConfig
    from ..train.train_step import TrainState, init_state, make_train_step

    spec = get_arch(args.arch)
    cfg = spec.smoke if args.smoke else spec.config
    d, m = (int(x) for x in args.mesh.split("x"))
    mesh = make_local_mesh((d, m), ("data", "model"))

    key = jax.random.PRNGKey(0)
    state_shape = jax.eval_shape(functools.partial(init_state, cfg), key)
    pspecs = sp.params_pspecs(state_shape.params, mesh)
    psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                       is_leaf=lambda x: isinstance(x, P))
    state_sh = TrainState(params=psh,
                          opt=opt.OptState(m=psh, v=psh,
                                           count=NamedSharding(mesh, P())),
                          step=NamedSharding(mesh, P()))
    shard_fns = sp.make_shard_fns(cfg, mesh, args.batch)
    step_fn = make_train_step(cfg, AdamWConfig(lr=args.lr),
                              microbatches=args.micro, shard_fns=shard_fns)
    jitted = jax.jit(step_fn, in_shardings=(state_sh, None),
                     out_shardings=(state_sh, None))

    state = jax.device_put(init_state(cfg, key), state_sh)

    def batches(step):
        k = jax.random.PRNGKey(step)
        toks = jax.random.randint(k, (args.batch, args.seq), 0,
                                  cfg.vocab_size)
        return {"tokens": toks, "labels": toks}

    last = {"m": None}

    def step_and_log(st, batch):
        st, metrics = jitted(st, batch)
        last["m"] = metrics
        return st

    t0 = time.time()
    if args.ckpt_dir:
        cm = CheckpointManager(args.ckpt_dir, keep=3)
        loop = ResilientLoop(step_and_log, cm, ckpt_every=args.ckpt_every,
                             state_shardings=state_sh)

        class B:
            n_steps = args.steps

            def __call__(self, s):
                return batches(s)
        state, steps = loop.run(state, B(), resume=args.resume)
    else:
        for s in range(args.steps):
            state = step_and_log(state, batches(s))
        steps = args.steps
    dt = time.time() - t0
    if last["m"] is None:        # --resume past --steps: nothing left to run
        print(f"done: already at step {steps}, no steps to run")
        return
    m = jax.tree.map(float, last["m"])
    print(f"done: {steps} steps in {dt:.1f}s "
          f"({dt / max(steps, 1) * 1e3:.0f} ms/step) loss={m['loss']:.4f} "
          f"grad_norm={m['grad_norm']:.3f}")


if __name__ == "__main__":
    main()
