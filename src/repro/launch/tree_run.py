"""Tree reconstruction from an already-aligned FASTA — no MSA redo.

The second half of the paper's title as its own launcher: point it at the
``aligned.fasta`` an earlier ``msa_run`` produced (or any aligned FASTA)
and it dispatches through the ``repro.phylo.TreeEngine``.

  PYTHONPATH=src python -m repro.launch.tree_run --fasta aligned.fasta \
      --out tree_out/ --backend tiled [--row-block 128] [--dist --mesh 4x1]

Outputs ``tree.nwk`` and ``report.json`` (effective backend, timings, and
for tiled backends the tile accountant's memory stats — peak resident
distance storage vs the one-row-block-strip budget).

Flags:
  --fasta               aligned FASTA, equal-width rows (required)
  --out                 output directory; default tree_out
  --alphabet            dna | rna | protein row encoding
  --backend             auto | dense | tiled | cluster (repro.phylo)
  --cluster-threshold   N at or below which cluster/auto go dense
  --row-block           tiled backend's strip height (per-host distance
                        budget = row_block * N * 4 bytes)
  --target-cluster      desired leaves per HPTree cluster
  --seed                sketch-sampling seed
  --tree-ll             also score the tree by JC69 log-likelihood
  --dist / --mesh       shard-map the distance strips over a DxM mesh
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

import numpy as np


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="repro.launch.tree_run",
        description="tree reconstruction from an already-aligned FASTA")
    ap.add_argument("--fasta", required=True,
                    help="aligned FASTA (equal-width rows, '-' for gaps)")
    ap.add_argument("--out", default="tree_out")
    ap.add_argument("--alphabet", default="dna",
                    choices=["dna", "rna", "protein"])
    ap.add_argument("--backend", default="auto",
                    choices=["auto", "dense", "tiled", "cluster"],
                    help="tree backend (repro.phylo registry)")
    ap.add_argument("--cluster-threshold", type=int, default=64,
                    help="N at or below which cluster/auto fall back to "
                         "dense NJ")
    ap.add_argument("--row-block", type=int, default=128,
                    help="tile row-block: the tiled backend's per-host "
                         "distance budget is row_block * N * 4 bytes")
    ap.add_argument("--target-cluster", type=int, default=64,
                    help="desired leaves per HPTree cluster")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--tree-ll", action="store_true",
                    help="also score the tree by JC69 log-likelihood "
                         "(DNA/RNA only)")
    ap.add_argument("--dist", action="store_true",
                    help="shard-map the distance strips over the mesh")
    ap.add_argument("--mesh", default=None,
                    help="data x model for --dist, e.g. 4x1; default: all "
                         "visible devices x 1")
    return ap


def main(argv=None):
    args = build_parser().parse_args(argv)

    from ..core import alphabet as ab
    from ..core import likelihood
    from ..data import read_fasta
    from ..phylo import TreeEngine

    names, seqs = read_fasta(args.fasta)
    widths = {len(s) for s in seqs}
    if len(widths) != 1:
        raise ValueError(
            f"{args.fasta} is not aligned (row widths {sorted(widths)[:5]}"
            f"...); run repro.launch.msa_run first")
    alpha = {"dna": ab.DNA, "rna": ab.RNA, "protein": ab.PROTEIN}[args.alphabet]
    msa = np.stack([alpha.encode_aligned(s) for s in seqs])

    mesh = None
    if args.dist:
        from .mesh import mesh_from_arg
        mesh = mesh_from_arg(args.mesh)

    engine = TreeEngine(gap_code=alpha.gap_code, n_chars=alpha.n_chars,
                        correct=args.alphabet != "protein",
                        backend=args.backend,
                        cluster_threshold=args.cluster_threshold,
                        row_block=args.row_block,
                        target_cluster=args.target_cluster,
                        seed=args.seed, mesh=mesh)
    result = engine.build(msa)

    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    (out / "tree.nwk").write_text(result.newick(names) + "\n")
    report = {"n_sequences": result.n_leaves, "width": msa.shape[1],
              "backend": result.backend, "requested_backend": args.backend,
              "tree_seconds": result.timings["total_seconds"],
              "tile_stats": result.tile_stats}
    if args.tree_ll and args.alphabet != "protein":
        import jax.numpy as jnp
        report["log_likelihood"] = float(likelihood.log_likelihood(
            jnp.asarray(msa), jnp.asarray(result.children),
            jnp.asarray(result.blen), result.root, gap_code=alpha.gap_code))
    (out / "report.json").write_text(json.dumps(report, indent=1))
    print(json.dumps(report, indent=1))


if __name__ == "__main__":
    main()
