"""Tree reconstruction from an already-aligned FASTA — no MSA redo.

The second half of the paper's title as its own launcher: point it at the
``aligned.fasta`` an earlier ``msa_run`` produced (or any aligned FASTA)
and it dispatches through the ``repro.phylo.TreeEngine``.

  PYTHONPATH=src python -m repro.launch.tree_run --fasta aligned.fasta \
      --out tree_out/ --backend tiled [--row-block 128] [--dist --mesh 4x1] \
      [--refine ml --model auto --bootstrap 100]

Outputs ``tree.nwk`` (with per-edge bootstrap support labels when
``--bootstrap`` ran) and ``report.json`` (effective backend, timings, for
tiled backends the tile accountant's memory stats, for ``--refine ml`` /
``search`` the selected model, per-model BIC, and logL before/after, and
for ``search`` the per-start trajectories and move counts).

Flags:
  --fasta               aligned FASTA, equal-width rows (required)
  --out                 output directory; default tree_out
  --alphabet            dna | rna | protein row encoding
  --backend             auto | dense | tiled | cluster (repro.phylo)
  --cluster-threshold   N at or below which cluster/auto go dense
  --row-block           tiled backend's strip height (per-host distance
                        budget = row_block * N * 4 bytes)
  --target-cluster      desired leaves per HPTree cluster
  --seed                sketch-sampling + bootstrap seed
  --tree-ll             also score the tree by JC69 log-likelihood
  --refine              none | ml | search: ml = single-start ML
                        refinement (autodiff branch lengths + vmapped
                        NNI), search = the multi-start NNI+SPR fleet
                        (repro.phylo.treesearch); DNA/RNA only
  --model               substitution model for --refine ml/search
                        (auto = select by BIC)
  --bootstrap           nonparametric bootstrap replicates for per-edge
                        support (0 = off; shards over --mesh)
  --ml-steps            adam steps per ML fit
  --nni-rounds          max accepted NNI rounds (--refine ml)
  --starts              fleet size K for --refine search
  --spr-radius          SPR regraft radius for --refine search
  --search-rounds       max move rounds for --refine search
  --restartable         checkpoint the search fleet per round
                        (to --ckpt-dir, default <out>/search_ckpt)
  --ckpt-dir            search checkpoint directory (implies
                        --restartable)
  --resume              resume a killed --restartable search from its
                        newest checkpoint
  --dist / --mesh       shard-map distance strips (and bootstrap
                        replicates) over a DxM mesh
  --trace-out           write the run's span tree as Chrome-trace JSON
  --metrics-out         write the final metrics snapshot as JSON
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

import numpy as np


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        prog="repro.launch.tree_run",
        description="tree reconstruction from an already-aligned FASTA")
    ap.add_argument("--fasta", required=True,
                    help="aligned FASTA (equal-width rows, '-' for gaps)")
    ap.add_argument("--out", default="tree_out")
    ap.add_argument("--alphabet", default="dna",
                    choices=["dna", "rna", "protein"])
    ap.add_argument("--backend", default="auto",
                    choices=["auto", "dense", "tiled", "cluster"],
                    help="tree backend (repro.phylo registry)")
    ap.add_argument("--cluster-threshold", type=int, default=64,
                    help="N at or below which cluster/auto fall back to "
                         "dense NJ")
    ap.add_argument("--row-block", type=int, default=128,
                    help="tile row-block: the tiled backend's per-host "
                         "distance budget is row_block * N * 4 bytes")
    ap.add_argument("--target-cluster", type=int, default=64,
                    help="desired leaves per HPTree cluster")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--tree-ll", action="store_true",
                    help="also score the tree by JC69 log-likelihood "
                         "(DNA/RNA only)")
    ap.add_argument("--refine", default="none",
                    choices=["none", "ml", "search"],
                    help="ml = single-start ML refinement "
                         "(repro.phylo.ml), search = the multi-start "
                         "NNI+SPR fleet (repro.phylo.treesearch); "
                         "DNA/RNA only")
    ap.add_argument("--model", default="auto",
                    choices=["auto", "jc69", "k80", "hky85", "gtr"],
                    help="substitution model for --refine ml/search "
                         "(auto = select by BIC)")
    ap.add_argument("--bootstrap", type=int, default=0,
                    help="bootstrap replicates for per-edge support "
                         "labels (0 = off; requires --refine ml or "
                         "search; shards over --mesh)")
    ap.add_argument("--ml-steps", type=int, default=150,
                    help="adam steps per ML branch-length/model fit")
    ap.add_argument("--nni-rounds", type=int, default=8,
                    help="max accepted NNI rounds for --refine ml")
    ap.add_argument("--starts", type=int, default=4,
                    help="fleet size K for --refine search (start "
                         "topologies: NJ, cluster-medoid, random "
                         "stepwise addition)")
    ap.add_argument("--spr-radius", type=int, default=3,
                    help="SPR regraft radius (hops from the prune wound) "
                         "for --refine search")
    ap.add_argument("--search-rounds", type=int, default=12,
                    help="max move rounds per search for --refine search")
    ap.add_argument("--restartable", action="store_true",
                    help="checkpoint the search fleet per round through "
                         "dist.checkpoint (to --ckpt-dir, default "
                         "<out>/search_ckpt); a killed run resumes "
                         "bit-identically with --resume")
    ap.add_argument("--ckpt-dir", default=None,
                    help="search checkpoint directory (implies "
                         "--restartable)")
    ap.add_argument("--resume", action="store_true",
                    help="resume a killed --restartable search from its "
                         "newest checkpoint")
    ap.add_argument("--dist", action="store_true",
                    help="shard-map the distance strips over the mesh")
    ap.add_argument("--mesh", default=None,
                    help="data x model mesh, e.g. 4x1 — builds the mesh "
                         "even without --dist (sharding ML bootstrap "
                         "replicates, and letting backend=auto pick "
                         "tiled); with --dist alone: all visible "
                         "devices x 1")
    from ..obs import export as obs_export
    obs_export.add_output_args(ap)
    return ap


def main(argv=None):
    parser = build_parser()
    args = parser.parse_args(argv)
    if args.bootstrap > 0 and args.refine == "none":
        parser.error("--bootstrap requires --refine ml or search")
    if args.refine != "none" and args.alphabet == "protein":
        parser.error(f"--refine {args.refine} needs a nucleotide alphabet "
                     "(the 4-state likelihood)")
    if args.resume and not (args.restartable or args.ckpt_dir):
        parser.error("--resume requires --restartable (or --ckpt-dir)")
    if (args.restartable or args.ckpt_dir) and args.refine != "search":
        parser.error("--restartable/--ckpt-dir apply to --refine search")
    from ..obs import export as obs_export
    from ..obs import trace as _trace
    with _trace.request_trace(), _trace.span("tree_run", fasta=args.fasta):
        _run(args)
    obs_export.write_outputs(args)


def _run(args):
    from ..obs import trace as _trace
    with _trace.span("load"):
        from ..core import alphabet as ab
        from ..core import likelihood
        from ..data import read_fasta
        from ..phylo import TreeEngine
        names, seqs = read_fasta(args.fasta)
    widths = {len(s) for s in seqs}
    if len(widths) != 1:
        raise ValueError(
            f"{args.fasta} is not aligned (row widths {sorted(widths)[:5]}"
            f"...); run repro.launch.msa_run first")
    alpha = {"dna": ab.DNA, "rna": ab.RNA, "protein": ab.PROTEIN}[args.alphabet]
    msa = np.stack([alpha.encode_aligned(s) for s in seqs])

    mesh = None
    if args.dist or args.mesh is not None:
        from .mesh import mesh_from_arg
        mesh = mesh_from_arg(args.mesh)

    ckpt_dir = args.ckpt_dir
    if args.restartable and ckpt_dir is None:
        ckpt_dir = str(Path(args.out) / "search_ckpt")
    engine = TreeEngine(gap_code=alpha.gap_code, n_chars=alpha.n_chars,
                        correct=args.alphabet != "protein",
                        backend=args.backend,
                        cluster_threshold=args.cluster_threshold,
                        row_block=args.row_block,
                        target_cluster=args.target_cluster,
                        seed=args.seed, mesh=mesh,
                        refine=args.refine, model=args.model,
                        bootstrap=args.bootstrap, ml_steps=args.ml_steps,
                        nni_rounds=args.nni_rounds, starts=args.starts,
                        spr_radius=args.spr_radius,
                        search_rounds=args.search_rounds,
                        ckpt_dir=ckpt_dir, resume=args.resume)
    result = engine.build(msa)

    out = Path(args.out)
    with _trace.span("write", out=str(out)):
        out.mkdir(parents=True, exist_ok=True)
        (out / "tree.nwk").write_text(result.newick(names) + "\n")
    report = {"n_sequences": result.n_leaves, "width": msa.shape[1],
              "backend": result.backend, "requested_backend": args.backend,
              "tree_seconds": result.timings["total_seconds"],
              "tile_stats": result.tile_stats}
    if result.logl is not None:
        report["refine"] = args.refine
        report["model"] = result.model
        report["logl"] = result.logl
        report["bic"] = result.bic
        report["n_nni"] = result.n_nni
        report["refine_seconds"] = result.timings.get("refine_seconds")
        if result.search is not None:
            report["search"] = dict(result.search,
                                    starts=args.starts,
                                    spr_radius=args.spr_radius,
                                    ckpt_dir=ckpt_dir)
    if args.bootstrap > 0 and result.support is not None:
        finite = result.support[np.isfinite(result.support)]
        report["bootstrap"] = {
            "replicates": args.bootstrap, "seed": args.seed,
            "mean_support": round(float(finite.mean()), 4)
            if finite.size else None,
            "bootstrap_seconds": result.timings.get("bootstrap_seconds")}
    if args.tree_ll and args.alphabet != "protein":
        import jax.numpy as jnp
        report["log_likelihood"] = float(likelihood.log_likelihood(
            jnp.asarray(msa), jnp.asarray(result.children),
            jnp.asarray(result.blen), result.root, gap_code=alpha.gap_code))
    (out / "report.json").write_text(json.dumps(report, indent=1))
    print(json.dumps(report, indent=1))


if __name__ == "__main__":
    main()
