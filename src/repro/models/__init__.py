from . import layers, mamba2, transformer  # noqa: F401
from .transformer import init_params, apply_model  # noqa: F401
