"""Shared NN layers: RMSNorm, RoPE / M-RoPE, GQA attention (XLA-flash with
online softmax over KV chunks), SWA, gated MLPs, capacity-based top-k MoE.

Everything is a pure function over explicit param pytrees; sharding enters
only through the ``shard_fns`` callbacks the planner injects (identity on a
single device), so the same code runs smoke tests, the 512-way dry-run and a
real pod.

Attention strategy: scores are never materialized at (S, S). Training and
prefill run a lax.scan over KV chunks carrying online-softmax stats (m, l,
acc) — the FlashAttention recurrence expressed in XLA, which is what makes
prefill_32k compile with sane per-device memory on any backend; the Pallas
kernel (repro.kernels.flash_attention) implements the same schedule for the
TPU target and is switchable via ``attn_impl='pallas'``.
"""
from __future__ import annotations

import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]
ShardFns = Dict[str, Callable]

DEFAULT_SHARD_FNS: ShardFns = {}


def shard(shard_fns: Optional[ShardFns], name: str, x):
    if shard_fns and name in shard_fns:
        return shard_fns[name](x)
    return x


def rms_norm(x, w, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + w.astype(jnp.float32))).astype(dt)


# ------------------------------------------------------------------- RoPE

def _rope_angles(positions, head_dim: int, theta: float):
    """positions: (..., S) -> cos/sin (..., S, head_dim/2)."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, positions, theta: float):
    """x: (B, S, H, D) rotated pairwise-half style; positions: (B, S)."""
    half = x.shape[-1] // 2
    cos, sin = _rope_angles(positions, x.shape[-1], theta)   # (B, S, half)
    cos = cos[:, :, None, :]
    sin = sin[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                           axis=-1).astype(x.dtype)


def apply_m_rope(x, positions3, sections: Tuple[int, int, int], theta: float):
    """Multimodal RoPE (qwen2-vl): head_dim/2 split into (t, h, w) sections,
    each rotated by its own position stream. positions3: (3, B, S)."""
    half = x.shape[-1] // 2
    cs, ss = [], []
    for pos, sec in zip(positions3, sections):
        c, s = _rope_angles(pos, 2 * sec, theta)     # (B, S, sec)
        cs.append(c)
        ss.append(s)
    cos = jnp.concatenate(cs, axis=-1)[:, :, None, :]
    sin = jnp.concatenate(ss, axis=-1)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos],
                           axis=-1).astype(x.dtype)


# -------------------------------------------------------------- attention

def xla_flash(q, k, v, *, scale: float, causal: bool, window: int,
              q_offset=0, kv_chunk: int = 1024):
    """Online-softmax attention, scores blocked over KV.

    q: (B, S, H, D); k/v: (B, T, KH, D). Returns (B, S, H, D).
    q_offset: absolute position of q[0] (prefill continuation support).
    """
    B, S, H, D = q.shape
    T, KH = k.shape[1], k.shape[2]
    g = H // KH
    qg = q.reshape(B, S, KH, g, D)
    kv_chunk = min(kv_chunk, T)
    pad = (-T) % kv_chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nC = (T + pad) // kv_chunk
    kc = k.reshape(B, nC, kv_chunk, KH, D).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nC, kv_chunk, KH, D).transpose(1, 0, 2, 3, 4)

    q_pos = q_offset + jnp.arange(S)

    def step(carry, xs):
        m, l, acc, ci = carry
        kb, vb = xs
        k_pos = ci * kv_chunk + jnp.arange(kv_chunk)
        s = jnp.einsum("bskgd,btkd->bskgt", qg.astype(jnp.float32),
                       kb.astype(jnp.float32)) * scale
        mask = k_pos[None, :] < T  # drop padding
        if causal:
            mask = mask & (q_pos[:, None] >= k_pos[None, :])
        if window > 0:
            mask = mask & ((q_pos[:, None] - k_pos[None, :]) < window)
        s = jnp.where(mask[None, :, None, None, :], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(mask[None, :, None, None, :], p, 0.0)
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bskgt,btkd->bskgd", p, vb.astype(jnp.float32))
        acc = acc * alpha[..., None] + pv
        return (m_new, l, acc, ci + 1), None

    m0 = jnp.full((B, S, KH, g), -1e30, jnp.float32)
    l0 = jnp.zeros((B, S, KH, g), jnp.float32)
    acc0 = jnp.zeros((B, S, KH, g, D), jnp.float32)
    (m, l, acc, _), _ = jax.lax.scan(step, (m0, l0, acc0, jnp.int32(0)), (kc, vc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(B, S, H, D).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, slot_pos, cur_pos, *, scale: float,
                     window: int):
    """Single-token attention over a (ring-buffer) cache.

    q: (B, 1, H, D); caches: (B, W, KH, D); slot_pos: (B, W) absolute
    positions (-1 = empty); cur_pos: (B,).
    """
    B, _, H, D = q.shape
    W, KH = k_cache.shape[1], k_cache.shape[2]
    g = H // KH
    qg = q.reshape(B, KH, g, D)
    s = jnp.einsum("bkgd,btkd->bkgt", qg.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * scale
    mask = (slot_pos >= 0) & (slot_pos <= cur_pos[:, None])
    if window > 0:
        mask = mask & ((cur_pos[:, None] - slot_pos) < window)
    s = jnp.where(mask[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(mask[:, None, None, :], p, 0.0)
    out = jnp.einsum("bkgt,btkd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, 1, H, D).astype(q.dtype)


def attention_block(params: Params, x, positions, cfg, shard_fns,
                    cache: Optional[Params] = None, pos3=None):
    """Full attention sub-layer (pre-norm residual outside).

    Returns (out, new_cache). In cache mode x is (B, 1, D).
    """
    B, S, D = x.shape
    H, KH, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    dt = x.dtype

    def proj(w, b, n):
        y = x @ w.astype(dt)
        if b is not None:
            y = y + b.astype(dt)
        return y.reshape(B, S, n, hd)

    q = proj(params["wq"], params.get("bq"), H)
    k = proj(params["wk"], params.get("bk"), KH)
    v = proj(params["wv"], params.get("bv"), KH)

    if cfg.m_rope and pos3 is not None:
        q = apply_m_rope(q, pos3, cfg.m_rope_sections, cfg.rope_theta)
        k = apply_m_rope(k, pos3, cfg.m_rope_sections, cfg.rope_theta)
    else:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    q = shard(shard_fns, "attn_q", q)
    k = shard(shard_fns, "attn_kv", k)
    v = shard(shard_fns, "attn_kv", v)

    scale = 1.0 / math.sqrt(hd)
    new_cache = None
    if cache is not None:
        W = cache["k"].shape[1]
        slot = (positions[:, 0] % W).astype(jnp.int32)       # (B,)
        bidx = jnp.arange(B)
        kc = cache["k"].at[bidx, slot].set(k[:, 0])
        vc = cache["v"].at[bidx, slot].set(v[:, 0])
        sp = cache["slot_pos"].at[bidx, slot].set(positions[:, 0])
        out = decode_attention(q, kc, vc, sp, positions[:, 0], scale=scale,
                               window=cfg.sliding_window)
        new_cache = {"k": kc, "v": vc, "slot_pos": sp}
    else:
        out = xla_flash(q, k, v, scale=scale, causal=cfg.causal,
                        window=cfg.sliding_window)
    out = out.reshape(B, S, H * hd)
    return out @ params["wo"].astype(dt), new_cache


# ------------------------------------------------------------------- MLPs

def mlp_block(params: Params, x, kind: str, shard_fns=None):
    dt = x.dtype
    gate = shard(shard_fns, "mlp_hidden", x @ params["w_gate"].astype(dt))
    up = shard(shard_fns, "mlp_hidden", x @ params["w_up"].astype(dt))
    act = jax.nn.gelu(gate) if kind == "geglu" else jax.nn.silu(gate)
    return (act * up) @ params["w_down"].astype(dt)


def moe_block(params: Params, x, cfg, shard_fns):
    """Capacity-based top-k MoE (Switch/MaxText dispatch), expert-parallel.

    x: (B, S, D) -> (y, aux_loss). Dispatch/combine are one-hot einsums; the
    (T, E, C) tensors are the documented memory driver — microbatching keeps
    T small (see EXPERIMENTS §Perf).
    """
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.experts_per_token
    T = B * S
    xt = x.reshape(T, D)
    logits = (xt.astype(jnp.float32) @ params["router"].astype(jnp.float32))
    gates_all = jax.nn.softmax(logits, axis=-1)              # (T, E)
    gate_vals, idx = jax.lax.top_k(gates_all, K)             # (T, K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    C = int(max(4, math.ceil(T * K / E * cfg.capacity_factor)))
    C = min(C, T)
    onehot_e = jax.nn.one_hot(idx, E, dtype=jnp.float32)     # (T, K, E)
    # position of each assignment within its expert queue
    flat = onehot_e.reshape(T * K, E)
    pos = jnp.cumsum(flat, axis=0) * flat                    # (T*K, E)
    pos_tk = jnp.max(pos.reshape(T, K, E), axis=-1) - 1.0    # (T, K)
    keep = (pos_tk >= 0) & (pos_tk < C)
    onehot_c = jax.nn.one_hot(pos_tk.astype(jnp.int32), C,
                              dtype=jnp.float32) * keep[..., None]
    dispatch = jnp.einsum("tke,tkc->tec", onehot_e, onehot_c)  # (T, E, C)
    dispatch = shard(shard_fns, "moe_dispatch", dispatch)

    xe = jnp.einsum("td,tec->ecd", xt.astype(jnp.float32), dispatch)
    xe = shard(shard_fns, "moe_xe", xe).astype(x.dtype)
    gate_h = jnp.einsum("ecd,edf->ecf", xe, params["w_gate"].astype(x.dtype))
    up_h = jnp.einsum("ecd,edf->ecf", xe, params["w_up"].astype(x.dtype))
    act = jax.nn.gelu(gate_h) if cfg.mlp == "geglu" else jax.nn.silu(gate_h)
    ye = jnp.einsum("ecf,efd->ecd", act * up_h,
                    params["w_down"].astype(x.dtype))
    ye = shard(shard_fns, "moe_xe", ye)
    combine = jnp.einsum("tke,tkc,tk->tec", onehot_e, onehot_c, gate_vals)
    y = jnp.einsum("tec,ecd->td", combine, ye.astype(jnp.float32))

    # load-balancing aux loss (Switch): E * mean(frac_tokens * mean_prob)
    frac = jnp.mean(onehot_e.sum(axis=1), axis=0)            # (E,)
    prob = jnp.mean(gates_all, axis=0)
    aux = E * jnp.sum(frac * prob)
    return y.reshape(B, S, D).astype(x.dtype), aux
