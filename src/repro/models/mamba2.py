"""Mamba2 (SSD — state-space duality, arXiv:2405.21060) blocks.

The SSD algorithm is itself the TPU-friendly formulation of a selective
scan: the sequence is chunked; within a chunk the recurrence is the
*quadratic attention-like* form (one (Q, Q) masked matmul per chunk — MXU
work); across chunks only the (heads, head_dim, state) states are carried by
a short lax.scan. This mirrors the center-star DP blocking in the paper's
kernel: sequential dependency compressed to a small carried state, bulk work
as dense tiles. ngroups = 1 (B/C shared across heads).

Decode is the O(1) recurrent update: h' = h * exp(dt*A) + dt * (B ⊗ x).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


def _conv1d_causal(x, w, state: Optional[jnp.ndarray] = None):
    """Depthwise causal conv. x: (B, S, C), w: (K, C). With ``state``
    ((B, K-1, C), decode) returns (y, new_state)."""
    K = w.shape[0]
    if state is not None:
        xs = jnp.concatenate([state, x], axis=1)             # (B, K-1+S, C)
        new_state = xs[:, -(K - 1):, :]
    else:
        xs = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
        new_state = None
    y = sum(xs[:, i: xs.shape[1] - (K - 1 - i), :] * w[i] for i in range(K))
    return y, new_state


def ssd_chunked(x, dt, A, Bm, Cm, *, chunk: int = 128,
                h0: Optional[jnp.ndarray] = None):
    """SSD forward.

    x: (B, S, nh, hp); dt: (B, S, nh) (post-softplus); A: (nh,) negative;
    Bm/Cm: (B, S, st). Returns (y, h_last) with h: (B, nh, hp, st).
    """
    Bsz, S, nh, hp = x.shape
    st = Bm.shape[-1]
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    S_p = S + pad
    nc = S_p // chunk

    def r(t, shape):  # reshape into chunks
        return t.reshape((Bsz, nc, chunk) + shape)
    xc = r(x, (nh, hp))
    dtc = r(dt, (nh,))
    Bc = r(Bm, (st,))
    Cc = r(Cm, (st,))

    dA = dtc * A[None, None, None, :]                        # (B,nc,Q,nh) <= 0
    cs = jnp.cumsum(dA, axis=2)                              # within-chunk
    total = cs[:, :, -1:, :]                                 # (B,nc,1,nh)

    # intra-chunk (quadratic form): y_i += sum_{j<=i} (C_i.B_j) e^{cs_i-cs_j} dt_j x_j
    CB = jnp.einsum("bnis,bnjs->bnij", Cc, Bc)               # (B,nc,Q,Q)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))[None, None, :, :, None]
    diff = cs[:, :, :, None, :] - cs[:, :, None, :, :]       # (B,nc,i,j,nh)
    # mask BEFORE exp: for i<j the exponent is positive and exp overflows to
    # inf; where(mask, inf, 0) is fine forward but its backward emits
    # 0 * inf = NaN. Inside the mask (i>=j) cs is non-increasing so diff<=0.
    L = jnp.where(mask, jnp.exp(jnp.where(mask, diff, 0.0)), 0.0)
    y_intra = jnp.einsum("bnij,bnijh,bnjh,bnjhp->bnihp",
                         CB, L, dtc, xc.astype(jnp.float32))

    # chunk states: S_n = sum_j B_j ⊗ (dt_j x_j) e^{cs_end - cs_j}
    w = jnp.exp(total - cs) * dtc                            # (B,nc,Q,nh)
    states = jnp.einsum("bnjs,bnjh,bnjhp->bnhps", Bc, w,
                        xc.astype(jnp.float32))              # (B,nc,nh,hp,st)

    # inter-chunk recurrence
    gamma = jnp.exp(total[:, :, 0, :])                       # (B,nc,nh)

    def step(h, xs):
        g, s = xs                                            # g: (B,nh), s: (B,nh,hp,st)
        h_new = h * g[:, :, None, None] + s
        return h_new, h                                      # emit h_prev

    h_init = h0 if h0 is not None else jnp.zeros(
        (Bsz, nh, hp, st), jnp.float32)
    h_last, h_prevs = jax.lax.scan(
        step, h_init, (gamma.transpose(1, 0, 2), states.transpose(1, 0, 2, 3, 4)))
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)               # (B,nc,nh,hp,st)

    # inter-chunk contribution: y_i += (C_i . h_prev) * e^{cs_i}
    y_inter = jnp.einsum("bnis,bnih,bnhps->bnihp", Cc, jnp.exp(cs), h_prevs)
    y = (y_intra + y_inter).reshape(Bsz, S_p, nh, hp)[:, :S]
    return y.astype(x.dtype), h_last


def mamba2_block(params: Params, x, cfg, shard_fns=None,
                 cache: Optional[Params] = None):
    """Full Mamba2 mixer. x: (B, S, D); cache: {'conv': (B,K-1,C), 'ssm': h}.

    Returns (out, new_cache)."""
    from .layers import rms_norm, shard
    B, S, D = x.shape
    di, st, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    hp = cfg.ssm_head_dim
    dt_ = x.dtype

    zxbcdt = x @ params["in_proj"].astype(dt_)
    z, xin, Bm, Cm, dt_raw = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + st, 2 * di + 2 * st], axis=-1)

    conv_in = jnp.concatenate([xin, Bm, Cm], axis=-1)
    conv_state = cache["conv"] if cache is not None else None
    conv_out, new_conv = _conv1d_causal(conv_in, params["conv_w"].astype(dt_),
                                        conv_state)
    conv_out = jax.nn.silu(conv_out + params["conv_b"].astype(dt_))
    xin, Bm, Cm = jnp.split(conv_out, [di, di + st], axis=-1)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) +
                         params["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    xh = xin.reshape(B, S, nh, hp)
    xh = shard(shard_fns, "ssm_x", xh)

    if cache is not None:
        # O(1) recurrent decode step
        h = cache["ssm"]                                      # (B,nh,hp,st)
        dt1 = dt[:, 0]                                        # (B,nh)
        g = jnp.exp(dt1 * A[None, :])
        upd = jnp.einsum("bs,bh,bhp->bhps", Bm[:, 0].astype(jnp.float32),
                         dt1, xh[:, 0].astype(jnp.float32))
        h_new = h * g[:, :, None, None] + upd
        y = jnp.einsum("bs,bhps->bhp", Cm[:, 0].astype(jnp.float32), h_new)
        y = y.reshape(B, 1, nh, hp)
        new_cache = {"conv": new_conv, "ssm": h_new}
    else:
        y, h_last = ssd_chunked(xh, dt, A, Bm.astype(jnp.float32),
                                Cm.astype(jnp.float32))
        new_cache = None

    y = y.astype(dt_) + xh * params["D"].astype(dt_)[None, None, :, None]
    y = y.reshape(B, S, di)
    y = rms_norm(y, params["norm"], cfg.rms_eps) * jax.nn.silu(z)
    out = y @ params["out_proj"].astype(dt_)
    return out, new_cache


def init_mamba2_params(key, cfg, dtype=jnp.float32) -> Params:
    di, st, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    D = cfg.d_model
    conv_dim = di + 2 * st
    ks = jax.random.split(key, 4)
    proj_out = 2 * di + 2 * st + nh
    scale = 1.0 / jnp.sqrt(D)
    return {
        "in_proj": (jax.random.normal(ks[0], (D, proj_out), dtype) * scale),
        "conv_w": jax.random.normal(ks[1], (cfg.d_conv, conv_dim), dtype) * 0.1,
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "dt_bias": jnp.log(jnp.expm1(
            jnp.exp(jax.random.uniform(ks[2], (nh,),
                                       minval=jnp.log(1e-3),
                                       maxval=jnp.log(1e-1))))).astype(dtype),
        "A_log": jnp.log(1.0 + jax.random.uniform(ks[3], (nh,)) * 15.0
                         ).astype(dtype),
        "D": jnp.ones((nh,), dtype),
        "norm": jnp.zeros((di,), dtype),
        "out_proj": jax.random.normal(ks[0], (di, D), dtype) / jnp.sqrt(di),
    }
