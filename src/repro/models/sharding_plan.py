"""Sharding planner: (config, mesh, shape) -> PartitionSpecs for everything.

Layout policy (Megatron TP x FSDP, divisibility-checked per dim):
  * column-parallel weights (wq/wk/wv, mlp up/gate, router, in_proj, embed^T):
    output dim over 'model', input dim over the FSDP axes ('pod','data').
  * row-parallel weights (wo, w_down, out_proj): input dim over 'model',
    output dim over FSDP axes.
  * MoE experts over 'model' (expert parallelism), expert-internal dims over
    FSDP axes where divisible.
  * activations: batch over ('pod','data'); attention shards heads over
    'model' when head count divides, else the *sequence* (context
    parallelism); KV caches shard batch when divisible, otherwise the cache
    length (distributed decode for global_batch=1 long-context).
Every rule falls back to replication rather than failing — that is what lets
all 40 (arch x shape) cells lower on both production meshes.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..dist import sharding as sh


def _dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def param_spec(name: str, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Trailing-dims rule; leading stack dims (scan groups) replicate."""
    dp = _dp_axes(mesh)
    mdl = "model"

    def m(dim, axes):
        return sh.maybe(mesh, dim, axes)

    nd = len(shape)
    if nd == 0:
        return P()
    if name in ("embed",):
        return P(m(shape[0], mdl), m(shape[1], dp))
    if name == "head":
        return P(m(shape[0], dp), m(shape[1], mdl))
    if name in ("wq", "wk", "wv", "in_proj", "router") or \
       (name in ("w_gate", "w_up") and nd >= 2):
        if nd >= 3 and name in ("w_gate", "w_up"):   # MoE (.., E, D, F)
            lead = (None,) * (nd - 3)
            return P(*lead, m(shape[-3], mdl), m(shape[-2], dp), None)
        lead = (None,) * (nd - 2)
        return P(*lead, m(shape[-2], dp), m(shape[-1], mdl))
    if name in ("wo", "out_proj") or (name == "w_down" and nd >= 2):
        if nd >= 3 and name == "w_down":             # MoE (.., E, F, D)
            lead = (None,) * (nd - 3)
            return P(*lead, m(shape[-3], mdl), None, m(shape[-1], dp))
        lead = (None,) * (nd - 2)
        return P(*lead, m(shape[-2], mdl), m(shape[-1], dp))
    if name == "conv_w":
        lead = (None,) * (nd - 2)
        return P(*lead, None, m(shape[-1], mdl))
    # biases, norms, A_log, D, dt_bias, conv_b: replicate
    return P(*(None,) * nd)


def params_pspecs(params_shape, mesh: Mesh):
    def mk(path, leaf):
        name = str(getattr(path[-1], "key", path[-1]))
        return param_spec(name, leaf.shape, mesh)
    return jax.tree_util.tree_map_with_path(mk, params_shape)


def batch_pspecs(cfg, shape_kind: str, global_batch: int, mesh: Mesh,
                 batch_shape: Dict[str, Any]):
    dp = _dp_axes(mesh)
    bs_ax = dp if global_batch % sh.axis_size(mesh, dp) == 0 else None
    out = {}
    for k, v in batch_shape.items():
        nd = len(v.shape)
        if k == "pos3":
            out[k] = P(None, bs_ax, *([None] * (nd - 2)))
        else:
            out[k] = P(bs_ax, *([None] * (nd - 1)))
    return out


def cache_pspecs(cfg, cache_shape, global_batch: int, mesh: Mesh):
    dp = _dp_axes(mesh)
    b_ok = global_batch % sh.axis_size(mesh, dp) == 0
    bs_ax = dp if b_ok else None
    seq_axes = ("model",) if b_ok else tuple(mesh.axis_names)

    def mk(path, leaf):
        name = str(getattr(path[-1], "key", ""))
        shp = leaf.shape
        if name in ("k", "v"):
            # (stack.., B, W, KH, hd)
            lead = (None,) * (len(shp) - 4)
            w_ax = sh.maybe(mesh, shp[-3], seq_axes)
            kv_ax = None if w_ax else sh.maybe(mesh, shp[-2], "model")
            return P(*lead, bs_ax, w_ax, kv_ax, None)
        if name == "slot_pos":
            lead = (None,) * (len(shp) - 2)
            return P(*lead, bs_ax, sh.maybe(mesh, shp[-1], seq_axes))
        if name == "ssm":
            lead = (None,) * (len(shp) - 4)
            return P(*lead, bs_ax, sh.maybe(mesh, shp[-3], "model"), None, None)
        if name == "conv":
            lead = (None,) * (len(shp) - 3)
            return P(*lead, bs_ax, None, sh.maybe(mesh, shp[-1], "model"))
        return P(*(None,) * len(shp))
    return jax.tree_util.tree_map_with_path(mk, cache_shape)


def make_shard_fns(cfg, mesh: Mesh, global_batch: int) -> Dict[str, Callable]:
    dp = _dp_axes(mesh)
    b_ok = global_batch % sh.axis_size(mesh, dp) == 0
    bs_ax = dp if b_ok else None

    def cons(spec):
        ns = NamedSharding(mesh, spec)
        return lambda x: jax.lax.with_sharding_constraint(x, ns)

    fns: Dict[str, Callable] = {}
    fns["hidden"] = cons(P(bs_ax, None, None))
    ff = cfg.d_ff_dense or cfg.d_ff
    if ff:
        ff_ax = sh.maybe(mesh, ff, "model")
        fns["mlp_hidden"] = cons(P(bs_ax, None, ff_ax))
    if cfg.n_heads:
        h_ok = cfg.n_heads % mesh.shape["model"] == 0
        if h_ok:
            fns["attn_q"] = cons(P(bs_ax, None, "model", None))
        else:
            fns["attn_q"] = cons(P(bs_ax, "model", None, None))
        kv_ok = cfg.n_kv_heads % mesh.shape["model"] == 0
        fns["attn_kv"] = cons(P(bs_ax, None, "model" if kv_ok else None, None))
    if cfg.n_experts:
        e_ax = sh.maybe(mesh, cfg.n_experts, "model")
        fns["moe_dispatch"] = cons(P(bs_ax, e_ax, None))
        fns["moe_xe"] = cons(P(e_ax, None, None))
    if cfg.ssm_state:
        nh_ax = sh.maybe(mesh, cfg.ssm_heads, "model")
        fns["ssm_x"] = cons(P(bs_ax, None, nh_ax, None))
    return fns


@dataclasses.dataclass
class Plan:
    mesh: Mesh
    param_specs: Any
    shard_fns: Dict[str, Callable]

    def sharding(self, spec_tree):
        return jax.tree.map(lambda s: NamedSharding(self.mesh, s), spec_tree,
                            is_leaf=lambda x: isinstance(x, P))


def plan_for(cfg, mesh: Mesh, global_batch: int, params_shape) -> Plan:
    return Plan(mesh=mesh,
                param_specs=params_pspecs(params_shape, mesh),
                shard_fns=make_shard_fns(cfg, mesh, global_batch))
