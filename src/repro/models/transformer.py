"""Composable decoder/encoder LM covering the whole zoo, scan-over-layers.

Layers are grouped into a repeating *pattern* (dense archs: 1 layer; jamba:
8 sub-layers with 1 attention + MoE every other) and the pattern is scanned
with stacked params — one pattern's HLO regardless of depth, which is what
keeps 61-layer/1T-param dry-runs compilable and lets XLA pipeline per-layer
FSDP all-gathers against compute. KV/SSM caches ride the scan as xs/ys.

Modes: train (no cache), prefill (full sequence + cache build), decode (one
token + cache update). Param/optimizer sharding is decided by
repro.models.sharding_plan; this module only calls the injected shard_fns.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from . import layers, mamba2
from .layers import shard

Params = Dict[str, Any]


def group_pattern(cfg) -> List[str]:
    if cfg.family == "ssm":
        return ["mamba_only"]
    size = cfg.attn_period if cfg.is_hybrid else 1
    start = cfg.first_dense
    return [cfg.layer_kind(start + i) for i in range(size)]


def n_groups(cfg) -> int:
    size = len(group_pattern(cfg))
    return (cfg.n_layers - cfg.first_dense) // size


# ------------------------------------------------------------------- init

def _init_attn(key, cfg, dtype):
    D, H, KH, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    s = 1.0 / jnp.sqrt(D)
    p = {
        "wq": jax.random.normal(ks[0], (D, H * hd), dtype) * s,
        "wk": jax.random.normal(ks[1], (D, KH * hd), dtype) * s,
        "wv": jax.random.normal(ks[2], (D, KH * hd), dtype) * s,
        "wo": jax.random.normal(ks[3], (H * hd, D), dtype) / jnp.sqrt(H * hd),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((KH * hd,), dtype)
        p["bv"] = jnp.zeros((KH * hd,), dtype)
    return p


def _init_mlp(key, cfg, dtype, ff: int):
    D = cfg.d_model
    ks = jax.random.split(key, 3)
    s = 1.0 / jnp.sqrt(D)
    return {
        "w_gate": jax.random.normal(ks[0], (D, ff), dtype) * s,
        "w_up": jax.random.normal(ks[1], (D, ff), dtype) * s,
        "w_down": jax.random.normal(ks[2], (ff, D), dtype) / jnp.sqrt(ff),
    }


def _init_moe(key, cfg, dtype):
    D, E, F = cfg.d_model, cfg.n_experts, cfg.d_ff
    ks = jax.random.split(key, 4)
    s = 1.0 / jnp.sqrt(D)
    return {
        "router": jax.random.normal(ks[0], (D, E), dtype) * s,
        "w_gate": jax.random.normal(ks[1], (E, D, F), dtype) * s,
        "w_up": jax.random.normal(ks[2], (E, D, F), dtype) * s,
        "w_down": jax.random.normal(ks[3], (E, F, D), dtype) / jnp.sqrt(F),
    }


def _init_block(key, kind: str, cfg, dtype, dense_ff: Optional[int] = None):
    D = cfg.d_model
    ks = jax.random.split(key, 3)
    p: Params = {"norm1": jnp.zeros((D,), dtype)}
    if kind.startswith("attn"):
        p["attn"] = _init_attn(ks[0], cfg, dtype)
    else:
        p["mamba"] = mamba2.init_mamba2_params(ks[0], cfg, dtype)
    if kind == "mamba_only":
        return p
    p["norm2"] = jnp.zeros((D,), dtype)
    if kind.endswith("_moe"):
        p["moe"] = _init_moe(ks[1], cfg, dtype)
    else:
        ff = dense_ff or cfg.d_ff_dense or cfg.d_ff
        p["mlp"] = _init_mlp(ks[1], cfg, dtype, ff)
    return p


def init_params(cfg, key, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 5)
    D, V = cfg.d_model, cfg.vocab_size
    p: Params = {}
    if cfg.embed_input:
        p["embed"] = jax.random.normal(ks[0], (V, D), dtype) * 0.02
    pattern = group_pattern(cfg)
    ng = n_groups(cfg)

    def one_group(k):
        sub = jax.random.split(k, len(pattern))
        return {f"l{i}": _init_block(sub[i], kind, cfg, dtype)
                for i, kind in enumerate(pattern)}

    p["blocks"] = jax.vmap(one_group)(jax.random.split(ks[1], ng))
    if cfg.first_dense:
        p["prefix"] = jax.vmap(
            lambda k: {"l0": _init_block(k, "attn", cfg, dtype,
                                         dense_ff=cfg.d_ff_dense or cfg.d_ff)}
        )(jax.random.split(ks[2], cfg.first_dense))
    p["final_norm"] = jnp.zeros((D,), dtype)
    if not cfg.tie_embeddings:
        p["head"] = jax.random.normal(ks[3], (D, V), dtype) * 0.02
    return p


# ------------------------------------------------------------------ cache

def init_cache(cfg, batch_size: int, max_len: int, dtype=jnp.bfloat16) -> Params:
    KH, hd = cfg.n_kv_heads, cfg.head_dim
    W = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len

    def attn_cache():
        return {"k": jnp.zeros((batch_size, W, KH, hd), dtype),
                "v": jnp.zeros((batch_size, W, KH, hd), dtype),
                "slot_pos": jnp.full((batch_size, W), -1, jnp.int32)}

    def mamba_cache():
        conv_dim = cfg.d_inner + 2 * cfg.ssm_state
        return {"conv": jnp.zeros((batch_size, cfg.d_conv - 1, conv_dim), dtype),
                "ssm": jnp.zeros((batch_size, cfg.ssm_heads, cfg.ssm_head_dim,
                                  cfg.ssm_state), jnp.float32)}

    pattern = group_pattern(cfg)
    ng = n_groups(cfg)

    def one(kind):
        return attn_cache() if kind.startswith("attn") else mamba_cache()

    def stack(tree, n):
        return jax.tree.map(lambda x: jnp.broadcast_to(x, (n,) + x.shape), tree)

    cache: Params = {"blocks": stack({f"l{i}": one(k)
                                      for i, k in enumerate(pattern)}, ng)}
    if cfg.first_dense:
        cache["prefix"] = stack({"l0": attn_cache()}, cfg.first_dense)
    return cache


# ------------------------------------------------------------------ apply

def _block_apply(kind: str, p: Params, h, positions, cfg, shard_fns,
                 cache, pos3, make_cache: bool):
    aux = jnp.float32(0.0)
    new_cache = None
    decode = (cache is not None) and (h.shape[1] == 1)
    x = layers.rms_norm(h, p["norm1"], cfg.rms_eps)
    if kind.startswith("attn"):
        y, nc = layers.attention_block(p["attn"], x, positions, cfg, shard_fns,
                                       cache=cache if decode else None,
                                       pos3=pos3)
        if make_cache:
            nc = _prefill_attn_cache(p, x, positions, cfg, cache)
        new_cache = nc
    else:
        if make_cache:
            y, new_cache = mamba2_prefill(p["mamba"], x, cfg, shard_fns)
        else:
            y, new_cache = mamba2.mamba2_block(p["mamba"], x, cfg, shard_fns,
                                               cache=cache if decode else None)
    h = h + y
    if kind == "mamba_only":
        return h, new_cache, aux
    x = layers.rms_norm(h, p["norm2"], cfg.rms_eps)
    if kind.endswith("_moe"):
        y, aux = layers.moe_block(p["moe"], x, cfg, shard_fns)
    else:
        y = layers.mlp_block(p["mlp"], x, cfg.mlp, shard_fns)
    return h + y, new_cache, aux


def _prefill_attn_cache(p, x_normed, positions, cfg, cache):
    """Fill the provided ring-buffer cache from a prefill pass (recomputes
    K/V — cheap relative to attention, keeps attention_block simple)."""
    B, S, D = x_normed.shape
    KH, hd = cfg.n_kv_heads, cfg.head_dim
    dt = cache["k"].dtype
    W = cache["k"].shape[1]
    k = (x_normed @ p["attn"]["wk"].astype(x_normed.dtype))
    v = (x_normed @ p["attn"]["wv"].astype(x_normed.dtype))
    if cfg.qkv_bias:
        k = k + p["attn"]["bk"].astype(k.dtype)
        v = v + p["attn"]["bv"].astype(v.dtype)
    k = k.reshape(B, S, KH, hd)
    v = v.reshape(B, S, KH, hd)
    k = layers.apply_rope(k, positions, cfg.rope_theta)
    keep = min(S, W)
    k_w, v_w, pos_w = k[:, -keep:], v[:, -keep:], positions[:, -keep:]
    bidx = jnp.arange(B)[:, None]
    slots = (pos_w % W).astype(jnp.int32)
    kc = cache["k"].at[bidx, slots].set(k_w.astype(dt))
    vc = cache["v"].at[bidx, slots].set(v_w.astype(dt))
    sp = cache["slot_pos"].at[bidx, slots].set(pos_w)
    return {"k": kc, "v": vc, "slot_pos": sp}


def mamba2_prefill(p, x_normed, cfg, shard_fns):
    """Prefill for SSM blocks: full SSD + final state as cache."""
    from .mamba2 import _conv1d_causal, ssd_chunked
    B, S, D = x_normed.shape
    di, st, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    dt_ = x_normed.dtype
    zxbcdt = x_normed @ p["in_proj"].astype(dt_)
    z, xin, Bm, Cm, dt_raw = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + st, 2 * di + 2 * st], axis=-1)
    conv_in = jnp.concatenate([xin, Bm, Cm], axis=-1)
    K = cfg.d_conv
    pad = max(0, (K - 1) - S)
    conv_state = jnp.pad(conv_in, ((0, 0), (pad, 0), (0, 0)))[:, -(K - 1):]
    conv_out, _ = _conv1d_causal(conv_in, p["conv_w"].astype(dt_))
    conv_out = jax.nn.silu(conv_out + p["conv_b"].astype(dt_))
    xin, Bm, Cm = jnp.split(conv_out, [di, di + st], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) +
                         p["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    xh = xin.reshape(B, S, nh, cfg.ssm_head_dim)
    y, h_last = ssd_chunked(xh, dt, A, Bm.astype(jnp.float32),
                            Cm.astype(jnp.float32))
    y = y.astype(dt_) + xh * p["D"].astype(dt_)[None, None, :, None]
    y = y.reshape(B, S, di)
    y = layers.rms_norm(y, p["norm"], cfg.rms_eps) * jax.nn.silu(z)
    out = y @ p["out_proj"].astype(dt_)
    return out, {"conv": conv_state, "ssm": h_last}


def apply_model(params: Params, cfg, batch: Dict[str, Any], *,
                shard_fns=None, cache: Optional[Params] = None,
                logits_mode: str = "all",
                compute_dtype=jnp.bfloat16) -> Tuple[jnp.ndarray,
                                                     Optional[Params],
                                                     jnp.ndarray]:
    """Returns (logits, new_cache, aux_loss).

    batch: tokens (B,S) i32 or embeds (B,S,D); optional positions (B,S),
    pos3 (3,B,S). cache => prefill (S>1) or decode (S==1).
    """
    if cfg.embed_input:
        tokens = batch["tokens"]
        B, S = tokens.shape
        h = params["embed"].astype(compute_dtype)[tokens]
    else:
        h = batch["embeds"].astype(compute_dtype)
        B, S = h.shape[:2]
    if cfg.scale_embeds:
        h = h * jnp.sqrt(jnp.float32(cfg.d_model)).astype(compute_dtype)
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    pos3 = batch.get("pos3")
    h = shard(shard_fns, "hidden", h)

    pattern = group_pattern(cfg)
    make_cache = cache is not None and S > 1
    aux_total = jnp.float32(0.0)

    def run_group(h, gp, gcache):
        aux_sum = jnp.float32(0.0)
        new_caches = {}
        for i, kind in enumerate(pattern):
            sub_cache = gcache[f"l{i}"] if gcache is not None else None
            h, nc, aux = _block_apply(kind, gp[f"l{i}"], h, positions, cfg,
                                      shard_fns, sub_cache, pos3, make_cache)
            h = shard(shard_fns, "hidden", h)
            if nc is not None:
                new_caches[f"l{i}"] = nc
            aux_sum = aux_sum + aux
        return h, new_caches, aux_sum

    def scan_body(carry, xs):
        h, aux = carry
        gp, gcache = xs
        h, ncache, aux_g = run_group(h, gp, gcache)
        return (h, aux + aux_g), ncache

    if cfg.remat:
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if cfg.remat_policy == "dots"
                  else jax.checkpoint_policies.nothing_saveable)
        scan_body = jax.checkpoint(scan_body, policy=policy)

    new_cache: Optional[Params] = {} if cache is not None else None

    if cfg.first_dense:
        pc = cache.get("prefix") if cache is not None else None

        def pfx_body(carry, xs):
            h, aux = carry
            gp, gcache = xs
            sub_cache = gcache["l0"] if gcache is not None else None
            h, nc, aux_g = _block_apply("attn", gp["l0"], h, positions, cfg,
                                        shard_fns, sub_cache, pos3, make_cache)
            return (h, aux + aux_g), ({"l0": nc} if nc is not None else {})

        if cfg.remat:
            policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                      if cfg.remat_policy == "dots"
                      else jax.checkpoint_policies.nothing_saveable)
            pfx_body = jax.checkpoint(pfx_body, policy=policy)
        (h, aux_total), pfx_cache = jax.lax.scan(
            pfx_body, (h, aux_total), (params["prefix"], pc),
            unroll=cfg.first_dense if cfg.unroll_layers else 1)
        if cache is not None:
            new_cache["prefix"] = pfx_cache

    bc = cache.get("blocks") if cache is not None else None
    (h, aux_total), blk_cache = jax.lax.scan(
        scan_body, (h, aux_total), (params["blocks"], bc),
        unroll=n_groups(cfg) if cfg.unroll_layers else 1)
    if cache is not None:
        new_cache["blocks"] = blk_cache

    h = layers.rms_norm(h, params["final_norm"], cfg.rms_eps)
    if logits_mode == "last":
        h = h[:, -1:, :]
    head = params.get("head")
    if head is None:
        head = params["embed"].T
    logits = (h @ head.astype(h.dtype)).astype(jnp.float32)
    if logits_mode == "last":
        logits = logits[:, 0, :]
    return logits, new_cache, aux_total
