"""repro.obs — unified tracing, metrics, and profiling.

Three dependency-free layers shared by every subsystem:

  metrics   process-wide registry of counters / gauges / histograms with
            a Prometheus text renderer (``GET /metrics``) and JSON
            snapshots (embedded in BENCH_* artifacts)
  trace     nestable ``span()`` context managers -> Chrome-trace JSON,
            with per-request trace-ID propagation and an optional
            ``jax.profiler`` bridge
  runtime   device/host memory gauges sampled at root-span boundaries

``obs.disabled()`` switches the whole layer off for a block — the
overhead-guardrail benchmarks use it to compare instrumented vs bare
runs of the same code.
"""
from __future__ import annotations

import contextlib
from typing import Iterator

from . import export, metrics, runtime, trace
from .metrics import REGISTRY, counter, gauge, histogram, parse_exposition
from .trace import (TRACER, chrome_coverage, current_trace_id,
                    enable_jax_annotations, new_trace_id, request_trace, span)

__all__ = [
    "metrics", "trace", "runtime", "export",
    "REGISTRY", "counter", "gauge", "histogram", "parse_exposition",
    "TRACER", "span", "request_trace", "current_trace_id", "new_trace_id",
    "enable_jax_annotations", "chrome_coverage", "disabled",
]


@contextlib.contextmanager
def disabled() -> Iterator[None]:
    """Turn all metric writes and span recording off for the block."""
    prev_m, prev_t = REGISTRY.enabled, TRACER.enabled
    REGISTRY.enabled = TRACER.enabled = False
    try:
        yield
    finally:
        REGISTRY.enabled, TRACER.enabled = prev_m, prev_t
