"""Shared ``--trace-out`` / ``--metrics-out`` plumbing for CLI runners."""
from __future__ import annotations

import argparse

from . import metrics, runtime, trace

__all__ = ["add_output_args", "write_outputs"]


def add_output_args(parser: argparse.ArgumentParser) -> None:
    g = parser.add_argument_group("observability")
    g.add_argument("--trace-out", default=None, metavar="PATH",
                   help="write Chrome-trace JSON of the run's span tree "
                        "(open in chrome://tracing or ui.perfetto.dev)")
    g.add_argument("--metrics-out", default=None, metavar="PATH",
                   help="write a JSON snapshot of the metrics registry")


def write_outputs(args: argparse.Namespace) -> None:
    """Honour the flags added by ``add_output_args`` after a run."""
    if getattr(args, "trace_out", None):
        trace.TRACER.write(args.trace_out)
        print(f"trace   -> {args.trace_out}")
    if getattr(args, "metrics_out", None):
        runtime.sample()
        metrics.REGISTRY.write_json(args.metrics_out)
        print(f"metrics -> {args.metrics_out}")
