"""Process-wide metrics registry: counters, gauges, fixed-bucket histograms.

Dependency-free (stdlib only — importing this module must never pull in
jax).  One global ``REGISTRY`` holds labeled *families*; a family plus a
concrete label assignment is a *child* that carries the actual value:

    REQS = metrics.counter("repro_requests_started_total",
                           "requests accepted", ("endpoint",))
    REQS.labels(endpoint="align").inc()

``snapshot()`` returns a plain-dict view (embedded in BENCH_* artifacts
and ``--metrics-out`` files); ``render()`` emits Prometheus text
exposition (served by ``GET /metrics``); ``parse_exposition()`` is the
inverse used by the CI service-smoke step to gate on schema drift.

The registry-wide ``enabled`` flag turns every write into a no-op — the
overhead-guardrail benchmarks flip it to measure instrumented vs bare
runs on identical code paths.
"""
from __future__ import annotations

import bisect
import json
import threading
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

__all__ = [
    "Counter", "Gauge", "Histogram", "Family", "MetricsRegistry",
    "REGISTRY", "counter", "gauge", "histogram", "parse_exposition",
    "DEFAULT_BUCKETS",
]

# Latency buckets in seconds: 1 ms .. 30 s, roughly 1-2.5-5 per decade.
DEFAULT_BUCKETS: Tuple[float, ...] = (
    0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5,
    1.0, 2.5, 5.0, 10.0, 30.0,
)


def _escape_label(v: str) -> str:
    return v.replace("\\", r"\\").replace("\n", r"\n").replace('"', r'\"')


def _fmt_labels(labels: Dict[str, str], extra: str = "") -> str:
    parts = [f'{k}="{_escape_label(str(v))}"' for k, v in labels.items()]
    if extra:
        parts.append(extra)
    return "{" + ",".join(parts) + "}" if parts else ""


def _fmt_value(v: float) -> str:
    if v == float("inf"):
        return "+Inf"
    if float(v).is_integer() and abs(v) < 1e15:
        return str(int(v))
    return repr(float(v))


class _Child:
    __slots__ = ("_family", "labels")

    def __init__(self, family: "Family", labels: Dict[str, str]):
        self._family = family
        self.labels = labels

    @property
    def _lock(self) -> threading.Lock:
        return self._family.registry._lock

    @property
    def _enabled(self) -> bool:
        return self._family.registry.enabled


class Counter(_Child):
    __slots__ = ("value",)

    def __init__(self, family, labels):
        super().__init__(family, labels)
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if not self._enabled:
            return
        if amount < 0:
            raise ValueError("counters only go up")
        with self._lock:
            self.value += amount


class Gauge(_Child):
    __slots__ = ("value",)

    def __init__(self, family, labels):
        super().__init__(family, labels)
        self.value = 0.0

    def set(self, value: float) -> None:
        if not self._enabled:
            return
        with self._lock:
            self.value = float(value)

    def inc(self, amount: float = 1.0) -> None:
        if not self._enabled:
            return
        with self._lock:
            self.value += amount

    def dec(self, amount: float = 1.0) -> None:
        self.inc(-amount)


class Histogram(_Child):
    __slots__ = ("bucket_counts", "sum", "count")

    def __init__(self, family, labels):
        super().__init__(family, labels)
        self.bucket_counts = [0] * (len(family.buckets) + 1)  # + overflow
        self.sum = 0.0
        self.count = 0

    def observe(self, value: float) -> None:
        if not self._enabled:
            return
        i = bisect.bisect_left(self._family.buckets, value)
        with self._lock:
            self.bucket_counts[i] += 1
            self.sum += value
            self.count += 1


_TYPES = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


class Family:
    """A named metric with a fixed label schema; children carry values."""

    def __init__(self, registry: "MetricsRegistry", name: str, mtype: str,
                 help: str, labelnames: Sequence[str],
                 buckets: Optional[Sequence[float]] = None):
        self.registry = registry
        self.name = name
        self.type = mtype
        self.help = help
        self.labelnames = tuple(labelnames)
        self.buckets = tuple(sorted(buckets)) if mtype == "histogram" else ()
        self._children: Dict[Tuple[str, ...], _Child] = {}

    def labels(self, **kv: str) -> _Child:
        if set(kv) != set(self.labelnames):
            raise ValueError(
                f"{self.name}: labels {sorted(kv)} != schema "
                f"{sorted(self.labelnames)}")
        key = tuple(str(kv[k]) for k in self.labelnames)
        child = self._children.get(key)
        if child is None:
            with self.registry._lock:
                child = self._children.get(key)
                if child is None:
                    child = _TYPES[self.type](
                        self, dict(zip(self.labelnames, key)))
                    self._children[key] = child
        return child

    # Convenience: unlabeled families proxy straight to their one child.
    def inc(self, amount: float = 1.0) -> None:
        self.labels().inc(amount)

    def set(self, value: float) -> None:
        self.labels().set(value)

    def dec(self, amount: float = 1.0) -> None:
        self.labels().dec(amount)

    def observe(self, value: float) -> None:
        self.labels().observe(value)

    @property
    def value(self) -> float:
        return self.labels().value

    def children(self) -> List[_Child]:
        with self.registry._lock:
            return list(self._children.values())


class MetricsRegistry:
    """Thread-safe get-or-create store of metric families."""

    def __init__(self):
        self._lock = threading.Lock()
        self._families: Dict[str, Family] = {}
        self.enabled = True

    def _get_or_create(self, name: str, mtype: str, help: str,
                       labelnames: Sequence[str],
                       buckets: Optional[Sequence[float]] = None) -> Family:
        with self._lock:
            fam = self._families.get(name)
            if fam is not None:
                if fam.type != mtype:
                    raise ValueError(
                        f"metric {name} already registered as {fam.type}, "
                        f"not {mtype}")
                if fam.labelnames != tuple(labelnames):
                    raise ValueError(
                        f"metric {name} already registered with labels "
                        f"{fam.labelnames}, not {tuple(labelnames)}")
                return fam
            fam = Family(self, name, mtype, help, labelnames, buckets)
            self._families[name] = fam
            return fam

    def counter(self, name: str, help: str = "",
                labelnames: Sequence[str] = ()) -> Family:
        return self._get_or_create(name, "counter", help, labelnames)

    def gauge(self, name: str, help: str = "",
              labelnames: Sequence[str] = ()) -> Family:
        return self._get_or_create(name, "gauge", help, labelnames)

    def histogram(self, name: str, help: str = "",
                  labelnames: Sequence[str] = (),
                  buckets: Sequence[float] = DEFAULT_BUCKETS) -> Family:
        return self._get_or_create(name, "histogram", help, labelnames,
                                   buckets)

    def families(self) -> List[Family]:
        with self._lock:
            return list(self._families.values())

    def reset(self) -> None:
        """Drop every family (tests only — holders keep stale handles)."""
        with self._lock:
            self._families.clear()

    # ------------------------------------------------------------- export

    def snapshot(self) -> Dict[str, dict]:
        """Plain-dict view of every family, for JSON embedding."""
        out: Dict[str, dict] = {}
        for fam in self.families():
            samples = []
            for child in fam.children():
                with self._lock:
                    if fam.type == "histogram":
                        samples.append({
                            "labels": dict(child.labels),
                            "count": child.count,
                            "sum": child.sum,
                            "buckets": {
                                _fmt_value(le): int(sum(
                                    child.bucket_counts[:i + 1]))
                                for i, le in enumerate(fam.buckets)
                            },
                        })
                    else:
                        samples.append({"labels": dict(child.labels),
                                        "value": child.value})
            out[fam.name] = {"type": fam.type, "help": fam.help,
                             "samples": samples}
        return out

    def render(self) -> str:
        """Prometheus text exposition (format version 0.0.4)."""
        lines: List[str] = []
        for fam in sorted(self.families(), key=lambda f: f.name):
            lines.append(f"# HELP {fam.name} {fam.help}")
            lines.append(f"# TYPE {fam.name} {fam.type}")
            for child in fam.children():
                with self._lock:
                    if fam.type == "histogram":
                        cum = 0
                        for i, le in enumerate(fam.buckets):
                            cum += child.bucket_counts[i]
                            extra = 'le="%s"' % _fmt_value(le)
                            lines.append(
                                f"{fam.name}_bucket"
                                f"{_fmt_labels(child.labels, extra)}"
                                f" {cum}")
                        inf_extra = 'le="+Inf"'
                        lines.append(
                            f"{fam.name}_bucket"
                            f"{_fmt_labels(child.labels, inf_extra)}"
                            f" {child.count}")
                        lines.append(
                            f"{fam.name}_sum{_fmt_labels(child.labels)}"
                            f" {_fmt_value(child.sum)}")
                        lines.append(
                            f"{fam.name}_count{_fmt_labels(child.labels)}"
                            f" {child.count}")
                    else:
                        lines.append(
                            f"{fam.name}{_fmt_labels(child.labels)}"
                            f" {_fmt_value(child.value)}")
        return "\n".join(lines) + "\n"

    def write_json(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.snapshot(), f, indent=1, sort_keys=True)


REGISTRY = MetricsRegistry()


def counter(name: str, help: str = "",
            labelnames: Sequence[str] = ()) -> Family:
    return REGISTRY.counter(name, help, labelnames)


def gauge(name: str, help: str = "", labelnames: Sequence[str] = ()) -> Family:
    return REGISTRY.gauge(name, help, labelnames)


def histogram(name: str, help: str = "", labelnames: Sequence[str] = (),
              buckets: Sequence[float] = DEFAULT_BUCKETS) -> Family:
    return REGISTRY.histogram(name, help, labelnames, buckets)


def parse_exposition(text: str) -> Dict[str, dict]:
    """Parse Prometheus text back to ``{family: {type, samples}}``.

    Histogram series (``_bucket``/``_sum``/``_count``) are folded into
    their parent family.  Raises ``ValueError`` on malformed lines, which
    is exactly what the CI schema gate wants.
    """
    families: Dict[str, dict] = {}
    types: Dict[str, str] = {}
    for lineno, line in enumerate(text.splitlines(), 1):
        line = line.strip()
        if not line:
            continue
        if line.startswith("#"):
            parts = line.split(None, 3)
            if len(parts) >= 3 and parts[1] == "TYPE":
                types[parts[2]] = parts[3] if len(parts) > 3 else ""
                families.setdefault(parts[2], {"type": parts[3],
                                               "samples": []})
            continue
        brace = line.find("{")
        if brace >= 0:
            close = line.rfind("}")
            if close < brace:
                raise ValueError(f"line {lineno}: unbalanced braces: {line}")
            name = line[:brace]
            labelstr = line[brace + 1:close]
            rest = line[close + 1:].strip()
            labels: Dict[str, str] = {}
            for item in _split_labels(labelstr):
                if "=" not in item:
                    raise ValueError(f"line {lineno}: bad label {item!r}")
                k, v = item.split("=", 1)
                if not (v.startswith('"') and v.endswith('"')):
                    raise ValueError(f"line {lineno}: unquoted label {item!r}")
                labels[k.strip()] = v[1:-1]
        else:
            name, _, rest = line.partition(" ")
            labels = {}
        rest = rest.strip()
        if not rest:
            raise ValueError(f"line {lineno}: missing value: {line}")
        value = float(rest.replace("+Inf", "inf"))
        base = name
        for suffix in ("_bucket", "_sum", "_count"):
            root = name[:-len(suffix)] if name.endswith(suffix) else None
            if root is not None and types.get(root) == "histogram":
                base = root
                break
        fam = families.setdefault(base, {"type": types.get(base, "untyped"),
                                         "samples": []})
        fam["samples"].append({"series": name, "labels": labels,
                               "value": value})
    return families


def _split_labels(s: str) -> Iterable[str]:
    out, cur, in_q, esc = [], [], False, False
    for ch in s:
        if esc:
            cur.append(ch)
            esc = False
        elif ch == "\\":
            cur.append(ch)
            esc = True
        elif ch == '"':
            cur.append(ch)
            in_q = not in_q
        elif ch == "," and not in_q:
            out.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        out.append("".join(cur))
    return [x for x in (i.strip() for i in out) if x]
