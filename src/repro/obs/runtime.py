"""Device/host memory gauges, sampled at root-span boundaries.

``sample()`` refreshes:

  repro_device_live_bytes   sum of nbytes over ``jax.live_arrays()``
  repro_host_peak_rss_bytes ``ru_maxrss`` of this process

Sampling is rate-limited (``MIN_INTERVAL`` seconds) because
``jax.live_arrays()`` walks every live buffer; ``trace.span`` calls
``maybe_sample()`` whenever a root span closes, so long-running
pipelines get a free memory timeline without any per-span cost.  jax is
imported lazily inside the sampler — importing this module stays
dependency-free.

Tile-accountant bytes (``repro_tile_resident_bytes``) and cache bytes
(``repro_cache_bytes``) are pushed by their owners
(``repro.phylo.tiles.TileAccountant`` / ``repro.serve.cache.ResultCache``)
rather than pulled here, since only the owners see alloc/free edges.
"""
from __future__ import annotations

import time

from . import metrics as _metrics

__all__ = ["sample", "maybe_sample", "MIN_INTERVAL"]

MIN_INTERVAL = 1.0

_G_DEVICE = _metrics.gauge(
    "repro_device_live_bytes", "bytes held by live jax arrays")
_G_RSS = _metrics.gauge(
    "repro_host_peak_rss_bytes", "peak resident set size of this process")

_last_sample = 0.0


def sample(force: bool = True) -> None:
    """Refresh memory gauges now (``force=False`` honours the rate limit)."""
    global _last_sample
    if not _metrics.REGISTRY.enabled:
        return
    now = time.monotonic()
    if not force and now - _last_sample < MIN_INTERVAL:
        return
    _last_sample = now
    try:
        import sys
        jax = sys.modules.get("jax")  # never *trigger* the import
        if jax is not None and hasattr(jax, "live_arrays"):
            _G_DEVICE.set(float(sum(
                getattr(a, "nbytes", 0) or 0 for a in jax.live_arrays())))
    except Exception:
        pass
    try:
        import resource
        ru = resource.getrusage(resource.RUSAGE_SELF).ru_maxrss
        _G_RSS.set(float(ru) * 1024.0)  # linux reports KiB
    except Exception:
        pass


def maybe_sample() -> None:
    sample(force=False)
