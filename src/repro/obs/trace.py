"""Nestable wall-clock spans with trace-ID propagation and Chrome-trace export.

    with trace.span("map1", backend="pallas", n=4096):
        ...work...

Spans nest through a thread-local stack, so a callee's span becomes a
child of whatever span its caller currently holds — no plumbing of
context objects through APIs.  Completed spans land in the process-wide
``TRACER`` ring buffer; ``TRACER.write(path)`` emits Chrome-trace JSON
(load in ``chrome://tracing`` or https://ui.perfetto.dev).

Request IDs: ``with trace.request_trace() as tid:`` stamps every span
opened on this thread (including nested callee spans) with ``tid``;
``repro.serve`` opens one per HTTP request and returns the ID in the
JSON response, so a client-reported ID selects the exact span subtree
that served it.

``enable_jax_annotations(True)`` additionally opens a
``jax.profiler.TraceAnnotation`` per span, so spans show up inside
device profiles.  It is off by default and the jax import happens only
when enabled — CPU/interpret runs pay nothing.

Every closed span also feeds the ``repro_span_seconds{name=...}``
histogram on the metrics registry, which is how benchmarks consume
stage timings without re-deriving them.
"""
from __future__ import annotations

import contextlib
import itertools
import json
import os
import threading
import time
import uuid
from collections import deque
from typing import Dict, Iterator, List, Optional, Set, Tuple

from . import metrics as _metrics

__all__ = [
    "SpanRecord", "Tracer", "TRACER", "span", "request_trace",
    "current_trace_id", "new_trace_id", "enable_jax_annotations",
    "chrome_coverage",
]

# Map perf_counter() readings onto the epoch so Chrome-trace timestamps
# are wall-clock anchored while durations keep perf_counter precision.
_EPOCH_OFFSET = time.time() - time.perf_counter()

_SPAN_SECONDS = _metrics.histogram(
    "repro_span_seconds", "wall-clock per completed span", ("name",))

_ids = itertools.count(1)
_tls = threading.local()

_jax_annotate = False


def enable_jax_annotations(on: bool = True) -> None:
    """Bridge spans into jax.profiler (off by default; imports jax lazily)."""
    global _jax_annotate
    _jax_annotate = bool(on)


def new_trace_id() -> str:
    return uuid.uuid4().hex[:16]


def current_trace_id() -> Optional[str]:
    return getattr(_tls, "trace_id", None)


def _stack() -> List["SpanRecord"]:
    s = getattr(_tls, "stack", None)
    if s is None:
        s = _tls.stack = []
    return s


class SpanRecord:
    __slots__ = ("name", "attrs", "trace_id", "span_id", "parent_id",
                 "t0", "t1", "tid")

    def __init__(self, name: str, attrs: Dict[str, object],
                 trace_id: Optional[str], parent_id: Optional[int]):
        self.name = name
        self.attrs = attrs
        self.trace_id = trace_id
        self.span_id = next(_ids)
        self.parent_id = parent_id
        self.t0 = time.perf_counter()
        self.t1 = self.t0
        self.tid = threading.get_ident()

    @property
    def duration(self) -> float:
        return self.t1 - self.t0

    def to_event(self) -> dict:
        args = {str(k): v for k, v in self.attrs.items()}
        args["span_id"] = self.span_id
        if self.parent_id is not None:
            args["parent_id"] = self.parent_id
        if self.trace_id is not None:
            args["trace_id"] = self.trace_id
        return {
            "name": self.name,
            "ph": "X",
            "ts": (self.t0 + _EPOCH_OFFSET) * 1e6,
            "dur": max(self.duration, 1e-9) * 1e6,
            "pid": os.getpid(),
            "tid": self.tid,
            "args": args,
        }


class Tracer:
    """Bounded ring buffer of completed spans."""

    def __init__(self, max_spans: int = 65536):
        self.enabled = True
        self._lock = threading.Lock()
        self._spans: deque = deque(maxlen=max_spans)

    def record(self, rec: SpanRecord) -> None:
        with self._lock:
            self._spans.append(rec)

    def spans(self) -> List[SpanRecord]:
        with self._lock:
            return list(self._spans)

    def clear(self) -> None:
        with self._lock:
            self._spans.clear()

    def chrome_trace(self) -> dict:
        events = [r.to_event() for r in self.spans()]
        events.sort(key=lambda e: e["ts"])
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def write(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f)


TRACER = Tracer()


@contextlib.contextmanager
def request_trace(trace_id: Optional[str] = None) -> Iterator[str]:
    """Set the thread's trace ID for the duration of the block."""
    prev = getattr(_tls, "trace_id", None)
    tid = trace_id or new_trace_id()
    _tls.trace_id = tid
    try:
        yield tid
    finally:
        _tls.trace_id = prev


@contextlib.contextmanager
def span(name: str, **attrs: object) -> Iterator[Optional[SpanRecord]]:
    """Open a nested span; yields the record (None when tracing is off)."""
    if not TRACER.enabled:
        yield None
        return
    stack = _stack()
    parent = stack[-1].span_id if stack else None
    rec = SpanRecord(name, attrs, current_trace_id(), parent)
    stack.append(rec)
    ann = None
    if _jax_annotate:
        from jax.profiler import TraceAnnotation
        ann = TraceAnnotation(name)
        ann.__enter__()
    try:
        yield rec
    finally:
        if ann is not None:
            ann.__exit__(None, None, None)
        rec.t1 = time.perf_counter()
        if stack and stack[-1] is rec:
            stack.pop()
        TRACER.record(rec)
        _SPAN_SECONDS.labels(name=name).observe(rec.duration)
        if not stack:
            from . import runtime as _runtime
            _runtime.maybe_sample()


def chrome_coverage(trace_obj: dict, root_name: str
                    ) -> Tuple[float, Set[str]]:
    """(fraction of root span covered by its children, child span names).

    Coverage is the summed duration of the root's *direct* children over
    the root's duration — the acceptance metric for "the span tree
    attributes the run's wall-clock to named stages".
    """
    events = trace_obj.get("traceEvents", [])
    roots = [e for e in events if e["name"] == root_name]
    if not roots:
        return 0.0, set()
    root = max(roots, key=lambda e: e["dur"])
    rid = root["args"]["span_id"]
    kids = [e for e in events if e["args"].get("parent_id") == rid]
    covered = sum(e["dur"] for e in kids)
    return covered / max(root["dur"], 1e-9), {e["name"] for e in kids}
