"""repro.phylo — the distributed, tiled phylogeny subsystem.

The second half of the paper's title at scale: ``tiles`` (the shard-mapped
tiled distance-matrix engine with streaming block-reductions), ``pipeline``
(the HPTree cluster-merge pipeline that never materializes an (N, N) — or
even (0.1 N, 0.1 N) — matrix), ``engine`` (the backend-dispatching
``TreeEngine``: dense | tiled | cluster, ``auto`` resolved by N and mesh),
``models`` (the JC69/K80/HKY85/GTR substitution-model registry with
eigendecomposed transition probabilities), ``ml`` (the MLRefiner:
autodiff branch lengths, vmapped NNI topology search, mesh-sharded
nonparametric bootstrap — ``TreeEngine(refine="ml")``), and
``treesearch`` (the restartable multi-start NNI+SPR fleet —
``TreeEngine(refine="search")``).
"""
from .engine import (AUTO_TILED_N, PhyloResult, REFINE_MODES,  # noqa: F401
                     TREE_BACKENDS, TreeEngine, resolve_tree_backend)
from .ml import MLRefiner, MLResult  # noqa: F401
from .models import MODELS  # noqa: F401
from .pipeline import tiled_phylogeny  # noqa: F401
from .tiles import TileAccountant, TileContext  # noqa: F401
from .treesearch import (TreeSearcher, TreeSearchResult,  # noqa: F401
                         fleet_starts, spr_candidates)
