"""repro.phylo — the distributed, tiled phylogeny subsystem.

The second half of the paper's title at scale: ``tiles`` (the shard-mapped
tiled distance-matrix engine with streaming block-reductions), ``pipeline``
(the HPTree cluster-merge pipeline that never materializes an (N, N) — or
even (0.1 N, 0.1 N) — matrix), and ``engine`` (the backend-dispatching
``TreeEngine``: dense | tiled | cluster, ``auto`` resolved by N and mesh).
"""
from .engine import (AUTO_TILED_N, PhyloResult, TREE_BACKENDS,  # noqa: F401
                     TreeEngine, resolve_tree_backend)
from .pipeline import tiled_phylogeny  # noqa: F401
from .tiles import TileAccountant, TileContext  # noqa: F401
