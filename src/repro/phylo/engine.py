"""TreeEngine: the backend-dispatching phylogeny engine (repro.align's shape).

One entry point for every tree reconstruction path in the repo — the
single-host CLI (``launch/msa_run.py --tree``), the aligned-FASTA launcher
(``launch/tree_run.py``), and the benchmarks all dispatch through it.

Backends (``TREE_BACKENDS``):

  dense     (N, N) matrix + monolithic NJ — exact, O(N^2) host memory
  tiled     streamed HPTree pipeline over distance tiles
            (``repro.phylo.pipeline``) — resident distance storage per
            host <= one (row_block, N) strip; resolves to ``tiled-exact``
            (tile-assembled matrix + monolithic NJ, still within budget)
            when N <= row_block
  cluster   the dense HPTree cluster-merge (``core.cluster``) — scalable
            compute, but still materializes the (0.1 N)^2 sample matrix
  auto      dense below ``cluster_threshold``; tiled on a multi-device
            mesh or ultra-large N; cluster otherwise

Any backend's tree can then be **refined**: ``refine="ml"`` runs the
``repro.phylo.ml`` MLRefiner — branch lengths by autodiff, substitution
model by BIC (``model="auto"``), topology by vmapped NNI hill-climb;
``refine="search"`` runs the ``repro.phylo.treesearch`` multi-start
fleet instead — ``starts`` searches (NJ, cluster-medoid, random
stepwise addition) each interleaving NNI with bounded-radius SPR
(``spr_radius``), restartable through ``ckpt_dir``/``resume``. Either
mode plus ``bootstrap=B`` attaches nonparametric bootstrap support to
every internal edge — replicates (and the search fleet's candidate
scoring) shard over the engine's mesh.

``build`` returns a uniform ``PhyloResult`` (tree arrays, the effective
backend that ran, timings, the tile accountant's memory stats, and — for
refined trees — the model, logL before/after, and per-node support).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, NamedTuple, Optional

import jax.numpy as jnp
import numpy as np

from ..core import cluster as cluster_mod
from ..core import distance as dist_mod
from ..core import nj as nj_mod
from ..core import treeio
from ..obs import metrics as _obs
from ..obs import trace as _trace
from . import pipeline, tiles

_M_BUILDS = _obs.counter("repro_tree_builds_total",
                         "tree reconstructions by effective backend",
                         ("backend",))

TREE_BACKENDS = ("auto", "dense", "tiled", "cluster")
REFINE_MODES = ("none", "ml", "search")

# above this N, `auto` prefers the tiled pipeline even on one device: the
# dense cluster path's (0.1 N)^2 sample matrix starts to dominate memory
AUTO_TILED_N = 4096


class PhyloResult(NamedTuple):
    children: np.ndarray     # (2N-1, 2) int32, -1 children marks a leaf
    blen: np.ndarray         # (2N-1, 2) float32 branch lengths
    root: int
    n_leaves: int
    backend: str             # effective backend that ran (see resolve)
    requested: str           # what the caller asked for
    timings: Dict[str, float]
    tile_stats: Optional[dict]   # accountant stats for tiled backends
    logl: Optional[Dict[str, float]] = None   # {"initial", "final"} (ml)
    model: Optional[str] = None               # fitted substitution model
    support: Optional[np.ndarray] = None      # per-node bootstrap support
    bic: Optional[Dict[str, float]] = None    # per-candidate-model BIC
    n_nni: Optional[int] = None               # accepted topology moves
    search: Optional[dict] = None             # fleet stats (refine=search)

    def newick(self, names=None) -> str:
        return treeio.to_newick(self.children, self.blen, self.root, names,
                                support=self.support)


def resolve_tree_backend(backend: str, *, n: int, mesh=None,
                         cluster_threshold: int = 64,
                         row_block: int = 128) -> str:
    """Map a requested backend + problem geometry to the one that runs.

    ``cluster`` drops to ``dense`` at or below ``cluster_threshold`` (the
    old hardcoded ``len(seqs) > 64`` launcher gate, now a knob); ``tiled``
    becomes ``tiled-exact`` when the whole matrix fits one strip.
    """
    if backend not in TREE_BACKENDS:
        raise ValueError(f"unknown tree backend {backend!r}; "
                         f"expected one of {TREE_BACKENDS}")
    if backend == "auto":
        if n <= cluster_threshold:
            return "dense"
        mesh_devices = int(np.asarray(mesh.devices).size) if mesh is not None \
            else 1
        if mesh_devices > 1 or n > AUTO_TILED_N:
            return "tiled" if n > row_block else "tiled-exact"
        return "cluster"
    if backend == "cluster" and n <= cluster_threshold:
        return "dense"
    if backend == "tiled" and n <= row_block:
        return "tiled-exact"
    return backend


@dataclasses.dataclass(frozen=True)
class TreeEngine:
    """One configured tree engine; construction is cheap (jit caches are
    module-level in the primitives it dispatches to)."""

    gap_code: int
    n_chars: int
    correct: bool = True             # JC69 correction (off for protein)
    backend: str = "auto"
    cluster_threshold: int = 64
    row_block: int = 128
    col_block: Optional[int] = None
    target_cluster: int = 64
    sample_frac: float = 0.10
    seed: int = 0
    mesh: Optional[object] = None
    use_kernel: Optional[bool] = None
    refine: str = "none"             # none | ml | search (repro.phylo)
    model: str = "auto"              # substitution model (auto = BIC)
    bootstrap: int = 0               # bootstrap replicates (ml/search)
    ml_steps: int = 150              # adam steps per ML fit
    nni_rounds: int = 8              # max accepted NNI rounds
    starts: int = 4                  # refine=search: fleet size K
    spr_radius: int = 3              # refine=search: SPR regraft radius
    search_rounds: int = 12          # refine=search: max move rounds
    ckpt_dir: Optional[str] = None   # refine=search: per-round checkpoints
    resume: bool = False             # refine=search: resume from ckpt_dir

    def cluster_cfg(self) -> cluster_mod.ClusterConfig:
        return cluster_mod.ClusterConfig(sample_frac=self.sample_frac,
                                         target_cluster=self.target_cluster,
                                         seed=self.seed, correct=self.correct)

    def tile_ctx(self, accountant: Optional[tiles.TileAccountant] = None
                 ) -> tiles.TileContext:
        return tiles.TileContext(gap_code=self.gap_code, n_chars=self.n_chars,
                                 correct=self.correct,
                                 row_block=self.row_block,
                                 col_block=self.col_block,
                                 use_kernel=self.use_kernel, mesh=self.mesh,
                                 accountant=accountant)

    def resolve(self, n: int) -> str:
        return resolve_tree_backend(self.backend, n=n, mesh=self.mesh,
                                    cluster_threshold=self.cluster_threshold,
                                    row_block=self.row_block)

    def build(self, msa, *,
              accountant: Optional[tiles.TileAccountant] = None,
              cache: Optional[dict] = None,
              cache_key: Optional[str] = None) -> PhyloResult:
        """Reconstruct a tree from aligned (N, L) int8 rows.

        ``cache``/``cache_key`` is the tree-from-cached-MSA hook used by
        ``repro.serve``: when a mutable mapping and a key (the service's
        content-hash MSA id + backend) are given, a hit returns the stored
        ``PhyloResult`` without touching the distance machinery, and a
        miss stores the freshly built result under that key. The engine
        itself stays stateless — the caller owns the mapping's lifetime
        and eviction policy.
        """
        # validate before the cache lookup — an invalid configuration
        # must error even when a compatible key is already cached
        if self.refine not in REFINE_MODES:
            raise ValueError(f"unknown refine mode {self.refine!r}; "
                             f"expected one of {REFINE_MODES}")
        if self.refine != "none" and self.n_chars > 5:
            raise ValueError(f"refine={self.refine!r} needs a nucleotide "
                             "alphabet (4-state likelihood); got n_chars="
                             f"{self.n_chars}")
        if self.bootstrap > 0 and self.refine == "none":
            raise ValueError("bootstrap support requires refine='ml' or "
                             f"'search' (got bootstrap={self.bootstrap} "
                             f"with refine={self.refine!r})")
        if cache is not None and cache_key is not None and cache_key in cache:
            return cache[cache_key]
        msa_np = np.asarray(msa)
        n = msa_np.shape[0]
        if n < 2:
            raise ValueError(f"need >= 2 sequences for a tree, got {n}")
        eff = self.resolve(n)
        acct = accountant or tiles.TileAccountant()

        # `timings` entries are views over the span durations below — the
        # spans are the source of truth; perf_counter deltas back them up
        # only when tracing is disabled (span() yields None).
        timings: Dict[str, float] = {}
        t0 = time.perf_counter()
        with _trace.span("tree", backend=eff, n=n) as sp_total:
            with _trace.span("tree.distance", backend=eff, n=n):
                if eff == "dense":
                    D = dist_mod.distance_matrix(jnp.asarray(msa_np),
                                                 gap_code=self.gap_code,
                                                 n_chars=self.n_chars,
                                                 correct=self.correct)
                    children, blen, root = nj_mod.host_tree(
                        nj_mod.neighbor_joining(D, n))
                elif eff == "tiled-exact":
                    ctx = self.tile_ctx(acct)
                    D = ctx.full(msa_np)
                    children, blen, root = nj_mod.host_tree(
                        nj_mod.neighbor_joining(jnp.asarray(D), n))
                    ctx.release(D)
                elif eff == "tiled":
                    cp = pipeline.tiled_phylogeny(msa_np,
                                                  tiles=self.tile_ctx(acct),
                                                  cfg=self.cluster_cfg())
                    children, blen, root = cp.children, cp.blen, cp.root
                else:   # cluster
                    cp = cluster_mod.cluster_phylogeny(
                        msa_np, gap_code=self.gap_code, n_chars=self.n_chars,
                        cfg=self.cluster_cfg())
                    children, blen, root = cp.children, cp.blen, cp.root

            tile_stats = None
            if eff.startswith("tiled"):
                tile_stats = dict(acct.stats(),
                                  row_block_bytes=self.row_block * n * 4)

            logl = model = support = bic = n_nni = search_stats = None
            if self.refine in ("ml", "search"):
                from ..core import likelihood as lik
                from .ml import MLRefiner
                refiner = MLRefiner(gap_code=self.gap_code,
                                    n_chars=self.n_chars,
                                    correct=self.correct,
                                    model=self.model, steps=self.ml_steps,
                                    nni_rounds=self.nni_rounds,
                                    seed=self.seed, mesh=self.mesh)
                # compress once; refine/search and bootstrap share patterns
                patterns, weights = lik.compress_patterns(msa_np)
                t1 = time.perf_counter()
                if self.refine == "ml":
                    with _trace.span("tree.refine",
                                     model=self.model) as sp_ref:
                        mlres = refiner.refine(msa_np, children, blen, root,
                                               patterns=patterns,
                                               weights=weights)
                    children, blen, root = (mlres.children, mlres.blen,
                                            mlres.root)
                    logl = {"initial": mlres.logl_init,
                            "final": mlres.logl_final}
                    model = mlres.model
                    bic = mlres.bic
                    n_nni = mlres.n_nni
                else:
                    # the multi-start fleet builds its own starting trees
                    # (NJ among them) — the backend tree above stays the
                    # distance-stage product the spans account for
                    from .treesearch import TreeSearcher
                    searcher = TreeSearcher(
                        gap_code=self.gap_code, n_chars=self.n_chars,
                        correct=self.correct, starts=self.starts,
                        spr_radius=self.spr_radius,
                        rounds=self.search_rounds, model=self.model,
                        steps=self.ml_steps, seed=self.seed, mesh=self.mesh,
                        ckpt_dir=self.ckpt_dir, resume=self.resume)
                    with _trace.span("tree.refine", model=self.model,
                                     mode="search") as sp_ref:
                        ts = searcher.search(msa_np, patterns=patterns,
                                             weights=weights)
                    children, blen, root = ts.children, ts.blen, ts.root
                    logl = {"initial": ts.logl_init, "final": ts.logl_final}
                    model = ts.model
                    bic = ts.bic
                    n_nni = int(ts.n_moves.sum())
                    search_stats = {
                        "best_start": ts.best_start,
                        "start_labels": list(ts.start_labels),
                        "trajectories": np.asarray(ts.trajectories).tolist(),
                        "n_moves": np.asarray(ts.n_moves).tolist(),
                        "round_seconds":
                            np.asarray(ts.round_seconds).tolist(),
                    }
                timings["refine_seconds"] = (
                    sp_ref.duration if sp_ref is not None
                    else time.perf_counter() - t1)
                if self.bootstrap > 0:
                    t1 = time.perf_counter()
                    with _trace.span("tree.bootstrap",
                                     replicates=self.bootstrap) as sp_bs:
                        support = refiner.bootstrap(msa_np, children, blen,
                                                    root, self.bootstrap,
                                                    patterns=patterns,
                                                    weights=weights)
                    timings["bootstrap_seconds"] = (
                        sp_bs.duration if sp_bs is not None
                        else time.perf_counter() - t1)
                eff = f"{eff}+{self.refine}"
        timings["total_seconds"] = (sp_total.duration if sp_total is not None
                                    else time.perf_counter() - t0)
        _M_BUILDS.labels(backend=eff).inc()

        result = PhyloResult(np.asarray(children), np.asarray(blen),
                             int(root), n, eff, self.backend, timings,
                             tile_stats, logl, model, support, bic, n_nni,
                             search_stats)
        if cache is not None and cache_key is not None:
            cache[cache_key] = result
        return result
