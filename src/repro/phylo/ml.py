"""MLRefiner: maximum-likelihood tree refinement over the pruning scan.

The paper scores phylogenies by maximum-likelihood value but can only
*evaluate* it; this module closes the loop and improves trees natively:

1. **Branch lengths by autodiff** — all 2N-2 lengths (plus the model's
   free parameters) optimized jointly with optax/adam through
   ``core.likelihood.pruning_log_likelihood``. Lengths live as softplus
   of an unconstrained vector (the positivity clamp lives *here*, not in
   the evaluator — true zero-length branches stay exact there), and the
   fit tracks the best point of the trajectory so the result is never
   worse than the input.
2. **Topology by vmapped NNI** — every internal edge contributes its two
   nearest-neighbor interchanges; all 2(N-2) candidates carry their own
   (children, blen, order) arrays and score in one batched pruning call
   (``order`` is what makes a swapped-but-not-renumbered tree scannable).
   The best strictly-improving swap is applied, branch lengths refit,
   repeat to convergence.
3. **Bootstrap by reweighting** — site-pattern compression turns a
   nonparametric bootstrap replicate into a multinomial reweighting of
   the pattern counts; each replicate is a weighted JC69 distance matrix
   plus one NJ run, vmapped over replicates (``replicate_trees``) or
   shard-mapped over a mesh (``dist.mapreduce.bootstrap_over_mesh`` —
   replicates are embarrassingly parallel). Support for an edge of the
   ML tree is the fraction of replicate trees containing its
   bipartition.

Model selection (``model="auto"``) fits every registry model and picks
the BIC minimizer; because BIC charges each extra parameter, the winner's
logL provably dominates the fitted-JC69 logL, which itself dominates the
input tree's — so refinement strictly improves logL whenever the input
branch lengths were not already ML-optimal (NJ's never are).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import distance as dist_mod
from ..core import likelihood as lik
from ..core import nj as nj_mod
from ..core import treeio
from . import models


def _inv_softplus(y):
    # the optimizer's positivity clamp: lengths enter as softplus(raw),
    # so the inverse floors at 1e-6 — evaluation of true zeros elsewhere
    # stays exact (see likelihood.jc69_transition)
    y = jnp.maximum(y, 1e-6)
    return y + jnp.log(-jnp.expm1(-y))


# ------------------------------------------------------------------ fitting

@functools.partial(jax.jit,
                   static_argnames=("model", "steps", "lr", "site_chunk"))
def _fit(patterns, weights, children, order, root, blen0, params0, *,
         model: str, steps: int, lr: float, site_chunk: int):
    """Joint branch-length + model-parameter fit; returns the best point.

    The adam trajectory starts at the input tree (step 0 evaluates it
    exactly), and the returned (blen, params, logl) is the argmax over
    the whole trajectory — monotone improvement by construction.
    """
    # deferred so `import repro.phylo` works without optax installed —
    # only refinement itself needs the optimizer
    import optax

    M = blen0.shape[0]
    packed0 = jnp.concatenate([_inv_softplus(blen0).reshape(-1),
                               jnp.asarray(params0, jnp.float32)])

    def nll(packed):
        bl = jax.nn.softplus(packed[:2 * M].reshape(M, 2))
        dec = models.decompose(model, packed[2 * M:])
        return -lik.pruning_log_likelihood(
            patterns, weights, children, bl, order, root,
            dec.lam, dec.U, dec.sp, dec.pi, site_chunk=site_chunk)

    opt = optax.adam(lr)

    def step(carry, _):
        p, s, best_nll, best_p = carry
        l, g = jax.value_and_grad(nll)(p)
        better = l < best_nll
        best_nll = jnp.where(better, l, best_nll)
        best_p = jnp.where(better, p, best_p)
        u, s = opt.update(g, s)
        return (optax.apply_updates(p, u), s, best_nll, best_p), None

    carry0 = (packed0, opt.init(packed0), jnp.float32(jnp.inf), packed0)
    (p, _, best_nll, best_p), _ = jax.lax.scan(step, carry0, None,
                                               length=steps)
    final_nll = nll(p)
    better = final_nll < best_nll
    best_nll = jnp.where(better, final_nll, best_nll)
    best_p = jnp.where(better, p, best_p)
    return (jax.nn.softplus(best_p[:2 * M].reshape(M, 2)), best_p[2 * M:],
            -best_nll)


@functools.partial(jax.jit, static_argnames=("model", "site_chunk"))
def _score_candidates(patterns, weights, children_k, blen_k, order_k, root,
                      params, *, model: str, site_chunk: int):
    """logL of every NNI candidate in one vmapped pruning call."""
    dec = models.decompose(model, params)

    def one(ch, bl, od):
        return lik.pruning_log_likelihood(
            patterns, weights, ch, bl, od, root,
            dec.lam, dec.U, dec.sp, dec.pi, site_chunk=site_chunk)

    return jax.vmap(one)(children_k, blen_k, order_k)


# ---------------------------------------------------------------- topology

def nni_candidates(children, blen, order, n_leaves: int):
    """All 2(N-2) nearest-neighbor interchanges around internal edges.

    For each edge (p, c) with c internal — p's other child d, c's
    children a, b — the two candidates exchange d with a and with b; the
    moved subtree keeps its pendant branch length. Each candidate carries
    its own processing ``order``: the current order with c moved to just
    before p (d precedes p in any topological order, so the result is
    again topological without renumbering a single node).

    Returns stacked (K, M, 2) children/blen and (K, M-N) orders, all
    numpy (host code — candidate construction is O(K * M) bookkeeping).
    """
    children = np.asarray(children)
    blen = np.asarray(blen)
    order = [int(n) for n in order]
    out_ch, out_bl, out_od = [], [], []
    for p in order:
        for ci in range(2):
            c = int(children[p, ci])
            if c < n_leaves:
                continue                      # edge must join two internals
            d = int(children[p, 1 - ci])
            base = [n for n in order if n != c]
            base.insert(base.index(p), c)
            for si in range(2):               # swap d with children[c, si]
                ch2 = children.copy()
                bl2 = blen.copy()
                swapped = int(children[c, si])
                ch2[p, 1 - ci] = swapped
                bl2[p, 1 - ci] = blen[c, si]
                ch2[c, si] = d
                bl2[c, si] = blen[p, 1 - ci]
                out_ch.append(ch2)
                out_bl.append(bl2)
                out_od.append(base)
    if not out_ch:
        return (np.zeros((0,) + children.shape, np.int32),
                np.zeros((0,) + blen.shape, np.float32),
                np.zeros((0, len(order)), np.int32))
    return (np.stack(out_ch).astype(np.int32),
            np.stack(out_bl).astype(np.float32),
            np.asarray(out_od, np.int32))


def renumber_topological(children, blen, root, order, n_leaves: int):
    """Relabel internal nodes so array index order is topological again.

    NNI leaves node ids in place and tracks validity through ``order``;
    downstream consumers (``core.likelihood.log_likelihood``, treeio,
    the engine) assume children-before-parents by index, so the final
    tree is renumbered: internal node ``order[i]`` becomes ``N + i``.
    """
    children = np.asarray(children)
    blen = np.asarray(blen)
    new = np.arange(children.shape[0])
    for i, node in enumerate(order):
        new[int(node)] = n_leaves + i
    ch2 = np.full_like(children, -1)
    bl2 = np.zeros_like(blen)
    for node in range(children.shape[0]):
        if children[node, 0] >= 0:
            ch2[new[node]] = new[children[node]]
            bl2[new[node]] = blen[node]
    return ch2.astype(np.int32), bl2.astype(np.float32), int(new[int(root)])


# --------------------------------------------------------------- bootstrap

@functools.partial(jax.jit, static_argnames=("n_replicates", "n_sites"))
def replicate_weights(key, weights, *, n_replicates: int, n_sites: int):
    """(B, P) multinomial bootstrap reweightings of the pattern counts.

    Replicate b's key is ``fold_in(key, b)`` — independent of how the
    batch is later sharded, so a fixed seed is bit-reproducible across
    mesh shapes.
    """
    logits = jnp.log(jnp.maximum(jnp.asarray(weights, jnp.float32), 1e-30))

    def one(b):
        idx = jax.random.categorical(jax.random.fold_in(key, b), logits,
                                     shape=(n_sites,))
        return jnp.zeros(weights.shape[0], jnp.float32).at[idx].add(1.0)

    return jax.vmap(one)(jnp.arange(n_replicates))


def weighted_distance_matrix(patterns, w, *, gap_code: int, n_chars: int,
                             correct: bool = True):
    """JC69 distance matrix under per-pattern weights.

    With unit weights this reproduces ``core.distance.distance_matrix``
    exactly (counts are integers in f32); under bootstrap weights the
    match/valid counts become weighted sums — still exact integers.
    """
    codes = patterns.astype(jnp.int32)
    valid = ((codes != gap_code) & (codes < n_chars))
    oh = ((codes[:, :, None] == jnp.arange(n_chars)) &
          valid[:, :, None]).astype(jnp.float32)            # (N, P, C)
    a = (oh * w[None, :, None]).reshape(oh.shape[0], -1)
    match = a @ oh.reshape(oh.shape[0], -1).T
    vf = valid.astype(jnp.float32)
    valid_ct = (vf * w[None, :]) @ vf.T
    d = dist_mod.counts_to_distance(match, valid_ct, correct=correct)
    d = 0.5 * (d + d.T)
    return d * (1.0 - jnp.eye(d.shape[0]))


@functools.partial(jax.jit,
                   static_argnames=("gap_code", "n_chars", "correct"))
def replicate_trees(patterns, W, *, gap_code: int, n_chars: int,
                    correct: bool = True):
    """One NJ tree per bootstrap reweighting: (B, 2N-1, 2) children/blen.

    The per-replicate unit (weighted distances + one NJ) is what
    ``dist.mapreduce.bootstrap_over_mesh`` shard-maps over the data axis.
    """
    n = patterns.shape[0]

    def one(w):
        D = weighted_distance_matrix(patterns, w, gap_code=gap_code,
                                     n_chars=n_chars, correct=correct)
        t = nj_mod.neighbor_joining(D, n)
        return t.children, t.blen

    return jax.vmap(one)(W)


def split_support(children, root, n_leaves: int, rep_children) -> np.ndarray:
    """Per-node bootstrap support for the final tree's internal edges.

    support[node] = fraction of replicate trees whose bipartition set
    contains the split induced by the edge above ``node``; NaN for
    leaves, the root, and trivial splits (those have no support notion).
    """
    from collections import Counter

    children = np.asarray(children)
    rep_children = np.asarray(rep_children)
    B = rep_children.shape[0]
    tally: Counter = Counter()
    rep_root = 2 * n_leaves - 2
    for b in range(B):
        tally.update(treeio.bipartitions(rep_children[b], rep_root, n_leaves))
    ml_sets = treeio.leaf_sets(children, int(root), n_leaves)
    all_leaves = frozenset(range(n_leaves))
    support = np.full(children.shape[0], np.nan, np.float32)
    for node, s in ml_sets.items():
        if node == int(root) or children[node][0] < 0:
            continue
        if not (1 < len(s) < n_leaves - 1):
            continue
        support[node] = tally[treeio.canonical_split(s, all_leaves)] / B
    return support


# ---------------------------------------------------------------- refiner

class MLResult(NamedTuple):
    children: np.ndarray      # (2N-1, 2) int32, index-topological again
    blen: np.ndarray          # (2N-1, 2) float32 optimized lengths
    root: int
    model: str                # the fitted (or BIC-selected) model
    params: np.ndarray        # its unconstrained parameter vector
    logl_init: float          # input tree under JC69 (what --tree-ll sees)
    logl_final: float         # refined tree under the selected model
    bic: Dict[str, float]     # per-candidate-model BIC (1 entry unless auto)
    n_nni: int                # accepted interchanges


@dataclasses.dataclass(frozen=True)
class MLRefiner:
    """Configured ML refinement; nucleotide alignments only (4 states)."""

    gap_code: int
    n_chars: int = 5             # distance-alphabet size (bootstrap NJ)
    correct: bool = True         # JC69 distance correction (bootstrap NJ)
    model: str = "auto"          # auto = BIC over the registry
    steps: int = 150             # adam steps per fit
    lr: float = 0.05
    nni_rounds: int = 8          # max accepted-interchange rounds
    min_gain: float = 1e-2       # logL gain an NNI must clear
    site_chunk: int = 2048       # checkpoint granularity (0 = off)
    seed: int = 0
    mesh: Optional[object] = None

    def __post_init__(self):
        if self.model != "auto":
            models.validate(self.model)

    # ------------------------------------------------------------- refine

    def refine(self, msa, children, blen, root, *,
               patterns=None, weights=None) -> MLResult:
        """Optimize branch lengths + model, hill-climb topology by NNI.

        ``children``/``blen`` must be index-topological (every tree the
        engine's backends emit is); the result is renumbered back to that
        convention. ``patterns``/``weights`` accept a precomputed
        ``compress_patterns(msa)`` so refine + bootstrap of the same
        alignment compress once (the engine does this).
        """
        msa = np.asarray(msa)
        n = msa.shape[0]
        patterns_np, weights_np = (patterns, weights) \
            if patterns is not None else lik.compress_patterns(msa)
        patterns = jnp.asarray(patterns_np)
        weights = jnp.asarray(weights_np)
        n_sites = float(weights_np.sum())
        children = np.asarray(children, np.int32)
        # NJ emits slightly negative lengths; evaluate (and start the
        # fit) from the zero-floored tree, matching the core evaluator
        blen = np.maximum(np.asarray(blen, np.float32), 0.0)
        root = int(root)
        M = children.shape[0]
        order = np.arange(n, M, dtype=np.int32)

        dec0 = models.decompose("jc69", np.zeros(0, np.float32))
        logl_init = float(lik.pruning_log_likelihood(
            patterns, weights, jnp.asarray(children), jnp.asarray(blen),
            jnp.asarray(order), root, dec0.lam, dec0.U, dec0.sp, dec0.pi,
            site_chunk=self.site_chunk))

        freqs = models.empirical_freqs(patterns_np, weights_np)
        candidates = models.MODELS if self.model == "auto" else (self.model,)
        fits, bics = {}, {}
        for m in candidates:
            bl_m, pr_m, ll_m = _fit(
                patterns, weights, jnp.asarray(children), jnp.asarray(order),
                root, jnp.asarray(blen), models.init_params(m, freqs),
                model=m, steps=self.steps, lr=self.lr,
                site_chunk=self.site_chunk)
            fits[m] = (np.asarray(bl_m), np.asarray(pr_m), float(ll_m))
            bics[m] = models.bic(float(ll_m), m, 2 * n - 2, n_sites)
        model = min(bics, key=bics.get)
        blen, params, logl = fits[model]

        n_nni = 0
        for _ in range(self.nni_rounds):
            ch_k, bl_k, od_k = nni_candidates(children, blen, order, n)
            if ch_k.shape[0] == 0:
                break
            lls = np.asarray(_score_candidates(
                patterns, weights, jnp.asarray(ch_k), jnp.asarray(bl_k),
                jnp.asarray(od_k), root, jnp.asarray(params),
                model=model, site_chunk=self.site_chunk))
            best = int(np.argmax(lls))
            if float(lls[best]) <= logl + self.min_gain:
                break
            children, blen, order = ch_k[best], bl_k[best], od_k[best]
            bl_j, pr_j, ll_j = _fit(
                patterns, weights, jnp.asarray(children), jnp.asarray(order),
                root, jnp.asarray(blen), jnp.asarray(params),
                model=model, steps=self.steps, lr=self.lr,
                site_chunk=self.site_chunk)
            blen, params, logl = (np.asarray(bl_j), np.asarray(pr_j),
                                  float(ll_j))
            n_nni += 1

        children, blen, root = renumber_topological(children, blen, root,
                                                    order, n)
        return MLResult(children, blen, root, model, np.asarray(params),
                        logl_init, float(logl), bics, n_nni)

    # ---------------------------------------------------------- bootstrap

    def bootstrap(self, msa, children, blen, root, n_replicates: int, *,
                  patterns=None, weights=None) -> np.ndarray:
        """Nonparametric bootstrap support for the tree's internal edges.

        Replicates shard over ``self.mesh`` (data axis) when one with
        more than one device is configured; otherwise they vmap on the
        local device. Either way replicate b's weights come from
        ``fold_in(seed, b)``, so a fixed seed is bit-reproducible across
        mesh shapes.
        """
        msa = np.asarray(msa)
        n = msa.shape[0]
        patterns_np, weights_np = (patterns, weights) \
            if patterns is not None else lik.compress_patterns(msa)
        n_sites = int(round(float(weights_np.sum())))
        W = replicate_weights(jax.random.PRNGKey(self.seed),
                              jnp.asarray(weights_np),
                              n_replicates=n_replicates, n_sites=n_sites)
        if self.mesh is not None:
            from ..dist import mapreduce
            from ..dist import sharding as sh
            n_shards = sh.axis_size(self.mesh, "data")
            W_np, b0 = mapreduce.pad_rows(np.asarray(W), n_shards)
            fn = mapreduce.bootstrap_over_mesh(
                self.mesh, gap_code=self.gap_code, n_chars=self.n_chars,
                correct=self.correct)
            ch_b, _ = fn(sh.broadcast(jnp.asarray(patterns_np), self.mesh),
                         sh.shard_rows(W_np, self.mesh, "data"))
            ch_b = mapreduce.unpad_rows(np.asarray(ch_b), b0)
        else:
            ch_b, _ = replicate_trees(jnp.asarray(patterns_np), W,
                                      gap_code=self.gap_code,
                                      n_chars=self.n_chars,
                                      correct=self.correct)
            ch_b = np.asarray(ch_b)
        return split_support(children, root, n, ch_b)
