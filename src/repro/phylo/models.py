"""Substitution-model registry: JC69 / K80 / HKY85 / GTR as one family.

Every model is a point in the general-time-reversible family: a symmetric
exchangeability matrix R (6 pairwise rates over A,C,G,T) and a stationary
distribution pi, composed as ``Q_ij = R_ij * pi_j`` with the diagonal set
so rows sum to zero and the whole matrix scaled to one expected
substitution per unit branch length. Transition probabilities come from
the eigendecomposition of the pi-symmetrized rate matrix
``S = diag(sqrt(pi)) Q diag(1/sqrt(pi))`` (symmetric for any reversible
Q), replacing the closed-form ``jc69_transition`` special case:

    P(t) = diag(1/sqrt(pi)) U exp(Lambda t) U^T diag(sqrt(pi))

| model | free params | constraints                                   |
|-------|-------------|-----------------------------------------------|
| jc69  | 0           | all rates equal, pi uniform                   |
| k80   | 1 (kappa)   | transitions (A<->G, C<->T) scaled, pi uniform |
| hky85 | 4           | kappa + free pi                               |
| gtr   | 8           | 5 free rates (GT fixed = 1) + free pi         |

The equal-frequency models (jc69, k80) share a *parameter-independent*
eigenbasis (the purine/pyrimidine Hadamard-like basis below), so their
decomposition is closed-form — important because their eigenvalues are
degenerate and ``eigh``'s VJP divides by eigenvalue gaps. HKY85/GTR
eigendecompose numerically; their eigenvalues are generically distinct
(``init_params`` seeds pi from empirical frequencies and distinct rates,
keeping the optimizer away from the degenerate submanifolds).

Unconstrained parameter vectors (what the optimizer sees): rates and
kappa through ``exp``, pi through a softmax with the T logit pinned to 0.
Model selection is by BIC (``bic``): k = free model params + 2N-2 branch
lengths, n = alignment columns (not unique patterns).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

MODELS = ("jc69", "k80", "hky85", "gtr")

N_FREE = {"jc69": 0, "k80": 1, "hky85": 4, "gtr": 8}

# symmetric pair order of the 6 exchangeabilities over A,C,G,T = 0..3
_PAIRS = ((0, 1), (0, 2), (0, 3), (1, 2), (1, 3), (2, 3))
_TRANSITIONS = (1, 4)      # AG and CT entries of _PAIRS (the kappa pairs)

# shared eigenbasis of every equal-frequency model (columns: stationary
# mode, purine-vs-pyrimidine, A-vs-G, C-vs-T) — eigenvectors of S for any
# kappa, so jc69/k80 never touch eigh
_EQ_BASIS = np.array([
    [0.5,  0.5,  np.sqrt(0.5),  0.0],
    [0.5, -0.5,  0.0,           np.sqrt(0.5)],
    [0.5,  0.5, -np.sqrt(0.5),  0.0],
    [0.5, -0.5,  0.0,          -np.sqrt(0.5)],
], np.float32)


class Decomposition(NamedTuple):
    """Eigendecomposed reversible model, ready for ``P(t)`` evaluation
    (the evaluator lives in ``core.likelihood``, which consumes lam/U/sp
    directly — core must not depend on this package)."""
    lam: jnp.ndarray     # (4,) eigenvalues of the symmetrized rate matrix
    U: jnp.ndarray       # (4, 4) orthonormal eigenvectors (columns)
    sp: jnp.ndarray      # (4,) sqrt(pi)
    pi: jnp.ndarray      # (4,) stationary distribution


def validate(model: str) -> str:
    if model not in MODELS:
        raise ValueError(f"unknown substitution model {model!r}; "
                         f"expected one of {MODELS}")
    return model


def empirical_freqs(patterns, weights) -> np.ndarray:
    """Weighted A,C,G,T frequencies of an alignment (gaps/N excluded).

    Pseudocounts plus a tiny deterministic tilt keep the result off the
    exactly-uniform point, where HKY85's eigenvalues degenerate.
    """
    patterns = np.asarray(patterns)
    weights = np.asarray(weights, np.float64)
    counts = np.zeros(4)
    for c in range(4):
        counts[c] = ((patterns == c) * weights[None, :]).sum()
    counts += 1.0 + 1e-3 * np.arange(4)
    return (counts / counts.sum()).astype(np.float32)


def init_params(model: str, freqs: Optional[np.ndarray] = None) -> np.ndarray:
    """Unconstrained starting point for the optimizer (f32 numpy).

    kappa starts at 2 (the transition bias virtually all DNA shows), GTR
    rates at distinct transition-biased values, pi logits at the
    empirical frequencies when given.
    """
    validate(model)
    if freqs is None:
        freqs = np.array([0.27, 0.23, 0.24, 0.26], np.float32)
    logits = np.log(np.maximum(freqs[:3], 1e-6) / max(float(freqs[3]), 1e-6))
    if model == "jc69":
        return np.zeros(0, np.float32)
    if model == "k80":
        return np.array([np.log(2.0)], np.float32)
    if model == "hky85":
        return np.concatenate([[np.log(2.0)], logits]).astype(np.float32)
    rates = np.log([1.1, 2.0, 0.9, 1.05, 2.1])     # AC AG AT CG CT (GT = 1)
    return np.concatenate([rates, logits]).astype(np.float32)


def unpack(model: str, params):
    """Unconstrained params -> (rates (6,), pi (4,)) in model constraints."""
    validate(model)
    params = jnp.asarray(params, jnp.float32)
    uniform = jnp.full(4, 0.25, jnp.float32)
    ones = jnp.ones(6, jnp.float32)
    if model == "jc69":
        return ones, uniform
    if model == "k80":
        kappa = jnp.exp(params[0])
        rates = ones.at[jnp.array(_TRANSITIONS)].set(kappa)
        return rates, uniform
    if model == "hky85":
        kappa = jnp.exp(params[0])
        rates = ones.at[jnp.array(_TRANSITIONS)].set(kappa)
        pi = jax.nn.softmax(jnp.concatenate([params[1:4], jnp.zeros(1)]))
        return rates, pi
    rates = jnp.concatenate([jnp.exp(params[:5]), jnp.ones(1)])
    pi = jax.nn.softmax(jnp.concatenate([params[5:8], jnp.zeros(1)]))
    return rates, pi


def rate_matrix(model: str, params):
    """(Q, pi): the normalized GTR-family rate matrix (1 sub/site/unit t)."""
    rates, pi = unpack(model, params)
    R = jnp.zeros((4, 4), jnp.float32)
    for k, (i, j) in enumerate(_PAIRS):
        R = R.at[i, j].set(rates[k]).at[j, i].set(rates[k])
    Q = R * pi[None, :]
    Q = Q - jnp.diag(jnp.sum(Q, axis=1))
    mu = -jnp.sum(pi * jnp.diag(Q))
    return Q / jnp.maximum(mu, 1e-12), pi


def decompose(model: str, params) -> Decomposition:
    """Eigendecompose the pi-symmetrized rate matrix.

    jc69/k80 use the fixed equal-frequency eigenbasis (their eigenvalues
    are degenerate, which would poison eigh's VJP); hky85/gtr go through
    ``jnp.linalg.eigh`` where eigenvalues are generically distinct.
    """
    Q, pi = rate_matrix(model, params)
    sp = jnp.sqrt(pi)
    S = sp[:, None] * Q / sp[None, :]
    S = 0.5 * (S + S.T)
    if model in ("jc69", "k80"):
        U = jnp.asarray(_EQ_BASIS)
        lam = jnp.einsum("ki,kl,li->i", U, S, U)
    else:
        lam, U = jnp.linalg.eigh(S)
    return Decomposition(lam, U, sp, pi)


def bic(logl: float, model: str, n_branches: int, n_sites: float) -> float:
    """Bayesian information criterion: k ln(n) - 2 logL (lower is better).

    k counts the free substitution parameters plus every branch length;
    n is the number of alignment columns (patterns expanded by weight).
    """
    k = N_FREE[validate(model)] + n_branches
    return float(k * np.log(max(n_sites, 1.0)) - 2.0 * logl)
