"""Distributed HPTree pipeline over distance tiles (paper Fig. 4 at scale).

Mirrors ``core.cluster.cluster_phylogeny`` stage for stage but never
materializes the (N, N) matrix — nor even the (m, m) sketch-sample matrix
that is the dense path's own cliff at ultra-large N:

  (1) sketch sample       host rng, same draws as the dense path
  (2) medoid selection    streamed greedy k-center (``TileContext``)
  (3) assignment          row-block strips against the k medoid rows
  (4) rebalance           host overflow spill (``core.cluster.rebalance``)
  (5) per-cluster NJ      ``nj_batch`` vmap over cluster chunks sized so
                          the padded matrices fit one tile row-block strip
  (6) skeleton + stitch   k x k NJ + ``treeio.stitch_cluster_trees``

Resident distance storage per host stays <= one (row_block, N) strip
throughout, tracked by the ``TileAccountant``. The only way to exceed it
is a single cluster whose padded matrix is more than half a strip
(2 * cap^2 > row_block * N, with cap ~ 1.5 * target_cluster) — impossible
in the ultra-large-N regime this backend targets (N >= ~1300 at the
defaults) since stage (5) always needs one cluster matrix plus its batch
slot resident. Given the same ``ClusterConfig`` the result is
bit-identical to the dense cluster path —
distance counts are exact integers in f32, so every tile equals the
corresponding dense sub-block — pinned by ``tests/test_phylo_engine.py``.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from ..core import cluster as cluster_mod
from ..core import nj as nj_mod
from ..core import treeio
from .tiles import TileContext


def tiled_phylogeny(msa, *, tiles: TileContext,
                    cfg: cluster_mod.ClusterConfig = cluster_mod.ClusterConfig()
                    ) -> cluster_mod.ClusterPhylogeny:
    """HPTree cluster-merge phylogeny with tiled, streamed distance stages.

    ``msa``: (N, L) int8 aligned rows; ``tiles`` carries alphabet, tile
    geometry, mesh placement, and the accountant. Returns the same
    ``ClusterPhylogeny`` as ``core.cluster.cluster_phylogeny``.
    """
    msa = np.asarray(msa)
    N = msa.shape[0]
    acct = tiles.accountant
    strip_bytes = tiles.row_block * N * 4
    rng = np.random.default_rng(cfg.seed)

    # (1)-(2): sketch sample + streamed medoid selection
    m = max(cfg.min_sample, int(N * cfg.sample_frac))
    sample = np.sort(rng.choice(N, size=min(m, N), replace=False))
    k = max(2, int(np.ceil(N / cfg.target_cluster)))
    med_local = tiles.greedy_k_center(msa[sample], k)
    medoids = sample[med_local]
    k = len(medoids)

    # (3): assignment, one row-block strip at a time
    xdist = tiles.nearest(msa, msa[medoids])
    assign = np.argmin(xdist, axis=1)

    # (4): cap + spill (shared host logic with the dense path)
    cap = max(3, int(np.ceil(cfg.balance_factor * N / k)))
    assign = cluster_mod.rebalance(assign, xdist, cap)
    tiles.release(xdist)            # assignment fixed; free before stage 5

    # (5): per-cluster NJ, vmapped in chunks that fit one strip
    members = [np.flatnonzero(assign == c) for c in range(k)]
    cap_sz = max(max(len(mm) for mm in members), 3)
    per = cap_sz * cap_sz * 4
    # one chunk of padded matrices + one transient sub-matrix <= one strip
    chunk = max(1, strip_bytes // per - 1)
    cluster_trees = []
    for c0 in range(0, k, chunk):
        cs = range(c0, min(c0 + chunk, k))
        Dpad = tiles.track(np.zeros((len(cs), cap_sz, cap_sz), np.float32))
        sizes = np.zeros((len(cs),), np.int32)
        for gi, c in enumerate(cs):
            mm = members[c]
            if len(mm) == 0:
                sizes[gi] = 1
                continue
            nbytes = acct.alloc(cap_sz * cap_sz * 4)
            sub = tiles.square(msa[mm], pad_to=cap_sz)
            Dpad[gi, : len(mm), : len(mm)] = sub
            acct.free(nbytes)
            sizes[gi] = len(mm)
        trees = nj_mod.nj_batch(jnp.asarray(Dpad), jnp.asarray(sizes))
        for gi in range(len(sizes)):
            cluster_trees.append((np.asarray(trees.children[gi]),
                                  np.asarray(trees.blen[gi]),
                                  int(trees.root[gi]), int(sizes[gi])))
        tiles.release(Dpad)

    # (6): skeleton over medoids + stitch
    Dm = tiles.track(tiles.square(msa[medoids]))
    skel = nj_mod.neighbor_joining(jnp.asarray(Dm), k)
    tiles.release(Dm)
    members_nonempty = [mm if len(mm) else np.asarray([medoids[c]])
                        for c, mm in enumerate(members)]
    children, blen, root = treeio.stitch_cluster_trees(
        np.asarray(skel.children), np.asarray(skel.blen), int(skel.root),
        cluster_trees, members_nonempty)
    return cluster_mod.ClusterPhylogeny(children, blen, root,
                                        assign.astype(np.int32), medoids, k)
