"""Tiled distance-matrix engine: (row-block x column-block) JC69 tiles.

The phylogeny stage's hot input is the (N, N) JC69 distance matrix. Dense
``core.distance.distance_matrix`` materializes all of it on one host — the
scaling cliff this subsystem removes. ``TileContext`` computes the same
matrix as independent tiles and exposes *streaming block-reductions* so the
HPTree pipeline (``repro.phylo.pipeline``) never holds more than one tile
row-block strip of distance storage per host:

  ``strips``          generator of (row_block, M) strips, one resident at a
                      time; shard-mapped over the ``repro.dist`` mesh when
                      one is given (``dist.mapreduce.distance_strip_over_mesh``)
  ``row_sums``        streamed row-sum reduction (medoid seeding)
  ``greedy_k_center`` streamed farthest-point medoid selection — identical
                      picks to ``core.cluster.farthest_point_medoids`` with
                      no (m, m) sample matrix
  ``nearest``         (N, k) distances to k anchor rows, strip by strip
  ``full``            assemble the whole matrix tile by tile — the parity /
                      debug / small-N-exact path, not the production one

Tiles reuse ``kernels/distance`` on device (compiled Pallas on TPU) with
``core.distance.cross_distance`` as the oracle everywhere else. Because the
underlying (match, valid) counts are exact integers in f32, every tile is
*bitwise equal* to the corresponding dense sub-block regardless of backend
or tiling — pinned by ``tests/test_phylo_engine.py``.

``TileAccountant`` tracks resident distance bytes; the acceptance test
asserts ``peak_resident_bytes <= row_block * N * 4`` through it.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from ..core import distance as dist_mod
from ..obs import metrics as _obs

_G_RESIDENT = _obs.gauge("repro_tile_resident_bytes",
                         "distance bytes currently resident (last accountant)")
_C_TILES = _obs.counter("repro_tiles_total", "distance tiles materialized")
_C_TILE_BYTES = _obs.counter("repro_tile_bytes_total",
                             "distance bytes materialized, cumulative")


class TileAccountant:
    """Byte accounting for resident distance storage (tile-callback hook).

    Every distance buffer the tiled pipeline materializes passes through
    ``alloc``/``free``; ``peak_resident_bytes`` is the memory bound the
    tiled backend advertises (one row-block strip), asserted in tests and
    reported by ``launch/tree_run.py``.
    """

    def __init__(self):
        self.resident = 0
        self.peak = 0
        self.n_tiles = 0
        self.total_bytes = 0

    def alloc(self, nbytes: int) -> int:
        nbytes = int(nbytes)
        self.resident += nbytes
        self.peak = max(self.peak, self.resident)
        self.n_tiles += 1
        self.total_bytes += nbytes
        _C_TILES.inc()
        _C_TILE_BYTES.inc(nbytes)
        _G_RESIDENT.set(self.resident)
        return nbytes

    def free(self, nbytes: int) -> None:
        self.resident -= int(nbytes)
        _G_RESIDENT.set(self.resident)

    def stats(self) -> dict:
        return {"peak_resident_bytes": self.peak,
                "n_tiles": self.n_tiles,
                "total_tile_bytes": self.total_bytes}


@dataclasses.dataclass
class TileContext:
    """One configured tile engine (alphabet + tile geometry + placement)."""

    gap_code: int
    n_chars: int
    correct: bool = True           # JC69 correction (off for protein)
    row_block: int = 128
    col_block: Optional[int] = None   # ``full`` only; defaults to row_block
    use_kernel: Optional[bool] = None  # None -> compiled Pallas on TPU only
    mesh: Optional[object] = None      # jax Mesh: shard-map the strips
    data_axis: str = "data"
    accountant: Optional[TileAccountant] = None

    def __post_init__(self):
        if self.use_kernel is None:
            from ..kernels import default_interpret
            self.use_kernel = not default_interpret()
        if self.accountant is None:
            self.accountant = TileAccountant()

    # ------------------------------------------------------------ accounting

    def track(self, arr: np.ndarray) -> np.ndarray:
        self.accountant.alloc(arr.nbytes)
        return arr

    def release(self, arr: np.ndarray) -> None:
        self.accountant.free(arr.nbytes)

    # ------------------------------------------------------------ tile math

    def block(self, rows, cols) -> np.ndarray:
        """One (r, c) distance tile between two row sets."""
        rows = jnp.asarray(rows)
        cols = jnp.asarray(cols)
        if self.use_kernel:
            from ..kernels.distance import match_valid_pallas
            m, v = match_valid_pallas(rows, cols, n_chars=self.n_chars,
                                      gap_code=self.gap_code)
            d = dist_mod.counts_to_distance(m, v, correct=self.correct)
        else:
            d = dist_mod.cross_distance(rows, cols, gap_code=self.gap_code,
                                        n_chars=self.n_chars,
                                        correct=self.correct)
        return np.asarray(d)

    def square(self, rows, pad_to: Optional[int] = None) -> np.ndarray:
        """Small dense symmetric matrix (per-cluster / skeleton blocks).

        ``pad_to`` pads the row count with gap rows so every per-cluster
        call compiles at one shape; the caller crops. Real-row entries are
        unaffected (pairwise counts are row-independent).
        """
        rows = np.asarray(rows)
        n = rows.shape[0]
        if pad_to is not None and n < pad_to:
            pad = np.full((pad_to - n, rows.shape[1]), self.gap_code,
                          rows.dtype)
            rows = np.concatenate([rows, pad], axis=0)
        d = dist_mod.distance_matrix(jnp.asarray(rows), gap_code=self.gap_code,
                                     n_chars=self.n_chars,
                                     correct=self.correct)
        return np.asarray(d)[:n, :n] if pad_to is not None else np.asarray(d)

    # ------------------------------------------------------------- streaming

    def strips(self, msa, cols=None) -> Iterator[Tuple[int, int, np.ndarray]]:
        """Yield ``(start, stop, strip)`` row-block strips of the cross
        distance between ``msa`` and ``cols`` (default: ``msa`` itself, i.e.
        one row-block of the (N, N) matrix per step).

        Exactly one strip is resident at a time (alloc on yield, free on
        resume). With a mesh and ``cols is None`` the strip computation is
        shard-mapped: each device computes its column shard of the tile row.
        """
        msa = np.asarray(msa)
        n, L = msa.shape
        cols_arr = msa if cols is None else np.asarray(cols)
        m = cols_arr.shape[0]
        rb = self.row_block
        mesh_fn = None
        if self.mesh is not None and cols is None:
            mesh_fn, S = self._mesh_strip_fn(msa)
        for start in range(0, n, rb):
            stop = min(start + rb, n)
            blk = msa[start:stop]
            if blk.shape[0] < rb:      # keep one compiled strip shape
                pad = np.full((rb - blk.shape[0], L), self.gap_code,
                              msa.dtype)
                blk = np.concatenate([blk, pad], axis=0)
            if mesh_fn is not None:
                strip = np.asarray(mesh_fn(jnp.asarray(blk), S))
            else:
                strip = self.block(blk, cols_arr)
            strip = strip[: stop - start, :m]
            nbytes = self.accountant.alloc(rb * m * 4)   # what was computed
            try:
                yield start, stop, strip
            finally:
                self.accountant.free(nbytes)

    def _mesh_strip_fn(self, msa: np.ndarray):
        from ..dist import mapreduce, sharding as sh
        n_shards = sh.axis_size(self.mesh, self.data_axis)
        padded, _ = mapreduce.pad_rows(msa, n_shards, fill=self.gap_code)
        S = sh.shard_rows(padded, self.mesh, self.data_axis)
        fn = mapreduce.distance_strip_over_mesh(
            self.mesh, gap_code=self.gap_code, n_chars=self.n_chars,
            correct=self.correct, data_axis=self.data_axis)
        return fn, S

    def row_sums(self, msa) -> np.ndarray:
        """Streamed row-sum reduction over the implicit (N, N) matrix."""
        msa = np.asarray(msa)
        out = np.zeros((msa.shape[0],), np.float32)
        for start, stop, strip in self.strips(msa):
            out[start:stop] = strip.sum(axis=1)
        return out

    def greedy_k_center(self, msa, k: int) -> np.ndarray:
        """Streamed farthest-point medoid selection.

        Same picks as ``core.cluster.farthest_point_medoids`` on the dense
        sample matrix: the seed is the max-row-sum point (streamed), then
        each round adds the point farthest from the chosen set, maintaining
        the (m,) min-distance vector with one single-column tile per round.
        """
        msa = np.asarray(msa)
        m = msa.shape[0]
        first = int(np.argmax(self.row_sums(msa)))
        chosen = [first]
        mind = self.block(msa, msa[first: first + 1])[:, 0]
        for _ in range(1, min(k, m)):
            nxt = int(np.argmax(mind))
            chosen.append(nxt)
            mind = np.minimum(mind, self.block(msa, msa[nxt: nxt + 1])[:, 0])
        return np.asarray(chosen)

    def nearest(self, msa, anchors) -> np.ndarray:
        """(N, k) distances to ``anchors``.

        Strip-streamed on one host; with a mesh the rows are sharded and
        every device computes its rows against the replicated anchors in
        one shard-mapped call (``dist.mapreduce.nearest_anchor_over_mesh``)
        — this is the pipeline's N-scale assignment stage. The result is
        tracked by the accountant; the caller releases it (``ctx.release``)
        once the assignment stage is done with it.
        """
        msa = np.asarray(msa)
        anchors = np.asarray(anchors)
        n = msa.shape[0]
        if self.mesh is not None:
            from ..dist import mapreduce, sharding as sh
            n_shards = sh.axis_size(self.mesh, self.data_axis)
            padded, _ = mapreduce.pad_rows(msa, n_shards, fill=self.gap_code)
            fn = mapreduce.nearest_anchor_over_mesh(
                self.mesh, gap_code=self.gap_code, n_chars=self.n_chars,
                correct=self.correct, data_axis=self.data_axis)
            xd = fn(sh.shard_rows(padded, self.mesh, self.data_axis),
                    sh.broadcast(jnp.asarray(anchors), self.mesh))
            return self.track(np.asarray(xd)[:n].copy())
        out = self.track(np.empty((n, anchors.shape[0]), np.float32))
        for start, stop, strip in self.strips(msa, cols=anchors):
            out[start:stop] = strip
        return out

    # ------------------------------------------------------------- assembly

    def full(self, msa) -> np.ndarray:
        """Assemble the complete (N, N) matrix from tiles.

        Parity/debug path plus the tiled backend's small-N exact route
        (N <= row_block, where the whole matrix is one strip). Bitwise
        equal to ``core.distance.distance_matrix``.
        """
        msa = np.asarray(msa)
        n = msa.shape[0]
        cb = self.col_block or self.row_block
        out = self.track(np.zeros((n, n), np.float32))
        for rs in range(0, n, self.row_block):
            re_ = min(rs + self.row_block, n)
            for cs in range(0, n, cb):
                ce = min(cs + cb, n)
                nbytes = self.accountant.alloc((re_ - rs) * (ce - cs) * 4)
                out[rs:re_, cs:ce] = self.block(msa[rs:re_], msa[cs:ce])
                self.accountant.free(nbytes)
        np.fill_diagonal(out, 0.0)
        return out
