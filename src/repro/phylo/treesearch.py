"""Multi-start ML tree search: a restartable K-start NNI+SPR fleet.

``MLRefiner`` hill-climbs from one NJ start with NNI only — a known
local-optimum trap. This module runs K independent searches to the same
convergence criterion and keeps the best:

1. **Start diversity** (``fleet_starts``) — start 0 is the NJ tree,
   start 1 the cluster-medoid skeleton (``core.cluster``; for small N it
   degenerates to NJ, which is fine — the remaining starts supply the
   diversity), starts 2+ are random stepwise-addition trees. Every start
   is normalized to the index-topological convention (root = 2N-2) so
   the whole fleet shares one scalar root.
2. **A wider move set** — each round pools the 2(N-2) NNI candidates
   with bounded-radius SPR candidates (``spr_candidates``: prune any
   subtree whose parent is not the root, regraft onto any edge within
   ``radius`` hops of the wound). All candidates of all K searches score
   in ONE batched pruning call (``score_fleet`` — the fleet analogue of
   ``ml._score_candidates``); each search accepts its best
   strictly-improving candidate and refits branch lengths + model
   parameters via ``ml._fit``, or deactivates.
3. **Mesh fan-out** — with a mesh configured the (K, C) candidate block
   shards over the data axis through
   ``dist.mapreduce.treesearch_over_mesh``; per-search scoring is
   row-independent vmapped math, so host and mesh runs are
   bit-identical (the same invariant ``bootstrap_over_mesh`` holds).
4. **Restartability** — the fleet state is a fixed-shape array pytree
   checkpointed per round through ``dist.checkpoint.CheckpointManager``
   and driven by ``dist.fault.ResilientLoop``: every step is a pure
   function of the state, so a mid-search ``StepFailure`` (or a kill +
   ``resume=True``) replays to a bit-identical final tree.

The per-start logL trajectories surface through ``repro.obs`` spans
(``tree.search`` carries the per-start finals, ``search.round`` the
per-round acceptance) and through ``TreeSearchResult.trajectories``.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from collections import deque
from typing import Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core import cluster as cluster_mod
from ..core import distance as dist_mod
from ..core import likelihood as lik
from ..core import nj as nj_mod
from ..obs import metrics as _obs
from ..obs import trace as _trace
from . import models
from .ml import _fit, nni_candidates, renumber_topological

_C_MOVES = _obs.counter("repro_treesearch_moves_total",
                        "accepted tree-search moves", ("kind",))
_C_ROUNDS = _obs.counter("repro_treesearch_rounds_total",
                         "tree-search fleet rounds executed")


# ------------------------------------------------------------------- trees

def topological_order(children, root: int, n_leaves: int) -> np.ndarray:
    """Postorder over internal nodes (children before parents, root last).

    The explicit ``order`` array is what lets a tree whose node ids are
    NOT index-topological still score in one vmapped pruning scan; this
    recomputes it from scratch for an arbitrary rooted binary tree.
    """
    children = np.asarray(children)
    order = []
    stack = [(int(root), False)]
    while stack:
        node, expanded = stack.pop()
        if children[node, 0] < 0:
            continue                              # leaf
        if expanded:
            order.append(node)
        else:
            stack.append((node, True))
            stack.append((int(children[node, 1]), False))
            stack.append((int(children[node, 0]), False))
    return np.asarray(order, np.int32)


def normalize_tree(children, blen, root: int, n_leaves: int):
    """Renumber an arbitrary rooted binary tree to index-topological form.

    Returns ``(children, blen, root)`` with internal node ``i`` stored at
    index ``n_leaves + rank(i)`` in postorder — so root = 2N-2 and the
    processing order is simply ``arange(N, 2N-1)``.
    """
    order = topological_order(children, root, n_leaves)
    return renumber_topological(children, blen, root, order, n_leaves)


def random_addition_tree(n_leaves: int, rng, init_blen: float = 0.05):
    """Random stepwise addition: one diverse fleet start.

    Leaves join in a random order, each attaching onto a uniformly random
    existing edge (the attachment splits that edge with a fresh internal
    node). Branch lengths start flat at ``init_blen`` — the fleet's first
    fit replaces them, only the topology matters here. Returns an
    index-topological ``(children, blen, root)``.
    """
    M = 2 * n_leaves - 1
    children = np.full((M, 2), -1, np.int32)
    blen = np.full((M, 2), init_blen, np.float32)
    perm = [int(x) for x in rng.permutation(n_leaves)]
    root = n_leaves
    children[root] = (perm[0], perm[1])
    nxt = root + 1
    edges = [(root, 0), (root, 1)]
    for leaf in perm[2:]:
        p, s = edges[int(rng.integers(len(edges)))]
        a = nxt
        nxt += 1
        children[a] = (int(children[p, s]), leaf)
        children[p, s] = a
        edges.append((a, 0))
        edges.append((a, 1))
    return normalize_tree(children, blen, root, n_leaves)


def fleet_starts(msa, *, k: int, gap_code: int, n_chars: int,
                 correct: bool = True, seed: int = 0):
    """K starting topologies: NJ, cluster-medoid skeleton, random addition.

    Returns ``(starts, labels)`` where each start is an index-topological
    ``(children, blen, root)`` and ``labels`` names the strategy per slot
    (``"nj"``, ``"cluster"``, ``"random<i>"``). NJ's slightly negative
    lengths are floored at zero, matching ``MLRefiner``.
    """
    msa = np.asarray(msa)
    n = msa.shape[0]
    starts, labels = [], []
    D = dist_mod.distance_matrix(jnp.asarray(msa), gap_code=gap_code,
                                 n_chars=n_chars, correct=correct)
    ch, bl, rt = nj_mod.host_tree(nj_mod.neighbor_joining(D, n))
    starts.append(normalize_tree(ch, np.maximum(bl, 0.0), rt, n))
    labels.append("nj")
    if k >= 2:
        cp = cluster_mod.cluster_phylogeny(
            msa, gap_code=gap_code, n_chars=n_chars,
            cfg=cluster_mod.ClusterConfig(seed=seed, correct=correct))
        starts.append(normalize_tree(np.asarray(cp.children),
                                     np.maximum(np.asarray(cp.blen), 0.0),
                                     int(cp.root), n))
        labels.append("cluster")
    for i in range(len(starts), k):
        rng = np.random.default_rng((seed, i))
        starts.append(random_addition_tree(n, rng))
        labels.append(f"random{i}")
    return starts, tuple(labels)


# -------------------------------------------------------------------- moves

def _parent_map(children, order) -> Dict[int, Tuple[int, int]]:
    """node -> (parent, slot) for every non-root node."""
    children = np.asarray(children)
    par: Dict[int, Tuple[int, int]] = {}
    for p in order:
        p = int(p)
        par[int(children[p, 0])] = (p, 0)
        par[int(children[p, 1])] = (p, 1)
    return par


def spr_candidates(children, blen, order, n_leaves: int, radius: int):
    """Bounded-radius subtree prune-and-regraft candidates.

    For every node v whose parent u is not the root, prune the subtree at
    v: u is suppressed — its sibling child w inherits the merged edge to
    u's parent g (lengths summed) — and u's node id is held back as the
    regraft attachment, so the array size and the root id never change.
    v then regrafts onto any edge (x, y) of the pruned tree within
    ``radius`` hops of the wound: the attachment u splits that edge in
    half, v keeps its pendant length.

    Hop distance: BFS over the pruned tree from both wound endpoints
    {g, w} at depth 0; edge (x, y) sits at ``1 + min(depth(x),
    depth(y))``. ``radius=1`` is the NNI-sized neighborhood (the <= 4
    edges adjacent to the wound); a radius >= the tree diameter
    enumerates every target — ``2*(N - leaves(v)) - 3`` per prune node
    (the merged edge (g, w) is excluded: regrafting there recreates the
    input topology).

    Returns stacked ``(K, M, 2)`` children/blen and ``(K, M-N)`` orders
    like ``ml.nni_candidates``; each candidate carries a freshly computed
    postorder. Candidate order is deterministic (prune nodes ascending,
    targets ascending by child id) — ties in downstream argmax resolve
    identically on every host/mesh.
    """
    children = np.asarray(children)
    blen = np.asarray(blen)
    order = [int(x) for x in order]
    root = order[-1] if order else int(2 * n_leaves - 2)
    par = _parent_map(children, order)
    out_ch, out_bl, out_od = [], [], []
    for v in range(children.shape[0]):
        if v == root or v not in par:
            continue
        u, sv = par[v]
        if u == root:
            continue                  # pruning a root child leaves no wound
        w = int(children[u, 1 - sv])
        g, su = par[u]
        chp = children.copy()
        blp = blen.copy()
        chp[g, su] = w
        blp[g, su] = blen[g, su] + blen[u, 1 - sv]
        parp = dict(par)
        parp[w] = (g, su)
        # BFS depths over the pruned tree from both wound endpoints; u and
        # v are unreachable (u was spliced out, v's only link was u)
        depth = {g: 0, w: 0}
        dq = deque((g, w))
        while dq:
            x = dq.popleft()
            nbrs = []
            if chp[x, 0] >= 0:
                nbrs += [int(chp[x, 0]), int(chp[x, 1])]
            if x in parp and x != root:
                nbrs.append(parp[x][0])
            for nb in nbrs:
                if nb not in depth:
                    depth[nb] = depth[x] + 1
                    dq.append(nb)
        for y in sorted(depth):
            if y == root:
                continue              # no edge above the root
            x, sy = parp[y]
            if (x, y) == (g, w):
                continue              # merged edge: the input topology
            if 1 + min(depth[x], depth[y]) > radius:
                continue
            ch2 = chp.copy()
            bl2 = blp.copy()
            half = blp[x, sy] * 0.5
            ch2[u, 1 - sv] = y        # u's slot sv still holds v
            bl2[u, sv] = blen[u, sv]
            bl2[u, 1 - sv] = half
            ch2[x, sy] = u
            bl2[x, sy] = half
            out_ch.append(ch2)
            out_bl.append(bl2)
            out_od.append(topological_order(ch2, root, n_leaves))
    if not out_ch:
        return (np.zeros((0,) + children.shape, np.int32),
                np.zeros((0,) + blen.shape, np.float32),
                np.zeros((0, len(order)), np.int32))
    return (np.stack(out_ch).astype(np.int32),
            np.stack(out_bl).astype(np.float32),
            np.stack(out_od).astype(np.int32))


# ------------------------------------------------------------------ scoring

@functools.partial(jax.jit, static_argnames=("model", "site_chunk"))
def score_fleet(patterns, weights, children_k, blen_k, order_k, params_k, *,
                model: str, site_chunk: int):
    """logL of every candidate of every search in one nested-vmap call.

    ``children_k``/``blen_k`` are (K, C, M, 2), ``order_k`` (K, C, M-N),
    ``params_k`` (K, P) — each search scores its own C candidates under
    its own fitted model parameters. All trees share the scalar root
    M-1 (the fleet is normalized once and NNI/SPR never reassign the
    root id). Per-(search, candidate) math is independent of every other
    row, which is what makes ``treesearch_over_mesh`` bit-identical to
    the host path.
    """
    root = children_k.shape[2] - 1

    def one_search(ch_c, bl_c, od_c, params):
        dec = models.decompose(model, params)

        def one(ch, bl, od):
            return lik.pruning_log_likelihood(
                patterns, weights, ch, bl, od, root,
                dec.lam, dec.U, dec.sp, dec.pi, site_chunk=site_chunk)

        return jax.vmap(one)(ch_c, bl_c, od_c)

    return jax.vmap(one_search)(children_k, blen_k, order_k, params_k)


def _pow2ceil(x: int) -> int:
    return 1 << max(int(x) - 1, 0).bit_length()


# ------------------------------------------------------------------- fleet

class TreeSearchResult(NamedTuple):
    children: np.ndarray      # (2N-1, 2) int32, index-topological again
    blen: np.ndarray          # (2N-1, 2) float32 optimized lengths
    root: int
    model: str                # fitted (or BIC-selected) model
    params: np.ndarray        # best start's unconstrained parameters
    logl_init: float          # NJ start under JC69 (MLResult convention)
    logl_final: float         # best start's final logL
    bic: Dict[str, float]     # per-candidate-model BIC (NJ start)
    best_start: int
    start_labels: Tuple[str, ...]
    trajectories: np.ndarray  # (K, rounds+1) f32 per-start logL per round
    n_moves: np.ndarray       # (K, 2) int32 accepted (nni, spr) per start
    round_seconds: np.ndarray  # (rounds+1,) wall seconds per executed round


class _Rounds:
    """The trivial ``batches`` protocol for ResilientLoop: batch == step."""

    def __init__(self, n_steps: int):
        self.n_steps = n_steps

    def __call__(self, step: int) -> int:
        return step


@dataclasses.dataclass(frozen=True)
class TreeSearcher:
    """Configured K-start search; nucleotide alignments only (4 states).

    With ``ckpt_dir`` set the fleet state checkpoints per round and the
    loop runs under ``ResilientLoop`` — pass ``resume=True`` to continue
    a killed search from its newest checkpoint (same config required:
    the state shapes must match). ``failure_hook``/``max_failures``
    forward to the loop (chaos injection in tests).
    """

    gap_code: int
    n_chars: int = 5
    correct: bool = True
    starts: int = 4
    spr_radius: int = 3
    rounds: int = 12              # max move rounds (beyond the initial fit)
    model: str = "auto"           # auto = BIC over the registry (NJ start)
    steps: int = 100              # adam steps per fit
    lr: float = 0.05
    min_gain: float = 1e-2        # logL gain a move must clear
    site_chunk: int = 2048
    seed: int = 0
    mesh: Optional[object] = None
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 1
    ckpt_keep: Optional[int] = 3
    resume: bool = False
    failure_hook: Optional[Callable[[int], None]] = None
    max_failures: Optional[int] = None

    def __post_init__(self):
        if self.model != "auto":
            models.validate(self.model)
        if self.starts < 1:
            raise ValueError(f"need at least one start, got {self.starts}")

    # ------------------------------------------------------------- search

    def search(self, msa, *, patterns=None, weights=None) -> TreeSearchResult:
        """Run the fleet; returns the best start's renumbered tree.

        ``patterns``/``weights`` accept a precomputed
        ``compress_patterns(msa)`` so the engine compresses once for
        search + bootstrap (same contract as ``MLRefiner.refine``).
        """
        msa = np.asarray(msa)
        n = msa.shape[0]
        if n < 3:
            raise ValueError(f"tree search needs >= 3 sequences, got {n}")
        patterns_np, weights_np = (patterns, weights) \
            if patterns is not None else lik.compress_patterns(msa)
        patterns = jnp.asarray(patterns_np)
        weights = jnp.asarray(weights_np)
        n_sites = float(weights_np.sum())
        K = self.starts
        M = 2 * n - 1
        root = M - 1

        with _trace.span("tree.search", starts=K, spr_radius=self.spr_radius,
                         rounds=self.rounds, mesh=self.mesh is not None) as sp:
            starts, labels = fleet_starts(
                msa, k=K, gap_code=self.gap_code, n_chars=self.n_chars,
                correct=self.correct, seed=self.seed)
            ch0 = np.stack([s[0] for s in starts]).astype(np.int32)
            bl0 = np.stack([s[1] for s in starts]).astype(np.float32)
            order = np.arange(n, M, dtype=np.int32)
            od0 = np.broadcast_to(order, (K, M - n)).copy()

            dec0 = models.decompose("jc69", np.zeros(0, np.float32))
            logl_init = float(lik.pruning_log_likelihood(
                patterns, weights, jnp.asarray(ch0[0]), jnp.asarray(bl0[0]),
                jnp.asarray(order), root, dec0.lam, dec0.U, dec0.sp, dec0.pi,
                site_chunk=self.site_chunk))

            # model selection on the NJ start only: one model for the whole
            # fleet keeps every search's params the same shape (the state
            # pytree must be fixed-shape for checkpointing) and matches
            # MLRefiner's BIC protocol
            freqs = models.empirical_freqs(patterns_np, weights_np)
            candidates = models.MODELS if self.model == "auto" \
                else (self.model,)
            bics = {}
            for m in candidates:
                _, _, ll_m = _fit(
                    patterns, weights, jnp.asarray(ch0[0]), jnp.asarray(order),
                    root, jnp.asarray(bl0[0]), models.init_params(m, freqs),
                    model=m, steps=self.steps, lr=self.lr,
                    site_chunk=self.site_chunk)
                bics[m] = models.bic(float(ll_m), m, 2 * n - 2, n_sites)
            model = min(bics, key=bics.get)
            params0 = np.asarray(models.init_params(model, freqs), np.float32)

            state0 = {
                "active": np.ones((K,), np.int8),
                "blen": bl0,
                "children": ch0,
                "logl": np.full((K,), -np.inf, np.float32),
                "moves": np.zeros((K, 2), np.int32),
                "order": od0,
                "params": np.broadcast_to(params0, (K,) + params0.shape
                                          ).astype(np.float32).copy(),
                "round": np.zeros((), np.int32),
                "traj": np.full((K, self.rounds + 1), np.nan, np.float32),
            }

            score = self._make_scorer(patterns, weights, model)
            round_secs: Dict[int, float] = {}
            step_fn = self._make_step(patterns, weights, model, n, root,
                                      score, round_secs)

            if self.ckpt_dir is not None:
                from ..dist.checkpoint import CheckpointManager
                from ..dist.fault import ResilientLoop
                loop = ResilientLoop(step_fn,
                                     CheckpointManager(self.ckpt_dir,
                                                       keep=self.ckpt_keep),
                                     ckpt_every=self.ckpt_every,
                                     failure_hook=self.failure_hook,
                                     max_failures=self.max_failures)
                state, _ = loop.run(state0, _Rounds(self.rounds + 1),
                                    resume=self.resume)
            else:
                state = state0
                for r in range(self.rounds + 1):
                    state = step_fn(state, r)

            st = {k: np.asarray(v) for k, v in state.items()}
            best = int(np.argmax(st["logl"]))
            ch_b, bl_b, root_b = renumber_topological(
                st["children"][best], st["blen"][best], root,
                st["order"][best], n)
            secs = np.zeros(self.rounds + 1, np.float32)
            for r, s in round_secs.items():
                secs[r] = s
            if sp is not None:
                sp.attrs.update(model=model, best_start=best,
                                logl_final=float(st["logl"][best]),
                                per_start_logl=[float(x)
                                                for x in st["logl"]],
                                n_moves=int(st["moves"].sum()))
            return TreeSearchResult(
                ch_b, bl_b, root_b, model, st["params"][best], logl_init,
                float(st["logl"][best]), bics, best, labels, st["traj"],
                st["moves"], secs)

    # ------------------------------------------------------------ internals

    def _make_scorer(self, patterns, weights, model: str):
        """(K, C, ...) candidate block -> (K, C) logL, host or mesh."""
        if self.mesh is None:
            def score(ch_k, bl_k, od_k, pr_k):
                return np.array(score_fleet(
                    patterns, weights, jnp.asarray(ch_k), jnp.asarray(bl_k),
                    jnp.asarray(od_k), jnp.asarray(pr_k), model=model,
                    site_chunk=self.site_chunk))
            return score

        from ..dist import mapreduce
        from ..dist import sharding as shd
        n_shards = shd.axis_size(self.mesh, "data")
        fn = mapreduce.treesearch_over_mesh(self.mesh, model=model,
                                            site_chunk=self.site_chunk)

        def score(ch_k, bl_k, od_k, pr_k):
            ch_p, k0 = mapreduce.pad_rows(ch_k, n_shards)
            bl_p, _ = mapreduce.pad_rows(bl_k, n_shards)
            od_p, _ = mapreduce.pad_rows(od_k, n_shards)
            pr_p, _ = mapreduce.pad_rows(pr_k, n_shards)
            lls = fn(shd.broadcast(patterns, self.mesh),
                     shd.broadcast(weights, self.mesh),
                     shd.shard_rows(ch_p, self.mesh, "data"),
                     shd.shard_rows(bl_p, self.mesh, "data"),
                     shd.shard_rows(od_p, self.mesh, "data"),
                     shd.shard_rows(pr_p, self.mesh, "data"))
            return np.array(mapreduce.unpad_rows(np.asarray(lls), k0))

        return score

    def _make_step(self, patterns, weights, model: str, n: int, root: int,
                   score, round_secs: Dict[int, float]):
        """The pure per-round step function ResilientLoop replays.

        Round 0 is the initial per-start fit; round r >= 1 generates
        NNI+SPR candidates for every active search, scores the padded
        (K, Cmax) block in one call, and per search either accepts the
        best strictly-improving move (then refits) or deactivates.
        Everything is a deterministic function of the state dict, so
        checkpoint replay is bit-exact.
        """
        K, M = self.starts, 2 * n - 1

        def step_fn(state, _step):
            t0 = time.perf_counter()
            st = {k: np.array(v) for k, v in state.items()}
            r = int(st["round"])
            ch, bl, od = st["children"], st["blen"], st["order"]
            prm, logl = st["params"], st["logl"]
            active, traj, moves = st["active"], st["traj"], st["moves"]

            if r == 0:
                for k in range(K):
                    b, p, ll = _fit(
                        patterns, weights, jnp.asarray(ch[k]),
                        jnp.asarray(od[k]), root, jnp.asarray(bl[k]),
                        jnp.asarray(prm[k]), model=model, steps=self.steps,
                        lr=self.lr, site_chunk=self.site_chunk)
                    bl[k], prm[k], logl[k] = (np.asarray(b), np.asarray(p),
                                              float(ll))
                traj[:, 0] = logl
            else:
                with _trace.span("search.round", round=r) as sp:
                    cands, n_cand = {}, np.zeros(K, np.int64)
                    for k in range(K):
                        if not active[k]:
                            continue
                        chn, bln, odn = nni_candidates(ch[k], bl[k],
                                                       od[k], n)
                        chs, bls, ods = spr_candidates(
                            ch[k], bl[k], od[k], n, radius=self.spr_radius)
                        cands[k] = (np.concatenate([chn, chs]),
                                    np.concatenate([bln, bls]),
                                    np.concatenate([odn, ods]),
                                    chn.shape[0])
                        n_cand[k] = cands[k][0].shape[0]
                    accepted = 0
                    if n_cand.max(initial=0) > 0:
                        # pad every search to one pow2 width with copies of
                        # its current tree — Cmax depends only on the real
                        # candidate sets, so host and mesh agree on shapes
                        Cmax = _pow2ceil(int(n_cand.max()))
                        ch_k = np.broadcast_to(ch[:, None], (K, Cmax, M, 2)
                                               ).copy()
                        bl_k = np.broadcast_to(bl[:, None], (K, Cmax, M, 2)
                                               ).copy()
                        od_k = np.broadcast_to(od[:, None], (K, Cmax, M - n)
                                               ).copy()
                        for k, c in cands.items():
                            ch_k[k, :n_cand[k]] = c[0]
                            bl_k[k, :n_cand[k]] = c[1]
                            od_k[k, :n_cand[k]] = c[2]
                        lls = score(ch_k, bl_k, od_k, prm)
                        for k in range(K):
                            lls[k, n_cand[k]:] = -np.inf
                        for k in range(K):
                            if not active[k]:
                                continue
                            best = int(np.argmax(lls[k]))
                            if float(lls[k, best]) <= float(logl[k]) \
                                    + self.min_gain:
                                active[k] = 0
                                continue
                            c = cands[k]
                            ch[k], bl[k], od[k] = (c[0][best], c[1][best],
                                                   c[2][best])
                            b, p, ll = _fit(
                                patterns, weights, jnp.asarray(ch[k]),
                                jnp.asarray(od[k]), root, jnp.asarray(bl[k]),
                                jnp.asarray(prm[k]), model=model,
                                steps=self.steps, lr=self.lr,
                                site_chunk=self.site_chunk)
                            bl[k], prm[k], logl[k] = (np.asarray(b),
                                                      np.asarray(p),
                                                      float(ll))
                            kind = "nni" if best < c[3] else "spr"
                            moves[k, 0 if kind == "nni" else 1] += 1
                            _C_MOVES.labels(kind=kind).inc()
                            accepted += 1
                    else:
                        active[:] = 0
                    traj[:, r] = logl
                    if sp is not None:
                        sp.attrs.update(accepted=accepted,
                                        n_active=int(active.sum()),
                                        best_logl=float(np.max(logl)))
            _C_ROUNDS.inc()
            round_secs[r] = time.perf_counter() - t0
            return {"active": active, "blen": bl, "children": ch,
                    "logl": logl, "moves": moves, "order": od,
                    "params": prm, "round": np.int32(r + 1), "traj": traj}

        return step_fn
