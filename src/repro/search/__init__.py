"""repro.search — batched query-vs-database homology search.

The front end that completes the search -> align -> tree pipeline:
``SearchIndex`` (encode a FASTA database once, per-row k-mer tables,
atomic persistence), ``SearchEngine`` (mesh-shardable seed prefilter +
``AlignEngine.align_pairs`` rescoring + e-value/coverage gates), and the
Karlin–Altschul conversion in ``search.evalue``. Consumed by
``launch/search_run`` (CLI, ``--pipeline`` chains a query FASTA all the
way to a supported Newick tree) and ``repro.serve``'s ``/search``
endpoint. docs/SEARCH.md is the guide.
"""
from .engine import SearchConfig, SearchEngine, seed_counts_batch
from .evalue import bit_scores, evalues
from .index import SearchIndex

__all__ = ["SearchConfig", "SearchEngine", "SearchIndex",
           "seed_counts_batch", "bit_scores", "evalues"]
