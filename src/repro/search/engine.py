"""SearchEngine: batched query-vs-database homology search.

The missing first stage of the paper's ultra-large pipeline. UPP-style
systems make million-sequence workloads tractable by aligning/treeing
only what search says belongs together; this engine provides that
selection as two stages that both reuse existing machinery:

  seed      every (query, DB row) pair runs the k-mer anchor chaining
            from ``core.kmer_index`` (the MSA stage's trie equivalent,
            probing the per-row tables a ``SearchIndex`` prebuilt). The
            accepted-anchor count is the prefilter score; pairs below
            ``min_anchors`` never reach the DP. On a mesh the count
            matrix is computed shard-parallel over the database
            (``dist.mapreduce.search_over_mesh``).
  rescore   surviving pairs re-enter ``AlignEngine.align_pairs`` — the
            pow2-bucketed, backend-dispatching batch-entry API, so the
            Pallas SW kernel is the hot path on TPU — and raw scores
            become bit scores / e-values (``search.evalue``).

Host reduction: per-query hits are gated (``max_evalue``,
``min_coverage``), ordered by (score desc, db index asc) — a total,
deterministic order — and truncated to ``max_hits``. Because per-pair
counts and scores are independent of the database partitioning, results
are bit-identical between single-host and any ``--dist`` mesh shape
(pinned by ``tests/test_search.py``).
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..core import alphabet as ab
from ..core import kmer_index
from ..obs import metrics as _obs
from ..obs import trace as _trace
from . import evalue as ev
from .index import SearchIndex

_C_QUERIES = _obs.counter("repro_search_queries_total", "queries searched")
_C_PAIRS = _obs.counter("repro_search_pairs_total",
                        "(query, db row) pairs considered by the prefilter")
_C_CAND = _obs.counter("repro_search_candidates_total",
                       "pairs surviving the seed prefilter into rescoring")
_G_SURVIVAL = _obs.gauge("repro_search_survival_ratio",
                         "prefilter survival of the last search call")
_H_RESCORE = _obs.histogram("repro_search_rescore_seconds",
                            "wall-clock of the DP rescoring stage")


@functools.partial(jax.jit, static_argnames=("k", "stride", "max_anchors",
                                             "max_seg"))
def seed_counts_batch(Q, qlens, dblens, tables, *, k: int, stride: int,
                      max_anchors: int, max_seg: int):
    """(B, D) accepted-anchor counts: every query chained against every
    database row's k-mer table. jit/shard_map-safe — the shard body of
    ``dist.mapreduce.search_over_mesh`` and the single-host path both
    call exactly this function, which is what makes the two bit-equal.
    """
    def per_db(lb, tbl):
        def per_q(q, lq):
            a = kmer_index.chain_anchors(q, lq, tbl, lb, k=k, stride=stride,
                                         max_anchors=max_anchors,
                                         max_seg=max_seg)
            return a.count
        return jax.vmap(per_q)(Q, qlens)            # (B,)
    return jax.vmap(per_db)(dblens, tables).T       # (B, D)


@dataclasses.dataclass(frozen=True)
class SearchConfig:
    """Everything that changes a search result (part of the cache key)."""
    alphabet: str = "dna"        # dna | rna (base-4 seeding)
    k: int = 6                   # seeding k-mer width (index build)
    stride: int = 1              # query probe stride
    max_anchors: int = 32        # prefilter count saturation
    chain_seg: int = 1 << 20     # chaining segment budget: effectively
                                 # unlimited — a DB hit may sit anywhere
    min_anchors: int = 1         # seed survival threshold
    max_hits: int = 10           # per-query top-k
    min_coverage: float = 0.0    # aligned-column coverage of the query
    max_evalue: float = 10.0
    match: int = 2
    mismatch: int = -1
    gap_open: int = 3
    gap_extend: int = 1
    local: bool = True           # Smith-Waterman rescoring (vs global)
    backend: str = "auto"        # repro.align backend registry
    band: int = 64
    lam: float = ev.DEFAULT_LAMBDA
    k_const: float = ev.DEFAULT_K

    def alpha(self) -> ab.Alphabet:
        return {"dna": ab.DNA, "rna": ab.RNA}[self.alphabet]

    def matrix(self) -> jnp.ndarray:
        return ab.dna_matrix(self.match, self.mismatch).astype(jnp.float32)

    def engine(self):
        from ..align import AlignEngine
        return AlignEngine(self.matrix(), gap_open=self.gap_open,
                           gap_extend=self.gap_extend,
                           gap_code=self.alpha().gap_code,
                           backend=self.backend, band=self.band,
                           local=self.local)

    def fingerprint(self) -> str:
        return (f"{self.alphabet}/{self.k}/{self.stride}/{self.max_anchors}/"
                f"{self.chain_seg}/{self.min_anchors}/{self.match}/"
                f"{self.mismatch}/{self.gap_open}/{self.gap_extend}/"
                f"{self.local}/{self.backend}/{self.band}/"
                f"{self.lam}/{self.k_const}")


@dataclasses.dataclass(frozen=True)
class SearchEngine:
    """One configured search engine; construction is cheap (jit caches
    are module-level in the primitives it dispatches to)."""

    cfg: SearchConfig = SearchConfig()
    mesh: Optional[object] = None
    data_axis: str = "data"

    # ------------------------------------------------------------ index

    def build_index(self, names: Sequence[str],
                    seqs: Sequence[str]) -> SearchIndex:
        return SearchIndex.build(names, seqs, k=self.cfg.k,
                                 alphabet=self.cfg.alphabet)

    # ------------------------------------------------------------- seed

    def _encode_queries(self, seqs: Sequence[str]):
        norm = [s.replace("U", "T").replace("u", "t")
                if self.cfg.alphabet == "rna" else s for s in seqs]
        Q, qlens = ab.encode_batch(norm, self.cfg.alpha())
        if Q.shape[1] == 0:                    # all-empty query batch
            Q, qlens = ab.encode_batch(norm, self.cfg.alpha(), pad_to=1)
        return np.asarray(Q), np.asarray(qlens)

    def seed_counts(self, Q, qlens, index: SearchIndex) -> np.ndarray:
        """(B, D) anchor counts; shard-parallel over the DB on a mesh."""
        cfg = self.cfg
        if self.mesh is not None:
            from ..dist import mapreduce
            from ..dist import sharding as sh
            n = sh.axis_size(self.mesh, self.data_axis)
            tables, _ = mapreduce.pad_rows(index.tables, n)
            lens, _ = mapreduce.pad_rows(index.lens, n)
            fn = mapreduce.search_over_mesh(
                self.mesh, k=index.k, stride=cfg.stride,
                max_anchors=cfg.max_anchors, max_seg=cfg.chain_seg,
                data_axis=self.data_axis)
            counts = fn(jnp.asarray(Q), jnp.asarray(qlens, jnp.int32),
                        sh.shard_rows(lens, self.mesh, self.data_axis),
                        sh.shard_rows(tables, self.mesh, self.data_axis))
            return np.asarray(counts)[:, :index.n_seqs]
        counts = seed_counts_batch(
            jnp.asarray(Q), jnp.asarray(qlens, jnp.int32),
            jnp.asarray(index.lens), jnp.asarray(index.tables),
            k=index.k, stride=cfg.stride, max_anchors=cfg.max_anchors,
            max_seg=cfg.chain_seg)
        return np.asarray(counts)

    # ----------------------------------------------------------- search

    def search(self, names: Sequence[str], seqs: Sequence[str],
               index: SearchIndex, *, max_hits: Optional[int] = None,
               min_coverage: Optional[float] = None,
               max_evalue: Optional[float] = None,
               exhaustive: bool = False) -> dict:
        """Top-k hits for every query; gates default to the config's.

        ``exhaustive=True`` skips the seed prefilter and rescores every
        (query, DB) pair — the small-scale oracle the benchmark measures
        prefilter recall against.
        """
        cfg = self.cfg
        if index.alphabet != cfg.alphabet:
            raise ValueError(f"index alphabet {index.alphabet!r} != engine "
                             f"alphabet {cfg.alphabet!r}")
        max_hits = cfg.max_hits if max_hits is None else int(max_hits)
        min_coverage = (cfg.min_coverage if min_coverage is None
                        else float(min_coverage))
        max_evalue = cfg.max_evalue if max_evalue is None else float(max_evalue)

        names = list(names)
        Q, qlens = self._encode_queries(seqs)
        B = Q.shape[0]
        with _trace.span("search.seed", n_queries=B, db_seqs=index.n_seqs,
                         seed="mesh" if self.mesh is not None else "host"):
            counts = self.seed_counts(Q, qlens, index)      # (B, D)

        cand = (np.ones_like(counts, bool) if exhaustive
                else counts >= cfg.min_anchors)
        qi, di = np.nonzero(cand)                            # row-major:
        n_cand = len(qi)                                     # deterministic
        _C_QUERIES.inc(B)
        _C_PAIRS.inc(B * index.n_seqs)
        _C_CAND.inc(n_cand)
        _G_SURVIVAL.set(n_cand / max(B * index.n_seqs, 1))

        per_query: List[List[dict]] = [[] for _ in range(B)]
        n_calls = 0
        if n_cand:
            engine = cfg.engine()
            t0 = time.perf_counter()
            with _trace.span("search.rescore", pairs=n_cand) as sp:
                res = engine.align_pairs(Q[qi], qlens[qi],
                                         index.S[di], index.lens[di])
                if sp is not None:
                    jax.block_until_ready(res.score)
            _H_RESCORE.observe(sp.duration if sp is not None
                               else time.perf_counter() - t0)
            n_calls = res.n_calls
            scores = np.asarray(res.score, np.float32)
            gap = cfg.alpha().gap_code
            a = np.asarray(res.a_row)
            b = np.asarray(res.b_row)
            aligned = ((a != gap) & (b != gap)).sum(axis=1)
            cov = aligned / np.maximum(qlens[qi], 1)
            bits = ev.bit_scores(scores, lam=cfg.lam, k_const=cfg.k_const)
            evals = ev.evalues(scores, qlens[qi], index.db_residues,
                               lam=cfg.lam, k_const=cfg.k_const)
            keep = (evals <= max_evalue) & (cov >= min_coverage)
            # total order: query, score desc, db index asc — ties cannot
            # reorder between runs or mesh shapes
            order = sorted(np.nonzero(keep)[0].tolist(),
                           key=lambda j: (qi[j], -scores[j], di[j]))
            for j in order:
                q = int(qi[j])
                if len(per_query[q]) >= max_hits:
                    continue
                d = int(di[j])
                per_query[q].append({
                    "target": index.names[d], "db_idx": d,
                    "score": float(scores[j]),
                    "bits": round(float(bits[j]), 4),
                    "evalue": float(evals[j]),
                    "coverage": round(float(cov[j]), 4),
                    "anchors": int(counts[q, d])})

        return {
            "queries": [{"name": names[i], "length": int(qlens[i]),
                         "hits": per_query[i]} for i in range(B)],
            "stats": {
                "db_seqs": index.n_seqs,
                "db_residues": index.db_residues,
                "candidates": n_cand,
                "survival": round(n_cand / max(B * index.n_seqs, 1), 4),
                "align_calls": n_calls,
                "seed": "mesh" if self.mesh is not None else "host",
                "exhaustive": bool(exhaustive)}}
