"""Karlin–Altschul statistics: raw DP scores -> bit scores -> e-values.

The search engine ranks candidate pairs by their Smith–Waterman (or
global Gotoh) score; a raw score is meaningless across queries of
different lengths or databases of different sizes, so hits are reported
in the standard extreme-value frame:

  bits  = (lambda * S - ln K) / ln 2
  E     = m * N * 2^(-bits)

with ``m`` the query length and ``N`` the total residue count of the
database (the search space). ``lambda``/``K`` are the Gumbel parameters
of the scoring system; the defaults below are the published ungapped
nucleotide values for a +2/-3-class matrix (lambda=1.28, K=0.46) and are
*nominal* — this engine uses them as a calibrated ranking transform, not
as a claim of exact gapped statistics (fitting gapped parameters per
matrix is out of scope; docs/SEARCH.md spells out the semantics). Both
are exposed on ``SearchConfig`` for callers who fit their own.

Everything here is pure numpy on tiny (n_candidates,) vectors — it runs
after the device-side scoring, on the host reduction path.
"""
from __future__ import annotations

import math

import numpy as np

# nominal ungapped DNA Gumbel parameters (blastn-class scoring)
DEFAULT_LAMBDA = 1.28
DEFAULT_K = 0.46


def bit_scores(scores, *, lam: float = DEFAULT_LAMBDA,
               k_const: float = DEFAULT_K) -> np.ndarray:
    """Normalized bit scores: (lambda*S - ln K) / ln 2."""
    s = np.asarray(scores, np.float64)
    return (lam * s - math.log(k_const)) / math.log(2.0)


def evalues(scores, query_lens, db_residues: int, *,
            lam: float = DEFAULT_LAMBDA,
            k_const: float = DEFAULT_K) -> np.ndarray:
    """Expected chance hits at or above each score: m * N * 2^-bits.

    ``query_lens`` broadcasts against ``scores`` (per-candidate query
    length m); ``db_residues`` is the summed true length of every
    database sequence — the search space is the same for every query
    against one index, which keeps e-values comparable across a batch.
    Exponents are clamped so a pathological score can never overflow to
    inf/0 silently.
    """
    bits = bit_scores(scores, lam=lam, k_const=k_const)
    m = np.asarray(query_lens, np.float64)
    space = m * float(max(int(db_residues), 1))
    return space * np.exp2(np.clip(-bits, -1022.0, 1022.0))
