"""SearchIndex: the encode-once, query-many database artifact.

The homology engine's database side is built exactly once per FASTA: the
sequences are encoded to the usual ``(D, Lmax) int8`` frame and every row
gets its own dense k-mer table (``core.kmer_index.build_center_index`` —
the same structure the MSA stage broadcasts for its center, here one per
database sequence, so the seeding stage is a pure reuse of the chaining
core). The whole artifact is a flat dict of arrays persisted through
``dist.checkpoint.atomic_save_npz``: build on one host, reload in every
worker, and a crash mid-save can never leave a torn index behind.

Size note: a table is ``4^k * r`` int32 per database sequence. The
search-seeding default ``k=6`` costs 64 KiB/sequence (4096 * 4 * 4 B);
the MSA-stage default ``k=11`` would cost 64 MiB/sequence — use small
seeding k for databases, large k only for the single broadcast center.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import List, Sequence, Tuple

import jax
import numpy as np

from ..core import alphabet as ab
from ..core import kmer_index

_FORMAT_VERSION = 1


def _alpha(alphabet: str) -> ab.Alphabet:
    if alphabet not in ("dna", "rna"):
        raise ValueError(
            f"search indexes need a nucleotide alphabet (base-4 k-mer "
            f"codes), got {alphabet!r}")
    return {"dna": ab.DNA, "rna": ab.RNA}[alphabet]


@dataclasses.dataclass(frozen=True)
class SearchIndex:
    """Immutable database artifact: encoded rows + per-row k-mer tables."""

    names: Tuple[str, ...]
    S: np.ndarray          # (D, Lmax) int8 encoded rows, gap-padded
    lens: np.ndarray       # (D,) int32 true lengths
    tables: np.ndarray     # (D, 4^k, r) int32 code -> first r positions
    k: int                 # seeding k-mer width
    r: int                 # occurrences kept per code
    alphabet: str          # dna | rna

    @property
    def n_seqs(self) -> int:
        return int(self.S.shape[0])

    @property
    def db_residues(self) -> int:
        """Total true residue count — the N of the e-value search space."""
        return int(self.lens.sum())

    def alpha(self) -> ab.Alphabet:
        return _alpha(self.alphabet)

    def fingerprint(self) -> str:
        """Content hash over everything that changes search results —
        the database half of the service's cache key."""
        h = hashlib.sha256()
        h.update(f"search-index/v{_FORMAT_VERSION}/{self.alphabet}/"
                 f"{self.k}/{self.r}".encode())
        h.update(np.ascontiguousarray(self.lens).tobytes())
        h.update(np.ascontiguousarray(self.S).tobytes())
        return h.hexdigest()

    # ------------------------------------------------------------ build

    @classmethod
    def build(cls, names: Sequence[str], seqs: Sequence[str], *,
              k: int = 6, alphabet: str = "dna",
              r: int = 4) -> "SearchIndex":
        alpha = _alpha(alphabet)
        if not seqs:
            raise ValueError("cannot index an empty database")
        if len(names) != len(seqs):
            raise ValueError(f"{len(names)} names for {len(seqs)} sequences")
        norm = [s.replace("U", "T").replace("u", "t")
                if alphabet == "rna" else s for s in seqs]
        S, lens = ab.encode_batch(norm, alpha)
        if S.shape[1] < k:          # keep at least one window's worth of
            S, lens = ab.encode_batch(norm, alpha, pad_to=k)  # table width
        tables = jax.vmap(
            lambda s, l: kmer_index.build_center_index(s, l, k=k, r=r)
        )(S, lens)
        return cls(names=tuple(names), S=np.asarray(S),
                   lens=np.asarray(lens), tables=np.asarray(tables),
                   k=k, r=r, alphabet=alphabet)

    # ---------------------------------------------------------- persist

    def save(self, path) -> None:
        """Atomic single-file persist (``dist.checkpoint.atomic_save_npz``)."""
        from ..dist.checkpoint import atomic_save_npz
        atomic_save_npz(path, {
            "version": np.int32(_FORMAT_VERSION),
            "names": np.array(self.names, dtype=np.str_),
            "S": self.S, "lens": self.lens, "tables": self.tables,
            "k": np.int32(self.k), "r": np.int32(self.r),
            "alphabet": np.str_(self.alphabet)})

    @classmethod
    def load(cls, path) -> "SearchIndex":
        with np.load(path) as z:
            version = int(z["version"])
            if version != _FORMAT_VERSION:
                raise ValueError(
                    f"search index {path} has format v{version}, this "
                    f"build reads v{_FORMAT_VERSION} — rebuild the index")
            return cls(names=tuple(str(n) for n in z["names"]),
                       S=z["S"].astype(np.int8),
                       lens=z["lens"].astype(np.int32),
                       tables=z["tables"].astype(np.int32),
                       k=int(z["k"]), r=int(z["r"]),
                       alphabet=str(z["alphabet"]))
