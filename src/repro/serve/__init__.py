"""repro.serve — the paper's web-service layer over the existing engines.

HAlign-II's third contribution is "a user-friendly web server based on
our distributed computing infrastructure"; this package is that layer,
reusing the engines instead of re-implementing them:

  ``cache``        content-hash result cache over canonicalized sequence
                   sets (LRU + byte budget, hit/miss stats)
  ``queue``        deadline-aware coalescing: concurrent align requests
                   merge into ``AlignEngine.align_pairs``'s pow2 buckets
                   so one jitted call serves many callers
  ``incremental``  add-to-MSA against a frozen center + merged gap
                   pattern — bit-identical columns for already-aligned
                   members, full realign past a drift threshold
  ``store``        persistent generation-versioned MSAStore of *named*
                   alignments: atomic crash-safe commits, retention,
                   corrupt-latest fallback, background drift realign
                   with atomic swap (``--store-dir``)
  ``service``      the MSAService facade + stdlib HTTP/JSON front end
                   (``/align``, ``/align/add``, ``/tree``, ``/healthz``)

``repro.launch.serve_msa`` is the CLI entry point.
"""
from .cache import ResultCache, canonical_key, canonicalize  # noqa: F401
from .incremental import AddResult, add_to_msa  # noqa: F401
from .queue import AlignJob, CoalescingAligner  # noqa: F401
from .service import MSAService, ServiceConfig, serve_http  # noqa: F401
from .store import (MSAStore, StoreEntry, StoreError,  # noqa: F401
                    content_fingerprint)
