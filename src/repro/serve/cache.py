"""Content-hash result cache for the MSA service.

Requests are keyed by *what they align*, not how they arrived: the
sequence set is canonicalized (sorted, names dropped — names never
influence an alignment) and hashed together with the engine fingerprint,
so the same family submitted in any order, under any names, hits the
same entry. The stored value is the alignment of the canonical order;
``MSAService`` maps rows back to each request's order on the way out,
which is also why a hit can be byte-identical to the miss that filled it.

Eviction is LRU under two budgets (entry count and total payload bytes);
``stats()`` feeds the hit/miss counters every response carries.
"""
from __future__ import annotations

import hashlib
import threading
from collections import OrderedDict
from typing import List, Optional, Sequence, Tuple

from ..obs import metrics as _obs

_C_LOOKUPS = _obs.counter("repro_cache_requests_total",
                          "cache lookups by outcome", ("outcome",))
_C_EVICTIONS = _obs.counter("repro_cache_evictions_total", "LRU evictions")
_G_BYTES = _obs.gauge("repro_cache_bytes",
                      "payload bytes resident (last cache instance)")
_G_ITEMS = _obs.gauge("repro_cache_items",
                      "entries resident (last cache instance)")


def canonicalize(seqs: Sequence[str]) -> Tuple[List[str], List[int]]:
    """Sort sequences; returns (sorted_seqs, perm) with seqs[perm[i]] ==
    sorted_seqs[i]. Duplicates keep a stable order so the permutation is
    deterministic."""
    perm = sorted(range(len(seqs)), key=lambda i: (seqs[i], i))
    return [seqs[i] for i in perm], perm


def canonical_key(seqs: Sequence[str], fingerprint: str = "",
                  center: Optional[str] = None) -> str:
    """sha256 over the canonicalized set + engine fingerprint.

    ``center`` pins the key to a specific frozen center sequence —
    incremental add-to-MSA results are centered on the *parent's* center,
    which a fresh align of the same set would not necessarily pick, so
    the two must not collide.
    """
    h = hashlib.sha256()
    h.update(fingerprint.encode())
    if center is not None:
        h.update(b"\x00center\x00")
        h.update(center.encode())
    canon, _ = canonicalize(seqs)
    for s in canon:
        h.update(b"\x00")
        h.update(s.encode())
    return h.hexdigest()


class ResultCache:
    """Thread-safe LRU keyed by content hash, bounded by items and bytes."""

    def __init__(self, max_bytes: int = 256 << 20, max_items: int = 4096):
        self.max_bytes = int(max_bytes)
        self.max_items = int(max_items)
        self._d: OrderedDict[str, Tuple[object, int]] = OrderedDict()
        self._bytes = 0
        self._hits = 0
        self._misses = 0
        self._evictions = 0
        self._lock = threading.Lock()

    @property
    def lock(self) -> threading.Lock:
        """The cache's own lock — exposed so a caller can combine this
        cache's stats with another component's under one acquisition
        (``MSAService.stats_snapshot``)."""
        return self._lock

    def get(self, key: str):
        with self._lock:
            ent = self._d.get(key)
            if ent is None:
                self._misses += 1
                _C_LOOKUPS.labels(outcome="miss").inc()
                return None
            self._d.move_to_end(key)
            self._hits += 1
            _C_LOOKUPS.labels(outcome="hit").inc()
            return ent[0]

    def peek(self, key: str):
        """Lookup without touching LRU order or hit/miss counters (used to
        resolve msa_id references, which are not align-request hits)."""
        with self._lock:
            ent = self._d.get(key)
            return None if ent is None else ent[0]

    def put(self, key: str, value, nbytes: int):
        with self._lock:
            if key in self._d:
                self._bytes -= self._d.pop(key)[1]
            self._d[key] = (value, int(nbytes))
            self._bytes += int(nbytes)
            while self._d and (len(self._d) > self.max_items
                               or self._bytes > self.max_bytes):
                _, (_, nb) = self._d.popitem(last=False)
                self._bytes -= nb
                self._evictions += 1
                _C_EVICTIONS.inc()
            _G_BYTES.set(self._bytes)
            _G_ITEMS.set(len(self._d))

    def stats_locked(self) -> dict:
        """Stats snapshot; caller must hold ``self.lock``."""
        return {"hits": self._hits, "misses": self._misses,
                "items": len(self._d), "bytes": self._bytes,
                "evictions": self._evictions}

    def stats(self) -> dict:
        with self._lock:
            return self.stats_locked()
