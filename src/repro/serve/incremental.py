"""Incremental add-to-MSA: align new sequences into an existing alignment.

In the spirit of UPP's phylogeny-aware profile insertion (*Ultra-large
alignments using Phylogeny-aware Profiles*), new sequences are aligned
against the *frozen center* of a previous center-star MSA rather than
re-aligning the whole family. Center-star makes this exact, not an
approximation:

  * the old MSA's center row encodes the merged gap profile ``G_old``
    completely (``G_old[j]`` = gap columns between center chars j-1, j),
  * new pairs are aligned to the center through the *same* map(1) code
    path a full run uses (``core.msa.map1_align_to_center``),
  * the merged profile is ``G_new = max(G_old, profiles(new pairs))``,
    which is exactly what a full realign over old + new pairs computes,
  * old rows move into the wider frame by a per-column shift
    ``cumsum(G_new) - cumsum(G_old)`` — every existing column reappears
    verbatim (new all-gap columns are interleaved, never rewritten), so
    already-aligned members are *bit-identical* to a full realign with
    the same center (pinned by ``tests/test_serve.py``).

Past a drift threshold (relative width growth) the profile-merge frame
is considered stale and the family is fully re-aligned from scratch —
the old sequences are recovered from the MSA rows by stripping gaps.
"""
from __future__ import annotations

from typing import NamedTuple, Sequence

import numpy as np

from ..core import centerstar
from ..core.msa import (MSAConfig, center_star_msa, encode_for_msa,
                        map1_align_to_center)


class AddResult(NamedTuple):
    msa: np.ndarray        # (N_old + N_new, width) int8, old rows first
    center_idx: int
    width: int
    n_new: int
    realigned: bool        # True = drift exceeded, full realign ran
    n_fallback: int
    growth: float          # (new_width - old_width) / old_width


def center_profile(msa: np.ndarray, center_idx: int, gap: int):
    """Recover (center codes, lc, G_old) from the stored center row."""
    crow = np.asarray(msa[center_idx])
    ischar = crow != gap
    center = crow[ischar]
    lc = int(center.shape[0])
    # slot of each column: number of center chars strictly before it
    slot = np.cumsum(ischar) - ischar
    G_old = np.bincount(slot[~ischar], minlength=lc + 1)[: lc + 1] \
        if (~ischar).any() else np.zeros(lc + 1, np.int64)
    return center.astype(np.int8), lc, G_old.astype(np.int64)


def expand_rows(msa: np.ndarray, center_idx: int, G_old, G_new, gap: int
                ) -> np.ndarray:
    """Re-emit old rows in the wider G_new frame, columns preserved.

    Each old column shifts right by ``(cumsum(G_new) - cumsum(G_old))``
    at its slot; the shift is constant within an insertion block, so
    right-packed blocks stay right-packed — the layout ``build_rows``
    would produce. New columns are all-gap for old members.
    """
    msa = np.asarray(msa)
    crow = msa[center_idx]
    ischar = crow != gap
    slot = np.cumsum(ischar) - ischar                      # (old_w,)
    delta = np.cumsum(G_new) - np.cumsum(G_old)            # (lc+1,) >= 0
    new_cols = np.arange(msa.shape[1]) + delta[slot]
    new_w = msa.shape[1] + int(delta[-1])
    out = np.full((msa.shape[0], new_w), gap, msa.dtype)
    out[:, new_cols] = msa
    return out


def add_to_msa(msa: np.ndarray, center_idx: int,
               new_seqs: Sequence[str], cfg: MSAConfig, *,
               drift_threshold: float = 0.25, engine=None) -> AddResult:
    """Insert ``new_seqs`` into an existing center-star MSA.

    ``msa`` is the previous aligned (N, W) int8 block, ``center_idx`` its
    frozen center row. Output rows keep the old order with new members
    appended. ``drift_threshold`` bounds relative width growth; past it
    the whole family (old sequences recovered from the rows) is
    re-aligned with ``cfg``'s own center policy and ``realigned=True``
    is reported.
    """
    alpha = cfg.alpha()
    gap = alpha.gap_code
    msa = np.asarray(msa)
    n_old, old_w = msa.shape
    center, lc, G_old = center_profile(msa, center_idx, gap)

    Q, qlens = encode_for_msa(list(new_seqs), cfg)
    a_rows, b_rows, n_fallback = map1_align_to_center(
        Q, qlens, np.asarray(center), np.int32(lc), cfg, engine)

    g = centerstar.gap_profiles(a_rows, b_rows, gap_code=gap,
                                num_slots=lc + 1)
    G_new = np.maximum(G_old, np.asarray(centerstar.merge_profiles(g)))
    new_w = lc + int(G_new.sum())
    growth = (new_w - old_w) / max(old_w, 1)

    if growth > drift_threshold:
        old_seqs = [alpha.decode(r).replace("-", "") for r in msa]
        res = center_star_msa(old_seqs + list(new_seqs), cfg)
        return AddResult(res.msa, res.center_idx, res.width, len(new_seqs),
                         True, res.n_fallback, growth)

    out = np.full((n_old + len(new_seqs), new_w), gap, np.int8)
    out[:n_old] = expand_rows(msa, center_idx, G_old, G_new, gap)
    out[n_old:] = np.asarray(centerstar.build_rows(
        a_rows, b_rows, np.asarray(G_new), gap_code=gap, out_len=new_w))
    return AddResult(out, center_idx, new_w, len(new_seqs), False,
                     int(n_fallback), growth)
