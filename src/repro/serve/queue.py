"""Deadline-aware request coalescing for the align service.

The expensive unit of work in a center-star request is map(1): a batch of
queries against that request's center. Concurrent requests each carry a
*different* center, so they cannot share the broadcast-center primitive —
but they can share ``AlignEngine.align_pairs``: every (query, center)
pair becomes one row of a per-pair-target batch, and the engine's pow2
(q_width, t_width) bucketing turns the merged batch into at most
log2(Lq)·log2(Lt) jitted calls no matter how many callers contributed.

Scheduling is max-wait / max-batch: a submitted job waits at most
``max_wait_ms`` for company (the deadline), and a group is flushed early
the moment it reaches ``max_batch`` pairs. One worker thread executes
groups serially — device work is serialized anyway; the coalescing win is
batching, not concurrency. Jobs only merge within an ``engine_key``
(same alphabet/scoring/backend), and ``close()`` drains: everything
already submitted completes, new submissions are refused.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import Future
from typing import Dict, List, NamedTuple, Optional, Tuple

import numpy as np

from ..obs import metrics as _obs
from ..obs import trace as _trace

_H_WAIT = _obs.histogram(
    "repro_queue_wait_seconds", "submit-to-batch-start wait per job",
    buckets=(0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25,
             0.5, 1.0, 2.5))
_H_OCCUPANCY = _obs.histogram(
    "repro_batch_pairs", "pairs per coalesced batch",
    buckets=(1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024))
_C_FAILED_BATCHES = _obs.counter("repro_failed_batches_total",
                                 "coalesced batches whose engine call failed")
_C_FAILED_PAIRS = _obs.counter("repro_failed_pairs_total",
                               "pairs failed with their batch")


@dataclasses.dataclass
class AlignJob:
    """One caller's map(1) work unit: queries against a frozen center."""
    Q: np.ndarray          # (B, Lq) int8 encoded queries (gap-padded)
    qlens: np.ndarray      # (B,) int32
    target: np.ndarray     # (m,) int8 encoded center (unpadded)
    tlen: int
    engine: object         # repro.align.AlignEngine
    engine_key: str        # jobs coalesce only within one key


class JobResult(NamedTuple):
    score: np.ndarray      # (B,) f32
    a_row: np.ndarray      # (B, P) int8
    b_row: np.ndarray      # (B, P) int8
    aln_len: np.ndarray    # (B,) i32
    meta: dict             # batch_jobs / batch_pairs / engine_calls


class CoalescingAligner:
    """Merge concurrent AlignJobs into bucketed ``align_pairs`` batches."""

    def __init__(self, *, max_batch: int = 256, max_wait_ms: float = 5.0):
        self.max_batch = int(max_batch)
        self.max_wait_ms = float(max_wait_ms)
        self._pending: Dict[str, List[Tuple[float, AlignJob, Future]]] = {}
        self._cond = threading.Condition()
        self._closing = False
        self._stats = {"jobs": 0, "pairs": 0, "batches": 0,
                       "engine_calls": 0, "coalesced_jobs": 0,
                       "fallback_pairs": 0, "failed_batches": 0,
                       "failed_pairs": 0}
        self._in_flight = 0
        self._worker = threading.Thread(target=self._loop,
                                        name="coalescing-aligner",
                                        daemon=True)
        self._worker.start()

    # ------------------------------------------------------------ public

    def submit(self, job: AlignJob) -> "Future[JobResult]":
        """Enqueue a job; the returned future resolves to a JobResult."""
        fut: Future = Future()
        deadline = time.monotonic() + self.max_wait_ms / 1e3
        with self._cond:
            if self._closing:
                raise RuntimeError("CoalescingAligner is draining; "
                                   "no new jobs accepted")
            self._pending.setdefault(job.engine_key, []).append(
                (deadline, job, fut))
            self._stats["jobs"] += 1
            self._stats["pairs"] += int(job.Q.shape[0])
            self._in_flight += 1
            self._cond.notify()
        return fut

    def close(self):
        """Drain: flush every pending group, finish in-flight work, stop.

        Idempotent; after it returns, all previously returned futures are
        resolved and ``submit`` raises.
        """
        with self._cond:
            self._closing = True
            self._cond.notify()
        self._worker.join()

    @property
    def lock(self) -> threading.Condition:
        """The queue's own lock, exposed for combined atomic snapshots
        (``MSAService.stats_snapshot`` holds it together with the cache
        lock so ``/healthz`` numbers come from one instant)."""
        return self._cond

    def stats_locked(self) -> dict:
        """Stats snapshot; caller must hold ``self.lock``."""
        return dict(self._stats, in_flight=self._in_flight)

    def stats(self) -> dict:
        with self._cond:
            return self.stats_locked()

    # ------------------------------------------------------------ worker

    def _ready_key(self, now: float) -> Optional[str]:
        for key, items in self._pending.items():
            pairs = sum(int(j.Q.shape[0]) for _, j, _ in items)
            if (self._closing or pairs >= self.max_batch
                    or min(d for d, _, _ in items) <= now):
                return key
        return None

    def _loop(self):
        while True:
            with self._cond:
                while True:
                    now = time.monotonic()
                    key = self._ready_key(now)
                    if key is not None:
                        items = self._pending.pop(key)
                        break
                    if self._closing and not self._pending:
                        return
                    if self._pending:
                        nxt = min(d for items in self._pending.values()
                                  for d, _, _ in items)
                        self._cond.wait(timeout=max(nxt - now, 0.0))
                    else:
                        self._cond.wait()
            self._run_batch(items)
            with self._cond:
                self._in_flight -= len(items)
                self._cond.notify()

    def _run_batch(self, items):
        jobs = [j for _, j, _ in items]
        futs = [f for _, _, f in items]
        now = time.monotonic()
        wait_budget = self.max_wait_ms / 1e3
        for deadline, _, _ in items:
            # submit time is deadline - max_wait, so no tuple change needed
            _H_WAIT.observe(max(now - (deadline - wait_budget), 0.0))
        n_pairs = sum(int(j.Q.shape[0]) for j in jobs)
        try:
            with _trace.span("serve.batch", jobs=len(jobs), pairs=n_pairs,
                             engine_key=jobs[0].engine_key):
                engine = jobs[0].engine
                gap = engine.gap_code
                counts = [int(j.Q.shape[0]) for j in jobs]
                B = sum(counts)
                Lq = max(int(j.Q.shape[1]) for j in jobs)
                Lt = max(int(j.tlen) for j in jobs)
                Q = np.full((B, Lq), gap, np.int8)
                T = np.full((B, Lt), gap, np.int8)
                qlens = np.zeros((B,), np.int32)
                tlens = np.zeros((B,), np.int32)
                off = 0
                for j, c in zip(jobs, counts):
                    Q[off:off + c, : j.Q.shape[1]] = np.asarray(j.Q)
                    T[off:off + c, : j.tlen] = np.asarray(j.target)[: j.tlen]
                    qlens[off:off + c] = np.asarray(j.qlens)
                    tlens[off:off + c] = j.tlen
                    off += c

                res = engine.align_pairs(Q, qlens, T, tlens)
                a_rows = np.asarray(res.a_row)
                b_rows = np.asarray(res.b_row)
                score = np.asarray(res.score)
                aln_len = np.asarray(res.aln_len)
            meta = {"batch_jobs": len(jobs), "batch_pairs": B,
                    "engine_calls": int(res.n_calls)}
            _H_OCCUPANCY.observe(B)
            with self._cond:
                self._stats["batches"] += 1
                self._stats["engine_calls"] += int(res.n_calls)
                self._stats["fallback_pairs"] += int(res.n_fallback)
                if len(jobs) > 1:
                    self._stats["coalesced_jobs"] += len(jobs)
            off = 0
            for fut, c in zip(futs, counts):
                fut.set_result(JobResult(score[off:off + c],
                                         a_rows[off:off + c],
                                         b_rows[off:off + c],
                                         aln_len[off:off + c], meta))
                off += c
        except BaseException as e:
            _C_FAILED_BATCHES.inc()
            _C_FAILED_PAIRS.inc(n_pairs)
            with self._cond:
                self._stats["failed_batches"] += 1
                self._stats["failed_pairs"] += n_pairs
            for fut in futs:
                if not fut.done():
                    fut.set_exception(e)
