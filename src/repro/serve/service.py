"""MSAService: the web-service facade over align / phylo / dist / serve.

The request dataflow (docs/ARCHITECTURE.md has the full map):

  POST /align      FASTA/JSON -> canonicalize -> cache lookup -> on miss,
                   center-select and submit the map(1) work to the
                   coalescing queue (one ``align_pairs`` batch serves
                   many concurrent requests) -> center-star assembly ->
                   cache fill -> rows mapped back to the caller's order.
                   With ``?name=`` (or ``"name"`` in the body) and a
                   configured ``--store-dir``: creates (sequences given)
                   or loads (no sequences) a *persistent named
                   alignment* in the ``store.MSAStore``
  POST /align/add  incremental insertion into a cached MSA against its
                   frozen center (``incremental.add_to_msa``); with
                   ``"name"`` the insertion commits a new store
                   generation (atomic, crash-safe) and past the drift
                   threshold schedules a *background* realign — readers
                   keep the stale-but-valid generation until the
                   realigned one swaps in
  POST /tree       TreeEngine over a cached MSA (tree results memoized
                   through the engine's cache hook) or fresh sequences;
                   ``"refine": "ml"`` routes through the ML refiner —
                   the cache fingerprint spans backend, refine mode,
                   substitution model, bootstrap count, and seed
  POST /search     query sequences -> per-query top-k database hits
                   (``repro.search``: mesh-shardable seed prefilter +
                   DP rescore + e-value gates), content-hash cached
                   like ``/align`` — requires a configured
                   ``ServiceConfig.search_index``
  GET  /healthz    liveness + cache / queue stats (one atomic snapshot)
  GET  /metrics    Prometheus text exposition of the ``repro.obs`` registry
  GET  /statusz    human-readable service snapshot (plain text)

Every request runs under ``repro.obs``: a fresh trace ID is opened per
request (returned as ``trace_id`` in each JSON response, stamped on every
span the request produces), request counters reconcile as
``started == finished + rejected``, and latency histograms cover the
request and the coalescer's queue wait / batch occupancy.

Big requests compose with ``repro.dist``: with a mesh configured,
families of ``dist_threshold`` or more sequences route through
``mapreduce.msa_over_mesh`` (shard_map over the data axis) instead of
the coalescing queue, and the TreeEngine shard-maps its distance strips
over the same mesh.

``serve_http`` wraps the facade in a stdlib ThreadingHTTPServer;
``drain()`` refuses new work, lets in-flight requests finish, and
flushes the queue — the graceful-shutdown path ``launch/serve_msa``
wires to SIGINT/SIGTERM.
"""
from __future__ import annotations

import contextlib
import dataclasses
import io
import json
import threading
import time
from collections import OrderedDict
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Iterator, List, Optional, Sequence, Tuple

import numpy as np

from ..core import msa as msa_mod
from ..core.msa import MSAConfig
from ..data import iter_fasta
from ..data.fasta import _normalize_seq
from ..obs import metrics as _obs
from ..obs import trace as _trace
from ..phylo import TreeEngine
from . import incremental
from .cache import ResultCache, canonical_key, canonicalize
from .queue import AlignJob, CoalescingAligner
from .store import MSAStore
from .store import StoreError as _StoreError

_M_STARTED = _obs.counter("repro_requests_started_total",
                          "requests received (accepted + rejected)",
                          ("endpoint",))
_M_FINISHED = _obs.counter("repro_requests_finished_total",
                           "requests completed", ("endpoint", "status"))
_M_REJECTED = _obs.counter("repro_requests_rejected_total",
                           "requests refused while draining", ("endpoint",))
_H_LATENCY = _obs.histogram("repro_request_seconds",
                            "request wall-clock", ("endpoint",))
_G_ACTIVE = _obs.gauge("repro_requests_active", "requests currently in flight")


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """Server-wide alignment/tree configuration (fixed per process —
    request payloads carry data, not scoring knobs, so one engine's jit
    caches serve all traffic)."""
    alphabet: str = "dna"
    method: str = "plain"        # plain | sw | kmer (kmer runs uncoalesced)
    backend: str = "auto"        # repro.align backend registry
    band: int = 64
    k: int = 11
    center: str = "first"
    max_batch: int = 256         # coalescing: flush at this many pairs
    max_wait_ms: float = 5.0     # coalescing: max time a request waits
    cache_bytes: int = 256 << 20
    cache_items: int = 4096
    tree_cache_items: int = 256
    drift_threshold: float = 0.25
    tree_backend: str = "auto"
    tree_refine: str = "none"    # none | ml: /tree default refinement
    tree_model: str = "auto"     # substitution model for refine=ml
    tree_bootstrap: int = 0      # bootstrap replicates for refine=ml
    tree_seed: int = 0           # bootstrap / ML seed
    cluster_threshold: int = 64
    mesh: Optional[object] = None
    dist_threshold: int = 512    # with a mesh: route N >= this through
                                 # mapreduce.msa_over_mesh
    search_index: Optional[object] = None   # repro.search.SearchIndex:
                                            # enables POST /search
    search_cfg: Optional[object] = None     # SearchConfig override
                                            # (default: index-matched)
    store_dir: Optional[str] = None         # persistent MSAStore root:
                                            # enables named alignments
    store_keep: int = 4                     # generations retained / name
    store_realign: str = "background"       # background | never

    def msa_cfg(self) -> MSAConfig:
        return MSAConfig(method=self.method, alphabet=self.alphabet,
                         k=self.k, center=self.center,
                         gap_open=11 if self.alphabet == "protein" else 3,
                         backend=self.backend, band=self.band)

    def fingerprint(self) -> str:
        c = self.msa_cfg()
        return (f"{c.alphabet}/{c.method}/{c.backend}/{c.band}/{c.k}/"
                f"{c.center}/{c.gap_open}/{c.gap_extend}")


def parse_sequences(payload: dict) -> Tuple[List[str], List[str]]:
    """Extract (names, sequences) from a request body.

    Accepts ``{"fasta": "..."} `` (streamed through ``iter_fasta`` — the
    text is parsed record-by-record, never re-joined) or
    ``{"sequences": [...], "names": [...]}``. Both paths apply the same
    normalization (uppercase, ``.``→``-``, ``\\r`` stripped, invalid
    characters rejected) so identical data yields identical alignments
    and cache keys regardless of payload format.
    """
    if "fasta" in payload:
        names, seqs = [], []
        for name, seq in iter_fasta(io.StringIO(payload["fasta"])):
            names.append(name)
            seqs.append(seq)
    elif "sequences" in payload:
        raw = payload["sequences"]
        names = payload.get("names") or [f"seq{i}" for i in range(len(raw))]
        if len(names) != len(raw):
            raise ValueError(f"{len(names)} names for {len(raw)} sequences")
        seqs = [_normalize_seq([s.replace("\r", "")], n)
                for n, s in zip(names, raw)]
    else:
        raise ValueError("request needs 'fasta' or 'sequences'")
    if not seqs:
        raise ValueError("no sequences in request")
    return names, seqs


class MSAService:
    """The service facade; thread-safe — HTTP handler threads call in."""

    def __init__(self, cfg: ServiceConfig = ServiceConfig()):
        self.cfg = cfg
        self.msa_cfg = cfg.msa_cfg()
        self.alpha = self.msa_cfg.alpha()
        self.engine = self.msa_cfg.engine()
        self.cache = ResultCache(max_bytes=cfg.cache_bytes,
                                 max_items=cfg.cache_items)
        self.coalescer = CoalescingAligner(max_batch=cfg.max_batch,
                                           max_wait_ms=cfg.max_wait_ms)
        self.tree_cache: OrderedDict = OrderedDict()
        self._tree_lock = threading.Lock()
        self._draining = False
        self._active = 0
        self._active_cond = threading.Condition()
        self._t0 = time.time()
        self.store = None
        if cfg.store_dir is not None:
            self.store = MSAStore(cfg.store_dir, keep=cfg.store_keep,
                                  drift_threshold=cfg.drift_threshold,
                                  realign=cfg.store_realign)
        self.search_engine = None
        self._search_db_fp = None
        if cfg.search_index is not None:
            from ..search import SearchConfig, SearchEngine
            scfg = cfg.search_cfg or SearchConfig(
                alphabet=cfg.search_index.alphabet, k=cfg.search_index.k)
            self.search_engine = SearchEngine(scfg, mesh=cfg.mesh)
            # the database half of every /search cache key; hash it once
            self._search_db_fp = cfg.search_index.fingerprint()

    # ----------------------------------------------------------- helpers

    @contextlib.contextmanager
    def _request(self, endpoint: str) -> Iterator[str]:
        """Per-request accounting + trace scope.

        Counts reconcile as ``started == finished + rejected`` whenever the
        service is idle; ``drain()`` waits on the active count this context
        maintains, so a request inside this block can never be cut off by
        shutdown.  Yields the request's trace ID (every span opened inside
        inherits it; the HTTP layer returns it to the client).
        """
        _M_STARTED.labels(endpoint=endpoint).inc()
        with self._active_cond:
            if self._draining:
                _M_REJECTED.labels(endpoint=endpoint).inc()
                raise RuntimeError("service is draining")
            self._active += 1
            _G_ACTIVE.set(self._active)
        t0 = time.perf_counter()
        status = "ok"
        try:
            with _trace.request_trace() as tid:
                with _trace.span(f"serve.{endpoint}"):
                    yield tid
        except BaseException:
            status = "error"
            raise
        finally:
            _H_LATENCY.labels(endpoint=endpoint).observe(
                time.perf_counter() - t0)
            _M_FINISHED.labels(endpoint=endpoint, status=status).inc()
            with self._active_cond:
                self._active -= 1
                _G_ACTIVE.set(self._active)
                self._active_cond.notify_all()

    def _decode_rows(self, msa) -> List[str]:
        return [self.alpha.decode(r) for r in np.asarray(msa)]

    def _compute_canonical(self, canon: List[str], names: List[str]) -> dict:
        """Align the canonical-order family; returns the cache entry."""
        gap = self.alpha.gap_code
        cfg = self.msa_cfg
        mesh = self.cfg.mesh
        meta = None
        if mesh is not None and len(canon) >= self.cfg.dist_threshold:
            from ..dist import mapreduce
            res = mapreduce.msa_over_mesh(canon, cfg, mesh)
            msa, cidx, width = res.msa, res.center_idx, res.width
            path = "dist"
        elif cfg.method == "kmer" or len(canon) < 2:
            # the k-mer path needs a per-center index; it runs standalone
            res = msa_mod.center_star_msa(canon, cfg)
            msa, cidx, width = res.msa, res.center_idx, res.width
            path = "standalone"
        else:
            S, lens = msa_mod.encode_for_msa(canon, cfg)
            S_np, lens_np = np.asarray(S), np.asarray(lens)
            cidx, _ = msa_mod._select_center(S, lens, cfg)
            lc = int(lens_np[cidx])
            others = np.array([i for i in range(len(canon)) if i != cidx])
            job = AlignJob(Q=S_np[others], qlens=lens_np[others],
                           target=S_np[cidx][:lc], tlen=lc,
                           engine=self.engine,
                           engine_key=self.cfg.fingerprint())
            jr = self.coalescer.submit(job).result()
            msa, width = msa_mod.assemble_center_star(
                jr.a_row, jr.b_row, S_np[cidx][:lc], lc, others=others,
                cidx=int(cidx), n_total=len(canon), gap=gap)
            meta = jr.meta
            path = "coalesced"
        return {"msa": np.asarray(msa), "center_idx": int(cidx),
                "width": int(width), "seqs": canon, "names": names,
                "path": path, "coalesce": meta}

    def _entry_bytes(self, entry: dict) -> int:
        return entry["msa"].nbytes + sum(len(s) for s in entry["seqs"])

    def _alignment_payload(self, msa_id: str, entry: dict,
                           names: Optional[List[str]] = None,
                           row_order: Optional[List[int]] = None) -> dict:
        rows = self._decode_rows(entry["msa"])
        if row_order is not None:
            rows = [rows[i] for i in row_order]
        return {"msa_id": msa_id,
                "names": names if names is not None else entry["names"],
                "rows": rows, "width": entry["width"],
                "center_idx": (row_order.index(entry["center_idx"])
                               if row_order is not None
                               else entry["center_idx"])}

    # ----------------------------------------------------------- methods

    def _align_entry(self, names: List[str], seqs: List[str]
                     ) -> Tuple[str, dict, bool, List[int]]:
        """Shared align resolution: (key, entry, cached, perm).

        Returns the entry object directly — consumers must not re-resolve
        the key through the cache (an entry bigger than the byte budget,
        or concurrent LRU pressure, can evict it between put and peek).
        """
        canon, perm = canonicalize(seqs)
        # canon is already sorted, so the key's internal re-sort is O(n)
        key = canonical_key(canon, self.cfg.fingerprint())
        entry = self.cache.get(key)
        cached = entry is not None
        if not cached:
            entry = self._compute_canonical(canon,
                                            [names[i] for i in perm])
            self.cache.put(key, entry, self._entry_bytes(entry))
        return key, entry, cached, perm

    def align(self, names: Sequence[str], seqs: Sequence[str]) -> dict:
        with self._request("align") as tid:
            return dict(self._align_impl(names, seqs), trace_id=tid)

    # ------------------------------------------------- named (store-backed)

    def _store_required(self):
        if self.store is None:
            raise ValueError("no persistent store configured "
                             "(serve_msa --store-dir)")
        return self.store

    def _store_payload(self, entry) -> dict:
        """Response body for a committed store generation."""
        return {"name": entry.name, "generation": entry.generation,
                "fingerprint": entry.fingerprint,
                "names": list(entry.names),
                "rows": self._decode_rows(entry.msa),
                "width": entry.width, "center_idx": entry.center_idx}

    def align_named(self, name: str, names: Optional[Sequence[str]] = None,
                    seqs: Optional[Sequence[str]] = None) -> dict:
        """``POST /align?name=``: create (sequences given) or load (no
        sequences) a persistent named alignment."""
        with self._request("align") as tid:
            return dict(self._align_named_impl(name, names, seqs),
                        trace_id=tid)

    def _align_named_impl(self, name, names, seqs) -> dict:
        t0 = time.perf_counter()
        store = self._store_required()
        if seqs:
            seqs = list(seqs)
            names = list(names) if names else [f"seq{i}"
                                               for i in range(len(seqs))]
            # align through the shared cached/coalesced path; the store
            # persists the canonical order (what the cache entry holds)
            _, entry, cached, _ = self._align_entry(names, seqs)
            se = store.create(name, msa=entry["msa"],
                              center_idx=entry["center_idx"],
                              seqs=entry["seqs"], names=entry["names"])
            created = True
        else:
            se = store.get(name)                 # KeyError -> 404
            created, cached = False, True
        return {"alignment": self._store_payload(se), "created": created,
                "cached": cached, "store": store.stats(),
                "elapsed_ms": (time.perf_counter() - t0) * 1e3}

    def _align_impl(self, names: Sequence[str], seqs: Sequence[str]) -> dict:
        t0 = time.perf_counter()
        names, seqs = list(names), list(seqs)
        key, entry, cached, perm = self._align_entry(names, seqs)
        # map canonical rows back to this request's order: canonical row i
        # holds request sequence perm[i], so request row j is canonical
        # row inv[j]
        inv = [0] * len(perm)
        for i, p in enumerate(perm):
            inv[p] = i
        return {"alignment": self._alignment_payload(key, entry,
                                                     names=names,
                                                     row_order=inv),
                "cached": cached, "path": entry["path"],
                "coalesce": entry["coalesce"],
                "cache": self.cache.stats(),
                "elapsed_ms": (time.perf_counter() - t0) * 1e3}

    def align_add(self, msa_id: Optional[str] = None,
                  names: Sequence[str] = (), seqs: Sequence[str] = (), *,
                  name: Optional[str] = None) -> dict:
        with self._request("align_add") as tid:
            if name is not None:
                return dict(self._align_add_named_impl(name, names, seqs),
                            trace_id=tid)
            return dict(self._align_add_impl(msa_id, names, seqs),
                        trace_id=tid)

    def _align_add_named_impl(self, name, names, seqs) -> dict:
        """Continuous ingestion: one committed store generation per add."""
        t0 = time.perf_counter()
        store = self._store_required()
        entry, info = store.add(name, list(names), list(seqs),
                                self.msa_cfg, engine=self.engine)
        return {"alignment": self._store_payload(entry), "add": info,
                "store": store.stats(),
                "elapsed_ms": (time.perf_counter() - t0) * 1e3}

    def _align_add_impl(self, msa_id: str, names: Sequence[str],
                        seqs: Sequence[str]) -> dict:
        t0 = time.perf_counter()
        parent = self.cache.peek(msa_id)
        if parent is None:
            raise KeyError(f"unknown msa_id {msa_id!r}")
        names, seqs = list(names), list(seqs)
        center_seq = parent["seqs"][parent["center_idx"]] \
            if parent["center_idx"] < len(parent["seqs"]) else ""
        key = canonical_key(parent["seqs"] + seqs, self.cfg.fingerprint(),
                            center=center_seq)
        entry = self.cache.get(key)
        cached = entry is not None
        add_info = entry["add"] if cached else None
        if not cached:
            res = incremental.add_to_msa(
                parent["msa"], parent["center_idx"], seqs, self.msa_cfg,
                drift_threshold=self.cfg.drift_threshold,
                engine=self.engine)
            add_info = {"n_new": res.n_new, "realigned": res.realigned,
                        "growth": round(res.growth, 4)}
            entry = {"msa": res.msa, "center_idx": res.center_idx,
                     "width": res.width,
                     "seqs": parent["seqs"] + seqs,
                     "names": parent["names"] + names,
                     "path": "incremental", "coalesce": None,
                     "add": add_info}
            self.cache.put(key, entry, self._entry_bytes(entry))
        # on a hit, credit the caller's names for the added rows when the
        # request's new-sequence order matches the stored suffix (a
        # different order still hits the same canonical key; rows then
        # keep the first filler's order and names)
        resp_names = None
        if cached and entry["seqs"][len(entry["seqs"]) - len(seqs):] == seqs:
            resp_names = entry["names"][: len(entry["names"]) - len(names)] \
                + names
        return {"alignment": self._alignment_payload(key, entry,
                                                     names=resp_names),
                "cached": cached, "path": entry["path"], "add": add_info,
                "cache": self.cache.stats(),
                "elapsed_ms": (time.perf_counter() - t0) * 1e3}

    def tree(self, msa_id: Optional[str] = None, **kw) -> dict:
        with self._request("tree") as tid:
            return dict(self._tree_impl(msa_id=msa_id, **kw), trace_id=tid)

    def _tree_impl(self, msa_id: Optional[str] = None,
                   name: Optional[str] = None,
                   names: Optional[Sequence[str]] = None,
                   seqs: Optional[Sequence[str]] = None,
                   backend: Optional[str] = None,
                   refine: Optional[str] = None,
                   model: Optional[str] = None,
                   bootstrap: Optional[int] = None,
                   seed: Optional[int] = None) -> dict:
        t0 = time.perf_counter()
        store_entry = None
        if name is not None:
            # named alignments key the tree cache by the generation's
            # content fingerprint — a tree can never mix generations,
            # and an add or realign swap naturally invalidates it
            store_entry = self._store_required().get(name)
            entry = {"msa": store_entry.msa,
                     "names": list(store_entry.names)}
            msa_id = f"store:{name}@{store_entry.fingerprint}"
        elif msa_id is None:
            if not seqs:
                raise ValueError(
                    "tree request needs 'msa_id', 'name', or sequences")
            seqs = list(seqs)
            msa_id, entry, _, _ = self._align_entry(
                list(names) if names else [f"seq{i}"
                                           for i in range(len(seqs))], seqs)
        else:
            entry = self.cache.peek(msa_id)
            if entry is None:
                raise KeyError(f"unknown msa_id {msa_id!r}")
        be = backend or self.cfg.tree_backend
        refine = refine or self.cfg.tree_refine
        model = model or self.cfg.tree_model
        if bootstrap is None:
            # the server-wide bootstrap default only makes sense under ML
            # refinement; a request overriding refine to "none" must not
            # inherit it (it would 400 on bootstrap-requires-ml)
            bootstrap = self.cfg.tree_bootstrap if refine == "ml" else 0
        bootstrap = int(bootstrap)
        seed = int(self.cfg.tree_seed if seed is None else seed)
        engine = TreeEngine(gap_code=self.alpha.gap_code,
                            n_chars=self.alpha.n_chars,
                            correct=self.cfg.alphabet != "protein",
                            backend=be,
                            cluster_threshold=self.cfg.cluster_threshold,
                            mesh=self.cfg.mesh,
                            refine=refine, model=model,
                            bootstrap=bootstrap, seed=seed)
        # the tree fingerprint spans everything that changes the result:
        # backend, refinement mode, substitution model, replicate count,
        # and the seed. An unrefined tree ignores model/bootstrap (those
        # collapse out of the key — no cache fragmentation for identical
        # results) but keeps seed: cluster/tiled sketch sampling uses it
        tkey = f"{msa_id}/{be}/none/{seed}" if refine == "none" else \
            f"{msa_id}/{be}/{refine}/{model}/{bootstrap}/{seed}"
        # tree_cache is shared across handler threads: the lock covers the
        # hit check, the build, and the LRU bound. Holding it through the
        # build serializes tree construction, which the single device
        # serializes anyway (same reasoning as the coalescer's one worker).
        with self._tree_lock:
            cached_tree = tkey in self.tree_cache
            result = engine.build(entry["msa"], cache=self.tree_cache,
                                  cache_key=tkey)
            self.tree_cache.move_to_end(tkey)
            while len(self.tree_cache) > self.cfg.tree_cache_items:
                self.tree_cache.popitem(last=False)
        resp = {"msa_id": msa_id, "newick": result.newick(entry["names"]),
                "backend": result.backend, "requested_backend": be,
                "refine": refine,
                "n_leaves": result.n_leaves, "cached_tree": cached_tree,
                "cache": self.cache.stats(),
                "elapsed_ms": (time.perf_counter() - t0) * 1e3}
        if store_entry is not None:
            resp["name"] = store_entry.name
            resp["generation"] = store_entry.generation
            resp["fingerprint"] = store_entry.fingerprint
        if result.logl is not None:
            resp["model"] = result.model
            resp["logl"] = result.logl
        return resp

    def search(self, names: Sequence[str], seqs: Sequence[str], *,
               max_hits: Optional[int] = None,
               min_coverage: Optional[float] = None,
               max_evalue: Optional[float] = None) -> dict:
        """Per-query top-k database hits, content-hash cached.

        The cache key spans everything that changes the result: the
        database fingerprint, the search config, the effective gates,
        and the canonicalized query set — so a permuted resubmission of
        the same queries hits, and hits are mapped back to the caller's
        order through the canonicalization permutation (same contract
        as ``/align``).
        """
        with self._request("search") as tid:
            return dict(self._search_impl(names, seqs, max_hits=max_hits,
                                          min_coverage=min_coverage,
                                          max_evalue=max_evalue),
                        trace_id=tid)

    def _search_impl(self, names: Sequence[str], seqs: Sequence[str], *,
                     max_hits: Optional[int] = None,
                     min_coverage: Optional[float] = None,
                     max_evalue: Optional[float] = None) -> dict:
        if self.search_engine is None:
            raise ValueError("no search database configured "
                             "(serve_msa --search-db)")
        t0 = time.perf_counter()
        names, seqs = list(names), list(seqs)
        eng = self.search_engine
        max_hits = eng.cfg.max_hits if max_hits is None else int(max_hits)
        min_coverage = (eng.cfg.min_coverage if min_coverage is None
                        else float(min_coverage))
        max_evalue = (eng.cfg.max_evalue if max_evalue is None
                      else float(max_evalue))
        canon, perm = canonicalize(seqs)
        key = canonical_key(canon, f"search/{self._search_db_fp}/"
                                   f"{eng.cfg.fingerprint()}/{max_hits}/"
                                   f"{min_coverage}/{max_evalue}")
        entry = self.cache.get(key)
        cached = entry is not None
        if not cached:
            result = eng.search([f"q{i}" for i in range(len(canon))],
                                canon, self.cfg.search_index,
                                max_hits=max_hits,
                                min_coverage=min_coverage,
                                max_evalue=max_evalue)
            entry = {"hits": [q["hits"] for q in result["queries"]],
                     "lengths": [q["length"] for q in result["queries"]],
                     "stats": result["stats"]}
            self.cache.put(key, entry, len(json.dumps(entry)))
        inv = [0] * len(perm)
        for i, p in enumerate(perm):
            inv[p] = i
        return {"search_id": key,
                "queries": [{"name": names[j],
                             "length": entry["lengths"][inv[j]],
                             "hits": entry["hits"][inv[j]]}
                            for j in range(len(seqs))],
                "stats": entry["stats"], "cached": cached,
                "cache": self.cache.stats(),
                "elapsed_ms": (time.perf_counter() - t0) * 1e3}

    def stats_snapshot(self) -> dict:
        """Cache + queue stats from one instant.

        Both locks are held together (cache first, then queue — the one
        fixed order in the codebase, so no deadlock is possible) instead
        of reading ``cache.stats()`` and ``coalescer.stats()`` at
        different times, which could disagree under load.
        """
        with self.cache.lock:
            with self.coalescer.lock:
                return {"cache": self.cache.stats_locked(),
                        "queue": self.coalescer.stats_locked()}

    def healthz(self) -> dict:
        snap = self.stats_snapshot()
        return {"status": "draining" if self._draining else "ok",
                "uptime_s": round(time.time() - self._t0, 3),
                "alphabet": self.cfg.alphabet, "method": self.cfg.method,
                "backend": self.engine.backend,
                "active_requests": self._active,
                "cache": snap["cache"],
                "queue": snap["queue"],
                "store": (self.store.stats()
                          if self.store is not None else None),
                "search_db": (self.cfg.search_index.n_seqs
                              if self.cfg.search_index is not None
                              else None)}

    def statusz(self) -> str:
        """Human-readable plain-text snapshot (``GET /statusz``)."""
        h = self.healthz()
        lines = [
            "repro.serve statusz",
            f"status           {h['status']}",
            f"uptime_s         {h['uptime_s']}",
            f"config           alphabet={h['alphabet']} method={h['method']}"
            f" backend={h['backend']}",
            f"active_requests  {h['active_requests']}",
            f"search_db_seqs   {h['search_db']}",
            "",
            "cache   " + " ".join(f"{k}={v}" for k, v in h["cache"].items()),
            "queue   " + " ".join(f"{k}={v}" for k, v in h["queue"].items()),
        ]
        if h["store"] is not None:
            st = dict(h["store"])
            gens = st.pop("generations")
            lines.append("store   " + " ".join(f"{k}={v}"
                                               for k, v in st.items()))
            for n, g in gens.items():
                e = self.store.get(n)
                lines.append(f"  {n:<16} generation={g} width={e.width} "
                             f"members={len(e.names)} "
                             f"fingerprint={e.fingerprint[:12]}")
        lines += [
            "",
            "requests (started == finished + rejected):",
        ]
        snap = _obs.REGISTRY.snapshot()
        for fam in ("repro_requests_started_total",
                    "repro_requests_finished_total",
                    "repro_requests_rejected_total"):
            for s in snap.get(fam, {}).get("samples", []):
                lbl = ",".join(f"{k}={v}" for k, v in s["labels"].items())
                lines.append(f"  {fam}{{{lbl}}} {int(s['value'])}")
        lines.append("")
        lines.append("recent root spans:")
        roots = [r for r in _trace.TRACER.spans() if r.parent_id is None]
        for r in roots[-10:]:
            lines.append(f"  {r.name:<16} {r.duration * 1e3:9.2f} ms"
                         f"  trace_id={r.trace_id}")
        return "\n".join(lines) + "\n"

    def drain(self, timeout: Optional[float] = None) -> bool:
        """Refuse new work, wait for in-flight requests, flush the queue.

        Blocks until every request that entered ``_request`` before the
        drain flag flipped has finished (or ``timeout`` elapses); then
        drains the coalescer. Returns False only on timeout.
        """
        with self._active_cond:
            self._draining = True
            done = self._active_cond.wait_for(lambda: self._active == 0,
                                              timeout)
        self.coalescer.close()
        if self.store is not None:
            # queued realigns finish and swap before exit; their commits
            # are atomic either way, so this only buys wall-clock
            self.store.close(wait=True)
        return done


# ------------------------------------------------------------- HTTP layer

class _Handler(BaseHTTPRequestHandler):
    protocol_version = "HTTP/1.1"

    def log_message(self, fmt, *args):            # stay quiet under test
        if getattr(self.server, "verbose", False):
            super().log_message(fmt, *args)

    def _send(self, code: int, obj: dict):
        data = json.dumps(obj).encode()
        self.send_response(code)
        self.send_header("Content-Type", "application/json")
        self.send_header("Content-Length", str(len(data)))
        self.end_headers()
        self.wfile.write(data)

    def _send_text(self, code: int, text: str,
                   content_type: str = "text/plain; charset=utf-8"):
        data = text.encode()
        self.send_response(code)
        self.send_header("Content-Type", content_type)
        self.send_header("Content-Length", str(len(data)))
        self.end_headers()
        self.wfile.write(data)

    def _payload(self) -> dict:
        n = int(self.headers.get("Content-Length", 0) or 0)
        body = self.rfile.read(n) if n else b""
        return json.loads(body or b"{}")

    def do_GET(self):
        if self.path == "/healthz":
            self._send(200, self.server.service.healthz())
        elif self.path == "/metrics":
            # the content type Prometheus scrapers expect for text format
            self._send_text(200, _obs.REGISTRY.render(),
                            "text/plain; version=0.0.4; charset=utf-8")
        elif self.path == "/statusz":
            self._send_text(200, self.server.service.statusz())
        else:
            self._send(404, {"error": f"unknown path {self.path}"})

    def do_POST(self):
        from urllib.parse import parse_qs, urlsplit

        svc: MSAService = self.server.service
        try:
            parts = urlsplit(self.path)
            path = parts.path
            payload = self._payload()
            # ?name=x and {"name": "x"} are equivalent; the body wins
            qs_name = parse_qs(parts.query).get("name", [None])[0]
            name = payload.get("name") or qs_name
            if path == "/align":
                if name is not None:
                    has_seqs = "fasta" in payload or "sequences" in payload
                    names, seqs = (parse_sequences(payload)
                                   if has_seqs else (None, None))
                    self._send(200, svc.align_named(name, names, seqs))
                else:
                    names, seqs = parse_sequences(payload)
                    self._send(200, svc.align(names, seqs))
            elif path == "/align/add":
                if name is None and "msa_id" not in payload:
                    raise ValueError("align/add needs 'msa_id' or 'name'")
                names, seqs = parse_sequences(payload)
                self._send(200, svc.align_add(payload.get("msa_id"),
                                              names, seqs, name=name))
            elif path == "/tree":
                tree_kw = {k: payload.get(k) for k in
                           ("backend", "refine", "model", "bootstrap",
                            "seed")}
                if name is not None:
                    self._send(200, svc.tree(name=name, **tree_kw))
                elif "msa_id" in payload:
                    self._send(200, svc.tree(msa_id=payload["msa_id"],
                                             **tree_kw))
                else:
                    names, seqs = parse_sequences(payload)
                    self._send(200, svc.tree(names=names, seqs=seqs,
                                             **tree_kw))
            elif path == "/search":
                names, seqs = parse_sequences(payload)
                kw = {k: payload.get(k) for k in
                      ("max_hits", "min_coverage", "max_evalue")}
                self._send(200, svc.search(names, seqs, **kw))
            else:
                self._send(404, {"error": f"unknown path {self.path}"})
        except KeyError as e:
            self._send(404, {"error": str(e)})
        except (ValueError, json.JSONDecodeError) as e:
            self._send(400, {"error": str(e)})
        except _StoreError as e:
            self._send(409, {"error": str(e)})
        except RuntimeError as e:
            self._send(503, {"error": str(e)})


class MSAHTTPServer(ThreadingHTTPServer):
    # non-daemon handler threads + block_on_close: server_close() waits
    # for in-flight requests — the graceful half of drain-on-shutdown
    daemon_threads = False
    block_on_close = True
    service: MSAService
    verbose: bool = False


def serve_http(service: MSAService, host: str = "127.0.0.1",
               port: int = 8642, verbose: bool = False) -> MSAHTTPServer:
    """Bind the HTTP front end; caller runs ``serve_forever()`` and on
    shutdown calls ``shutdown(); server_close(); service.drain()``."""
    httpd = MSAHTTPServer((host, port), _Handler)
    httpd.service = service
    httpd.verbose = verbose
    return httpd
