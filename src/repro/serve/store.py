"""Persistent, generation-versioned store of named alignments.

The service's in-process cache (``serve/cache.py``) is content-addressed
and volatile: a restart loses every alignment, and ``/align/add`` can
only extend what happens to still be resident. This module is the
surveillance-scale answer (UPP's accrete-onto-a-backbone shape): each
*named* alignment lives on disk as a sequence of immutable generation
files, new sequences accrete through ``incremental.add_to_msa``, and
when cumulative width drift crosses a threshold a *background* realign
rebuilds the family while readers keep being served the stale-but-valid
current generation — the realigned result then swaps in atomically as
the next generation.

Durability model (one directory per name under the store root):

  <root>/<name>/gen_0000000000.npz     generation 0 (creation)
  <root>/<name>/gen_0000000001.npz     generation 1 (one /align/add)
  ...

* Every commit goes through ``dist/checkpoint.atomic_save_npz`` (temp
  file + one ``os.replace``), so a crash mid-commit leaves the previous
  generation intact — never a torn file.
* Retention keeps the newest ``keep`` generation files per name
  (``CheckpointManager``'s policy, applied per named alignment).
* Restore walks generations newest→oldest and skips unreadable files
  *and* files whose stored content fingerprint does not match the
  recomputed one — a corrupt latest generation costs one commit, not
  the alignment (mirrors ``CheckpointManager.restore``).
* The in-memory registry is strictly a cache of disk: a failed commit
  invalidates the name so the next access reloads the committed truth.

Generations are monotone per name; the *content fingerprint* (sha256
over rows + center + member names) identifies what a generation holds,
which is what ``/tree`` cache keys incorporate so trees never mix
generations. Concurrency: one lock per name serializes mutation
(add / realign-swap); readers never take it — ``get`` returns the
current immutable entry. ``fault_hook`` is the crash-injection seam the
``tests/test_store.py`` harness drives (labels documented on
``COMMIT_FAULT_LABELS``).
"""
from __future__ import annotations

import dataclasses
import hashlib
import math
import re
import threading
import warnings
from concurrent.futures import Future, ThreadPoolExecutor
from pathlib import Path
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.msa import MSAConfig, center_star_msa
from ..dist.checkpoint import atomic_save_npz
from ..obs import metrics as _obs
from ..obs import trace as _trace
from . import incremental

_GEN_PREFIX = "gen_"
_GEN_SUFFIX = ".npz"
_SCHEMA_VERSION = 1
_NAME_RE = re.compile(r"^[A-Za-z0-9][A-Za-z0-9._-]{0,63}$")

# the fault-injection points a commit passes through, in order; a hook
# raising at any label before save.post-replace must leave the previous
# generation committed, at or after it the new one (pinned by the
# crash-atomicity property test)
COMMIT_FAULT_LABELS = (
    "commit.begin", "save.serialize", "save.pre-replace",
    "save.post-replace", "commit.gc", "commit.end",
)

_C_COMMITS = _obs.counter("repro_store_commits_total",
                          "generation commits by kind", ("kind",))
_C_REALIGNS = _obs.counter("repro_store_realigns_total",
                           "background realigns by outcome", ("outcome",))
_C_RESTORES = _obs.counter("repro_store_restores_total",
                           "named alignments restored from disk")
_G_GENERATION = _obs.gauge("repro_store_generation",
                           "current generation per named alignment",
                           ("name",))
_G_BYTES = _obs.gauge("repro_store_bytes",
                      "resident MSA bytes across named alignments")
_G_NAMES = _obs.gauge("repro_store_names", "named alignments resident")
_G_PENDING = _obs.gauge("repro_store_pending_realigns",
                        "background realigns queued or running")
_H_COMMIT = _obs.histogram("repro_store_commit_seconds",
                           "serialize + atomic replace per commit")
_H_REALIGN = _obs.histogram("repro_store_realign_seconds",
                            "background realign wall-clock (incl. swap)")
_H_RESTORE = _obs.histogram("repro_store_restore_seconds",
                            "disk restore per named alignment")


class StoreError(RuntimeError):
    """A store operation failed (commit fault, closed store, bad name)."""


@dataclasses.dataclass(frozen=True)
class StoreEntry:
    """One immutable committed generation of a named alignment."""
    name: str
    msa: np.ndarray          # (N, width) int8, gap == alphabet gap code
    center_idx: int
    width: int
    seqs: Tuple[str, ...]    # ungapped members, row order
    names: Tuple[str, ...]   # member names, row order
    generation: int
    base_width: int          # width at the last full (re)align — the
                             # drift baseline cumulative growth is
                             # measured against
    fingerprint: str         # content fingerprint (rows+center+names)

    @property
    def nbytes(self) -> int:
        return self.msa.nbytes + sum(len(s) for s in self.seqs)

    def growth(self) -> float:
        """Cumulative relative width growth since the last full realign."""
        return (self.width - self.base_width) / max(self.base_width, 1)


def content_fingerprint(msa: np.ndarray, center_idx: int,
                        names: Sequence[str]) -> str:
    """sha256 over what a generation *is*: the aligned rows, the frozen
    center, and the member names. Content-derived (not generation-
    numbered) so identical content yields identical tree cache keys."""
    msa = np.ascontiguousarray(np.asarray(msa, np.int8))
    h = hashlib.sha256()
    h.update(str(msa.shape).encode())
    h.update(msa.tobytes())
    h.update(f"\x00{int(center_idx)}\x00".encode())
    for n in names:
        h.update(b"\x00")
        h.update(n.encode())
    return h.hexdigest()


class _Named:
    """Registry slot: the current entry plus the per-name mutation lock."""

    __slots__ = ("entry", "lock", "realign_future")

    def __init__(self, entry: StoreEntry):
        self.entry = entry
        self.lock = threading.Lock()
        self.realign_future: Optional[Future] = None


class MSAStore:
    """Persistent named-alignment store; thread-safe."""

    def __init__(self, root, *, keep: int = 4,
                 drift_threshold: float = 0.25,
                 realign: str = "background",
                 fault_hook: Optional[Callable[[str], None]] = None):
        if realign not in ("background", "never"):
            raise ValueError(f"realign must be background|never, "
                             f"got {realign!r}")
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.keep = int(keep)
        self.drift_threshold = float(drift_threshold)
        self.realign = realign
        self.fault_hook = fault_hook
        self._registry: Dict[str, _Named] = {}
        self._reg_lock = threading.Lock()
        self._pending_realigns = 0
        self._closed = False
        self._pool = ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="store-realign")

    # ------------------------------------------------------------ inventory

    def _dir(self, name: str) -> Path:
        return self.root / name

    def _gen_path(self, name: str, gen: int) -> Path:
        return self._dir(name) / f"{_GEN_PREFIX}{gen:010d}{_GEN_SUFFIX}"

    def generations(self, name: str) -> List[int]:
        """Generation numbers present on disk, oldest first."""
        gens = []
        for p in self._dir(name).glob(f"{_GEN_PREFIX}*{_GEN_SUFFIX}"):
            try:
                gens.append(int(p.name[len(_GEN_PREFIX):-len(_GEN_SUFFIX)]))
            except ValueError:
                continue
        return sorted(gens)

    def names(self) -> List[str]:
        """Every named alignment: resident or restorable from disk."""
        on_disk = {p.parent.name
                   for p in self.root.glob(f"*/{_GEN_PREFIX}*{_GEN_SUFFIX}")}
        with self._reg_lock:
            return sorted(on_disk | set(self._registry))

    def stats(self) -> dict:
        """One-instant snapshot for /healthz and /statusz."""
        with self._reg_lock:
            entries = {n: s.entry for n, s in self._registry.items()
                       if s.entry is not None}
            pending = self._pending_realigns
        return {"names": len(self.names()),
                "resident": len(entries),
                "bytes": sum(e.nbytes for e in entries.values()),
                "pending_realigns": pending,
                "generations": {n: e.generation
                                for n, e in sorted(entries.items())}}

    # -------------------------------------------------------------- loading

    def get(self, name: str) -> StoreEntry:
        """Current generation (memory first, disk restore on miss).

        Never blocks on the per-name mutation lock: while an add or a
        realign swap is in flight, callers keep getting the previous
        committed generation.
        """
        with self._reg_lock:
            slot = self._registry.get(name)
            if slot is not None and slot.entry is not None:
                return slot.entry
        entry = self._restore(name)
        with self._reg_lock:
            slot = self._registry.get(name)
            if slot is None:                     # lost race: first in wins
                slot = self._registry[name] = _Named(entry)
                self._publish_gauges_locked()
            if slot.entry is None:               # creation still committing
                raise KeyError(f"unknown named alignment {name!r}")
            return slot.entry

    def _restore(self, name: str) -> StoreEntry:
        """Newest readable + fingerprint-consistent generation from disk."""
        import time
        t0 = time.perf_counter()
        with _trace.span("store.restore", alignment=name):
            for gen in self.generations(name)[::-1]:
                entry = self._read_gen(name, gen)
                if entry is not None:
                    _C_RESTORES.inc()
                    _H_RESTORE.observe(time.perf_counter() - t0)
                    return entry
        raise KeyError(f"unknown named alignment {name!r}")

    def _read_gen(self, name: str, gen: int) -> Optional[StoreEntry]:
        path = self._gen_path(name, gen)
        try:
            with np.load(path, allow_pickle=False) as z:
                if int(z["schema_version"]) != _SCHEMA_VERSION:
                    raise ValueError(
                        f"schema v{int(z['schema_version'])} != "
                        f"v{_SCHEMA_VERSION}")
                entry = StoreEntry(
                    name=str(z["name"]),
                    msa=np.asarray(z["msa"], np.int8),
                    center_idx=int(z["center_idx"]),
                    width=int(z["msa"].shape[1]),
                    seqs=tuple(str(s) for s in z["seqs"]),
                    names=tuple(str(s) for s in z["names"]),
                    generation=int(z["generation"]),
                    base_width=int(z["base_width"]),
                    fingerprint=str(z["fingerprint"]))
        except Exception as e:
            warnings.warn(f"store: skipping unreadable generation "
                          f"{path}: {e!r}")
            return None
        actual = content_fingerprint(entry.msa, entry.center_idx,
                                     entry.names)
        if actual != entry.fingerprint or entry.name != name \
                or entry.generation != gen:
            warnings.warn(f"store: skipping torn/mislabeled generation "
                          f"{path} (fingerprint mismatch)")
            return None
        return entry

    # ------------------------------------------------------------ mutation

    def _hook(self, label: str):
        if self.fault_hook is not None:
            self.fault_hook(label)

    def create(self, name: str, *, msa, center_idx: int,
               seqs: Sequence[str], names: Sequence[str]) -> StoreEntry:
        """Commit generation 0 of a new named alignment."""
        if not _NAME_RE.match(name):
            raise ValueError(
                f"invalid alignment name {name!r} (want "
                f"[A-Za-z0-9][A-Za-z0-9._-]*, at most 64 chars)")
        msa = np.asarray(msa, np.int8)
        if len(seqs) != msa.shape[0] or len(names) != msa.shape[0]:
            raise ValueError(f"{len(seqs)} seqs / {len(names)} names for "
                             f"{msa.shape[0]} rows")
        slot = _Named(None)  # type: ignore[arg-type]
        with self._reg_lock:
            if self._closed:
                raise StoreError("store is closed")
            if name in self._registry:
                raise StoreError(f"alignment {name!r} already exists")
            if self.generations(name):
                raise StoreError(f"alignment {name!r} already on disk "
                                 f"(restore it with get() first)")
            self._registry[name] = slot
        try:
            with slot.lock:
                entry = StoreEntry(
                    name=name, msa=msa, center_idx=int(center_idx),
                    width=int(msa.shape[1]), seqs=tuple(seqs),
                    names=tuple(names), generation=0,
                    base_width=int(msa.shape[1]),
                    fingerprint=content_fingerprint(msa, center_idx, names))
                self._commit(slot, entry, kind="create")
                return entry
        except BaseException:
            with self._reg_lock:
                if self._registry.get(name) is slot and slot.entry is None:
                    del self._registry[name]
            raise

    def add(self, name: str, new_names: Sequence[str],
            new_seqs: Sequence[str], cfg: MSAConfig, *,
            engine=None) -> Tuple[StoreEntry, dict]:
        """Accrete ``new_seqs`` onto ``name``'s current generation.

        The incremental merge (frozen center, ``incremental.add_to_msa``)
        always commits as the next generation — bit-identical rows for
        existing members. When the *cumulative* width growth since the
        last full realign crosses ``drift_threshold``, a background
        realign of the full member set is scheduled; readers keep this
        (valid) generation until the realigned one swaps in.
        """
        slot = self._slot(name)
        with slot.lock:
            cur = slot.entry
            res = incremental.add_to_msa(
                cur.msa, cur.center_idx, list(new_seqs), cfg,
                drift_threshold=math.inf, engine=engine)
            assert not res.realigned
            entry = StoreEntry(
                name=name, msa=np.asarray(res.msa, np.int8),
                center_idx=res.center_idx, width=res.width,
                seqs=cur.seqs + tuple(new_seqs),
                names=cur.names + tuple(new_names),
                generation=cur.generation + 1,
                base_width=cur.base_width,
                fingerprint=content_fingerprint(
                    res.msa, res.center_idx, cur.names + tuple(new_names)))
            self._commit(slot, entry, kind="add")
            drifted = entry.growth() > self.drift_threshold
            pending = drifted and self._schedule_realign(name, slot, entry,
                                                         cfg)
        info = {"n_new": len(new_seqs), "n_fallback": res.n_fallback,
                "growth": round(entry.growth(), 4),
                "drifted": drifted, "realign_pending": pending}
        return entry, info

    def _slot(self, name: str) -> _Named:
        with self._reg_lock:
            if self._closed:
                raise StoreError("store is closed")
            slot = self._registry.get(name)
        if slot is None:
            self.get(name)                       # restore from disk
            with self._reg_lock:
                slot = self._registry[name]
        if slot.entry is None:
            raise StoreError(f"alignment {name!r} is still being created")
        return slot

    def _commit(self, slot: _Named, entry: StoreEntry, *, kind: str):
        """Atomically persist ``entry`` as its generation file, publish it
        to readers, and apply retention. Caller holds ``slot.lock``.

        Exception safety: disk is the truth. Any failure before the
        ``os.replace`` leaves the previous generation current; a failure
        after it means the commit *happened* — either way the in-memory
        slot is invalidated so the next access reloads committed state.
        """
        import time
        t0 = time.perf_counter()
        try:
            with _trace.span("store.commit", alignment=entry.name,
                             generation=entry.generation, kind=kind):
                self._hook("commit.begin")
                atomic_save_npz(
                    self._gen_path(entry.name, entry.generation),
                    {"schema_version": np.int64(_SCHEMA_VERSION),
                     "name": np.str_(entry.name),
                     "msa": entry.msa,
                     "center_idx": np.int64(entry.center_idx),
                     "generation": np.int64(entry.generation),
                     "base_width": np.int64(entry.base_width),
                     "seqs": np.array(entry.seqs),
                     "names": np.array(entry.names),
                     "fingerprint": np.str_(entry.fingerprint)},
                    _hook=self._hook if self.fault_hook is not None
                    else None)
                slot.entry = entry
                self._hook("commit.gc")
                self._gc(entry.name)
                self._hook("commit.end")
        except BaseException:
            # memory may now disagree with disk (e.g. a fault after the
            # replace): drop the slot so the next access re-restores
            with self._reg_lock:
                if self._registry.get(entry.name) is slot:
                    del self._registry[entry.name]
                self._publish_gauges_locked()
            raise
        _H_COMMIT.observe(time.perf_counter() - t0)
        _C_COMMITS.labels(kind=kind).inc()
        _G_GENERATION.labels(name=entry.name).set(entry.generation)
        with self._reg_lock:
            self._publish_gauges_locked()

    def _gc(self, name: str):
        gens = self.generations(name)
        for g in gens[:max(len(gens) - self.keep, 0)]:
            try:
                self._gen_path(name, g).unlink()
            except FileNotFoundError:
                pass

    def _publish_gauges_locked(self):
        _G_BYTES.set(sum(s.entry.nbytes for s in self._registry.values()
                         if s.entry is not None))
        _G_NAMES.set(len(self._registry))
        _G_PENDING.set(self._pending_realigns)

    # ------------------------------------------------------------- realign

    def _schedule_realign(self, name: str, slot: _Named, entry: StoreEntry,
                          cfg: MSAConfig) -> bool:
        """Queue a background realign of ``entry``'s member set (one in
        flight per name). Caller holds ``slot.lock``."""
        if self.realign != "background":
            return False
        if slot.realign_future is not None and \
                not slot.realign_future.done():
            return True                          # one already pending
        with self._reg_lock:
            if self._closed:
                return False
            self._pending_realigns += 1
            self._publish_gauges_locked()
        slot.realign_future = self._pool.submit(
            self._realign, name, slot, entry.generation, cfg)
        return True

    def _realign(self, name: str, slot: _Named, from_gen: int,
                 cfg: MSAConfig):
        """Worker-thread body: cold full realign, then atomic swap."""
        import time
        t0 = time.perf_counter()
        outcome = "error"
        try:
            with _trace.span("store.realign", alignment=name,
                             from_generation=from_gen):
                # member set frozen at schedule time — if more adds land
                # while we realign, the swap is discarded (the next
                # drifted add reschedules over the larger set)
                base = slot.entry
                if base.generation != from_gen:
                    outcome = "stale"
                    return
                res = center_star_msa(list(base.seqs), cfg)
                new = StoreEntry(
                    name=name, msa=np.asarray(res.msa, np.int8),
                    center_idx=res.center_idx, width=res.width,
                    seqs=base.seqs, names=base.names,
                    generation=from_gen + 1, base_width=res.width,
                    fingerprint=content_fingerprint(
                        res.msa, res.center_idx, base.names))
                with slot.lock:
                    if slot.entry.generation != from_gen:
                        outcome = "stale"
                        return
                    self._commit(slot, new, kind="realign")
                    outcome = "swapped"
        except BaseException:
            warnings.warn(f"store: background realign of {name!r} failed",
                          stacklevel=2)
            raise
        finally:
            _C_REALIGNS.labels(outcome=outcome).inc()
            _H_REALIGN.observe(time.perf_counter() - t0)
            with self._reg_lock:
                self._pending_realigns -= 1
                self._publish_gauges_locked()

    def wait_realigns(self, timeout: Optional[float] = None):
        """Block until every scheduled realign resolved (raises theirs)."""
        with self._reg_lock:
            futures = [s.realign_future for s in self._registry.values()
                       if s.realign_future is not None]
        for f in futures:
            f.result(timeout=timeout)

    # --------------------------------------------------------------- close

    def close(self, wait: bool = True):
        """Refuse new work; optionally let queued realigns finish (their
        commits are atomic, so ``wait=False`` just forfeits wall-clock,
        never durability)."""
        with self._reg_lock:
            self._closed = True
        self._pool.shutdown(wait=wait, cancel_futures=not wait)
