from . import optimizer, serve_step, train_step  # noqa: F401
