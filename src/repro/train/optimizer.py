"""AdamW with global-norm clipping, as a pure pytree transform.

Optimizer state shards exactly like the params (the planner maps the same
PartitionSpec over m/v), which is what makes FSDP + elastic restore work
without special cases.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100


class OptState(NamedTuple):
    m: Any
    v: Any
    count: jnp.ndarray


def init(params) -> OptState:
    z = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return OptState(jax.tree.map(z, params), jax.tree.map(z, params),
                    jnp.zeros((), jnp.int32))


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(jax.tree.map(
        lambda g: jnp.sum(g.astype(jnp.float32) ** 2), tree))
    return jnp.sqrt(sum(leaves))


def update(params, grads, state: OptState, cfg: AdamWConfig):
    count = state.count + 1
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gn, 1e-12))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    lr = cfg.lr * jnp.minimum(1.0, count / max(cfg.warmup_steps, 1))
    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    new_m = jax.tree.map(lambda m, g: cfg.b1 * m + (1 - cfg.b1) * g,
                         state.m, grads)
    new_v = jax.tree.map(lambda v, g: cfg.b2 * v + (1 - cfg.b2) * g * g,
                         state.v, grads)

    def upd(p, m, v):
        step = lr * (m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps)
        step = step + lr * cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - step).astype(p.dtype)

    new_params = jax.tree.map(upd, params, new_m, new_v)
    return new_params, OptState(new_m, new_v, count), {"grad_norm": gn, "lr": lr}
