"""Serving steps: prefill (sequence -> last logits + cache) and decode
(one token per call against the cache). These are the ``serve_step``
lowerings for the decode_* / long_* dry-run shapes.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models.transformer import apply_model, init_cache


def make_prefill_step(cfg, *, shard_fns=None, max_len: Optional[int] = None):
    def prefill(params, batch):
        B = (batch["tokens"].shape[0] if cfg.embed_input
             else batch["embeds"].shape[0])
        S = (batch["tokens"].shape[1] if cfg.embed_input
             else batch["embeds"].shape[1])
        cache = init_cache(cfg, B, max_len or S)
        logits, cache, _ = apply_model(params, cfg, batch,
                                       shard_fns=shard_fns, cache=cache,
                                       logits_mode="last")
        return logits, cache
    return prefill


def make_decode_step(cfg, *, shard_fns=None):
    """decode(params, cache, tokens (B,) or embeds (B,D), pos (B,)) ->
    (logits (B,V), cache)."""
    def decode(params, cache, token, pos):
        if cfg.embed_input:
            batch = {"tokens": token[:, None],
                     "positions": pos[:, None]}
        else:
            batch = {"embeds": token[:, None, :],
                     "positions": pos[:, None]}
        if cfg.m_rope:
            batch["pos3"] = jnp.broadcast_to(pos[None, :, None],
                                             (3,) + pos.shape + (1,))
        logits, cache, _ = apply_model(params, cfg, batch,
                                       shard_fns=shard_fns, cache=cache,
                                       logits_mode="last")
        return logits, cache
    return decode


def greedy_generate(cfg, params, prompt_tokens, *, steps: int, max_len: int,
                    shard_fns=None):
    """Reference generation loop for the examples/tests (prefill + N decodes)."""
    prefill = make_prefill_step(cfg, shard_fns=shard_fns, max_len=max_len)
    decode = make_decode_step(cfg, shard_fns=shard_fns)
    B, S = prompt_tokens.shape
    logits, cache = prefill(params, {"tokens": prompt_tokens})
    out = [jnp.argmax(logits, -1)]
    pos = jnp.full((B,), S, jnp.int32)
    for _ in range(steps - 1):
        logits, cache = decode(params, cache, out[-1].astype(jnp.int32), pos)
        out.append(jnp.argmax(logits, -1))
        pos = pos + 1
    return jnp.stack(out, axis=1)
