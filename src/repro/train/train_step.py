"""Training step: microbatched grad accumulation (scan), remat, AdamW.

The global batch is reshaped to (microbatches, micro_batch, seq) and scanned;
each micro step runs the rematerialized model, so peak activation memory is
one micro-batch's worth and — with MoE — the (T, E, C) dispatch tensors stay
small (the §Perf lever that makes kimi-k2 train_4k lowerable). Gradients
accumulate in f32; XLA turns the param-gradient psum across data shards into
reduce-scatters against the FSDP layout.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models.transformer import apply_model
from . import optimizer as opt


class TrainState(NamedTuple):
    params: Any
    opt: opt.OptState
    step: jnp.ndarray


def cross_entropy(logits, labels, ignore_id: int = -1):
    """logits (B,S,V) f32, labels (B,S) i32; mean over non-ignored."""
    V = logits.shape[-1]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    nll = logz - gold
    mask = (labels != ignore_id).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def loss_fn(params, cfg, batch, shard_fns, aux_weight: float = 0.01):
    logits, _, aux = apply_model(params, cfg, batch, shard_fns=shard_fns)
    if cfg.causal:
        logits = logits[:, :-1]
        labels = batch["labels"][:, 1:]
    else:
        labels = batch["labels"]
    loss = cross_entropy(logits, labels)
    return loss + aux_weight * aux, (loss, aux)


def make_train_step(cfg, adamw: opt.AdamWConfig, *, microbatches: int = 1,
                    shard_fns=None, grad_shardings=None):
    """Returns train_step(state, batch) -> (state, metrics); jit it with the
    planner's in/out shardings.

    grad_shardings: optional params-shaped tree of NamedSharding applied to
    the gradient accumulators — without it, XLA's SPMD propagation can fall
    back to replicating the scan-carried accumulators (flops/collective
    blow-up observed on the 16x16 mesh; see EXPERIMENTS.md §Perf iteration 0).
    """

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def constrain(g):
        if grad_shardings is None:
            return g
        return jax.tree.map(jax.lax.with_sharding_constraint, g,
                            grad_shardings)

    def split_micro(name, x):
        if name == "pos3":                       # (3, B, S): batch is axis 1
            b = x.shape[1]
            return x.reshape((3, microbatches, b // microbatches) +
                             x.shape[2:]).swapaxes(0, 1)
        b = x.shape[0]
        return x.reshape((microbatches, b // microbatches) + x.shape[1:])

    def train_step(state: TrainState, batch: Dict[str, Any]):
        if microbatches == 1:
            (l, (ce, aux)), grads = grad_fn(state.params, cfg, batch,
                                            shard_fns)
            grads = constrain(grads)
            lsum, asum = ce, aux
        else:
            micro = {k: split_micro(k, v) for k, v in batch.items()}

            def micro_step(carry, mb):
                gsum, lsum, asum = carry
                (l, (ce, aux)), g = grad_fn(state.params, cfg, mb, shard_fns)
                g = constrain(g)
                gsum = jax.tree.map(lambda a, b: a + b.astype(jnp.float32),
                                    gsum, g)
                return (constrain(gsum), lsum + ce, asum + aux), None

            zeros = constrain(jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params))
            (gsum, lsum, asum), _ = jax.lax.scan(
                micro_step, (zeros, jnp.float32(0), jnp.float32(0)), micro)
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
        params, opt_state, om = opt.update(state.params, grads, state.opt, adamw)
        metrics = {"loss": lsum / microbatches, "aux": asum / microbatches,
                   **om}
        return TrainState(params, opt_state, state.step + 1), metrics

    return train_step


def init_state(cfg, key, dtype=jnp.float32) -> TrainState:
    from ..models.transformer import init_params
    params = init_params(cfg, key, dtype)
    return TrainState(params, opt.init(params), jnp.zeros((), jnp.int32))
