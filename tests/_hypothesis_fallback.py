"""Seeded stand-in for the tiny hypothesis subset test_property.py uses.

The CI image does not ship hypothesis; when it is installed the real
library is used (see the try/except in test_property.py) and this module is
ignored. The fallback draws ``max_examples`` deterministic samples per
test, always starting with the strategy's boundary values so the cheap
edge cases are never missed. No shrinking — failures print the drawn
arguments instead.
"""
from __future__ import annotations

import random


class _Strategy:
    def boundary(self):
        return []

    def example(self, rng):
        raise NotImplementedError


class _Integers(_Strategy):
    def __init__(self, min_value=0, max_value=0):
        self.lo, self.hi = min_value, max_value

    def boundary(self):
        return [self.lo, self.hi]

    def example(self, rng):
        return rng.randint(self.lo, self.hi)


class _Text(_Strategy):
    def __init__(self, alphabet="abc", min_size=0, max_size=10):
        self.alphabet, self.lo, self.hi = alphabet, min_size, max_size

    def boundary(self):
        return [self.alphabet[0] * self.lo]

    def example(self, rng):
        n = rng.randint(self.lo, self.hi)
        return "".join(rng.choice(self.alphabet) for _ in range(n))


class _Lists(_Strategy):
    def __init__(self, elements, min_size=0, max_size=10):
        self.el, self.lo, self.hi = elements, min_size, max_size

    def boundary(self):
        rng = random.Random(0)
        return [[self.el.example(rng) for _ in range(self.lo)]]

    def example(self, rng):
        n = rng.randint(self.lo, self.hi)
        return [self.el.example(rng) for _ in range(n)]


class strategies:
    @staticmethod
    def integers(min_value=0, max_value=0):
        return _Integers(min_value, max_value)

    @staticmethod
    def text(alphabet="abc", min_size=0, max_size=10):
        return _Text(alphabet, min_size, max_size)

    @staticmethod
    def lists(elements, min_size=0, max_size=10):
        return _Lists(elements, min_size, max_size)


def settings(max_examples=20, deadline=None, **_):
    def deco(fn):
        fn._max_examples = max_examples
        return fn
    return deco


def given(*strats):
    def deco(fn):
        # NOTE: no functools.wraps — pytest must see a zero-arg signature,
        # not the original one (it would resolve the params as fixtures).
        def wrapper():
            n = getattr(wrapper, "_max_examples", 20)
            rng = random.Random(1234)
            cases = []
            bounds = [s.boundary() for s in strats]
            if all(bounds):
                # full cross-product (capped) so no strategy's boundary is
                # dropped when lists differ in length (zip would truncate)
                import itertools
                for combo in itertools.islice(itertools.product(*bounds), 8):
                    cases.append(list(combo))
            while len(cases) < n:
                cases.append([s.example(rng) for s in strats])
            for drawn in cases:
                try:
                    fn(*drawn)
                except Exception:
                    print(f"falsifying example ({fn.__name__}): {drawn!r}")
                    raise
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper._max_examples = getattr(fn, "_max_examples", 20)
        return wrapper
    return deco
