import os
import sys

# Tests run on the real device count (1 CPU); the 512-device forcing lives
# ONLY in launch/dryrun.py (run via subprocess in test_dryrun_small.py).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def mutate(s, rng, nsub=3, nins=1, ndel=1, alphabet="ACGT"):
    s = list(s)
    for _ in range(nsub):
        i = rng.integers(0, len(s))
        s[i] = alphabet[rng.integers(0, len(alphabet))]
    for _ in range(nins):
        i = rng.integers(0, len(s) + 1)
        s.insert(i, alphabet[rng.integers(0, len(alphabet))])
    for _ in range(ndel):
        if len(s) > 2:
            i = rng.integers(0, len(s))
            del s[i]
    return "".join(s)


@pytest.fixture
def dna_family():
    # dedicated generator: family content must not depend on test order
    r = np.random.default_rng(42)
    base = "".join(r.choice(list("ACGT"), 300))
    return [base] + [mutate(base, r, 4, 1, 1) for _ in range(7)]
