"""repro.align: backend parity, banded overflow fallback, bucketing, engine."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.align import AlignEngine, BACKENDS, resolve_backend
from repro.align import banded as banded_mod
from repro.align import backends as be
from repro.align.bucketing import bucket_plan
from repro.core import alphabet as ab

RNG = np.random.default_rng(7)


def _random_case(B, n, m, n_chars, *, edge_lens=True):
    Q = RNG.integers(0, n_chars, (B, n)).astype(np.int8)
    b = RNG.integers(0, n_chars, (m,)).astype(np.int8)
    lens = RNG.integers(0, n + 1, B).astype(np.int32)
    if edge_lens:
        lens[0] = 0            # empty query
        lens[min(1, B - 1)] = 1  # length-1 query
        lens[-1] = n           # full-width query
    return jnp.asarray(Q), jnp.asarray(lens), jnp.asarray(b)


def _run_backend(name, Q, lens, b, lb, sub, go, ge, band):
    kw = dict(gap_open=go, gap_extend=ge, gap_code=5)
    if name == "banded":
        return be.banded_align_batch(Q, lens, b, lb, sub, band=band, **kw)
    if name == "banded-pallas":
        return be.banded_pallas_align_batch(Q, lens, b, lb, sub, band=band,
                                            **kw)
    if name == "pallas":
        return be.pallas_align_batch(Q, lens, b, lb, sub, block_rows=32, **kw)
    return be.jnp_align_batch(Q, lens, b, lb, sub, **kw)


@pytest.mark.parametrize("alphabet,go,ge", [("dna", 3, 1), ("protein", 11, 1)])
@pytest.mark.parametrize("lb", [0, 1, 30])
def test_backend_parity(alphabet, go, ge, lb):
    """jnp, pallas, and banded (band wide enough) agree exactly on scores,
    aligned rows, and lengths — including la=0/lb=0 and length-1 pairs."""
    n_chars = 4 if alphabet == "dna" else 20
    sub = (ab.dna_matrix() if alphabet == "dna"
           else ab.blosum62()).astype(jnp.float32)
    B, n, m = 5, 40, 36
    Q, lens, b = _random_case(B, n, m, n_chars)
    band = 2 * m + 4                       # full column coverage: exact DP
    ref = _run_backend("jnp", Q, lens, b, jnp.int32(lb), sub, go, ge, band)
    for name in ("pallas", "banded", "banded-pallas"):
        got = _run_backend(name, Q, lens, b, jnp.int32(lb), sub, go, ge, band)
        np.testing.assert_array_equal(np.asarray(ref.score),
                                      np.asarray(got.score), err_msg=name)
        np.testing.assert_array_equal(np.asarray(ref.aln_len),
                                      np.asarray(got.aln_len), err_msg=name)
        assert bool(jnp.all(got.ok)), name
        for i in range(B):
            k = int(ref.aln_len[i])
            np.testing.assert_array_equal(
                np.asarray(ref.a_row[i])[:k], np.asarray(got.a_row[i])[:k],
                err_msg=f"{name} pair {i} a_row")
            np.testing.assert_array_equal(
                np.asarray(ref.b_row[i])[:k], np.asarray(got.b_row[i])[:k],
                err_msg=f"{name} pair {i} b_row")


def test_backend_parity_random_sweep():
    """Property sweep: random geometries/params, all backends identical."""
    for trial in range(6):
        n = int(RNG.integers(4, 48))
        m = int(RNG.integers(4, 48))
        go = int(RNG.integers(2, 8))
        ge = int(RNG.integers(1, go + 1))
        sub = ab.dna_matrix(2, -int(RNG.integers(1, 4))).astype(jnp.float32)
        Q, lens, b = _random_case(3, n, m, 4)
        lb = jnp.int32(int(RNG.integers(0, m + 1)))
        band = 2 * m + 4
        outs = {name: _run_backend(name, Q, lens, b, lb, sub, go, ge, band)
                for name in BACKENDS}
        for name in ("pallas", "banded", "banded-pallas"):
            np.testing.assert_array_equal(
                np.asarray(outs["jnp"].score), np.asarray(outs[name].score),
                err_msg=f"trial {trial} {name}")
            for i in range(3):
                k = int(outs["jnp"].aln_len[i])
                np.testing.assert_array_equal(
                    np.asarray(outs["jnp"].a_row[i])[:k],
                    np.asarray(outs[name].a_row[i])[:k],
                    err_msg=f"trial {trial} {name} pair {i}")


def test_banded_dirs_shape_is_n_by_band():
    """The banded forward never materializes (n+1)x(m+1) directions."""
    n, m, W = 64, 256, 16
    a = jnp.asarray(RNG.integers(0, 4, n).astype(np.int8))
    b = jnp.asarray(RNG.integers(0, 4, m).astype(np.int8))
    sub = ab.dna_matrix().astype(jnp.float32)
    fwd = banded_mod.banded_forward(a, jnp.int32(n), b, jnp.int32(200), sub,
                                    3, 1, band=W)
    assert fwd.dirs.shape == (n, W)
    assert fwd.dirs.dtype == jnp.int8


def test_banded_overflow_falls_back_to_full_dp():
    """A 30-column insert forces the path off the diagonal: a narrow band
    must flag the pair and the engine must return the exact full-DP rows."""
    pre, post = "ACGTACGTACGT", "TTGGCCAATTGG"
    a = ab.DNA.encode(pre + post)
    bq = ab.DNA.encode(pre + "C" * 30 + post)
    Q = np.full((1, 64), 0, np.int8)
    Q[0, :len(a)] = a
    b = np.zeros((64,), np.int8)
    b[:len(bq)] = bq
    sub = ab.dna_matrix().astype(jnp.float32)

    raw = be.banded_align_batch(jnp.asarray(Q), jnp.int32([len(a)]),
                                jnp.asarray(b), jnp.int32(len(bq)), sub,
                                gap_open=3, gap_extend=1, band=8, gap_code=5)
    assert not bool(raw.ok[0])

    eng = AlignEngine(sub, gap_open=3, gap_extend=1, gap_code=5,
                      backend="banded", band=8, bucket=False)
    ref = AlignEngine(sub, gap_open=3, gap_extend=1, gap_code=5,
                      backend="jnp", bucket=False)
    got = eng.align_to_center(Q, np.int32([len(a)]), b, jnp.int32(len(bq)))
    want = ref.align_to_center(Q, np.int32([len(a)]), b, jnp.int32(len(bq)))
    assert got.n_fallback == 1
    np.testing.assert_array_equal(np.asarray(got.score),
                                  np.asarray(want.score))
    np.testing.assert_array_equal(np.asarray(got.a_row),
                                  np.asarray(want.a_row))


def test_banded_never_silently_suboptimal():
    """Adversarial property: on random unequal-length pairs at a tiny
    band, every pair the banded backend does NOT flag must score exactly
    the full DP optimum (overflow detection has no silent escapes)."""
    from repro.core import pairwise as pw
    import jax
    rng = np.random.default_rng(0)
    B, n, m = 150, 24, 24
    sub = ab.dna_matrix().astype(jnp.float32)
    Q = jnp.asarray(rng.integers(0, 4, (B, n)).astype(np.int8))
    T = jnp.asarray(rng.integers(0, 4, (B, m)).astype(np.int8))
    las = jnp.asarray(rng.integers(1, n + 1, B).astype(np.int32))
    lbs = jnp.asarray(rng.integers(1, m + 1, B).astype(np.int32))

    @jax.jit
    def both(q, la, t, lb):
        ref = pw.score_only(q, la, t, lb, sub, gap_open=3, gap_extend=1)
        fwd = banded_mod.banded_forward(q, la, t, lb, sub, 3, 1, band=8)
        _, _, _, ok = banded_mod.banded_traceback(q, t, fwd, 5, band=8)
        return ref, fwd.score, ok

    ref, got, ok = jax.vmap(both)(Q, las, T, lbs)
    ref, got, ok = np.asarray(ref), np.asarray(got), np.asarray(ok)
    silent = ok & (got != ref)
    assert not silent.any(), np.flatnonzero(silent)[:5]
    # and the detector is not just flagging everything: exact unflagged
    # pairs exist even in this adversarial regime
    assert (ok & (got == ref)).sum() > 0


def test_kmer_fallback_is_global_under_local_engine():
    """realign_failed must force global alignment even when the engine is
    configured local (the k-mer assembly is global)."""
    sub = ab.dna_matrix().astype(jnp.float32)
    rng = np.random.default_rng(2)
    n = 40
    Q = jnp.asarray(rng.integers(0, 4, (2, n)).astype(np.int8))
    lens = jnp.asarray(np.full(2, n, np.int32))
    b = jnp.asarray(rng.integers(0, 4, n).astype(np.int8))
    dummy = jnp.full((2, 2 * n), 5, jnp.int8)
    ok = jnp.asarray([False, False])
    loc = AlignEngine(sub, gap_open=3, gap_extend=1, gap_code=5,
                      backend="jnp", local=True)
    glob = AlignEngine(sub, gap_open=3, gap_extend=1, gap_code=5,
                       backend="jnp", local=False)
    al, _, nfl = loc.realign_failed(Q, lens, b, jnp.int32(n), dummy, dummy, ok)
    ag, _, nfg = glob.realign_failed(Q, lens, b, jnp.int32(n), dummy, dummy, ok)
    assert nfl == nfg == 2
    np.testing.assert_array_equal(np.asarray(al), np.asarray(ag))


def test_bucketed_matches_unbucketed():
    """Length bucketing is a pure scheduling change: identical output."""
    lengths = (0, 1, 5, 17, 33, 64, 120, 300)
    seqs = ["".join(RNG.choice(list("ACGT"), L)) for L in lengths]
    Q, lens = ab.encode_batch(seqs, ab.DNA)
    center, lc = np.asarray(Q[-1]), int(lens[-1])
    sub = ab.dna_matrix().astype(jnp.float32)
    for backend in ("jnp", "banded"):
        kw = dict(gap_open=3, gap_extend=1, gap_code=5, backend=backend,
                  band=700)
        rb = AlignEngine(sub, bucket=True, min_bucket=16,
                         **kw).align_to_center(Q, lens, center, jnp.int32(lc))
        ru = AlignEngine(sub, bucket=False,
                         **kw).align_to_center(Q, lens, center, jnp.int32(lc))
        np.testing.assert_array_equal(np.asarray(rb.score),
                                      np.asarray(ru.score), err_msg=backend)
        np.testing.assert_array_equal(np.asarray(rb.aln_len),
                                      np.asarray(ru.aln_len), err_msg=backend)
        for i in range(len(seqs)):
            k = int(ru.aln_len[i])
            np.testing.assert_array_equal(
                np.asarray(rb.a_row[i])[:k], np.asarray(ru.a_row[i])[:k],
                err_msg=f"{backend} row {i}")


def test_bucket_plan_pow2_and_clamped():
    plan = bucket_plan(np.array([0, 1, 5, 17, 33, 120, 300]), 300,
                       min_bucket=16)
    widths = [w for w, _ in plan]
    assert widths == sorted(widths)
    assert all(w <= 300 for w in widths)
    covered = np.concatenate([ix for _, ix in plan])
    assert sorted(covered.tolist()) == list(range(7))
    lens = np.array([0, 1, 5, 17, 33, 120, 300])
    for w, ix in plan:
        assert (lens[ix] <= w).all()


def test_resolve_backend():
    assert resolve_backend("jnp") == "jnp"
    assert resolve_backend("auto") in BACKENDS
    with pytest.raises(ValueError):
        resolve_backend("spark")


def test_msa_through_backends():
    """center_star_msa recovers inputs through every backend."""
    from repro.core.msa import MSAConfig, center_star_msa, decode_msa
    r = np.random.default_rng(3)
    base = "".join(r.choice(list("ACGT"), 60))
    fam = [base]
    for _ in range(3):
        s = list(base)
        for _ in range(2):
            s[r.integers(0, len(s))] = "ACGT"[r.integers(0, 4)]
        fam.append("".join(s))
    for backend in ("jnp", "pallas", "banded", "banded-pallas"):
        cfg = MSAConfig(method="plain", backend=backend, band=144)
        res = center_star_msa(fam, cfg)
        rows = decode_msa(res.msa, cfg)
        assert all(rw.replace("-", "") == s for s, rw in zip(fam, rows)), \
            backend
        assert res.n_fallback == 0, backend


def test_msa_kmer_fallback_via_engine():
    """Chain failures re-align through the engine (device-side merge)."""
    from repro.core.msa import MSAConfig, center_star_msa, decode_msa
    r = np.random.default_rng(11)
    center = "".join(r.choice(list("ACGT"), 80))
    diverged = "".join(r.choice(list("ACGT"), 70))   # no shared 8-mers
    fam = [center, diverged, center[:60]]
    cfg = MSAConfig(method="kmer", k=8, backend="jnp")
    res = center_star_msa(fam, cfg)
    rows = decode_msa(res.msa, cfg)
    assert all(rw.replace("-", "") == s for s, rw in zip(fam, rows))
    assert res.n_fallback >= 1


def test_center_sampled_protein_warns_and_reports_mode():
    from repro.core.msa import MSAConfig, center_star_msa
    prots = ["MKTAYIAKQRQISFVKSHFSRQLEERLGLIEVQ",
             "MKTAYIAKQRQISFVKSHFSRQLEERLGLIEV",
             "MKTAYIAQQRQISFVKSHFSRQLEERLGLIEVQA"]
    cfg = MSAConfig(method="sw", alphabet="protein", gap_open=11,
                    center="sampled")
    with pytest.warns(UserWarning, match="sampled"):
        res = center_star_msa(prots, cfg)
    assert res.center_mode == "first"


def test_center_sampled_dna_reports_mode(dna_family):
    from repro.core.msa import MSAConfig, center_star_msa
    res = center_star_msa(dna_family, MSAConfig(method="kmer", k=8,
                                                center="sampled"))
    assert res.center_mode == "sampled"
    assert 0 <= res.center_idx < len(dna_family)


def test_dist_mapreduce_banded_backend():
    """The shard_map pipeline accepts the banded backend in-graph."""
    from repro.core.msa import MSAConfig, decode_msa
    from repro.dist import mapreduce
    from repro.launch.mesh import make_local_mesh
    r = np.random.default_rng(5)
    base = "".join(r.choice(list("ACGT"), 64))
    fam = [base]
    for _ in range(3):
        s = list(base)
        s[r.integers(0, len(s))] = "ACGT"[r.integers(0, 4)]
        fam.append("".join(s))
    mesh = make_local_mesh((1, 1), ("data", "model"))
    cfg = MSAConfig(method="plain", backend="banded", band=160)
    res = mapreduce.msa_over_mesh(fam, cfg, mesh)
    rows = decode_msa(res.msa, cfg)
    assert all(rw.replace("-", "") == s for s, rw in zip(fam, rows))


def test_local_routes_away_from_banded():
    sub = ab.dna_matrix().astype(jnp.float32)
    for backend in ("banded", "banded-pallas"):
        eng = AlignEngine(sub, gap_open=3, gap_extend=1, backend=backend,
                          local=True)
        assert eng.backend == "jnp"


def test_band_bucket_plan_shares_same_width_pairs():
    """Pairs with the same pow2 shapes AND band requirement share one
    bucket; wildly skewed pairs get a wider W instead of a fallback."""
    from repro.align.bucketing import band_bucket_plan
    qlens = np.array([29, 31, 32, 30, 100, 4], np.int32)
    tlens = np.array([30, 30, 30, 29, 10, 120], np.int32)
    plan = band_bucket_plan(qlens, tlens, 128, 128, band=8, min_bucket=16)
    covered = np.concatenate([ix for *_, ix in plan])
    assert sorted(covered.tolist()) == list(range(6))
    for wq, wt, W, ix in plan:
        assert W & (W - 1) == 0                       # pow2
        assert (qlens[ix] <= wq).all() and (tlens[ix] <= wt).all()
        # W covers the skew of every member pair, unless it was clamped
        # to full column coverage (where the band is exact regardless)
        assert W >= 2 * wt + 2 or \
            (np.abs(qlens[ix] - tlens[ix]) + 2 <= W).all()
        assert W <= 1 << int(np.ceil(np.log2(2 * wt + 2)))
    # the four similar-length pairs share one bucket (one kernel instance)
    sizes = sorted(len(ix) for *_, ix in plan)
    assert sizes[-1] >= 4
    assert band_bucket_plan([], [], 8, 8, band=8) == []


@pytest.mark.parametrize("backend", ["banded", "banded-pallas"])
def test_adaptive_band_policy_avoids_fallbacks(backend):
    """band_policy='adaptive' widens the band per skew bucket: strictly
    fewer full-DP fallbacks than a fixed thin band (skew-driven overflow
    is designed away; random-walk overflow can remain), and the merged
    result matches the jnp oracle exactly."""
    rng = np.random.default_rng(21)
    B, n = 12, 96
    Q = jnp.asarray(rng.integers(0, 4, (B, n)).astype(np.int8))
    T = jnp.asarray(rng.integers(0, 4, (B, n)).astype(np.int8))
    qlens = jnp.asarray(rng.integers(1, n + 1, B).astype(np.int32))
    tlens = jnp.asarray(rng.integers(1, n + 1, B).astype(np.int32))
    sub = ab.dna_matrix().astype(jnp.float32)
    kw = dict(gap_open=3, gap_extend=1, gap_code=5, band=8)
    ref = AlignEngine(sub, backend="jnp", **kw).align_pairs(
        Q, qlens, T, tlens)
    fixed = AlignEngine(sub, backend=backend, band_policy="fixed",
                        **kw).align_pairs(Q, qlens, T, tlens)
    adapt = AlignEngine(sub, backend=backend, band_policy="adaptive",
                        **kw).align_pairs(Q, qlens, T, tlens)
    assert fixed.n_fallback > 0          # band=8 is genuinely too thin
    assert adapt.n_fallback < fixed.n_fallback
    np.testing.assert_array_equal(np.asarray(adapt.score),
                                  np.asarray(ref.score))
    np.testing.assert_array_equal(np.asarray(adapt.aln_len),
                                  np.asarray(ref.aln_len))
    for i in range(B):
        k = int(ref.aln_len[i])
        np.testing.assert_array_equal(np.asarray(adapt.a_row[i])[:k],
                                      np.asarray(ref.a_row[i])[:k])


def test_band_policy_validated():
    sub = ab.dna_matrix().astype(jnp.float32)
    with pytest.raises(ValueError, match="band_policy"):
        AlignEngine(sub, gap_open=3, gap_extend=1, backend="banded",
                    band_policy="wide")
