"""Distribution runtime: checkpoint, fault loop, compression, collectives,
distributed MSA semantics on a trivial mesh (multi-device in
test_multidevice.py via subprocess)."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist import collectives as col
from repro.dist import shard_map
from repro.dist import grad_compression as gc
from repro.dist import mapreduce, sharding as sh
from repro.dist.checkpoint import CheckpointManager
from repro.dist.fault import BackupShardPlan, ResilientLoop, StepFailure
from repro.launch.mesh import make_local_mesh


def test_checkpoint_roundtrip_and_gc():
    state = {"w": jnp.arange(12.0).reshape(3, 4), "step": jnp.int32(7),
             "nested": {"b": jnp.ones(5)}}
    with tempfile.TemporaryDirectory() as d:
        cm = CheckpointManager(d, keep=2)
        for s in (0, 10, 20):
            cm.save(s, state, block=True)
        assert cm.all_steps() == [10, 20]
        like = jax.tree.map(jnp.zeros_like, state)
        restored, step = cm.restore(like)
        assert step == 20
        np.testing.assert_allclose(np.asarray(restored["w"]),
                                   np.asarray(state["w"]))


def test_checkpoint_async_then_wait():
    state = {"w": jnp.ones((64, 64))}
    with tempfile.TemporaryDirectory() as d:
        cm = CheckpointManager(d, keep=3, async_write=True)
        cm.save(1, state)
        cm.wait()
        assert cm.all_steps() == [1]


def test_elastic_restore_new_mesh():
    state = {"w": jnp.arange(16.0).reshape(4, 4)}
    with tempfile.TemporaryDirectory() as d:
        cm = CheckpointManager(d)
        cm.save(5, state, block=True)
        mesh = make_local_mesh((1,), ("data",))
        shardings = {"w": NamedSharding(mesh, P("data", None))}
        restored, _ = cm.restore(jax.tree.map(jnp.zeros_like, state),
                                 shardings=shardings)
        np.testing.assert_allclose(np.asarray(restored["w"]),
                                   np.asarray(state["w"]))


def test_resilient_loop_replays_from_checkpoint():
    with tempfile.TemporaryDirectory() as d:
        cm = CheckpointManager(d, keep=3, async_write=False)
        fails = {8: 1, 3: 1}

        def hook(step):
            if fails.get(step, 0) > 0:
                fails[step] -= 1
                raise StepFailure(f"injected at {step}")

        class Batches:
            n_steps = 12

            def __call__(self, step):
                return jnp.float32(1.0)

        loop = ResilientLoop(lambda s, b: {"w": s["w"] + b}, cm,
                             ckpt_every=5, failure_hook=hook)
        final, steps = loop.run({"w": jnp.float32(0.0)}, Batches())
        assert steps == 12
        assert float(final["w"]) == 12.0  # deterministic replay => exact


def test_backup_shard_plan_invariants():
    plan = BackupShardPlan(n_hosts=8, replication=3)
    for s in range(8):
        owners = plan.owners(s)
        assert len(set(owners)) == 3 and owners[0] == s
    for dead in range(8):
        for s, takeover in plan.reassignment(dead).items():
            assert takeover != dead and dead in plan.owners(s)


def test_grad_compression_accuracy_and_error_feedback():
    mesh = make_local_mesh((1,), ("data",))
    g = {"a": jnp.asarray(np.random.default_rng(0).normal(0, 1, (128,)),
                          jnp.float32)}
    ef = gc.init_ef(g)
    fn = shard_map(lambda g, e: gc.tree_compressed_psum_mean(g, "data", e),
                   mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()),
                   check_vma=False)
    mean, ef2 = fn(g, ef)
    scale = float(jnp.max(jnp.abs(g["a"]))) / 127.0
    assert float(jnp.max(jnp.abs(mean["a"] - g["a"]))) <= scale * 1.01
    # error feedback holds the quantization residual
    np.testing.assert_allclose(np.asarray(ef2["a"]),
                               np.asarray(g["a"] - mean["a"]), atol=1e-6)


def test_collective_matmul_matches_plain():
    mesh = make_local_mesh((1,), ("data",))
    x = jnp.asarray(np.random.default_rng(1).normal(0, 1, (4, 16)), jnp.float32)
    w = jnp.asarray(np.random.default_rng(2).normal(0, 1, (16, 8)), jnp.float32)
    fn = shard_map(lambda x, w: col.ag_matmul_overlap(x, w, "data"),
                   mesh=mesh, in_specs=(P(), P(None, "data")), out_specs=P(),
                   check_vma=False)
    np.testing.assert_allclose(np.asarray(fn(x, w)), np.asarray(x @ w),
                               rtol=1e-5)


def test_distributed_center_star_equals_host_version(dna_family):
    from repro.core import alphabet as ab
    from repro.core import kmer_index
    from repro.core.msa import MSAConfig, center_star_msa

    mesh = make_local_mesh((1, 1), ("data", "model"))
    seqs = dna_family[1:]           # queries
    center_s = dna_family[0]
    S, lens = ab.encode_batch(seqs, ab.DNA)
    center = jnp.asarray(ab.DNA.encode(center_s))
    lc = jnp.int32(len(center_s))
    table = kmer_index.build_center_index(center, lc, k=8)
    sub = ab.dna_matrix().astype(jnp.float32)

    fn = mapreduce.distributed_center_star(
        mesh, method="kmer", sub=sub, gap_code=ab.DNA.gap_code,
        out_len=400, num_slots=int(center.shape[0]) + 1, gap_open=3,
        gap_extend=1, k=8, max_anchors=96, max_seg=48)
    rows, G = fn(sh.shard_rows(S, mesh), sh.shard_rows(lens, mesh),
                 sh.broadcast(center, mesh), lc, sh.broadcast(table, mesh))
    for s, r in zip(seqs, np.asarray(rows)):
        assert ab.DNA.decode(r).replace("-", "") == s


def test_sharding_helpers():
    mesh = make_local_mesh((1, 1), ("data", "model"))
    assert sh.axis_size(mesh, ("data", "model")) == 1
    assert sh.maybe(mesh, 7, "data") == "data"   # 7 % 1 == 0
    assert sh.first_fit(mesh, 8, "model", None) == "model"
