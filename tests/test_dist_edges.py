"""Edge cases for the distribution runtime: checkpoint retention/restore
(empty dir, corrupt latest step, structure mismatch), fault-plan
takeover/reassignment under cascading failures, ResilientLoop retry
exhaustion, and the mapreduce padding path when the shard count does not
divide the sequence count."""
import json
import subprocess
import sys
import warnings
from pathlib import Path

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import alphabet as ab
from repro.core import kmer_index
from repro.dist import mapreduce, sharding as sh
from repro.dist.checkpoint import CheckpointManager
from repro.dist.fault import BackupShardPlan, ResilientLoop, StepFailure
from repro.launch.mesh import make_local_mesh


# ------------------------------------------------------------- checkpoints

def test_restore_empty_dir_raises(tmp_path):
    cm = CheckpointManager(tmp_path)
    assert cm.all_steps() == []
    assert cm.latest_step() is None
    with pytest.raises(FileNotFoundError):
        cm.restore({"w": jnp.zeros(3)})


def test_restore_skips_corrupt_latest(tmp_path):
    cm = CheckpointManager(tmp_path)
    state = {"w": jnp.arange(6.0)}
    cm.save(10, state, block=True)
    cm.save(20, {"w": state["w"] * 2}, block=True)
    cm._path(20).write_bytes(b"not an npz file")
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        restored, step = cm.restore({"w": jnp.zeros(6)})
    assert step == 10
    np.testing.assert_allclose(np.asarray(restored["w"]),
                               np.asarray(state["w"]))
    # explicitly requesting the corrupt step is strict
    with pytest.raises(Exception):
        cm.restore({"w": jnp.zeros(6)}, step=20)


def test_restore_skips_structure_mismatch(tmp_path):
    cm = CheckpointManager(tmp_path)
    cm.save(1, {"w": jnp.ones(4)}, block=True)
    cm.save(2, {"w": jnp.ones(4), "extra": jnp.ones(2)}, block=True)
    cm.save(3, {"w": jnp.ones(7)}, block=True)        # wrong shape
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        _, step = cm.restore({"w": jnp.zeros(4)})
    assert step == 1
    with pytest.raises(ValueError):
        cm.restore({"w": jnp.zeros(4)}, step=3)       # strict on explicit step


def test_retention_keep_one(tmp_path):
    cm = CheckpointManager(tmp_path, keep=1)
    for s in (1, 2, 3):
        cm.save(s, {"w": jnp.full(2, float(s))}, block=True)
    assert cm.all_steps() == [3]
    _, step = cm.restore({"w": jnp.zeros(2)})
    assert step == 3


# ----------------------------------------------------------- fault plans

def test_takeover_when_backup_owner_also_dead():
    plan = BackupShardPlan(n_hosts=4, replication=3)
    assert plan.owners(0) == [0, 1, 2]
    assert plan.takeover(0, 0) == 1             # single failure (int form)
    assert plan.takeover({0, 1}, 0) == 2        # backup owner dead too
    assert plan.takeover([1, 0], 0) == 2        # any iterable, any order
    assert plan.takeover({0, 1, 2}, 0) is None  # every replica gone
    # an unaffected shard still answers with its primary
    assert plan.takeover({0, 1, 2}, 3) == 3


def test_reassignment_after_cascading_failures():
    plan = BackupShardPlan(n_hosts=4, replication=2)
    out = plan.reassignment({0, 1})
    # shard 0's owners (0, 1) are both dead: it must be ABSENT, not
    # silently mapped to a dead host
    assert 0 not in out
    assert out == {1: 2, 3: 3}
    for s, h in out.items():
        assert h not in {0, 1}
        assert h in plan.owners(s)
    # the cascade is strictly worse than either single failure
    assert set(out) < set(plan.reassignment(0)) | set(plan.reassignment(1))


def test_reassignment_replication_one_drops_dead_shard():
    plan = BackupShardPlan(n_hosts=3, replication=1)
    assert plan.reassignment(1) == {}           # no replica to take over
    assert plan.reassignment({0, 1, 2}) == {}


def test_resilient_loop_retry_exhaustion(tmp_path):
    """A fault that persists across replays must surface after
    max_failures replays instead of looping forever."""
    class Batches:
        n_steps = 3

        def __call__(self, step):
            return step

    def always_fail(step):
        if step == 1:
            raise StepFailure("persistent fault")

    loop = ResilientLoop(lambda s, b: s + 1, CheckpointManager(tmp_path),
                         ckpt_every=1, failure_hook=always_fail,
                         max_failures=2)
    with pytest.raises(StepFailure, match="persistent fault"):
        loop.run(jnp.int32(0), Batches())


def test_resilient_loop_failure_without_checkpoint_raises(tmp_path):
    """ckpt_every=0 never saved — a StepFailure has nothing to replay
    from and must propagate immediately."""
    class Batches:
        n_steps = 2

        def __call__(self, step):
            return step

    def fail_first(step):
        raise StepFailure("no checkpoint to fall back to")

    loop = ResilientLoop(lambda s, b: s + 1, CheckpointManager(tmp_path),
                         ckpt_every=0, failure_hook=fail_first)
    with pytest.raises(StepFailure):
        loop.run(jnp.int32(0), Batches())


# ------------------------------------------------- mapreduce shard padding

def test_pad_rows_roundtrip():
    x = np.arange(10).reshape(5, 2)
    padded, n = mapreduce.pad_rows(x, 4)
    assert padded.shape == (8, 2) and n == 5
    np.testing.assert_array_equal(mapreduce.unpad_rows(padded, n), x)
    same, n2 = mapreduce.pad_rows(x, 5)
    assert same.shape == (5, 2) and n2 == 5


def test_padded_queries_align_as_all_gap(dna_family):
    """Empty-query padding rows must produce all-gap output rows and leave
    the merged profile untouched (checked on a 1-device mesh by feeding the
    padded batch directly)."""
    mesh = make_local_mesh((1, 1), ("data", "model"))
    seqs = dna_family[1:4]
    center_s = dna_family[0]
    S, lens = ab.encode_batch(seqs, ab.DNA)
    Q, n_q = mapreduce.pad_rows(np.asarray(S), 4)
    qlens, _ = mapreduce.pad_rows(np.asarray(lens), 4)
    assert Q.shape[0] == 4 and n_q == 3
    center = jnp.asarray(ab.DNA.encode(center_s))
    lc = jnp.int32(len(center_s))
    table = kmer_index.build_center_index(center, lc, k=8)
    fn = mapreduce.distributed_center_star(
        mesh, method="kmer", sub=ab.dna_matrix().astype(jnp.float32),
        gap_code=ab.DNA.gap_code, out_len=400,
        num_slots=int(center.shape[0]) + 1, gap_open=3, gap_extend=1, k=8,
        max_anchors=96, max_seg=48)
    rows, G = fn(sh.shard_rows(Q, mesh), sh.shard_rows(qlens, mesh),
                 sh.broadcast(center, mesh), lc, sh.broadcast(table, mesh))
    rows = np.asarray(rows)
    for s, r in zip(seqs, rows[:n_q]):
        assert ab.DNA.decode(r).replace("-", "") == s
    assert (rows[n_q:] == ab.DNA.gap_code).all()          # padding -> all gap


def test_shard_rows_rejects_nondividing():
    mesh = make_local_mesh((1, 1), ("data", "model"))
    ok = sh.shard_rows(np.zeros((3, 2), np.int8), mesh)   # 3 % 1 == 0
    assert ok.shape == (3, 2)


SCRIPT = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import sys
sys.path.insert(0, %r)
import json
import numpy as np
from repro.core.msa import MSAConfig, center_star_msa, decode_msa
from repro.dist import mapreduce
from repro.launch.mesh import make_local_mesh

rng = np.random.default_rng(7)
base = "".join(rng.choice(list("ACGT"), 80))
def mut(s):
    s = list(s)
    for _ in range(3):
        i = rng.integers(0, len(s)); s[i] = "ACGT"[rng.integers(0, 4)]
    return "".join(s)
seqs = [base] + [mut(base) for _ in range(5)]   # 5 queries over 2 shards
cfg = MSAConfig(method="kmer", k=8, max_anchors=64, max_seg=48)
mesh = make_local_mesh((2, 1), ("data", "model"))
res = mapreduce.msa_over_mesh(seqs, cfg, mesh)
host = center_star_msa(seqs, cfg)
rows = decode_msa(res.msa, cfg)
ok = all(r.replace("-", "") == s for s, r in zip(seqs, rows))
print("RESULT " + json.dumps({
    "ok": bool(ok), "n": len(rows), "width": int(res.width),
    "host_width": int(host.width)}))
'''


def test_mapreduce_nondividing_sequence_count_two_shards():
    """5 queries over 2 shards (padded to 6): distributed result must decode
    to the inputs and match the host pipeline's width."""
    src = str(Path(__file__).resolve().parent.parent / "src")
    proc = subprocess.run([sys.executable, "-c", SCRIPT % src],
                          capture_output=True, text=True, timeout=560)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")][-1]
    out = json.loads(line[len("RESULT "):])
    assert out["ok"]
    assert out["n"] == 6
    assert out["width"] == out["host_width"]
