"""Docs stay true: CLI reference drift + markdown link integrity +
repo hygiene (no committed bytecode)."""
import re
import shutil
import subprocess
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]

_LINK = re.compile(r"\[[^\]]*\]\(([^)#\s]+)(#[^)\s]*)?\)")


def test_cli_reference_not_drifted():
    """docs/CLI.md must match what the launchers' parsers render today.

    Regenerate with: PYTHONPATH=src python -m repro.launch.cli_docs
    """
    from repro.launch import cli_docs
    on_disk = (ROOT / "docs" / "CLI.md").read_text()
    assert on_disk == cli_docs.render(), (
        "docs/CLI.md is stale — a launcher flag changed; regenerate with "
        "`PYTHONPATH=src python -m repro.launch.cli_docs`")


def test_markdown_relative_links_resolve():
    md_files = [ROOT / "README.md", *sorted((ROOT / "docs").glob("*.md"))]
    assert len(md_files) >= 3
    missing = []
    for md in md_files:
        for m in _LINK.finditer(md.read_text()):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            if not (md.parent / target).exists():
                missing.append(f"{md.relative_to(ROOT)} -> {target}")
    assert not missing, f"broken relative links: {missing}"


def test_architecture_doc_covers_the_six_subsystems():
    text = (ROOT / "docs" / "ARCHITECTURE.md").read_text()
    for subsystem in ("repro.align", "repro.dist", "repro.phylo",
                      "repro.phylo.ml", "repro.serve", "repro.search"):
        assert f"`{subsystem}`" in text, f"{subsystem} missing"
    # the README points at the architecture map instead of duplicating it
    readme = (ROOT / "README.md").read_text()
    assert "docs/ARCHITECTURE.md" in readme
    assert "docs/CLI.md" in readme


def test_every_docs_page_is_reachable_from_architecture():
    """Docs lint: the doc set must stay a connected graph — every file in
    docs/ has to be reachable from docs/ARCHITECTURE.md via relative
    links, or it is an orphan nobody will find."""
    docs = ROOT / "docs"
    start = docs / "ARCHITECTURE.md"
    seen = {start.resolve()}
    frontier = [start]
    while frontier:
        md = frontier.pop()
        for m in _LINK.finditer(md.read_text()):
            target = m.group(1)
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            p = (md.parent / target).resolve()
            if p.suffix == ".md" and p.exists() and p not in seen:
                seen.add(p)
                frontier.append(p)
    orphans = [p.name for p in sorted(docs.glob("*.md"))
               if p.resolve() not in seen]
    assert not orphans, (
        f"docs pages unreachable from docs/ARCHITECTURE.md: {orphans} — "
        f"link them from the architecture map (or a page it links)")


def test_no_tracked_bytecode():
    """Hygiene lint: compiled bytecode must never be committed — it is
    machine-specific noise that churns every diff (.gitignore covers
    ``__pycache__/`` and ``*.pyc``; this catches force-adds)."""
    if shutil.which("git") is None or not (ROOT / ".git").exists():
        pytest.skip("not a git checkout")
    proc = subprocess.run(["git", "ls-files", "*.pyc", "**/__pycache__/*"],
                          cwd=ROOT, capture_output=True, text=True)
    if proc.returncode != 0:
        pytest.skip(f"git ls-files unavailable: {proc.stderr.strip()}")
    tracked = [ln for ln in proc.stdout.splitlines() if ln]
    assert not tracked, f"bytecode files are tracked by git: {tracked}"
