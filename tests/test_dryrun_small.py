"""Dry-run machinery on a small forced-device mesh (subprocess): lower +
compile one real (arch x shape) cell with the production sharding planner on
a (2,2,2) pod/data/model mesh — the same code path as the 512-device run,
scaled so CI stays fast."""
import json
import subprocess
import sys
from pathlib import Path

SCRIPT = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, %r)
import json
import numpy as np
import jax

from repro.launch.steps import build_step
from repro.launch.dryrun import collective_bytes

mesh = jax.sharding.Mesh(
    np.asarray(jax.devices()[:8]).reshape(2, 2, 2), ("pod", "data", "model"))
with mesh:
    jitted, args = build_step("qwen1.5-0.5b", "decode_32k", mesh)
    compiled = jitted.lower(*args).compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    coll, counts, _ = collective_bytes(compiled.as_text())
out = {"flops": float(cost.get("flops", -1)),
       "collectives": {k: int(v) for k, v in coll.items()}}
print("RESULT " + json.dumps(out))
'''


def test_dryrun_cell_small_mesh():
    src = str(Path(__file__).resolve().parent.parent / "src")
    proc = subprocess.run([sys.executable, "-c", SCRIPT % src],
                          capture_output=True, text=True, timeout=560)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")][-1]
    out = json.loads(line[len("RESULT "):])
    assert out["flops"] > 0
