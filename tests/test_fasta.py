"""FASTA robustness: CRLF, lowercase, ambiguity codes, streaming parity."""
import io

import numpy as np
import pytest

from repro.core import alphabet as ab
from repro.data import iter_fasta, read_fasta, write_fasta

CORPUS = (
    ">ref some description\r\n"
    "acgtacgtACGT\r\n"
    "ggcc\r\n"
    "\r\n"
    ">lower\n"
    "acgtnnacgt\n"
    ">ambig\n"
    "ACGTRYSWKM\n"          # IUPAC ambiguity codes beyond ACGTN
    ">dotgap\n"
    "AC.GT\n"
)


def test_crlf_and_lowercase_normalized(tmp_path):
    p = tmp_path / "c.fa"
    p.write_bytes(CORPUS.encode())
    names, seqs = read_fasta(p)
    assert names == ["ref", "lower", "ambig", "dotgap"]
    assert seqs[0] == "ACGTACGTACGTGGCC"          # upper, \r stripped, joined
    assert seqs[1] == "ACGTNNACGT"
    assert seqs[2] == "ACGTRYSWKM"                # ambiguity codes preserved
    assert seqs[3] == "AC-GT"                     # '.' gap normalized to '-'
    assert not any("\r" in s for s in seqs)


def test_ambiguity_codes_encode_to_unknown(tmp_path):
    p = tmp_path / "c.fa"
    p.write_bytes(CORPUS.encode())
    _, seqs = read_fasta(p)
    codes = ab.DNA.encode(seqs[2])
    # R/Y/S/W/K/M are outside the DNA table -> unknown code (N), never a
    # silent pass-through of raw bytes
    assert (np.asarray(codes[4:]) == ab.DNA.unknown_code).all()


def test_iter_fasta_streams_from_filelike():
    recs = list(iter_fasta(io.StringIO(CORPUS)))
    assert [n for n, _ in recs] == ["ref", "lower", "ambig", "dotgap"]
    assert recs[0][1] == "ACGTACGTACGTGGCC"


def test_iter_fasta_matches_read_fasta(tmp_path):
    p = tmp_path / "c.fa"
    p.write_bytes(CORPUS.encode())
    names, seqs = read_fasta(p)
    assert list(iter_fasta(p)) == list(zip(names, seqs))


def test_sequence_before_header_rejected():
    with pytest.raises(ValueError, match="before the first"):
        list(iter_fasta(io.StringIO("ACGT\n>late\nACGT\n")))


def test_invalid_character_rejected():
    with pytest.raises(ValueError, match="invalid character"):
        list(iter_fasta(io.StringIO(">x\nAC4GT\n")))


def test_roundtrip_through_write(tmp_path):
    p = tmp_path / "w.fa"
    write_fasta(p, ["a", "b"], ["ACGT" * 50, "GG-CC"])
    names, seqs = read_fasta(p)
    assert names == ["a", "b"]
    assert seqs == ["ACGT" * 50, "GG-CC"]
