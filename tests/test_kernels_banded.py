"""Banded Pallas kernels vs the jnp band oracle: bit-identity on scores,
direction bytes, overflow flags, and traceback rows — plus the seeded
adversarial escape sweep for BOTH band implementations and the roofline
cost-model invariants the CI gate relies on."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.align import banded as banded_mod
from repro.core import alphabet as ab
from repro.core import pairwise as pw
from repro.kernels.banded.ops import banded_forward_pallas, banded_pairs_fused

RNG = np.random.default_rng(0)
SUB = ab.dna_matrix().astype(jnp.float32)


def _case(B, n, m, *, edge_lens=True):
    A = RNG.integers(0, 4, (B, n)).astype(np.int8)
    Bm = RNG.integers(0, 4, (B, m)).astype(np.int8)
    lens = np.stack([RNG.integers(0, n + 1, B),
                     RNG.integers(0, m + 1, B)], 1).astype(np.int32)
    if edge_lens:
        lens[0] = (0, m)             # empty query
        lens[min(1, B - 1)] = (n, 0)  # empty target
        lens[min(2, B - 1)] = (1, 1)  # length-1 pair
        lens[-1] = (n, m)            # full width
    return jnp.asarray(A), jnp.asarray(Bm), jnp.asarray(lens)


def _oracle_forward(a, b, lens, *, go, ge, band):
    return jax.vmap(
        lambda q, t, l: banded_mod.banded_forward(
            q, l[0], t, l[1], SUB, go, ge, band=band))(a, b, lens)


@pytest.mark.parametrize("B,n,m,W,block", [
    (3, 32, 32, 8, 16), (4, 64, 48, 16, 32), (2, 96, 128, 32, 96),
    (1, 40, 40, 84, 8),     # band >= 2*m+2: full coverage, odd block split
])
@pytest.mark.parametrize("go,ge", [(3, 1), (5, 2)])
def test_forward_kernel_bit_identical(B, n, m, W, block, go, ge):
    """Scores, end state, direction bytes, and the forward edge-pressure
    flag all match the jnp scan exactly — shared math, same bits."""
    a, b, lens = _case(B, n, m)
    ref = _oracle_forward(a, b, lens, go=go, ge=ge, band=W)
    got = banded_forward_pallas(a, b, lens, SUB, gap_open=go, gap_extend=ge,
                                band=W, block_rows=block)
    np.testing.assert_array_equal(np.asarray(ref.score), np.asarray(got.score))
    np.testing.assert_array_equal(np.asarray(ref.dirs), np.asarray(got.dirs))
    np.testing.assert_array_equal(np.asarray(ref.start_state),
                                  np.asarray(got.start_state))
    np.testing.assert_array_equal(np.asarray(ref.edge), np.asarray(got.edge))


@pytest.mark.parametrize("B,n,m,W", [
    (4, 32, 32, 8), (3, 64, 48, 16), (2, 48, 64, 132),
])
def test_fused_pairs_kernel_bit_identical(B, n, m, W):
    """The fused score+traceback kernel returns byte-identical aligned
    rows, lengths, and ok flags to forward + jnp traceback."""
    a, b, lens = _case(B, n, m)
    go, ge, gap = 3, 1, 5

    def one(q, t, l):
        fwd = banded_mod.banded_forward(q, l[0], t, l[1], SUB, go, ge, band=W)
        ar, br, k, ok = banded_mod.banded_traceback(q, t, fwd, gap, band=W)
        return fwd.score, ar, br, k, ok

    rscore, rar, rbr, rk, rok = jax.vmap(one)(a, b, lens)
    score, ar, br, k, ok = banded_pairs_fused(a, b, lens, SUB, gap_open=go,
                                              gap_extend=ge, band=W,
                                              gap_code=gap)
    np.testing.assert_array_equal(np.asarray(rscore), np.asarray(score))
    np.testing.assert_array_equal(np.asarray(rk), np.asarray(k))
    np.testing.assert_array_equal(np.asarray(rok), np.asarray(ok))
    np.testing.assert_array_equal(np.asarray(rar), np.asarray(ar))
    np.testing.assert_array_equal(np.asarray(rbr), np.asarray(br))


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_escape_sweep_both_band_implementations(seed):
    """Smoke-sized rerun of the adversarial sweep pinned in
    ``align/banded.py``'s docstring (3000 random unrelated 24-mers at
    band=8, zero silent escapes): every pair a band implementation does
    NOT flag must score exactly the full-DP optimum — checked for the
    jnp scan AND the Pallas kernels, which must also agree on the flags."""
    rng = np.random.default_rng(seed)
    B, n, W, go, ge = 100, 24, 8, 3, 1
    Q = jnp.asarray(rng.integers(0, 4, (B, n)).astype(np.int8))
    T = jnp.asarray(rng.integers(0, 4, (B, n)).astype(np.int8))
    lens = jnp.asarray(np.stack([rng.integers(1, n + 1, B),
                                 rng.integers(1, n + 1, B)], 1)
                       .astype(np.int32))

    full = jax.vmap(lambda q, t, l: pw.score_only(
        q, l[0], t, l[1], SUB, gap_open=go, gap_extend=ge))(Q, T, lens)

    def jnp_one(q, t, l):
        fwd = banded_mod.banded_forward(q, l[0], t, l[1], SUB, go, ge, band=W)
        _, _, _, ok = banded_mod.banded_traceback(q, t, fwd, 5, band=W)
        return fwd.score, ok

    jscore, jok = jax.vmap(jnp_one)(Q, T, lens)
    pscore, _, _, _, pok = banded_pairs_fused(Q, T, lens, SUB, gap_open=go,
                                              gap_extend=ge, band=W)
    for name, score, ok in (("jnp", jscore, jok), ("pallas", pscore, pok)):
        score, ok = np.asarray(score), np.asarray(ok)
        silent = ok & (score != np.asarray(full))
        assert not silent.any(), (name, np.flatnonzero(silent)[:5])
        assert (ok & (score == np.asarray(full))).sum() > 0, name
    np.testing.assert_array_equal(np.asarray(jok), np.asarray(pok))
    np.testing.assert_array_equal(np.asarray(jscore), np.asarray(pscore))


def test_cost_models_fused_beats_direction_matrix():
    """The analytic invariant behind BENCH_kernels: at every default
    bucket shape the fused pairs kernel moves fewer HBM bytes than the
    SW direction-matrix path, and banded dirs beat O(n·m) dirs."""
    import sys
    from pathlib import Path
    sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
    from benchmarks import roofline

    for B, n, m, W in [(64, 128, 128, 16), (64, 256, 256, 32),
                       (32, 512, 512, 64)]:
        sw = roofline.sw_forward_cost(B, n, m)
        banded = roofline.banded_forward_cost(B, n, m, W)
        fused = roofline.fused_pairs_cost(B, n, m, W)
        assert fused["hbm_bytes"] < banded["hbm_bytes"] < sw["hbm_bytes"]
        # the fused path has no O(n·band) dirs term at all: its traffic
        # stays linear in the sequences
        assert fused["hbm_bytes"] < 20 * B * (n + m)


def test_kernel_gate_passes_on_current_code():
    """The recorded BENCH_kernels baseline matches the code as committed:
    model rows reproduce and the invariant check is clean."""
    import sys
    from pathlib import Path
    sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
    from benchmarks import bench_kernels

    rows = bench_kernels.model_rows()
    assert bench_kernels.check_invariants(rows) == []
    assert bench_kernels.check_against_baseline(rows) == []
