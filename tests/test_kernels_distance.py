"""Distance MXU kernel vs jnp oracle across shapes/alphabets/blocks."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.distance import distance_matrix_pallas, match_valid_pallas
from repro.kernels.distance.ref import match_valid_ref

RNG = np.random.default_rng(1)


@pytest.mark.parametrize("N,M,L,C,gap,bn,bl", [
    (16, 16, 100, 5, 5, 16, 32),
    (65, 33, 130, 5, 5, 64, 64),
    (40, 40, 257, 21, 21, 32, 128),
    (128, 8, 64, 5, 5, 128, 64),
])
def test_match_valid_vs_oracle(N, M, L, C, gap, bn, bl):
    a = RNG.integers(0, C + 1, (N, L)).astype(np.int8)
    b = RNG.integers(0, C + 1, (M, L)).astype(np.int8)
    mk, vk = match_valid_pallas(jnp.asarray(a), jnp.asarray(b), n_chars=C,
                                gap_code=gap, bn=bn, bl=bl)
    mr, vr = match_valid_ref(jnp.asarray(a), jnp.asarray(b), n_chars=C,
                             gap_code=gap)
    np.testing.assert_allclose(np.asarray(mk), np.asarray(mr))
    np.testing.assert_allclose(np.asarray(vk), np.asarray(vr))


def test_distance_matrix_pallas_matches_core():
    from repro.core.distance import distance_matrix
    a = RNG.integers(0, 6, (30, 200)).astype(np.int8)
    dk = distance_matrix_pallas(jnp.asarray(a), n_chars=5, gap_code=5,
                                bn=32, bl=64)
    dr = distance_matrix(jnp.asarray(a), gap_code=5, n_chars=5)
    np.testing.assert_allclose(np.asarray(dk), np.asarray(dr), rtol=1e-5,
                               atol=1e-6)


def test_all_gap_rows_saturate():
    a = np.full((4, 64), 5, np.int8)  # all gaps
    d = distance_matrix_pallas(jnp.asarray(a), n_chars=5, gap_code=5,
                               bn=4, bl=64, correct=False)
    off_diag = np.asarray(d)[~np.eye(4, dtype=bool)]
    assert np.allclose(off_diag, 0.75)  # saturated p-distance
