"""Flash attention Pallas kernel vs jnp oracle: GQA/causal/SWA/dtype sweep."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_attention.ref import attention_ref

RNG = np.random.default_rng(2)


def _qkv(B, H, KH, S, D, dt):
    q = jnp.asarray(RNG.normal(0, 1, (B, H, S, D)), dt)
    k = jnp.asarray(RNG.normal(0, 1, (B, KH, S, D)), dt)
    v = jnp.asarray(RNG.normal(0, 1, (B, KH, S, D)), dt)
    return q, k, v


@pytest.mark.parametrize("B,H,KH,S,D,causal,window", [
    (2, 4, 2, 256, 64, True, 0),
    (1, 8, 1, 128, 32, True, 64),     # MQA + sliding window
    (2, 4, 4, 256, 64, False, 0),     # encoder
    (1, 2, 2, 512, 128, True, 128),
])
def test_flash_vs_ref_f32(B, H, KH, S, D, causal, window):
    q, k, v = _qkv(B, H, KH, S, D, jnp.float32)
    o = flash_attention(q, k, v, 1.0 / np.sqrt(D), causal, window, 64, 64, True)
    r = attention_ref(q, k, v, scale=1.0 / np.sqrt(D), causal=causal,
                      window=window)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=2e-5)


def test_flash_bf16():
    q, k, v = _qkv(2, 4, 2, 256, 64, jnp.bfloat16)
    o = flash_attention(q, k, v, 0.125, True, 0, 128, 128, True)
    r = attention_ref(q, k, v, scale=0.125, causal=True, window=0)
    err = np.max(np.abs(np.asarray(o, np.float32) - np.asarray(r, np.float32)))
    assert err < 2e-2


@pytest.mark.parametrize("bq,bk", [(32, 32), (64, 128), (128, 64)])
def test_block_shape_invariance(bq, bk):
    q, k, v = _qkv(1, 2, 2, 256, 32, jnp.float32)
    o = flash_attention(q, k, v, 0.2, True, 0, bq, bk, True)
    r = attention_ref(q, k, v, scale=0.2, causal=True, window=0)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=2e-5)


def test_gradients_match_ref():
    q, k, v = _qkv(1, 2, 2, 128, 32, jnp.float32)

    def f_kern(q, k, v):
        return jnp.sum(flash_attention(q, k, v, 0.17, True, 0, 64, 64, True) ** 2)

    def f_ref(q, k, v):
        return jnp.sum(attention_ref(q, k, v, scale=0.17, causal=True) ** 2)

    g1 = jax.grad(f_kern, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)
