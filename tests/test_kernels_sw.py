"""SW/Gotoh Pallas kernel vs jnp oracle: shape/dtype/param sweeps."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import alphabet as ab
from repro.core import pairwise as pw
from repro.kernels.sw.ops import gotoh_forward_pallas
from repro.kernels.sw.ref import boundary_row, gotoh_forward_ref

RNG = np.random.default_rng(0)


def _case(B, n, m, n_chars=4):
    A = RNG.integers(0, n_chars, (B, n)).astype(np.int8)
    Bm = RNG.integers(0, n_chars, (B, m)).astype(np.int8)
    lens = np.stack([RNG.integers(5, n + 1, B),
                     RNG.integers(5, m + 1, B)], 1).astype(np.int32)
    return jnp.asarray(A), jnp.asarray(Bm), jnp.asarray(lens)


@pytest.mark.parametrize("B,n,m,block", [
    (2, 32, 48, 16), (4, 64, 96, 32), (3, 128, 64, 128), (1, 96, 200, 32),
])
@pytest.mark.parametrize("local", [False, True])
def test_kernel_matches_oracle(B, n, m, block, local):
    a, b, lens = _case(B, n, m)
    sub = ab.dna_matrix().astype(jnp.float32)
    k = gotoh_forward_pallas(a, b, lens, sub, gap_open=3, gap_extend=1,
                             local=local, block_rows=block)
    dref, oref = gotoh_forward_ref(a, b, lens, sub, gap_open=3, gap_extend=1,
                                   local=local)
    np.testing.assert_allclose(np.asarray(k.score), np.asarray(oref[:, 0]))
    for i in range(B):
        la, lb = int(lens[i, 0]), int(lens[i, 1])
        dk = np.asarray(k.dirs[i])[: la + 1, : lb + 1]
        dr = np.concatenate([np.asarray(boundary_row(m, lb))[None],
                             np.asarray(dref[i])])[: la + 1, : lb + 1]
        assert (dk == dr).all()


@pytest.mark.parametrize("go,ge", [(2, 1), (11, 1), (5, 2)])
def test_gap_params(go, ge):
    a, b, lens = _case(2, 64, 64)
    sub = ab.dna_matrix(match=2, mismatch=-3).astype(jnp.float32)
    k = gotoh_forward_pallas(a, b, lens, sub, gap_open=go, gap_extend=ge,
                             local=False, block_rows=32)
    _, oref = gotoh_forward_ref(a, b, lens, sub, gap_open=go, gap_extend=ge,
                                local=False)
    np.testing.assert_allclose(np.asarray(k.score), np.asarray(oref[:, 0]))


def test_protein_blosum():
    a, b, lens = _case(2, 64, 64, n_chars=20)
    sub = ab.blosum62().astype(jnp.float32)
    k = gotoh_forward_pallas(a, b, lens, sub, gap_open=11, gap_extend=1,
                             local=True, block_rows=32)
    _, oref = gotoh_forward_ref(a, b, lens, sub, gap_open=11, gap_extend=1,
                                local=True)
    np.testing.assert_allclose(np.asarray(k.score), np.asarray(oref[:, 0]))


def test_traceback_through_kernel_dirs():
    a, b, lens = _case(3, 64, 64)
    sub = ab.dna_matrix().astype(jnp.float32)
    k = gotoh_forward_pallas(a, b, lens, sub, gap_open=3, gap_extend=1,
                             local=False, block_rows=32)
    for i in range(3):
        fwd = pw.ForwardResult(k.dirs[i], k.score[i], k.start_i[i],
                               k.start_j[i], k.start_state[i])
        ra, rb, kk = pw.traceback(a[i], b[i], fwd, ab.DNA.gap_code)
        dec = ab.DNA.decode(np.asarray(ra)[: int(kk)])
        assert dec.replace("-", "") == ab.DNA.decode(
            np.asarray(a[i])[: int(lens[i, 0])])
