"""Property tests hardening the k-mer seeding/chaining core.

``core.kmer_index`` is load-bearing twice over: the MSA stage's trie
replacement and ``repro.search``'s seed prefilter both stand on
``kmer_codes`` -> ``build_center_index`` -> ``chain_anchors``. These
tests pin the invariants both consumers assume:

  * every accepted anchor is a *true* k-mer match inside both true
    lengths, and the chain is strictly monotone and non-overlapping in
    both coordinates;
  * a pair chains >= 1 anchor iff the two sequences share any valid
    k-mer at all (the brute-force sensitivity oracle — no silent seed
    misses, no fabricated seeds);
  * ``ok`` is exactly the "every DP segment fits the budget" predicate,
    including the count==0 corner: a pair with no anchors is still ok
    when the whole [0,lq)x[0,lc) rectangle fits one full-DP segment
    (short queries, fragments below the k-mer width) — the driver's
    fallback would do exactly that DP anyway;
  * ``kmer_codes`` degenerate inputs: buffers shorter than k yield the
    empty code array (never a negative-size window), all-ambiguous
    windows are invalid, and valid codes equal the brute-force base-4
    encoding.
"""
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # CI image has no hypothesis; seeded fallback
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import alphabet as ab
from repro.core import kmer_index

K = 5
BIG_SEG = 1 << 20

DNA_SEQ = st.text(alphabet="ACGTN", min_size=0, max_size=80)


def _chain(q, c, *, k=K, max_seg=BIG_SEG, max_anchors=16):
    qe = jnp.asarray(ab.DNA.encode(q))
    ce = jnp.asarray(ab.DNA.encode(c))
    table = kmer_index.build_center_index(ce, jnp.int32(len(c)), k=k)
    a = kmer_index.chain_anchors(qe, jnp.int32(len(q)), table,
                                 jnp.int32(len(c)), k=k, stride=1,
                                 max_anchors=max_anchors, max_seg=max_seg)
    return (np.asarray(a.q_pos), np.asarray(a.c_pos),
            int(a.count), bool(a.ok))


def _valid_kmers(s, k=K):
    return {s[i: i + k] for i in range(len(s) - k + 1)
            if "N" not in s[i: i + k]}


@settings(max_examples=30, deadline=None)
@given(DNA_SEQ, DNA_SEQ)
def test_anchors_are_true_matches_and_strictly_monotone(q, c):
    qp, cp, cnt, _ = _chain(q, c)
    for i in range(cnt):
        # true k-mer match, fully inside both true lengths, unambiguous
        assert qp[i] + K <= len(q) and cp[i] + K <= len(c)
        window = q[qp[i]: qp[i] + K]
        assert window == c[cp[i]: cp[i] + K]
        assert "N" not in window
    for i in range(cnt - 1):
        # strictly monotone and non-overlapping in both coordinates
        assert qp[i + 1] >= qp[i] + K
        assert cp[i + 1] >= cp[i] + K


@settings(max_examples=30, deadline=None)
@given(DNA_SEQ, DNA_SEQ)
def test_sensitivity_oracle_anchor_iff_shared_kmer(q, c):
    # brute force: does any valid k-mer occur in both sequences?
    shared = bool(_valid_kmers(q) & _valid_kmers(c))
    _, _, cnt, _ = _chain(q, c)
    # the first shared window always chains from the empty chain (the
    # table stores first occurrences, min >= 0 exists), and every anchor
    # is a true match — so count >= 1 exactly when a shared k-mer exists
    assert (cnt >= 1) == shared


@settings(max_examples=25, deadline=None)
@given(st.text(alphabet="ACGT", min_size=2 * K, max_size=100),
       st.integers(0, 10**6))
def test_sensitivity_on_high_identity_pairs(base, seed):
    # sparse substitutions (one per 3k positions) leave intact shared
    # windows: a homologous pair above ~93% identity must always seed
    rng = np.random.default_rng(seed)
    q = list(base)
    for p in range(0, len(q), 3 * K):
        q[p] = "ACGT"[rng.integers(0, 4)]
    _, _, cnt, ok = _chain("".join(q), base)
    assert cnt >= 1
    assert ok      # unlimited budget: the pair never needs a fallback


@settings(max_examples=30, deadline=None)
@given(DNA_SEQ, DNA_SEQ, st.integers(1, 12))
def test_ok_is_exactly_the_segment_budget_predicate(q, c, max_seg):
    qp, cp, cnt, ok = _chain(q, c, max_seg=max_seg)
    q_end = c_end = 0
    for i in range(cnt):
        # accepted anchors can only close segments within the budget
        assert qp[i] - q_end <= max_seg and cp[i] - c_end <= max_seg
        q_end, c_end = qp[i] + K, cp[i] + K
    tail_within = (len(q) - q_end <= max_seg) and (len(c) - c_end <= max_seg)
    if cnt == 0:
        # no anchors: ok iff the whole rectangle is one in-budget DP
        # segment (with q_end == c_end == 0 that is the tail predicate)
        assert ok == (len(q) <= max_seg and len(c) <= max_seg)
    else:
        assert ok == tail_within


@settings(max_examples=30, deadline=None)
@given(DNA_SEQ, st.integers(2, 8))
def test_kmer_codes_match_bruteforce(s, k):
    codes = np.asarray(kmer_index.kmer_codes(
        jnp.asarray(ab.DNA.encode(s)), jnp.int32(len(s)), k))
    if len(s) < k:
        assert codes.shape == (0,)
        return
    assert codes.shape == (len(s) - k + 1,)
    enc = ab.DNA.encode(s)
    for i, code in enumerate(codes):
        window = enc[i: i + k]
        if np.all(window < 4):
            assert code == int(sum(int(b) * 4**j
                                   for j, b in enumerate(window)))
        else:
            assert code == -1


def test_kmer_codes_degenerate_inputs():
    # shorter than k (including empty): no window, empty code array
    for s in ("", "A", "ACG"):
        codes = kmer_index.kmer_codes(
            jnp.asarray(ab.DNA.encode(s)), jnp.int32(len(s)), 5)
        assert codes.shape == (0,)
    # all-ambiguous: every window invalid
    codes = kmer_index.kmer_codes(
        jnp.asarray(ab.DNA.encode("N" * 12)), jnp.int32(12), 5)
    assert codes.shape == (8,) and bool(np.all(np.asarray(codes) == -1))
    # length == k: exactly one (valid) window
    codes = kmer_index.kmer_codes(
        jnp.asarray(ab.DNA.encode("ACGTA")), jnp.int32(5), 5)
    assert codes.shape == (1,) and int(codes[0]) >= 0
    # padded buffer, short true length: windows past length-k are invalid
    codes = np.asarray(kmer_index.kmer_codes(
        jnp.asarray(ab.DNA.encode("ACGTACGT")), jnp.int32(6), 5))
    assert list(codes >= 0) == [True, True, False, False]


def test_short_query_chain_reports_ok_within_budget():
    # a query below the k-mer width chains zero anchors; the pair is
    # still ok when the whole rectangle fits one DP segment ...
    _, _, cnt, ok = _chain("ACG", "ACGTACGTACGT", max_seg=64)
    assert cnt == 0 and ok
    # ... and must flag fallback when it does not
    _, _, cnt, ok = _chain("ACG", "ACGTACGTACGT", max_seg=8)
    assert cnt == 0 and not ok


def test_kmer_msa_equals_plain_msa_on_fragment_families():
    # driver equivalence for the count==0-but-ok path: a family holding a
    # fragment below the k-mer width aligns bit-identically through the
    # k-mer assembly (which full-DPs the single segment) and the plain
    # full-DP path
    from repro.core.msa import MSAConfig, center_star_msa, decode_msa
    seqs = ["ACGTACGTACGTACGTACGT", "ACGTACGTACGAACGTACGT", "ACGTA",
            "CGT"]
    plain = center_star_msa(seqs, MSAConfig(method="plain"))
    kmer = center_star_msa(seqs, MSAConfig(method="kmer", k=11))
    assert decode_msa(plain.msa, MSAConfig(method="plain")) == \
        decode_msa(kmer.msa, MSAConfig(method="kmer", k=11))
