"""Per-arch smoke tests (reduced configs): forward shapes, no NaNs, train
convergence, cache continuity, SSD math."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALL_ARCHS, get_arch
from repro.models.mamba2 import ssd_chunked
from repro.models.transformer import apply_model, init_cache, init_params
from repro.train.optimizer import AdamWConfig
from repro.train.serve_step import greedy_generate
from repro.train.train_step import init_state, make_train_step

KEY = jax.random.PRNGKey(0)
B, S = 2, 32


def _batch(cfg, with_labels=False):
    batch = {}
    if cfg.embed_input:
        batch["tokens"] = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    else:
        batch["embeds"] = jax.random.normal(KEY, (B, S, cfg.d_model))
    if cfg.m_rope:
        pos = jnp.broadcast_to(jnp.arange(S), (B, S))
        batch["pos3"] = jnp.broadcast_to(pos[None], (3, B, S))
    if with_labels:
        batch["labels"] = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_shapes_no_nan(arch):
    cfg = get_arch(arch).smoke
    params = init_params(cfg, KEY)
    logits, _, aux = apply_model(params, cfg, _batch(cfg))
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not np.isnan(np.asarray(logits)).any()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ["gemma-2b", "jamba-1.5-large-398b",
                                  "mamba2-130m", "kimi-k2-1t-a32b",
                                  "hubert-xlarge"])
def test_train_loss_decreases(arch):
    cfg = get_arch(arch).smoke
    state = init_state(cfg, KEY)
    batch = _batch(cfg, with_labels=True)
    step = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-3), microbatches=2))
    losses = []
    for _ in range(5):
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]
    assert np.isfinite(losses).all()


@pytest.mark.parametrize("arch", ["gemma-2b", "mamba2-130m",
                                  "h2o-danube-3-4b"])
def test_prefill_decode_continuity(arch):
    cfg = get_arch(arch).smoke
    params = init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (B, 24), 0, cfg.vocab_size)
    full, _, _ = apply_model(params, cfg, {"tokens": toks},
                             compute_dtype=jnp.float32)
    cache = init_cache(cfg, B, 64, dtype=jnp.float32)
    _, cache, _ = apply_model(params, cfg, {"tokens": toks[:, :23]},
                              cache=cache, logits_mode="last",
                              compute_dtype=jnp.float32)
    pos = jnp.full((B, 1), 23, jnp.int32)
    dec, _, _ = apply_model(params, cfg, {"tokens": toks[:, 23:24],
                                          "positions": pos}, cache=cache,
                            logits_mode="last", compute_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full[:, -1]),
                               atol=2e-3)


def test_moe_continuity_without_drops():
    cfg = dataclasses.replace(get_arch("kimi-k2-1t-a32b").smoke,
                              capacity_factor=8.0)
    params = init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (B, 24), 0, cfg.vocab_size)
    full, _, _ = apply_model(params, cfg, {"tokens": toks},
                             compute_dtype=jnp.float32)
    cache = init_cache(cfg, B, 64, dtype=jnp.float32)
    _, cache, _ = apply_model(params, cfg, {"tokens": toks[:, :23]},
                              cache=cache, logits_mode="last",
                              compute_dtype=jnp.float32)
    pos = jnp.full((B, 1), 23, jnp.int32)
    dec, _, _ = apply_model(params, cfg, {"tokens": toks[:, 23:24],
                                          "positions": pos}, cache=cache,
                            logits_mode="last", compute_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full[:, -1]),
                               atol=2e-3)


def test_ssd_chunked_equals_recurrence():
    rng = np.random.default_rng(0)
    Bs, Sq, nh, hp, st = 2, 70, 3, 8, 16
    x = jnp.asarray(rng.normal(0, 1, (Bs, Sq, nh, hp)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.1, (Bs, Sq, nh)), jnp.float32)
    A = -jnp.asarray(rng.uniform(0.5, 2.0, (nh,)), jnp.float32)
    Bm = jnp.asarray(rng.normal(0, 1, (Bs, Sq, st)), jnp.float32)
    Cm = jnp.asarray(rng.normal(0, 1, (Bs, Sq, st)), jnp.float32)

    h = np.zeros((Bs, nh, hp, st))
    ys = []
    for t in range(Sq):
        g = np.exp(np.asarray(dt[:, t]) * np.asarray(A)[None])
        upd = np.einsum("bs,bh,bhp->bhps", np.asarray(Bm[:, t]),
                        np.asarray(dt[:, t]), np.asarray(x[:, t]))
        h = h * g[:, :, None, None] + upd
        ys.append(np.einsum("bs,bhps->bhp", np.asarray(Cm[:, t]), h))
    y_ref = np.stack(ys, 1)

    for chunk in (16, 128):
        y, hN = ssd_chunked(x, dt, A, Bm, Cm, chunk=chunk)
        np.testing.assert_allclose(np.asarray(y), y_ref, atol=1e-3)
        np.testing.assert_allclose(np.asarray(hN), h, atol=1e-3)


def test_sliding_window_attention_masks_far_tokens():
    # single layer: receptive field == window (it grows by W per layer)
    cfg = dataclasses.replace(get_arch("h2o-danube-3-4b").smoke, n_layers=1)
    params = init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (1, 64), 0, cfg.vocab_size)
    base, _, _ = apply_model(params, cfg, {"tokens": toks},
                             compute_dtype=jnp.float32)
    # perturbing a token > window before the end must not change last logits
    toks2 = toks.at[0, 8].set((toks[0, 8] + 1) % cfg.vocab_size)
    pert, _, _ = apply_model(params, cfg, {"tokens": toks2},
                             compute_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(base[0, -1]),
                               np.asarray(pert[0, -1]), atol=1e-5)


def test_generation_runs():
    cfg = get_arch("qwen1.5-0.5b").smoke
    params = init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (2, 16), 0, cfg.vocab_size)
    out = greedy_generate(cfg, params, toks, steps=4, max_len=64)
    assert out.shape == (2, 4)


def test_param_count_analytic_matches_actual():
    for arch in ["gemma-2b", "mamba2-130m", "kimi-k2-1t-a32b"]:
        cfg = get_arch(arch).smoke
        params = init_params(cfg, KEY)
        actual = sum(x.size for x in jax.tree.leaves(params))
        analytic = cfg.param_count()
        assert abs(actual - analytic) / actual < 0.05, (arch, actual, analytic)
