import dataclasses

import numpy as np
import pytest

from repro.core import alphabet as ab
from repro.core import centerstar
from repro.core.msa import MSAConfig, center_star_msa, decode_msa


@pytest.mark.parametrize("method", ["plain", "kmer"])
def test_msa_recovers_sequences(dna_family, method):
    cfg = MSAConfig(method=method, k=8, max_anchors=96, max_seg=48)
    res = center_star_msa(dna_family, cfg)
    rows = decode_msa(res.msa, cfg)
    assert len({len(r) for r in rows}) == 1
    for s, r in zip(dna_family, rows):
        assert r.replace("-", "") == s


def test_kmer_equals_plain_quality(dna_family):
    from repro.core.sp_score import avg_sp
    import jax.numpy as jnp
    gap, nch = ab.DNA.gap_code, ab.DNA.n_chars
    sp_p = float(avg_sp(jnp.asarray(center_star_msa(
        dna_family, MSAConfig(method="plain")).msa), gap_code=gap, n_chars=nch))
    sp_k = float(avg_sp(jnp.asarray(center_star_msa(
        dna_family, MSAConfig(method="kmer", k=8, max_anchors=96,
                              max_seg=48)).msa), gap_code=gap, n_chars=nch))
    # anchored path must stay within 15% of full-DP quality (lower=better)
    assert sp_k <= sp_p * 1.15 + 1.0


def test_protein_sw(dna_family):
    prots = ["MKTAYIAKQRQISFVKSHFSRQLEERLGLIEVQ",
             "MKTAYIAKQRQISFVKSHFSRQLEERLGLIEV",
             "MKTAYIAQQRQISFVKSHFSRQLEERLGLIEVQA"]
    cfg = MSAConfig(method="sw", alphabet="protein", gap_open=11, gap_extend=1)
    res = center_star_msa(prots, cfg)
    for s, r in zip(prots, decode_msa(res.msa, cfg)):
        assert r.replace("-", "") == s


def test_center_selection_sampled(dna_family):
    cfg = MSAConfig(method="kmer", center="sampled", k=8)
    res = center_star_msa(dna_family, cfg)
    assert 0 <= res.center_idx < len(dna_family)


def test_identical_sequences_align_trivially():
    seqs = ["ACGTACGTAA"] * 5
    res = center_star_msa(seqs, MSAConfig(method="plain"))
    assert res.width == 10
    assert (res.msa == res.msa[0]).all()


def test_progressive_baseline_valid_and_better_on_diverged():
    import jax.numpy as jnp
    from repro.core.progressive import progressive_msa
    from repro.core.sp_score import avg_sp
    from repro.data import SimConfig, simulate_family
    fam = simulate_family(SimConfig(n_leaves=8, root_len=250, branch_sub=0.06,
                                    branch_indel=0.004, seed=5))
    cfg = MSAConfig(method="plain")
    prog = progressive_msa(fam.seqs, cfg)
    rows = decode_msa(prog.msa, cfg)
    for s, r in zip(fam.seqs, rows):
        assert r.replace("-", "") == s
    gap, nch = ab.DNA.gap_code, ab.DNA.n_chars
    sp_prog = float(avg_sp(jnp.asarray(prog.msa), gap_code=gap, n_chars=nch))
    sp_cs = float(avg_sp(jnp.asarray(center_star_msa(fam.seqs, cfg).msa),
                         gap_code=gap, n_chars=nch))
    # the paper's Table 2-4 relationship: progressive class >= center star
    # on diverged families (lower penalty is better)
    assert sp_prog <= sp_cs * 1.02


def test_drop_dead_columns():
    gap = ab.DNA.gap_code
    msa = np.array([[0, gap, 1], [2, gap, 3]], np.int8)
    out = centerstar.drop_dead_columns(msa, gap)
    assert out.shape == (2, 2)
