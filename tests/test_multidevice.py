"""Real multi-device checks via subprocess (8 forced host devices):
distributed MSA == single-device result; sharded train step; elastic
restore across mesh shapes. Kept in a subprocess so the main pytest process
stays at the true device count."""
import json
import subprocess
import sys
from pathlib import Path

SCRIPT = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

sys_path = %r
import sys
sys.path.insert(0, sys_path)

from repro.core import alphabet as ab, kmer_index
from repro.core.msa import MSAConfig, center_star_msa
from repro.dist import mapreduce, sharding as sh
from repro.launch.mesh import make_local_mesh

assert jax.device_count() == 8

out = {}
# ---- distributed MSA on 4x2 mesh == host result
rng = np.random.default_rng(0)
base = "".join(rng.choice(list("ACGT"), 256))
def mut(s):
    s = list(s)
    for _ in range(4):
        i = rng.integers(0, len(s)); s[i] = "ACGT"[rng.integers(0, 4)]
    return "".join(s)
seqs = [mut(base) for _ in range(16)]
S, lens = ab.encode_batch(seqs, ab.DNA)
center = jnp.asarray(ab.DNA.encode(base)); lc = jnp.int32(len(base))
table = kmer_index.build_center_index(center, lc, k=8)
sub = ab.dna_matrix().astype(jnp.float32)
mesh = make_local_mesh((4, 2), ("data", "model"))
fn = mapreduce.distributed_center_star(
    mesh, method="kmer", sub=sub, gap_code=ab.DNA.gap_code, out_len=300,
    num_slots=int(center.shape[0]) + 1, gap_open=3, gap_extend=1, k=8,
    max_anchors=64, max_seg=48)
rows, G = fn(sh.shard_rows(S, mesh), sh.shard_rows(lens, mesh),
             sh.broadcast(center, mesh), lc, sh.broadcast(table, mesh))
ok = all(ab.DNA.decode(r).replace("-", "") == s
         for s, r in zip(seqs, np.asarray(rows)))
out["msa_distributed_ok"] = bool(ok)
out["msa_sharding"] = str(rows.sharding.spec)

# ---- sharded train step on 4x2 mesh (FSDP x TP), smoke config
from repro.configs import get_arch
from repro.models import sharding_plan as sp
from repro.models.transformer import init_params
from repro.train.train_step import init_state, make_train_step
from repro.train.optimizer import AdamWConfig
import functools

cfg = get_arch("llama3.2-1b").smoke
key = jax.random.PRNGKey(0)
state_shape = jax.eval_shape(functools.partial(init_state, cfg), key)
pspecs = sp.params_pspecs(state_shape.params, mesh)
psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                   is_leaf=lambda x: isinstance(x, P))
from repro.train.train_step import TrainState
from repro.train import optimizer as opt
state_sh = TrainState(params=psh,
                      opt=opt.OptState(m=psh, v=psh, count=NamedSharding(mesh, P())),
                      step=NamedSharding(mesh, P()))
shard_fns = sp.make_shard_fns(cfg, mesh, 8)
fn2 = make_train_step(cfg, AdamWConfig(lr=1e-3), microbatches=2,
                      shard_fns=shard_fns)
jitted = jax.jit(fn2, in_shardings=(state_sh, None), out_shardings=(state_sh, None))
state = init_state(cfg, key)
state = jax.device_put(state, state_sh)
batch = {"tokens": jax.random.randint(key, (8, 32), 0, cfg.vocab_size),
         "labels": jax.random.randint(key, (8, 32), 0, cfg.vocab_size)}
losses = []
for _ in range(3):
    state, m = jitted(state, batch)
    losses.append(float(m["loss"]))
out["train_losses"] = losses
out["train_ok"] = bool(losses[-1] < losses[0])

# ---- elastic: save on 4x2, restore on 8x1
import tempfile
from repro.dist.checkpoint import CheckpointManager
with tempfile.TemporaryDirectory() as d:
    cm = CheckpointManager(d)
    cm.save(1, state.params, block=True)
    mesh2 = make_local_mesh((8, 1), ("data", "model"))
    pspecs2 = sp.params_pspecs(state_shape.params, mesh2)
    psh2 = jax.tree.map(lambda s: NamedSharding(mesh2, s), pspecs2,
                        is_leaf=lambda x: isinstance(x, P))
    restored, _ = cm.restore(jax.tree.map(
        lambda x: jnp.zeros(x.shape, x.dtype), state.params), shardings=psh2)
    w_old = np.asarray(jax.tree.leaves(state.params)[0])
    w_new = np.asarray(jax.tree.leaves(restored)[0])
    out["elastic_ok"] = bool(np.allclose(w_old, w_new))

print("RESULT " + json.dumps(out))
'''


def test_multidevice_subprocess():
    src = str(Path(__file__).resolve().parent.parent / "src")
    proc = subprocess.run([sys.executable, "-c", SCRIPT % src],
                          capture_output=True, text=True, timeout=560)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")][-1]
    out = json.loads(line[len("RESULT "):])
    assert out["msa_distributed_ok"]
    assert out["train_ok"], out["train_losses"]
    assert out["elastic_ok"]
