"""Executable spec of repro.obs (ISSUE 8): metrics registry semantics,
span nesting + trace-ID propagation, launcher trace coverage, the
coalescer's failed-batch accounting, and graceful drain with in-flight
HTTP requests (metrics must reconcile: started == finished + rejected).
"""
import json
import threading
import time
import urllib.error
import urllib.request

import numpy as np
import pytest

from repro.obs import REGISTRY, TRACER, chrome_coverage, disabled
from repro.obs import trace as obs_trace
from repro.obs.metrics import MetricsRegistry, parse_exposition
from repro.serve import AlignJob, CoalescingAligner, MSAService, \
    ServiceConfig, serve_http


def _total(name: str) -> float:
    """Sum of a counter/gauge family's samples in the global registry."""
    snap = REGISTRY.snapshot()
    return sum(s["value"]
               for s in snap.get(name, {"samples": []})["samples"])


# ------------------------------------------------------------------ metrics

def test_counter_gauge_render_parse_roundtrip():
    reg = MetricsRegistry()
    c = reg.counter("t_requests_total", "requests", ("endpoint",))
    c.labels(endpoint="align").inc()
    c.labels(endpoint="align").inc(2)
    c.labels(endpoint="tree").inc()
    g = reg.gauge("t_active", "in flight")
    g.set(3)
    g.dec()
    text = reg.render()
    fams = parse_exposition(text)
    assert fams["t_requests_total"]["type"] == "counter"
    by_ep = {s["labels"]["endpoint"]: s["value"]
             for s in fams["t_requests_total"]["samples"]}
    assert by_ep == {"align": 3.0, "tree": 1.0}
    (g_sample,) = fams["t_active"]["samples"]
    assert g_sample["value"] == 2.0


def test_histogram_buckets_cumulative_in_exposition():
    reg = MetricsRegistry()
    h = reg.histogram("t_seconds", "latency", buckets=(0.1, 1.0, 10.0))
    for v in (0.05, 0.5, 0.5, 5.0, 50.0):
        h.observe(v)
    fams = parse_exposition(reg.render())
    series = {(s["series"], s["labels"].get("le")): s["value"]
              for s in fams["t_seconds"]["samples"]}
    assert series[("t_seconds_bucket", "0.1")] == 1
    assert series[("t_seconds_bucket", "1")] == 3       # cumulative
    assert series[("t_seconds_bucket", "10")] == 4
    assert series[("t_seconds_bucket", "+Inf")] == 5
    assert series[("t_seconds_count", None)] == 5
    assert series[("t_seconds_sum", None)] == pytest.approx(56.05)
    # the snapshot view folds the same numbers into a dict
    (snap,) = reg.snapshot()["t_seconds"]["samples"]
    assert snap["count"] == 5 and snap["buckets"]["1"] == 3


def test_family_schema_conflicts_raise():
    reg = MetricsRegistry()
    reg.counter("t_x", "a", ("k",))
    with pytest.raises(ValueError, match="already registered"):
        reg.gauge("t_x")
    with pytest.raises(ValueError, match="already registered"):
        reg.counter("t_x", "a", ("other",))
    with pytest.raises(ValueError, match="labels"):
        reg.counter("t_x", "a", ("k",)).labels(wrong="v")


def test_parse_exposition_rejects_malformed():
    with pytest.raises(ValueError):
        parse_exposition('t_x{k="v" 1\n')          # unbalanced braces
    with pytest.raises(ValueError):
        parse_exposition("t_x\n")                  # missing value


def test_disabled_makes_writes_noops():
    reg = MetricsRegistry()
    c = reg.counter("t_c", "c")
    before = len(TRACER.spans())
    with disabled():
        # the global switch only covers the global registry; flip this
        # private one by hand to exercise the same path
        reg.enabled = False
        c.inc()
        reg.enabled = True
        with obs_trace.span("t_invisible") as sp:
            assert sp is None
    assert c.value == 0
    assert len(TRACER.spans()) == before
    assert all(r.name != "t_invisible" for r in TRACER.spans())


# ------------------------------------------------------------------- spans

def test_span_nesting_parent_ids_and_trace_id():
    with obs_trace.request_trace("cafe0123deadbeef") as tid:
        assert tid == "cafe0123deadbeef"
        with obs_trace.span("t_outer", n=1) as outer:
            with obs_trace.span("t_inner") as inner:
                pass
    assert outer.parent_id is None
    assert inner.parent_id == outer.span_id
    assert outer.trace_id == inner.trace_id == "cafe0123deadbeef"
    assert obs_trace.current_trace_id() is None     # restored on exit
    assert inner.duration >= 0
    # every closed span feeds the repro_span_seconds histogram
    snap = REGISTRY.snapshot()["repro_span_seconds"]["samples"]
    assert any(s["labels"]["name"] == "t_inner" for s in snap)


def test_chrome_trace_events_and_coverage():
    with obs_trace.span("t_root"):
        with obs_trace.span("t_kid_a"):
            time.sleep(0.01)
        with obs_trace.span("t_kid_b"):
            time.sleep(0.01)
    trace_obj = TRACER.chrome_trace()
    cov, kids = chrome_coverage(trace_obj, "t_root")
    assert {"t_kid_a", "t_kid_b"} <= kids
    assert 0.5 < cov <= 1.0 + 1e-6
    ev = next(e for e in trace_obj["traceEvents"] if e["name"] == "t_kid_a")
    assert ev["ph"] == "X" and ev["dur"] >= 10_000 * 0.5   # us
    assert "parent_id" in ev["args"]


def test_runtime_sample_sets_rss_gauge():
    from repro.obs import runtime
    runtime.sample(force=True)
    assert _total("repro_host_peak_rss_bytes") > 1 << 20


# ------------------------------------------- launcher trace (acceptance)

def test_msa_run_trace_covers_wallclock_with_named_stages(tmp_path):
    """ISSUE 8 acceptance: msa_run --trace-out on the phi_dna fixture
    produces a Chrome trace whose root span is >= 95% covered by named
    stages (load -> center -> map1 -> assemble -> tree)."""
    from repro.data.datasets import phi_dna
    from repro.launch import msa_run

    fam = phi_dna(scale=1)
    fasta = tmp_path / "phi.fa"
    fasta.write_text("".join(f">{n}\n{s}\n"
                             for n, s in zip(fam.names, fam.seqs)))
    trace_path = tmp_path / "trace.json"
    metrics_path = tmp_path / "metrics.json"
    msa_run.main(["--fasta", str(fasta), "--out", str(tmp_path / "out"),
                  "--tree", "nj",
                  "--trace-out", str(trace_path),
                  "--metrics-out", str(metrics_path)])

    trace_obj = json.loads(trace_path.read_text())
    cov, kids = chrome_coverage(trace_obj, "msa_run")
    assert {"load", "center", "map1", "assemble", "tree"} <= kids
    assert cov >= 0.95, f"span tree covers only {cov:.1%} of msa_run"

    snap = json.loads(metrics_path.read_text())
    assert "repro_align_calls_total" in snap
    assert "repro_tree_builds_total" in snap
    assert snap["repro_span_seconds"]["type"] == "histogram"


# ------------------------------------------------- coalescer failure path

def test_failed_batch_fails_futures_and_counts():
    """ISSUE 8 satellite: an engine failure inside _run_batch must fail
    every affected future AND show up in stats + obs counters (this path
    was previously `except BaseException: pragma: no cover`)."""
    class BoomEngine:
        gap_code = 5

        def align_pairs(self, *a, **k):
            raise RuntimeError("boom")

    b0 = _total("repro_failed_batches_total")
    p0 = _total("repro_failed_pairs_total")
    co = CoalescingAligner(max_batch=2, max_wait_ms=1.0)
    job = AlignJob(Q=np.zeros((2, 8), np.int8),
                   qlens=np.full(2, 8, np.int32),
                   target=np.zeros(8, np.int8), tlen=8,
                   engine=BoomEngine(), engine_key="x")
    fut = co.submit(job)
    with pytest.raises(RuntimeError, match="boom"):
        fut.result(timeout=30)
    co.close()
    st = co.stats()
    assert st["failed_batches"] == 1
    assert st["failed_pairs"] == 2
    assert st["in_flight"] == 0
    assert _total("repro_failed_batches_total") - b0 == 1
    assert _total("repro_failed_pairs_total") - p0 == 2


# ------------------------------------------------------- service + HTTP

def test_stats_snapshot_is_one_combined_view():
    svc = MSAService(ServiceConfig(max_wait_ms=1.0))
    snap = svc.stats_snapshot()
    assert set(snap) == {"cache", "queue"}
    assert "failed_batches" in snap["queue"]
    assert "in_flight" in snap["queue"]
    assert {"hits", "misses", "bytes"} <= set(snap["cache"])
    h = svc.healthz()
    assert h["active_requests"] == 0
    assert h["queue"]["failed_pairs"] == 0
    svc.drain()


def _post(port, path, obj, timeout=60):
    req = urllib.request.Request(
        f"http://127.0.0.1:{port}{path}",
        data=json.dumps(obj).encode(),
        headers={"Content-Type": "application/json"})
    try:
        with urllib.request.urlopen(req, timeout=timeout) as r:
            return r.status, json.loads(r.read())
    except urllib.error.HTTPError as e:
        return e.code, json.loads(e.read())


def test_metrics_and_statusz_endpoints():
    svc = MSAService(ServiceConfig(max_wait_ms=1.0))
    httpd = serve_http(svc, "127.0.0.1", 0)
    port = httpd.server_address[1]
    threading.Thread(target=httpd.serve_forever, daemon=True).start()
    try:
        st, resp = _post(port, "/align",
                         {"sequences": ["ACGTACGTAA", "ACGTACGAAA"]})
        assert st == 200
        assert len(resp["trace_id"]) == 16      # every response carries one
        text = urllib.request.urlopen(
            f"http://127.0.0.1:{port}/metrics", timeout=30).read().decode()
        fams = parse_exposition(text)           # must parse cleanly
        for required in ("repro_requests_started_total",
                         "repro_request_seconds",
                         "repro_align_calls_total",
                         "repro_span_seconds"):
            assert required in fams, required
        statusz = urllib.request.urlopen(
            f"http://127.0.0.1:{port}/statusz", timeout=30).read().decode()
        assert "active_requests" in statusz
        assert "serve.align" in statusz         # recent root spans listed
    finally:
        httpd.shutdown()
        httpd.server_close()
        svc.drain()


def test_http_drain_waits_for_inflight_then_rejects_with_503():
    """ISSUE 8 satellite: drain with in-flight /tree and /search requests
    completes them, post-drain requests get a clean 503, and the request
    counters reconcile (started == finished + rejected)."""
    svc = MSAService(ServiceConfig(max_wait_ms=1.0))
    entered = {"tree": threading.Event(), "search": threading.Event()}
    release = {"tree": threading.Event(), "search": threading.Event()}

    def gated(kind, payload):
        def impl(*a, **k):
            entered[kind].set()
            assert release[kind].wait(30)
            return dict(payload)
        return impl

    svc._tree_impl = gated("tree", {"newick": "(a,b);"})
    svc._search_impl = gated("search", {"queries": [], "stats": {}})

    s0 = _total("repro_requests_started_total")
    f0 = _total("repro_requests_finished_total")
    r0 = _total("repro_requests_rejected_total")

    httpd = serve_http(svc, "127.0.0.1", 0)
    port = httpd.server_address[1]
    threading.Thread(target=httpd.serve_forever, daemon=True).start()
    results = {}

    def client(key, path, obj):
        results[key] = _post(port, path, obj)

    threads = [
        threading.Thread(target=client,
                         args=("tree", "/tree",
                               {"sequences": ["ACGT", "ACGA", "AGGT"]})),
        threading.Thread(target=client,
                         args=("search", "/search",
                               {"sequences": ["ACGTACGT"]})),
    ]
    for t in threads:
        t.start()
    assert entered["tree"].wait(30) and entered["search"].wait(30)

    drain_done = {}
    drainer = threading.Thread(
        target=lambda: drain_done.update(ok=svc.drain(timeout=60)))
    drainer.start()
    time.sleep(0.3)
    assert drainer.is_alive(), "drain returned with requests in flight"
    assert _total("repro_requests_active") == 2

    client("late", "/align", {"sequences": ["ACGT", "ACGA"]})
    assert results["late"][0] == 503
    assert "draining" in results["late"][1]["error"]

    for ev in release.values():
        ev.set()
    for t in threads:
        t.join(30)
    drainer.join(30)
    assert drain_done.get("ok") is True
    assert results["tree"][0] == 200
    assert results["tree"][1]["newick"] == "(a,b);"
    assert results["tree"][1]["trace_id"]
    assert results["search"][0] == 200

    httpd.shutdown()
    httpd.server_close()

    started = _total("repro_requests_started_total") - s0
    finished = _total("repro_requests_finished_total") - f0
    rejected = _total("repro_requests_rejected_total") - r0
    assert started == 3 and finished == 2 and rejected == 1
    assert started == finished + rejected
    assert _total("repro_requests_active") == 0
