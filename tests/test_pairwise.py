import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import alphabet as ab
from repro.core import pairwise as pw

DNA = ab.DNA
SUB = ab.dna_matrix().astype(jnp.float32)


def align(s1, s2, local=False, go=3, ge=1):
    a = jnp.asarray(DNA.encode(s1))
    b = jnp.asarray(DNA.encode(s2))
    r = pw.align_pair(a, jnp.int32(len(s1)), b, jnp.int32(len(s2)), SUB,
                      gap_open=go, gap_extend=ge, local=local,
                      gap_code=DNA.gap_code)
    k = int(r.aln_len)
    return (float(r.score), DNA.decode(np.asarray(r.a_row)[:k]),
            DNA.decode(np.asarray(r.b_row)[:k]))


def test_identical():
    s, ra, rb = align("ACGTACGT", "ACGTACGT")
    assert s == 16 and ra == rb == "ACGTACGT"


def test_single_mismatch():
    s, ra, rb = align("ACGT", "AGGT")
    assert s == 5 and "-" not in ra


def test_single_deletion_affine():
    s, ra, rb = align("ACGTACGT", "ACGACGT")
    assert s == 11
    assert ra.replace("-", "") == "ACGTACGT"
    assert rb.replace("-", "") == "ACGACGT"
    assert rb.count("-") == 1


def test_affine_gap_cheaper_than_two_opens():
    # 2-length gap costs go+ge = 4, not 2*go = 6
    s, _, _ = align("AACCGGTT", "AAGGTT")
    assert s == 6 * 2 - 4


def test_local_extracts_island():
    s, ra, rb = align("TTTTACGTACGTTTTT", "CCCCACGTACGCCC", local=True)
    assert ra == rb == "ACGTACG" and s == 14


def test_score_symmetry():
    s1, _, _ = align("ACGTTGCA", "ACGTGCA")
    s2, _, _ = align("ACGTGCA", "ACGTTGCA")
    assert s1 == s2


def test_batched_matches_single(dna_family):
    seqs = dna_family[:4]
    A, lens = ab.encode_batch(seqs, DNA)
    b = jnp.asarray(DNA.encode(seqs[0]))
    res = pw.align_many_to_one(A, lens, b, jnp.int32(len(seqs[0])), SUB,
                               gap_open=3, gap_extend=1, gap_code=DNA.gap_code)
    for i, s in enumerate(seqs):
        single = pw.align_pair(A[i], lens[i], b, jnp.int32(len(seqs[0])), SUB,
                               gap_open=3, gap_extend=1, gap_code=DNA.gap_code)
        assert float(res.score[i]) == float(single.score)


def test_gap_removal_recovers_inputs(dna_family):
    for s in dna_family[1:3]:
        sc, ra, rb = align(dna_family[0], s)
        assert ra.replace("-", "") == dna_family[0]
        assert rb.replace("-", "") == s


def test_empty_vs_full():
    # aligning to a 2-char sequence: all-gap costs
    s, ra, rb = align("ACGT", "AC")
    assert ra.replace("-", "") == "ACGT" and rb.replace("-", "") == "AC"
