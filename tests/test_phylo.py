import jax.numpy as jnp
import numpy as np

from repro.core import alphabet as ab
from repro.core import cluster, distance, likelihood, nj, treeio
from repro.core.msa import MSAConfig, center_star_msa
from repro.data import SimConfig, simulate_family


class _T:
    def __init__(self, children, root):
        self.children, self.root = children, root


def _reconstruct(n_leaves=12, seed=3):
    fam = simulate_family(SimConfig(n_leaves=n_leaves, root_len=500,
                                    branch_sub=0.02, branch_indel=0.001,
                                    seed=seed))
    res = center_star_msa(fam.seqs, MSAConfig(method="kmer", k=10,
                                              max_anchors=128, max_seg=48))
    return fam, jnp.asarray(res.msa)


def test_nj_recovers_topology():
    fam, msa = _reconstruct()
    D = distance.distance_matrix(msa, gap_code=ab.DNA.gap_code,
                                 n_chars=ab.DNA.n_chars)
    tree = nj.neighbor_joining(D, 12)
    rf = treeio.normalized_rf(
        _T(np.asarray(tree.children), int(tree.root)),
        _T(fam.children, fam.root), 12)
    assert rf <= 0.35


def test_distance_matrix_properties():
    _, msa = _reconstruct(8, seed=5)
    D = np.asarray(distance.distance_matrix(msa, gap_code=ab.DNA.gap_code,
                                            n_chars=ab.DNA.n_chars))
    assert np.allclose(D, D.T)
    assert np.allclose(np.diag(D), 0)
    assert (D >= 0).all()


def test_likelihood_finite_and_negative():
    fam, msa = _reconstruct(8, seed=7)
    D = distance.distance_matrix(msa, gap_code=ab.DNA.gap_code,
                                 n_chars=ab.DNA.n_chars)
    tree = nj.neighbor_joining(D, 8)
    ll = float(likelihood.log_likelihood(msa, tree.children, tree.blen,
                                         tree.root, gap_code=ab.DNA.gap_code))
    assert np.isfinite(ll) and ll < 0


def test_better_tree_higher_likelihood():
    """The NJ tree should beat a random topology in likelihood."""
    fam, msa = _reconstruct(10, seed=11)
    gap = ab.DNA.gap_code
    D = distance.distance_matrix(msa, gap_code=gap, n_chars=ab.DNA.n_chars)
    good = nj.neighbor_joining(D, 10)
    ll_good = float(likelihood.log_likelihood(msa, good.children, good.blen,
                                              good.root, gap_code=gap))
    # random tree: NJ on shuffled distances
    rng = np.random.default_rng(0)
    perm = rng.permutation(10)
    Dbad = np.asarray(D)[np.ix_(perm, perm)]
    # relabel leaves so the tree is over the wrong taxa
    bad = nj.neighbor_joining(jnp.asarray(Dbad), 10)
    ll_bad = float(likelihood.log_likelihood(msa, bad.children, bad.blen,
                                             bad.root, gap_code=gap))
    assert ll_good >= ll_bad


def test_cluster_phylogeny_runs_and_covers_all_leaves():
    fam, msa = _reconstruct(48, seed=13)
    cp = cluster.cluster_phylogeny(np.asarray(msa), gap_code=ab.DNA.gap_code,
                                   n_chars=ab.DNA.n_chars,
                                   cfg=cluster.ClusterConfig(target_cluster=12,
                                                             seed=1))
    sets = treeio.leaf_sets(cp.children, cp.root, 48)
    assert sets[cp.root] == frozenset(range(48))
    nwk = treeio.to_newick(cp.children, cp.blen, cp.root, fam.names)
    assert nwk.count("seq") == 48


def test_newick_deep_caterpillar_no_recursion_error():
    """to_newick on a 5000-leaf caterpillar — the recursive writer died at
    ~1000 leaves (Python recursion limit); the iterative one must not."""
    n = 5000
    children = np.full((2 * n - 1, 2), -1, np.int32)
    blen = np.full((2 * n - 1, 2), 0.5, np.float32)
    children[n] = (0, 1)
    for i in range(1, n - 1):
        children[n + i] = (n + i - 1, i + 1)
    root = 2 * n - 2
    nwk = treeio.to_newick(children, blen, root)
    assert nwk.count(",") == n - 1
    assert nwk.count("(") == nwk.count(")") == n - 1
    assert nwk.endswith(";")


def test_stitch_deep_caterpillar_no_recursion_error():
    """stitch_cluster_trees on a 3000-leaf caterpillar cluster subtree —
    the recursive copier died at ~1000 leaves like to_newick did."""
    n0 = 3000
    ch = np.full((2 * n0 - 1, 2), -1, np.int32)
    bl = np.full((2 * n0 - 1, 2), 0.5, np.float32)
    ch[n0] = (0, 1)
    for i in range(1, n0 - 1):
        ch[n0 + i] = (n0 + i - 1, i + 1)
    skel_ch = np.array([[-1, -1], [-1, -1], [0, 1]], np.int32)
    skel_bl = np.zeros((3, 2), np.float32)
    children, blen, root = treeio.stitch_cluster_trees(
        skel_ch, skel_bl, 2,
        [(ch, bl, 2 * n0 - 2, n0), (ch[:1], bl[:1], 0, 1)],
        [np.arange(n0), np.asarray([n0])])
    sets = treeio.leaf_sets(children, root, n0 + 1)
    assert sets[root] == frozenset(range(n0 + 1))


def test_newick_roundtrip_structure():
    fam, msa = _reconstruct(6, seed=17)
    D = distance.distance_matrix(msa, gap_code=ab.DNA.gap_code,
                                 n_chars=ab.DNA.n_chars)
    tree = nj.neighbor_joining(D, 6)
    nwk = treeio.to_newick(tree.children, tree.blen, int(tree.root),
                           fam.names)
    assert nwk.endswith(";") and nwk.count("(") == nwk.count(")")
