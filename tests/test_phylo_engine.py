"""repro.phylo: tiled distance parity, streamed medoids, the HPTree
pipeline's memory bound + dense equivalence, the TreeEngine registry, the
mesh strip hook, and the tree_run launcher at N=2000."""
import json

import jax.numpy as jnp
import numpy as np

from repro.core import alphabet as ab
from repro.core import cluster, distance, treeio
from repro.data import SimConfig, simulate_family
from repro.launch import tree_run
from repro.phylo import (TileAccountant, TileContext, TreeEngine,
                         resolve_tree_backend, tiled_phylogeny)

GAP, NCH = ab.DNA.gap_code, ab.DNA.n_chars


def _ctx(**kw):
    return TileContext(gap_code=GAP, n_chars=NCH, **kw)


def _rand_msa(n, L, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, GAP + 1, (n, L)).astype(np.int8)  # incl. gaps


def _aligned_family(n, L=300, sub=0.03, seed=0):
    """Substitution-only family: equal-length rows == already aligned."""
    fam = simulate_family(SimConfig(n_leaves=n, root_len=L, branch_sub=sub,
                                    branch_indel=0.0, seed=seed))
    S, _ = ab.encode_batch(fam.seqs, ab.DNA)
    return fam, np.asarray(S)


def _dense(msa, correct=True):
    return np.asarray(distance.distance_matrix(
        jnp.asarray(msa), gap_code=GAP, n_chars=NCH, correct=correct))


# ----------------------------------------------------------------- tiles


def test_tiled_full_matches_dense_exactly():
    """Tile-assembled matrix == dense, incl. N not divisible by the tile."""
    for n, L, rb, cb in [(30, 70, 16, 16), (33, 64, 8, 16),
                         (64, 128, 16, 64), (13, 40, 5, 7)]:
        msa = _rand_msa(n, L, seed=n)
        tiled = _ctx(row_block=rb, col_block=cb).full(msa)
        np.testing.assert_array_equal(tiled, _dense(msa))


def test_tiled_full_uncorrected_parity():
    msa = _rand_msa(21, 50, seed=9)
    tiled = _ctx(row_block=8, col_block=6, correct=False).full(msa)
    np.testing.assert_array_equal(tiled, _dense(msa, correct=False))


def test_streamed_medoids_match_dense():
    """greedy_k_center picks the same medoids as the (m, m) dense helper."""
    msa = _rand_msa(40, 80, seed=3)
    dense_med = cluster.farthest_point_medoids(_dense(msa), 5)
    tiled_med = _ctx(row_block=16).greedy_k_center(msa, 5)
    np.testing.assert_array_equal(tiled_med, dense_med)


def test_strips_respect_budget():
    """Exactly one row-block strip resident at a time while streaming."""
    msa = _rand_msa(50, 60, seed=1)
    acct = TileAccountant()
    ctx = _ctx(row_block=16, accountant=acct)
    for start, stop, strip in ctx.strips(msa):
        assert strip.shape == (stop - start, 50)
        assert acct.resident == 16 * 50 * 4
    assert acct.resident == 0
    assert acct.peak == 16 * 50 * 4


def test_mesh_strip_hook_parity():
    """Shard-mapped strips (dist.mapreduce hook) == dense sub-blocks.

    Counts are exact either way; shard_map compiles a different program, so
    the JC69 log may differ in the last ulps — allclose, not array_equal.
    """
    from repro.launch.mesh import make_local_mesh
    msa = _rand_msa(39, 64, seed=7)
    mesh = make_local_mesh((1, 1), ("data", "model"))
    out = np.zeros((39, 39), np.float32)
    for start, stop, strip in _ctx(row_block=16, mesh=mesh).strips(msa):
        out[start:stop] = strip
    np.fill_diagonal(out, 0.0)
    np.testing.assert_allclose(out, _dense(msa), rtol=1e-5, atol=1e-6)

    # the assignment stage's shard-mapped path (rows sharded, anchors
    # replicated) against the host cross-distance
    ctx = _ctx(row_block=16, mesh=mesh)
    xd = ctx.nearest(msa, msa[:5])
    host = np.asarray(distance.cross_distance(
        jnp.asarray(msa), jnp.asarray(msa[:5]), gap_code=GAP, n_chars=NCH))
    np.testing.assert_allclose(xd, host, rtol=1e-5, atol=1e-6)
    ctx.release(xd)
    assert ctx.accountant.resident == 0


# ------------------------------------------------------------- pipeline


def test_dense_vs_tiled_rf_zero():
    """Satellite: RF == 0 between dense and tiled NJ trees on clean data."""
    _, msa = _aligned_family(40, sub=0.02, seed=11)
    kw = dict(gap_code=GAP, n_chars=NCH, seed=0)
    dense_tree = TreeEngine(backend="dense", **kw).build(msa)
    tiled_tree = TreeEngine(backend="tiled", row_block=64, col_block=16,
                            **kw).build(msa)
    assert tiled_tree.backend == "tiled-exact"
    assert treeio.rf_distance(dense_tree, tiled_tree, 40) == 0


def test_tiled_pipeline_equals_dense_cluster_path():
    """Same config -> the tiled pipeline is bit-identical to core.cluster."""
    _, msa = _aligned_family(150, L=200, seed=5)
    cfg = cluster.ClusterConfig(target_cluster=24, seed=2)
    cp_dense = cluster.cluster_phylogeny(msa, gap_code=GAP, n_chars=NCH,
                                         cfg=cfg)
    cp_tiled = tiled_phylogeny(msa, tiles=_ctx(row_block=32), cfg=cfg)
    np.testing.assert_array_equal(cp_tiled.medoids, cp_dense.medoids)
    np.testing.assert_array_equal(cp_tiled.assignments, cp_dense.assignments)
    np.testing.assert_array_equal(cp_tiled.children, cp_dense.children)
    assert treeio.to_newick(cp_tiled.children, cp_tiled.blen, cp_tiled.root) \
        == treeio.to_newick(cp_dense.children, cp_dense.blen, cp_dense.root)


def test_tiled_pipeline_covers_all_leaves_exactly_once():
    n = 150
    _, msa = _aligned_family(n, L=200, seed=5)
    cp = tiled_phylogeny(msa, tiles=_ctx(row_block=32),
                         cfg=cluster.ClusterConfig(target_cluster=24, seed=2))
    sets = treeio.leaf_sets(cp.children, cp.root, n)
    assert sets[cp.root] == frozenset(range(n))
    # every leaf referenced as a child exactly once
    refs = [int(x) for row in cp.children for x in row if 0 <= x < n]
    assert sorted(refs) == list(range(n))


def test_tiled_pipeline_memory_bound():
    """Resident distance storage stays <= one (row_block, N) strip."""
    n = 300
    _, msa = _aligned_family(n, L=200, seed=8)
    acct = TileAccountant()
    tiled_phylogeny(msa, tiles=_ctx(row_block=32, accountant=acct),
                    cfg=cluster.ClusterConfig(target_cluster=24, seed=0))
    assert 0 < acct.peak <= 32 * n * 4
    assert acct.resident == 0


# --------------------------------------------------------------- engine


def test_resolve_tree_backend():
    r = resolve_tree_backend
    assert r("auto", n=40, cluster_threshold=64) == "dense"
    assert r("auto", n=200, cluster_threshold=64) == "cluster"
    assert r("auto", n=5000, cluster_threshold=64, row_block=128) == "tiled"
    assert r("auto", n=200, cluster_threshold=199) == "cluster"
    assert r("cluster", n=40, cluster_threshold=64) == "dense"
    assert r("cluster", n=65, cluster_threshold=64) == "cluster"
    assert r("tiled", n=40, row_block=64) == "tiled-exact"
    assert r("tiled", n=200, row_block=64) == "tiled"
    assert r("dense", n=10**6) == "dense"
    try:
        r("hptree", n=10)
        assert False, "expected ValueError"
    except ValueError:
        pass


def test_engine_two_leaves():
    """A 2-sequence input still yields a tree (the old msa_run behavior)."""
    msa = _rand_msa(2, 60, seed=4)
    res = TreeEngine(gap_code=GAP, n_chars=NCH, backend="auto").build(msa)
    assert res.backend == "dense" and res.n_leaves == 2
    nwk = res.newick(["a", "b"])
    assert nwk.count(",") == 1 and "a" in nwk and "b" in nwk


def test_engine_cluster_threshold_gate():
    _, msa = _aligned_family(40, seed=3)
    kw = dict(gap_code=GAP, n_chars=NCH)
    assert TreeEngine(backend="cluster", cluster_threshold=64,
                      **kw).build(msa).backend == "dense"
    res = TreeEngine(backend="cluster", cluster_threshold=16,
                     target_cluster=12, **kw).build(msa)
    assert res.backend == "cluster"
    assert treeio.leaf_sets(res.children, res.root, 40)[res.root] \
        == frozenset(range(40))


# ------------------------------------------------------------ launchers


def test_tree_run_2000_tiled_within_budget(tmp_path):
    """Acceptance: tree_run on 2000 sequences with the tiled backend, peak
    resident distance storage <= one tile row-block strip."""
    n, L = 2000, 120
    rng = np.random.default_rng(0)
    base = rng.integers(0, 4, L).astype(np.int8)
    msa = np.tile(base, (n, 1))
    mask = rng.random((n, L)) < 0.05
    msa[mask] = rng.integers(0, 4, int(mask.sum())).astype(np.int8)
    fasta = tmp_path / "aligned.fasta"
    with open(fasta, "w") as f:
        for i in range(n):
            f.write(f">s{i}\n{ab.DNA.decode(msa[i])}\n")

    out = tmp_path / "tree_out"
    tree_run.main(["--fasta", str(fasta), "--out", str(out),
                   "--backend", "tiled", "--row-block", "128"])
    report = json.loads((out / "report.json").read_text())
    assert report["n_sequences"] == n
    assert report["backend"] == "tiled"
    stats = report["tile_stats"]
    assert stats["row_block_bytes"] == 128 * n * 4
    assert 0 < stats["peak_resident_bytes"] <= stats["row_block_bytes"]
    nwk = (out / "tree.nwk").read_text()
    assert nwk.count(",") == n - 1 and nwk.strip().endswith(";")


def test_msa_run_tree_flags(tmp_path):
    """msa_run: --tree tiled + --cluster-threshold + --tree-ll wiring."""
    fam = simulate_family(SimConfig(n_leaves=12, root_len=300,
                                    branch_sub=0.02, branch_indel=0.001,
                                    seed=6))
    fasta = tmp_path / "fam.fasta"
    with open(fasta, "w") as f:
        for nm, s in zip(fam.names, fam.seqs):
            f.write(f">{nm}\n{s}\n")
    from repro.launch import msa_run

    out = tmp_path / "out1"
    msa_run.main(["--fasta", str(fasta), "--out", str(out), "--method",
                  "kmer", "--k", "10", "--tree", "tiled"])
    report = json.loads((out / "report.json").read_text())
    assert report["tree_backend"] == "tiled-exact"    # 12 <= row_block
    assert "log_likelihood" not in report             # gated behind --tree-ll

    out2 = tmp_path / "out2"
    msa_run.main(["--fasta", str(fasta), "--out", str(out2), "--method",
                  "kmer", "--k", "10", "--tree", "cluster",
                  "--cluster-threshold", "4", "--tree-ll"])
    report2 = json.loads((out2 / "report.json").read_text())
    assert report2["tree_backend"] == "cluster"       # 12 > threshold 4
    assert np.isfinite(report2["log_likelihood"])
