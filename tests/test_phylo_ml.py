"""ML tree refinement: model registry vs a brute-force oracle, pruning
invariances, NNI candidate validity, bootstrap reproducibility, and the
engine / launcher dispatch (``refine="ml"``)."""
import itertools
import json
import re

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import alphabet as ab
from repro.core import distance, likelihood, nj, treeio
from repro.core.msa import MSAConfig, center_star_msa, decode_msa
from repro.data import SimConfig, phi_dna, simulate_family, write_fasta
from repro.phylo import MLRefiner, TreeEngine, models
from repro.phylo import ml as ml_mod

GAP, NCH = ab.DNA.gap_code, ab.DNA.n_chars


def _aligned_family(n, L=300, seed=4, sub=0.03):
    fam = simulate_family(SimConfig(n_leaves=n, root_len=L, branch_sub=sub,
                                    branch_indel=0.0, seed=seed))
    S, _ = ab.encode_batch(fam.seqs, ab.DNA)
    return fam, np.asarray(S)


def _nj_tree(msa):
    D = distance.distance_matrix(jnp.asarray(msa), gap_code=GAP, n_chars=NCH)
    return nj.host_tree(nj.neighbor_joining(D, msa.shape[0]))


def _general_ll(patterns, weights, children, blen, root, model, params,
                order=None, site_chunk=0):
    n = patterns.shape[0]
    if order is None:
        order = np.arange(n, children.shape[0], dtype=np.int32)
    dec = models.decompose(model, params)
    return float(likelihood.pruning_log_likelihood(
        jnp.asarray(patterns), jnp.asarray(weights, jnp.float32),
        jnp.asarray(children, jnp.int32), jnp.asarray(blen, jnp.float32),
        jnp.asarray(order), int(root), dec.lam, dec.U, dec.sp, dec.pi,
        site_chunk=site_chunk))


# ------------------------------------------------------------ regression

def test_jc69_transition_zero_length_exact_identity():
    """t == 0 must be the exact identity — the old 1e-8 clamp silently
    floored true zero-length branches off the diagonal."""
    P = np.asarray(likelihood.jc69_transition(jnp.float32(0.0)))
    assert np.array_equal(P, np.eye(4, dtype=P.dtype))
    # positive lengths unchanged by the fix
    P = np.asarray(likelihood.jc69_transition(jnp.float32(0.1)))
    assert np.allclose(P.sum(1), 1.0, atol=1e-6) and (P > 0).all()


# --------------------------------------------------- oracle + invariances

def _oracle_ll(patterns, weights, children, blen, root, Q, pi):
    """Pure-numpy likelihood summed over all internal-state histories."""
    M = children.shape[0]
    N = patterns.shape[0]
    internal = [n for n in range(M) if children[n][0] >= 0]
    w_eig, V = np.linalg.eig(np.asarray(Q, np.float64))
    Vinv = np.linalg.inv(V)
    P = {(n_, k): ((V * np.exp(w_eig * float(blen[n_, k]))) @ Vinv).real
         for n_ in internal for k in (0, 1)}
    total = 0.0
    for s in range(patterns.shape[1]):
        col = patterns[:, s]
        tot = 0.0
        for assign in itertools.product(range(4), repeat=len(internal)):
            st = {internal[i]: assign[i] for i in range(len(internal))}
            for leaf in range(N):
                st[leaf] = int(col[leaf])
            pr = float(pi[st[root]])
            for n_ in internal:
                for k in (0, 1):
                    pr *= P[(n_, k)][st[n_], st[int(children[n_][k])]]
            tot += pr
        total += float(weights[s]) * np.log(tot)
    return total


@pytest.mark.parametrize("model", models.MODELS)
def test_pruning_matches_bruteforce_oracle(model):
    """Every registry model, every site pattern on a 4-leaf tree, checked
    against a numpy sum-over-histories oracle (independent expm path)."""
    rng = np.random.default_rng(7)
    children = np.array([[-1, -1]] * 4 + [[0, 1], [2, 3], [4, 5]], np.int32)
    blen = np.zeros((7, 2), np.float32)
    blen[4:] = rng.uniform(0.02, 0.6, (3, 2)).astype(np.float32)
    patterns = np.array(list(itertools.product(range(4), repeat=4)),
                        np.int8).T                     # (4, 256): all columns
    weights = rng.integers(1, 5, 256).astype(np.float32)
    params = models.init_params(model)
    params = (params + rng.normal(0, 0.3, params.shape)).astype(np.float32) \
        if params.size else params
    got = _general_ll(patterns, weights, children, blen, 6, model, params)
    Q, pi = models.rate_matrix(model, params)
    want = _oracle_ll(patterns, weights, children, blen, 6,
                      np.asarray(Q), np.asarray(pi))
    assert got == pytest.approx(want, rel=5e-4)


def test_gap_columns_are_uninformative():
    """Appending all-N / all-gap patterns (weight w) must not change logL."""
    rng = np.random.default_rng(1)
    children = np.array([[-1, -1]] * 4 + [[0, 1], [2, 3], [4, 5]], np.int32)
    blen = np.abs(rng.normal(0.1, 0.05, (7, 2))).astype(np.float32)
    pat = rng.integers(0, 4, (4, 40)).astype(np.int8)
    w = np.ones(40, np.float32)
    base = _general_ll(pat, w, children, blen, 6, "jc69", np.zeros(0))
    pat2 = np.concatenate([pat, np.full((4, 3), 4, np.int8),
                           np.full((4, 2), GAP, np.int8)], axis=1)
    w2 = np.concatenate([w, np.full(5, 7.0, np.float32)])
    aug = _general_ll(pat2, w2, children, blen, 6, "jc69", np.zeros(0))
    assert aug == pytest.approx(base, abs=1e-3)


def test_negative_branch_lengths_floor_at_identity():
    """NJ emits slightly negative lengths; the evaluator must treat them
    as zero (like jc69_transition), not let exp(lam*t) push diagonal
    transition probabilities above 1 and inflate logL."""
    fam, msa = _aligned_family(6, L=150, seed=3)
    children, blen, root = _nj_tree(msa)
    patterns, weights = likelihood.compress_patterns(msa)
    neg = blen.copy()
    neg[root, 0] = -0.2
    ll_neg = _general_ll(patterns, weights, children, neg, root, "jc69",
                         np.zeros(0))
    ll_zero = _general_ll(patterns, weights, children,
                          np.maximum(neg, 0.0), root, "jc69", np.zeros(0))
    assert ll_neg == pytest.approx(ll_zero, rel=1e-6)
    # refinement from a negative-length tree still strictly improves a
    # *valid* baseline
    res = MLRefiner(gap_code=GAP, n_chars=NCH, model="jc69", steps=60,
                    nni_rounds=1).refine(msa, children, neg, root)
    assert res.logl_init == pytest.approx(ll_zero, rel=1e-6)
    assert res.logl_final > res.logl_init


def test_site_chunk_checkpointing_parity():
    rng = np.random.default_rng(2)
    fam, msa = _aligned_family(6, L=200, seed=9)
    children, blen, root = _nj_tree(msa)
    patterns, weights = likelihood.compress_patterns(msa)
    full = _general_ll(patterns, weights, children, blen, root, "jc69",
                       np.zeros(0), site_chunk=0)
    chunked = _general_ll(patterns, weights, children, blen, root, "jc69",
                          np.zeros(0), site_chunk=7)
    assert chunked == pytest.approx(full, rel=1e-6)


def test_rerooting_invariance():
    """Reversible models are root-invariant: the same unrooted quartet
    rooted on the middle edge (any pulley split) and on a pendant edge
    must have identical logL."""
    rng = np.random.default_rng(5)
    a, b, c, d, e = rng.uniform(0.05, 0.4, 5)
    patterns = np.array(list(itertools.product(range(4), repeat=4)),
                        np.int8).T
    weights = rng.integers(1, 4, 256).astype(np.float32)
    params = (models.init_params("gtr")
              + rng.normal(0, 0.2, 8)).astype(np.float32)

    def quartet(ch, bl):
        return _general_ll(patterns, weights, np.asarray(ch, np.int32),
                           np.asarray(bl, np.float32), 6, "gtr", params)

    # rooted on the middle edge, pulley split x / e - x
    lls = []
    for x in (0.0, 0.37 * e, e):
        ch = [[-1, -1]] * 4 + [[0, 1], [2, 3], [4, 5]]
        bl = [[0, 0]] * 4 + [[a, b], [c, d], [x, e - x]]
        lls.append(quartet(ch, bl))
    # rooted on leaf 0's pendant edge (split a in half, e intact)
    ch = [[-1, -1]] * 4 + [[2, 3], [1, 4], [0, 5]]
    bl = [[0, 0]] * 4 + [[c, d], [b, e], [a / 2, a / 2]]
    lls.append(quartet(ch, bl))
    assert np.allclose(lls, lls[0], atol=0.05)


# --------------------------------------------------------------- topology

def test_nni_candidates_are_valid_trees():
    fam, msa = _aligned_family(10, seed=11)
    children, blen, root = _nj_tree(msa)
    n = msa.shape[0]
    order = np.arange(n, children.shape[0], dtype=np.int32)
    ch_k, bl_k, od_k = ml_mod.nni_candidates(children, blen, order, n)
    assert ch_k.shape[0] == 2 * (n - 2)
    all_leaves = frozenset(range(n))
    for k in range(ch_k.shape[0]):
        pos = {int(v): i for i, v in enumerate(od_k[k])}
        for node in od_k[k]:
            for c in ch_k[k][int(node)]:
                if int(c) >= n:                   # internal child first
                    assert pos[int(c)] < pos[int(node)]
        assert treeio.leaf_sets(ch_k[k], root, n)[root] == all_leaves


def test_refiner_strictly_improves_and_renumbers():
    fam, msa = _aligned_family(8, seed=4)
    children, blen, root = _nj_tree(msa)
    res = MLRefiner(gap_code=GAP, n_chars=NCH, model="jc69", steps=80,
                    nni_rounds=2).refine(msa, children, blen, root)
    assert res.logl_final > res.logl_init
    # renumbered tree is index-topological again: the core JC69 evaluator
    # (which assumes it) agrees with the refiner's own final logL
    ll_core = float(likelihood.log_likelihood(
        jnp.asarray(msa), jnp.asarray(res.children), jnp.asarray(res.blen),
        res.root, gap_code=GAP))
    assert ll_core == pytest.approx(res.logl_final, rel=1e-4)


def test_bic_auto_selects_argmin():
    fam, msa = _aligned_family(6, L=200, seed=8)
    children, blen, root = _nj_tree(msa)
    res = MLRefiner(gap_code=GAP, n_chars=NCH, model="auto", steps=40,
                    nni_rounds=0).refine(msa, children, blen, root)
    assert set(res.bic) == set(models.MODELS)
    assert res.model == min(res.bic, key=res.bic.get)
    assert all(np.isfinite(v) for v in res.bic.values())


# -------------------------------------------------------------- bootstrap

def test_weighted_distance_unit_weights_matches_dense():
    rng = np.random.default_rng(3)
    msa = rng.integers(0, 6, (12, 80)).astype(np.int8)   # incl. N + gaps
    got = np.asarray(ml_mod.weighted_distance_matrix(
        jnp.asarray(msa), jnp.ones(80, jnp.float32), gap_code=GAP,
        n_chars=NCH))
    want = np.asarray(distance.distance_matrix(jnp.asarray(msa),
                                               gap_code=GAP, n_chars=NCH))
    assert np.array_equal(got, want)


def test_bootstrap_reproducible_and_mesh_sharded():
    from repro.launch.mesh import make_local_mesh
    fam, msa = _aligned_family(8, seed=4)
    children, blen, root = _nj_tree(msa)
    r = MLRefiner(gap_code=GAP, n_chars=NCH, seed=12)
    s1 = r.bootstrap(msa, children, blen, root, 12)
    s2 = r.bootstrap(msa, children, blen, root, 12)
    assert np.array_equal(s1, s2, equal_nan=True)
    finite = s1[np.isfinite(s1)]
    assert finite.size > 0 and ((finite >= 0) & (finite <= 1)).all()
    # leaves and root carry no support
    assert not np.isfinite(s1[:8]).any() and not np.isfinite(s1[root])
    # replicates sharded over a mesh are bit-identical for the same seed
    r_mesh = MLRefiner(gap_code=GAP, n_chars=NCH, seed=12,
                       mesh=make_local_mesh((1, 1)))
    s3 = r_mesh.bootstrap(msa, children, blen, root, 12)
    assert np.array_equal(s1, s3, equal_nan=True)
    # a different seed resamples different site counts
    s4 = MLRefiner(gap_code=GAP, n_chars=NCH, seed=13).bootstrap(
        msa, children, blen, root, 12)
    assert not np.array_equal(s1, s4, equal_nan=True)


# ------------------------------------------------------- engine + launcher

def test_engine_refine_dispatch_and_support_newick():
    fam, msa = _aligned_family(8, seed=4)
    eng = TreeEngine(gap_code=GAP, n_chars=NCH, refine="ml", model="jc69",
                     bootstrap=8, ml_steps=40, nni_rounds=1)
    res = eng.build(msa)
    assert res.backend.endswith("+ml") and res.model == "jc69"
    assert res.logl["final"] > res.logl["initial"]
    assert res.support is not None
    assert re.search(r"\)\d\.\d\d:", res.newick(fam.names))
    assert "refine_seconds" in res.timings
    with pytest.raises(ValueError):
        TreeEngine(gap_code=21, n_chars=21, refine="ml").build(msa)
    with pytest.raises(ValueError):
        TreeEngine(gap_code=GAP, n_chars=NCH, refine="wat").build(msa)
    # bootstrap without refinement must fail loudly, not silently drop
    with pytest.raises(ValueError):
        TreeEngine(gap_code=GAP, n_chars=NCH, bootstrap=8).build(msa)


def test_service_tree_refine_fingerprint():
    from repro.serve import MSAService, ServiceConfig
    fam, msa = _aligned_family(6, L=120, seed=6)
    seqs = [ab.DNA.decode(r).replace("-", "") for r in msa]
    svc = MSAService(ServiceConfig(method="plain"))
    r1 = svc.tree(seqs=seqs, refine="ml", model="jc69")
    assert r1["refine"] == "ml" and r1["logl"]["final"] >= r1["logl"]["initial"]
    r2 = svc.tree(msa_id=r1["msa_id"], refine="ml", model="jc69")
    assert r2["cached_tree"]
    # unrefined request misses the refined fingerprint
    r3 = svc.tree(msa_id=r1["msa_id"])
    assert not r3["cached_tree"] and r3["refine"] == "none"
    # refine=none ignores the model, so it must not fragment the cache
    # key — but seed stays in it (cluster/tiled sketch sampling uses it)
    r4 = svc.tree(msa_id=r1["msa_id"], model="gtr")
    assert r4["cached_tree"]
    r5 = svc.tree(msa_id=r1["msa_id"], seed=99)
    assert not r5["cached_tree"]
    # invalid config errors even when a compatible key is warm in the
    # cache (validation runs before the lookup)
    with pytest.raises(ValueError):
        svc.tree(msa_id=r1["msa_id"], bootstrap=10)
    svc.drain()
    # a server-wide bootstrap default must not leak into requests that
    # override refine to "none" (they would 400 on bootstrap-requires-ml)
    svc2 = MSAService(ServiceConfig(method="plain", tree_refine="ml",
                                    tree_model="jc69", tree_bootstrap=4))
    r6 = svc2.tree(seqs=seqs, refine="none")
    assert r6["refine"] == "none" and "logl" not in r6
    svc2.drain()


def test_tree_run_refine_ml_improves_on_phi_dna(tmp_path):
    """The acceptance run: phi_dna family -> center-star MSA ->
    ``tree_run --refine ml --model auto --bootstrap B --mesh 1x1``
    strictly improves logL over the unrefined NJ tree and emits
    support-labelled Newick."""
    from repro.launch import tree_run
    fam = phi_dna()
    cfg = MSAConfig(method="kmer")
    res = center_star_msa(fam.seqs, cfg)
    fa = tmp_path / "aligned.fasta"
    write_fasta(fa, fam.names, decode_msa(res.msa, cfg))
    out = tmp_path / "tree"
    tree_run.main(["--fasta", str(fa), "--out", str(out),
                   "--refine", "ml", "--model", "auto", "--bootstrap", "16",
                   "--ml-steps", "60", "--nni-rounds", "2",
                   "--mesh", "1x1", "--tree-ll"])
    rep = json.loads((out / "report.json").read_text())
    assert rep["logl"]["final"] > rep["logl"]["initial"]
    assert rep["model"] in models.MODELS
    assert rep["bootstrap"]["replicates"] == 16
    nwk = (out / "tree.nwk").read_text()
    assert re.search(r"\)\d\.\d\d:", nwk)
    assert nwk.count("seq") == len(fam.seqs)
