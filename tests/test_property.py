"""Hypothesis property tests on system invariants."""
import jax.numpy as jnp
import numpy as np
try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # CI image has no hypothesis; seeded fallback
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import alphabet as ab
from repro.core import nj as nj_mod
from repro.core import treeio
from repro.core.msa import MSAConfig, center_star_msa, decode_msa
from repro.dist.fault import BackupShardPlan

DNA_SEQ = st.text(alphabet="ACGT", min_size=4, max_size=60)


@settings(max_examples=15, deadline=None)
@given(st.lists(DNA_SEQ, min_size=2, max_size=6))
def test_msa_gap_removal_recovers_inputs(seqs):
    res = center_star_msa(seqs, MSAConfig(method="plain"))
    rows = decode_msa(res.msa, MSAConfig(method="plain"))
    for s, r in zip(seqs, rows):
        assert r.replace("-", "") == s
    assert len({len(r) for r in rows}) == 1


@settings(max_examples=15, deadline=None)
@given(DNA_SEQ, DNA_SEQ)
def test_alignment_score_symmetric(s1, s2):
    from repro.core import pairwise as pw
    sub = ab.dna_matrix().astype(jnp.float32)

    def score(a, b):
        return float(pw.score_only(
            jnp.asarray(ab.DNA.encode(a)), jnp.int32(len(a)),
            jnp.asarray(ab.DNA.encode(b)), jnp.int32(len(b)), sub,
            gap_open=3, gap_extend=1))
    assert score(s1, s2) == score(s2, s1)


@settings(max_examples=15, deadline=None)
@given(DNA_SEQ)
def test_self_alignment_is_perfect(s):
    from repro.core import pairwise as pw
    sub = ab.dna_matrix().astype(jnp.float32)
    sc = float(pw.score_only(
        jnp.asarray(ab.DNA.encode(s)), jnp.int32(len(s)),
        jnp.asarray(ab.DNA.encode(s)), jnp.int32(len(s)), sub,
        gap_open=3, gap_extend=1))
    assert sc == 2 * len(s)


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=4, max_value=12), st.integers(0, 10**6))
def test_nj_produces_valid_binary_tree(n, seed):
    rng = np.random.default_rng(seed)
    pts = rng.normal(0, 1, (n, 3))
    D = np.sqrt(((pts[:, None] - pts[None]) ** 2).sum(-1)).astype(np.float32)
    tree = nj_mod.neighbor_joining(jnp.asarray(D), n)
    sets = treeio.leaf_sets(np.asarray(tree.children), int(tree.root), n)
    assert sets[int(tree.root)] == frozenset(range(n))
    internal = [i for i in range(2 * n - 1)
                if np.asarray(tree.children)[i][0] >= 0]
    assert len(internal) == n - 1  # binary rooted tree


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 64), st.integers(2, 4))
def test_backup_plan_full_coverage(n_hosts, repl):
    repl = min(repl, n_hosts)
    plan = BackupShardPlan(n_hosts=n_hosts, replication=repl)
    for s in range(n_hosts):
        assert len(set(plan.owners(s))) == repl
        if repl > 1:
            for dead in plan.owners(s):
                assert plan.takeover(dead, s) != dead


@settings(max_examples=10, deadline=None)
@given(st.lists(st.integers(0, 4), min_size=8, max_size=64),
       st.integers(2, 5))
def test_sp_score_nonnegative_and_zero_for_identical(codes, n):
    from repro.core.sp_score import avg_sp
    row = np.asarray(codes, np.int8)
    msa = jnp.asarray(np.tile(row, (n, 1)))
    sp = float(avg_sp(msa, gap_code=5, n_chars=5))
    assert sp == 0.0
