"""repro.search tests: engine units, index persistence, the /search
service method, and a subprocess end-to-end run pinning the ISSUE
acceptance: ``search_run --pipeline`` turns a query FASTA + database
FASTA into a supported Newick tree, with hits and topology bit-identical
between single-host and a 2-shard ``--dist`` mesh and across repeated
runs."""
import json
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.search import SearchConfig, SearchEngine, SearchIndex

SRC = str(Path(__file__).resolve().parent.parent / "src")


def _family_db(seed=0, n_members=4, n_decoys=4, L=120):
    rng = np.random.default_rng(seed)

    def rseq(n):
        return "".join("ACGT"[i] for i in rng.integers(0, 4, n))

    def mut(s, p=0.06):
        return "".join("ACGT"[rng.integers(0, 4)] if rng.random() < p else x
                       for x in s)

    base = rseq(L)
    names = [f"fam_m{j}" for j in range(n_members)] + \
        [f"decoy{j}" for j in range(n_decoys)]
    seqs = [mut(base) for _ in range(n_members)] + \
        [rseq(L) for _ in range(n_decoys)]
    return names, seqs, mut(base)


@pytest.fixture(scope="module")
def planted():
    names, seqs, query = _family_db()
    engine = SearchEngine(SearchConfig(max_hits=6, max_evalue=1e-6))
    index = engine.build_index(names, seqs)
    return engine, index, query


def test_planted_family_ranks_top(planted):
    engine, index, query = planted
    res = engine.search(["q"], [query], index)
    hits = res["queries"][0]["hits"]
    assert hits, "planted homolog found no hits"
    top = hits[0]
    assert top["target"].startswith("fam_")
    assert top["coverage"] > 0.9
    assert top["evalue"] < 1e-20
    # scores are sorted descending within the query
    assert [h["score"] for h in hits] == \
        sorted((h["score"] for h in hits), reverse=True)


def test_gates_are_respected(planted):
    engine, index, query = planted
    assert len(engine.search(["q"], [query], index,
                             max_hits=2)["queries"][0]["hits"]) <= 2
    assert engine.search(["q"], [query], index,
                         max_evalue=0.0)["queries"][0]["hits"] == []
    assert engine.search(["q"], [query], index,
                         min_coverage=1.01)["queries"][0]["hits"] == []


def test_prefiltered_topk_matches_exhaustive_oracle(planted):
    engine, index, query = planted
    fast = engine.search(["q"], [query], index)
    oracle = engine.search(["q"], [query], index, exhaustive=True)
    assert fast["queries"][0]["hits"] == oracle["queries"][0]["hits"]
    assert fast["stats"]["candidates"] <= oracle["stats"]["candidates"]


def test_empty_and_short_queries_return_no_hits(planted):
    engine, index, _ = planted
    res = engine.search(["empty", "tiny"], ["", "ACG"], index)
    assert [q["hits"] for q in res["queries"]] == [[], []]


def test_index_save_load_roundtrip(planted, tmp_path):
    engine, index, query = planted
    path = tmp_path / "db.idx.npz"
    index.save(path)
    loaded = SearchIndex.load(path)
    assert loaded.fingerprint() == index.fingerprint()
    assert loaded.names == index.names
    a = engine.search(["q"], [query], index)
    b = engine.search(["q"], [query], loaded)
    assert json.dumps(a) == json.dumps(b)


def test_index_rejects_future_format_version(tmp_path):
    path = tmp_path / "future.npz"
    np.savez(path, version=np.int32(99))
    with pytest.raises(ValueError, match="format v99"):
        SearchIndex.load(path)


def test_index_build_validation():
    with pytest.raises(ValueError, match="empty database"):
        SearchIndex.build([], [], k=5)
    with pytest.raises(ValueError, match="nucleotide"):
        SearchIndex.build(["a"], ["ACDEFG"], alphabet="protein")
    with pytest.raises(ValueError, match="names"):
        SearchIndex.build(["a", "b"], ["ACGT"])


def test_service_search_endpoint_caches_and_maps_order(planted):
    from repro.serve import MSAService, ServiceConfig
    _, index, query = planted
    svc = MSAService(ServiceConfig(search_index=index))
    names, seqs = ["q0", "q1"], [query, "ACGTACGTACGT"]
    r1 = svc.search(names, seqs, max_evalue=1e-6)
    assert not r1["cached"]
    assert r1["queries"][0]["hits"][0]["target"].startswith("fam_")
    # permuted resubmission hits the cache and maps back to caller order
    r2 = svc.search(list(reversed(names)), list(reversed(seqs)),
                    max_evalue=1e-6)
    assert r2["cached"]
    assert r2["queries"][1]["name"] == "q0"
    assert r2["queries"][1]["hits"] == r1["queries"][0]["hits"]
    assert svc.healthz()["search_db"] == index.n_seqs
    # a service without a database 400s the request
    svc_nodb = MSAService(ServiceConfig())
    with pytest.raises(ValueError, match="no search database"):
        svc_nodb.search(names, seqs)


# --------------------------------------------------------- subprocess e2e

SCRIPT = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import json
import sys
sys.path.insert(0, %r)
import numpy as np

workdir = %r
rng = np.random.default_rng(3)
def rseq(n):
    return "".join("ACGT"[i] for i in rng.integers(0, 4, n))
def mut(s, p=0.06):
    return "".join("ACGT"[rng.integers(0, 4)] if rng.random() < p else x
                   for x in s)
base = rseq(100)
with open(workdir + "/db.fasta", "w") as f:
    for j in range(4):
        f.write(f">fam_m{j}\n{mut(base)}\n")
    for j in range(3):
        f.write(f">decoy{j}\n{rseq(100)}\n")
with open(workdir + "/q.fasta", "w") as f:
    f.write(f">query\n{mut(base)}\n")

from repro.launch import search_run

common = ["--db", workdir + "/db.fasta", "--query", workdir + "/q.fasta",
          "--max-hits", "4", "--max-evalue", "1e-6",
          "--pipeline", "--bootstrap", "2", "--ml-steps", "4"]

def run(out, extra=()):
    search_run.main(common + ["--out", workdir + "/" + out] + list(extra))
    hits = open(workdir + "/" + out + "/hits.json").read()
    tree = open(workdir + "/" + out + "/family_000_query/tree.nwk").read()
    return hits, tree

h_host, t_host = run("host")
h_rep, t_rep = run("host_rep")                      # repeated run
h_mesh, t_mesh = run("mesh", ["--dist", "--mesh", "2x1"])

def hits_only(h):
    # the stats block records which seeding stage ran ("host" vs
    # "mesh"); bit-identity is over the scientific payload
    return json.dumps(json.loads(h)["queries"])

out = {
    "repeat_hits_identical": h_host == h_rep,
    "repeat_tree_identical": t_host == t_rep,
    "mesh_hits_identical": hits_only(h_host) == hits_only(h_mesh),
    "mesh_tree_identical": t_host == t_mesh,
    "mesh_seed_stage": json.loads(h_mesh)["stats"]["seed"],
    "n_hits": len(json.loads(h_host)["queries"][0]["hits"]),
    "newick": t_host.strip(),
}
print("RESULT " + json.dumps(out))
'''


def test_pipeline_e2e_mesh_and_repeat_bit_identical(tmp_path):
    """query FASTA + DB FASTA -> supported Newick; hits and topology
    bit-identical between 1x1-host and 2-shard mesh, and across runs."""
    proc = subprocess.run(
        [sys.executable, "-c", SCRIPT % (SRC, str(tmp_path))],
        capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stderr[-4000:]
    line = [ln for ln in proc.stdout.splitlines()
            if ln.startswith("RESULT ")][-1]
    out = json.loads(line[len("RESULT "):])
    assert out["repeat_hits_identical"]
    assert out["repeat_tree_identical"]
    assert out["mesh_hits_identical"]
    assert out["mesh_tree_identical"]
    assert out["mesh_seed_stage"] == "mesh"
    assert out["n_hits"] == 4          # the whole planted family
    nwk = out["newick"]
    assert nwk.endswith(";") and "query" in nwk
    # bootstrap support labels on internal edges: ")<float>:" in newick
    import re
    assert re.search(r"\)\d+\.\d+:", nwk), nwk
