"""Executable spec of repro.serve: coalescing, cache, incremental, drain.

The four service invariants from ISSUE 4:
  * N concurrent requests merge into <= pow2-bucket-count engine calls,
  * a cache hit returns a byte-identical alignment response,
  * incremental add preserves previously aligned members bit-exactly
    (equal to a full realign with the same frozen center),
  * drain-on-shutdown completes in-flight requests, then refuses work.
"""
import json
import threading
import time
import urllib.error
import urllib.request

import numpy as np
import pytest

from repro.align.bucketing import _pow2_widths, pair_bucket_plan
from repro.core.msa import MSAConfig, center_star_msa
from repro.serve import (AlignJob, CoalescingAligner, MSAService,
                         ServiceConfig, add_to_msa, serve_http)
from repro.serve.cache import ResultCache, canonical_key, canonicalize


def _family(rng, n, length, nsub=3):
    base = "".join(rng.choice(list("ACGT"), length))
    out = [base]
    for _ in range(n - 1):
        s = list(base)
        for _ in range(nsub):
            s[rng.integers(0, len(s))] = "ACGT"[rng.integers(0, 4)]
        out.append("".join(s))
    return out


# ------------------------------------------------------------- align_pairs

def test_align_pairs_matches_broadcast_path():
    rng = np.random.default_rng(0)
    cfg = MSAConfig(method="plain")
    eng = cfg.engine()
    gap = cfg.alpha().gap_code
    qs = [rng.integers(0, 4, n).astype(np.int8) for n in (20, 33, 70, 140)]
    ts = [rng.integers(0, 4, n).astype(np.int8) for n in (25, 40, 60, 130)]
    Lq, Lt = max(map(len, qs)), max(map(len, ts))
    Q = np.full((4, Lq), gap, np.int8)
    T = np.full((4, Lt), gap, np.int8)
    for i, (q, t) in enumerate(zip(qs, ts)):
        Q[i, : len(q)] = q
        T[i, : len(t)] = t
    qlens = np.array([len(q) for q in qs], np.int32)
    tlens = np.array([len(t) for t in ts], np.int32)
    res = eng.align_pairs(Q, qlens, T, tlens)
    for i in range(4):
        ref = eng.align_to_center(Q[i: i + 1, : len(qs[i])],
                                  qlens[i: i + 1], ts[i], tlens[i])
        k = int(res.aln_len[i])
        assert float(ref.score[0]) == float(res.score[i])
        assert np.array_equal(np.asarray(ref.a_row[0][:k]),
                              np.asarray(res.a_row[i][:k]))
        assert np.array_equal(np.asarray(ref.b_row[0][:k]),
                              np.asarray(res.b_row[i][:k]))


def test_align_pairs_banded_overflow_falls_back():
    rng = np.random.default_rng(1)
    cfg = MSAConfig(method="plain")
    ref_eng = cfg.engine()
    band_eng = MSAConfig(method="plain", backend="banded", band=4).engine()
    # indel-heavy pair pushes the tiny band -> per-pair full-DP fallback
    t = rng.integers(0, 4, 80).astype(np.int8)
    q = np.concatenate([t[:10], t[40:]])
    Q = np.full((1, 80), 5, np.int8)
    Q[0, : q.size] = q
    T = t[None, :]
    ql = np.array([q.size], np.int32)
    tl = np.array([80], np.int32)
    res = band_eng.align_pairs(Q, ql, T, tl)
    ref = ref_eng.align_pairs(Q, ql, T, tl)
    assert res.n_fallback >= 1
    assert float(res.score[0]) == float(ref.score[0])


def test_pair_bucket_plan_bounds_shapes():
    rng = np.random.default_rng(2)
    qlens = rng.integers(10, 500, 300)
    tlens = rng.integers(10, 500, 300)
    plan = pair_bucket_plan(qlens, tlens, 500, 500)
    assert sum(len(idx) for _, _, idx in plan) == 300
    wq = _pow2_widths(qlens, 500, 32)
    wt = _pow2_widths(tlens, 500, 32)
    assert len(plan) == len(set(zip(wq.tolist(), wt.tolist())))
    for q_w, t_w, idx in plan:
        assert (qlens[idx] <= q_w).all() and (tlens[idx] <= t_w).all()


# ------------------------------------------------------------- coalescing

def test_coalescing_merges_requests_into_bucket_count_calls():
    rng = np.random.default_rng(3)
    cfg = MSAConfig(method="plain")
    engine = cfg.engine()
    gap = cfg.alpha().gap_code
    co = CoalescingAligner(max_batch=10_000, max_wait_ms=100.0)
    jobs, lens = [], []
    for _ in range(12):
        L = int(rng.integers(20, 250))
        t = rng.integers(0, 4, L).astype(np.int8)
        q = t.copy()
        q[rng.integers(0, L, 3)] = rng.integers(0, 4, 3).astype(np.int8)
        Q = np.full((1, L), gap, np.int8)
        Q[0] = q
        jobs.append(AlignJob(Q=Q, qlens=np.array([L], np.int32), target=t,
                             tlen=L, engine=engine, engine_key="k"))
        lens.append(L)
    futs = [co.submit(j) for j in jobs]
    results = [f.result(timeout=120) for f in futs]
    stats = co.stats()
    co.close()
    n_buckets = len(pair_bucket_plan(np.array(lens), np.array(lens),
                                     max(lens), max(lens)))
    assert stats["batches"] == 1                      # one merged flush
    assert stats["engine_calls"] <= n_buckets < 12    # << one call per req
    assert stats["coalesced_jobs"] == 12
    assert all(r.meta["batch_jobs"] == 12 for r in results)


def test_coalescer_drain_completes_inflight_then_refuses():
    cfg = MSAConfig(method="plain")
    engine = cfg.engine()
    gap = cfg.alpha().gap_code
    # long max_wait: without drain these jobs would sit until the deadline
    co = CoalescingAligner(max_batch=10_000, max_wait_ms=30_000.0)
    Q = np.full((1, 16), gap, np.int8)
    Q[0] = np.arange(16) % 4
    t = (np.arange(16) % 4).astype(np.int8)
    futs = [co.submit(AlignJob(Q=Q, qlens=np.array([16], np.int32),
                               target=t, tlen=16, engine=engine,
                               engine_key="k")) for _ in range(3)]
    t0 = time.perf_counter()
    co.close()
    assert time.perf_counter() - t0 < 20             # not the 30s deadline
    assert all(f.done() for f in futs)
    for f in futs:
        assert f.result().a_row.shape[0] == 1
    with pytest.raises(RuntimeError, match="draining"):
        co.submit(AlignJob(Q=Q, qlens=np.array([16], np.int32), target=t,
                           tlen=16, engine=engine, engine_key="k"))


# ------------------------------------------------------------------ cache

def test_result_cache_lru_and_byte_budget():
    c = ResultCache(max_bytes=100, max_items=10)
    c.put("a", 1, 40)
    c.put("b", 2, 40)
    assert c.get("a") == 1                  # 'a' now most recent
    c.put("c", 3, 40)                       # evicts 'b' (LRU)
    assert c.get("b") is None
    assert c.get("a") == 1 and c.get("c") == 3
    s = c.stats()
    assert s["evictions"] == 1 and s["bytes"] <= 100
    assert s["hits"] == 3 and s["misses"] == 1


def test_canonical_key_order_and_name_invariant():
    fp = "dna/plain"
    assert canonical_key(["AAC", "GGT"], fp) == canonical_key(
        ["GGT", "AAC"], fp)
    assert canonical_key(["AAC", "GGT"], fp) != canonical_key(
        ["AAC", "GGT"], fp, center="AAC")
    canon, perm = canonicalize(["GGT", "AAC"])
    assert canon == ["AAC", "GGT"] and perm == [1, 0]


# ---------------------------------------------------------------- service

@pytest.fixture(scope="module")
def service():
    svc = MSAService(ServiceConfig(max_wait_ms=20.0))
    yield svc
    if not svc._draining:
        svc.drain()


def test_service_concurrent_aligns_coalesce_and_match_reference(service):
    rng = np.random.default_rng(4)
    fams = [_family(rng, 4, 100) for _ in range(5)]
    results = [None] * len(fams)

    def call(i):
        results[i] = service.align([f"s{j}" for j in range(4)], fams[i])

    before = service.coalescer.stats()["engine_calls"]
    threads = [threading.Thread(target=call, args=(i,))
               for i in range(len(fams))]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    # every family exactly reproduces the single-host driver's MSA
    cfg = MSAConfig(method="plain")
    for fam, resp in zip(fams, results):
        canon, _ = canonicalize(fam)
        ref = center_star_msa(canon, cfg)
        entry = service.cache.peek(resp["alignment"]["msa_id"])
        assert np.array_equal(entry["msa"], ref.msa)
        for s, row in zip(fam, resp["alignment"]["rows"]):
            assert row.replace("-", "") == s
    # 5 requests x 3 queries each: far fewer engine calls than requests
    calls = service.coalescer.stats()["engine_calls"] - before
    assert calls < len(fams)


def test_service_cache_hit_is_byte_identical(service):
    rng = np.random.default_rng(5)
    fam = _family(rng, 4, 90)
    names = [f"n{j}" for j in range(4)]
    r1 = service.align(names, fam)
    r2 = service.align(names, fam)
    assert r1["cached"] is False and r2["cached"] is True
    assert json.dumps(r1["alignment"]) == json.dumps(r2["alignment"])
    # same set in another order hits the same entry, rows follow the order
    order = [2, 0, 3, 1]
    r3 = service.align([names[i] for i in order], [fam[i] for i in order])
    assert r3["cached"] is True
    assert r3["alignment"]["rows"] == [r1["alignment"]["rows"][i]
                                       for i in order]


def test_service_tree_and_tree_cache(service):
    rng = np.random.default_rng(6)
    fam = _family(rng, 5, 80)
    resp = service.align([f"t{j}" for j in range(5)], fam)
    mid = resp["alignment"]["msa_id"]
    t1 = service.tree(msa_id=mid)
    t2 = service.tree(msa_id=mid)
    assert t1["cached_tree"] is False and t2["cached_tree"] is True
    assert t1["newick"] == t2["newick"]
    assert t1["newick"].count("(") == 4                  # 5 leaves
    with pytest.raises(KeyError):
        service.tree(msa_id="bogus")


def test_incremental_add_bit_identical_to_full_realign(service):
    rng = np.random.default_rng(7)
    base = "".join(rng.choice(list("ACGT"), 120))
    fam = [base, base[:50] + base[51:], base[:30] + "T" + base[30:]]
    new = [base[:10] + "ACGT" + base[10:], base[3:]]     # forces new columns
    resp = service.align(["a", "b", "c"], fam)
    radd = service.align_add(resp["alignment"]["msa_id"], ["d", "e"], new)
    assert radd["add"]["realigned"] is False
    canon, _ = canonicalize(fam)
    full = center_star_msa(canon + new, MSAConfig(method="plain"))
    entry = service.cache.peek(radd["alignment"]["msa_id"])
    assert entry["width"] == full.width
    # previously aligned members reproduce the full realign bit-for-bit
    assert np.array_equal(entry["msa"][: len(fam)], full.msa[: len(fam)])
    assert np.array_equal(entry["msa"], full.msa)
    with pytest.raises(KeyError):
        service.align_add("bogus", ["x"], ["ACGT"])


def test_incremental_drift_triggers_full_realign():
    cfg = MSAConfig(method="plain")
    rng = np.random.default_rng(8)
    base = "".join(rng.choice(list("ACGT"), 80))
    prev = center_star_msa([base, base[:40] + base[41:]], cfg)
    new = [base[:10] + "ACGTACGTACGT" + base[10:]]
    res = add_to_msa(prev.msa, prev.center_idx, new, cfg,
                     drift_threshold=0.01)
    assert res.realigned is True
    full = center_star_msa([base, base[:40] + base[41:]] + new, cfg)
    assert np.array_equal(res.msa, full.msa)


def test_json_and_fasta_payloads_normalize_identically():
    from repro.serve.service import parse_sequences
    fasta_names, fasta_seqs = parse_sequences(
        {"fasta": ">a\nac.gt\r\nACGT\n"})
    json_names, json_seqs = parse_sequences(
        {"sequences": ["ac.gt\rACGT"], "names": ["a"]})
    assert fasta_seqs == json_seqs == ["AC-GTACGT"]
    with pytest.raises(ValueError, match="invalid character"):
        parse_sequences({"sequences": ["AC4GT"]})


def test_tree_from_sequences_survives_cache_eviction():
    # byte budget smaller than any entry: every put self-evicts, so the
    # tree path must use the entry it just computed, not re-resolve it
    svc = MSAService(ServiceConfig(max_wait_ms=1.0, cache_bytes=1))
    rng = np.random.default_rng(10)
    fam = _family(rng, 3, 60)
    resp = svc.tree(names=["a", "b", "c"], seqs=fam)
    assert resp["newick"].endswith(";")
    svc.drain()


def test_align_add_hit_credits_caller_names(service):
    rng = np.random.default_rng(11)
    fam = _family(rng, 3, 70)
    new = [_family(rng, 1, 70)[0]]
    mid = service.align(["a", "b", "c"], fam)["alignment"]["msa_id"]
    r1 = service.align_add(mid, ["first"], new)
    r2 = service.align_add(mid, ["second"], new)
    assert r1["cached"] is False and r2["cached"] is True
    assert r1["alignment"]["names"][-1] == "first"
    assert r2["alignment"]["names"][-1] == "second"
    assert r1["alignment"]["rows"] == r2["alignment"]["rows"]


def test_service_drain_refuses_new_work():
    svc = MSAService(ServiceConfig(max_wait_ms=1.0))
    rng = np.random.default_rng(9)
    fam = _family(rng, 3, 60)
    svc.align(["a", "b", "c"], fam)
    svc.drain()
    with pytest.raises(RuntimeError, match="draining"):
        svc.align(["a", "b", "c"], fam)
    assert svc.healthz()["status"] == "draining"


# ------------------------------------------------------------------- HTTP

def test_http_roundtrip_and_graceful_shutdown():
    svc = MSAService(ServiceConfig(max_wait_ms=2.0))
    httpd = serve_http(svc, "127.0.0.1", 0)
    port = httpd.server_address[1]
    thread = threading.Thread(target=httpd.serve_forever, daemon=True)
    thread.start()

    def post(path, obj):
        req = urllib.request.Request(
            f"http://127.0.0.1:{port}{path}",
            data=json.dumps(obj).encode(),
            headers={"Content-Type": "application/json"})
        try:
            with urllib.request.urlopen(req, timeout=120) as r:
                return r.status, json.loads(r.read())
        except urllib.error.HTTPError as e:
            return e.code, json.loads(e.read())

    with urllib.request.urlopen(
            f"http://127.0.0.1:{port}/healthz", timeout=30) as r:
        health = json.loads(r.read())
    assert health["status"] == "ok"

    fasta = ">a\nACGTACGTAAGGCC\n>b\nacgtacgaaaggcc\r\n>c\nACGTTCGTAAGGC\n"
    st, resp = post("/align", {"fasta": fasta})
    assert st == 200
    rows = resp["alignment"]["rows"]
    assert rows[1].replace("-", "") == "ACGTACGAAAGGCC"  # CRLF+lower fixed
    mid = resp["alignment"]["msa_id"]

    st, tresp = post("/tree", {"msa_id": mid})
    assert st == 200 and tresp["newick"].endswith(";")

    st, aresp = post("/align/add",
                     {"msa_id": mid, "sequences": ["ACGTACGTAAGGC"],
                      "names": ["d"]})
    assert st == 200 and len(aresp["alignment"]["rows"]) == 4

    assert post("/tree", {"msa_id": "nope"})[0] == 404
    assert post("/align", {"bogus": 1})[0] == 400

    httpd.shutdown()
    httpd.server_close()          # waits for in-flight handler threads
    svc.drain()
    assert svc.coalescer.stats()["in_flight"] == 0
