"""Sharding planner invariants on a trivial mesh + spec sanity on fake
multi-axis meshes (using abstract mesh shapes via divisibility math)."""
import functools

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs import ALL_ARCHS, SHAPES, get_arch, shape_applicable
from repro.launch.mesh import make_local_mesh
from repro.launch.steps import input_specs, microbatches_for
from repro.models import sharding_plan as sp
from repro.models.transformer import init_params


def test_param_specs_cover_tree():
    mesh = make_local_mesh((1, 1), ("data", "model"))
    cfg = get_arch("kimi-k2-1t-a32b").smoke
    shapes = jax.eval_shape(functools.partial(init_params, cfg),
                            jax.random.PRNGKey(0))
    specs = sp.params_pspecs(shapes, mesh)
    n_leaves = len(jax.tree.leaves(shapes))
    n_specs = len(jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P)))
    assert n_specs == n_leaves


def test_spec_ranks_match_leaf_ranks():
    mesh = make_local_mesh((1, 1), ("data", "model"))
    for arch in ALL_ARCHS:
        cfg = get_arch(arch).smoke
        shapes = jax.eval_shape(functools.partial(init_params, cfg),
                                jax.random.PRNGKey(0))

        def check(path, leaf):
            name = str(getattr(path[-1], "key", path[-1]))
            spec = sp.param_spec(name, leaf.shape, mesh)
            assert len(spec) <= len(leaf.shape), (arch, path, spec, leaf.shape)
        jax.tree_util.tree_map_with_path(check, shapes)


def test_input_specs_shapes():
    for arch in ALL_ARCHS:
        cfg = get_arch(arch).config
        for shape_name, shape in SHAPES.items():
            ok, _ = shape_applicable(cfg, shape)
            if not ok:
                continue
            specs = input_specs(arch, shape_name)
            if shape.kind in ("train", "prefill"):
                main = specs.get("tokens", specs.get("embeds"))
                assert main.shape[0] == shape.global_batch
                assert main.shape[1] == shape.seq_len
            else:
                assert specs["token"].shape[0] == shape.global_batch


def test_skip_rules():
    assert not shape_applicable(get_arch("gemma-2b").config,
                                SHAPES["long_500k"])[0]
    assert not shape_applicable(get_arch("hubert-xlarge").config,
                                SHAPES["decode_32k"])[0]
    assert shape_applicable(get_arch("mamba2-130m").config,
                            SHAPES["long_500k"])[0]
    assert shape_applicable(get_arch("h2o-danube-3-4b").config,
                            SHAPES["long_500k"])[0]
    assert shape_applicable(get_arch("jamba-1.5-large-398b").config,
                            SHAPES["long_500k"])[0]


def test_microbatch_divisibility():
    mesh = make_local_mesh((1, 1), ("data", "model"))
    for arch in ALL_ARCHS:
        mu = microbatches_for(arch, "train_4k", mesh)
        assert SHAPES["train_4k"].global_batch % mu == 0
