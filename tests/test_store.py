"""Executable spec of the persistent MSA store (ISSUE 10).

Four harnesses make the stateful subsystem trustworthy:

  * crash-atomicity: faults injected at randomized points inside the
    commit path (>= 200 schedules) — after "restart" (a fresh
    ``MSAStore`` over the same directory) the named alignment restores
    to exactly the previous committed generation or exactly the new
    one, never a torn state, and ingestion continues;
  * concurrency stress: threads interleave ``/align/add`` + ``/align``
    + ``/tree`` against one named alignment through the real HTTP
    front end — every response is internally consistent, generations
    are monotone per thread, counters reconcile on drain, and the
    final store contents equal a serial replay of the committed order;
  * incremental-vs-realign property: random add sequences onto random
    seed MSAs stay bit-identical to a full center-star realign, and a
    drift-triggered background realign swap is bit-identical to a cold
    full realign of the same member set;
  * kill-and-resume e2e (subprocess): SIGKILL of a serving worker, then
    restart from the same ``--store-dir``, restores every committed
    generation bit-identically and keeps ingesting.
"""
import json
import os
import signal
import socket
import subprocess
import sys
import threading
import time
import urllib.error
import urllib.request
from pathlib import Path

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:      # CI image has no hypothesis; seeded fallback
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core.alphabet import DNA
from repro.core.msa import MSAConfig, center_star_msa
from repro.dist.fault import StepFailure
from repro.obs import REGISTRY
from repro.serve import MSAService, ServiceConfig, serve_http
from repro.serve.store import (COMMIT_FAULT_LABELS, MSAStore, StoreError,
                               content_fingerprint)

SRC = str(Path(__file__).resolve().parent.parent / "src")
CFG = MSAConfig(method="plain")


def _seq(rng, n):
    return "".join("ACGT"[c] for c in rng.integers(0, 4, n))


def _sub(s, rng, k=2):
    s = list(s)
    for _ in range(k):
        s[rng.integers(0, len(s))] = "ACGT"[rng.integers(0, 4)]
    return "".join(s)


def _make_store(tmp_path, **kw):
    kw.setdefault("drift_threshold", 10.0)
    return MSAStore(tmp_path / "store", **kw)


def _seeded(store, name="fam", n=3, L=40, seed=0):
    rng = np.random.default_rng(seed)
    base = _seq(rng, L)
    fam = [base] + [_sub(base, rng) for _ in range(n - 1)]
    res = center_star_msa(fam, CFG)
    return store.create(name, msa=res.msa, center_idx=res.center_idx,
                        seqs=fam, names=[f"m{i}" for i in range(n)]), fam


def _entries_equal(a, b):
    return (a.generation == b.generation and a.fingerprint == b.fingerprint
            and np.array_equal(a.msa, b.msa) and a.seqs == b.seqs
            and a.names == b.names and a.center_idx == b.center_idx
            and a.base_width == b.base_width)


# ------------------------------------------------------------- store basics

def test_store_create_add_restart_roundtrip(tmp_path):
    store = _make_store(tmp_path, keep=8)
    e0, fam = _seeded(store)
    rng = np.random.default_rng(1)
    new = [fam[0][:11] + "ACG" + fam[0][11:]]
    e1, info = store.add("fam", ["d"], new, CFG)
    assert e1.generation == 1 and info["n_new"] == 1
    assert e1.seqs == tuple(fam) + tuple(new)
    # incremental commit is bit-identical to the full realign (same
    # frozen first-center) — the serve-layer invariant now persistent
    full = center_star_msa(fam + new, CFG)
    assert np.array_equal(e1.msa, full.msa)
    store.close()

    # "restart": a fresh store over the same directory
    store2 = _make_store(tmp_path)
    r = store2.get("fam")
    assert _entries_equal(r, e1)
    assert store2.names() == ["fam"]
    # ingestion continues from the restored generation
    e2, _ = store2.add("fam", ["e"], [_sub(fam[0], rng)], CFG)
    assert e2.generation == 2
    store2.close()


def test_store_retention_keeps_newest_generations(tmp_path):
    store = _make_store(tmp_path, keep=2)
    _, fam = _seeded(store)
    rng = np.random.default_rng(2)
    for i in range(4):
        store.add("fam", [f"x{i}"], [_sub(fam[0], rng)], CFG)
    gens = store.generations("fam")
    assert gens == [3, 4]                        # newest keep=2 retained
    store.close()


def test_store_rejects_bad_names_and_duplicates(tmp_path):
    store = _make_store(tmp_path)
    _seeded(store)
    with pytest.raises(StoreError, match="already exists"):
        _seeded(store)
    with pytest.raises(ValueError, match="invalid alignment name"):
        store.create("../evil", msa=np.zeros((1, 4), np.int8),
                     center_idx=0, seqs=["AAAA"], names=["a"])
    with pytest.raises(KeyError):
        store.get("nope")
    store.close()


def test_corrupt_latest_generation_falls_back(tmp_path):
    store = _make_store(tmp_path, keep=8)
    e0, fam = _seeded(store)
    rng = np.random.default_rng(3)
    e1, _ = store.add("fam", ["d"], [_sub(fam[0], rng)], CFG)
    store.close()

    # torn bytes: truncate the newest generation file
    p1 = tmp_path / "store" / "fam" / f"gen_{1:010d}.npz"
    p1.write_bytes(p1.read_bytes()[:100])
    with pytest.warns(UserWarning, match="unreadable"):
        r = _make_store(tmp_path).get("fam")
    assert _entries_equal(r, e0)                 # previous generation wins

    # content/fingerprint mismatch: a readable file that lies is skipped
    from repro.dist.checkpoint import atomic_save_npz
    atomic_save_npz(p1, {
        "schema_version": np.int64(1), "name": np.str_("fam"),
        "msa": e0.msa, "center_idx": np.int64(e0.center_idx),
        "generation": np.int64(1), "base_width": np.int64(e0.base_width),
        "seqs": np.array(e0.seqs), "names": np.array(e0.names),
        "fingerprint": np.str_("0" * 64)})
    with pytest.warns(UserWarning, match="fingerprint mismatch"):
        r = _make_store(tmp_path).get("fam")
    assert _entries_equal(r, e0)


# --------------------------------------------------- crash-atomicity (prop)

class _FaultAt:
    """Raises StepFailure at the k-th hook invocation; records the label."""

    def __init__(self, fire_at):
        self.fire_at = fire_at
        self.calls = 0
        self.fired_label = None

    def __call__(self, label):
        self.calls += 1
        if self.calls == self.fire_at:
            self.fired_label = label
            raise StepFailure(f"injected at {label}")


def test_commit_crash_atomicity_property(tmp_path):
    """>= 200 randomized fault schedules over the commit path: restore
    always yields the previous committed generation (fault before the
    atomic replace) or the new one (fault at/after it) — never a torn
    state — and ingestion continues after every "restart"."""
    import random

    # fixed family so jit caches are shared across all schedules
    rng = np.random.default_rng(7)
    base = _seq(rng, 32)
    fam = [base, _sub(base, rng), _sub(base, rng)]
    adds = [base[:9] + "ACG" + base[9:], _sub(base, rng),
            base[:20] + "T" + base[20:]]
    res = center_star_msa(fam, CFG)
    n_labels = len(COMMIT_FAULT_LABELS)
    # labels strictly before the replace must roll back; at/after, commit
    replace_idx = COMMIT_FAULT_LABELS.index("save.post-replace")

    n_schedules = 0
    for seed in range(200):
        r = random.Random(seed)
        root = tmp_path / f"s{seed}"
        store = MSAStore(root, keep=8, drift_threshold=10.0)
        e, _ = _seeded_fixed(store, fam, res)
        # 0-2 clean adds first so faults hit arbitrary generations
        for j in range(r.randrange(3)):
            e, _ = store.add("fam", [f"pre{j}"], [adds[j]], CFG)
        prev = store.get("fam")

        fault = _FaultAt(r.randrange(1, n_labels + 1))
        store.fault_hook = fault
        new_seq = adds[r.randrange(len(adds))]
        with pytest.raises(StepFailure):
            store.add("fam", ["faulted"], [new_seq], CFG)
        store.fault_hook = None
        store.close()
        n_schedules += 1

        restored = MSAStore(root, keep=8, drift_threshold=10.0)
        got = restored.get("fam")
        fired = COMMIT_FAULT_LABELS.index(fault.fired_label)
        if fired < replace_idx:
            # crash before the replace: previous generation, bit-identical
            assert _entries_equal(got, prev), \
                f"seed {seed}: torn state after fault at {fault.fired_label}"
        else:
            # crash after the replace: the commit happened exactly once
            assert got.generation == prev.generation + 1
            assert got.seqs == prev.seqs + (new_seq,)
            assert got.names == prev.names + ("faulted",)
            assert content_fingerprint(got.msa, got.center_idx,
                                       got.names) == got.fingerprint
        # ingestion continues from the restored truth
        nxt, _ = restored.add("fam", ["after"], [adds[0]], CFG)
        assert nxt.generation == got.generation + 1
        restored.close()
    assert n_schedules >= 200


def _seeded_fixed(store, fam, res):
    entry = store.create("fam", msa=res.msa, center_idx=res.center_idx,
                         seqs=fam, names=[f"m{i}" for i in range(len(fam))])
    return entry, fam


# --------------------------------------- incremental vs realign (property)

DNA_SEQ = st.text(alphabet="ACGT", min_size=8, max_size=40)


@settings(max_examples=10, deadline=None)
@given(st.lists(DNA_SEQ, min_size=2, max_size=4),
       st.lists(DNA_SEQ, min_size=1, max_size=3))
def test_store_adds_bit_identical_to_full_realign(seed_fam, new_seqs):
    """Every committed generation of accreted adds equals the cold full
    center-star realign of the same member set (same frozen first
    center) — the serve-layer incremental invariant, now per
    generation and persistent."""
    import tempfile
    res = center_star_msa(seed_fam, CFG)
    with tempfile.TemporaryDirectory() as d:
        store = MSAStore(d, keep=99, drift_threshold=10.0, realign="never")
        store.create("fam", msa=res.msa, center_idx=res.center_idx,
                     seqs=seed_fam,
                     names=[f"m{i}" for i in range(len(seed_fam))])
        members = list(seed_fam)
        for g, s in enumerate(new_seqs, start=1):
            entry, _ = store.add("fam", [f"n{g}"], [s], CFG)
            members.append(s)
            full = center_star_msa(members, CFG)
            assert entry.generation == g
            assert entry.width == full.width
            assert np.array_equal(entry.msa, full.msa), \
                f"generation {g} diverged from the cold realign"
        store.close()


def test_background_realign_swap_is_cold_full_realign(tmp_path):
    store = _make_store(tmp_path, keep=8, drift_threshold=0.2)
    e0, fam = _seeded(store)
    # an insert-heavy add pushes cumulative growth past the threshold
    big = fam[0][:4] + "ACGTACGTACGTACGT" + fam[0][4:]
    e1, info = store.add("fam", ["big"], [big], CFG)
    assert info["drifted"] and info["realign_pending"]
    # readers are never blocked: whatever they see is a committed
    # generation — the pre-swap one or (if the worker won the race)
    # the realigned one
    assert store.get("fam").generation in (e1.generation,
                                           e1.generation + 1)
    store.wait_realigns(timeout=300)
    swapped = store.get("fam")
    cold = center_star_msa(list(e1.seqs), CFG)
    assert swapped.generation == e1.generation + 1
    assert np.array_equal(swapped.msa, cold.msa)
    assert swapped.base_width == cold.width      # drift baseline reset
    assert swapped.growth() == 0.0
    # the swap is durable: a restart restores the realigned generation
    store.close()
    store2 = _make_store(tmp_path)
    assert _entries_equal(store2.get("fam"), swapped)
    store2.close()


# ------------------------------------------------- service + tree wiring

def test_service_named_align_add_tree_generation_keys(tmp_path):
    svc = MSAService(ServiceConfig(max_wait_ms=1.0,
                                   store_dir=str(tmp_path / "store"),
                                   store_realign="never"))
    rng = np.random.default_rng(11)
    base = _seq(rng, 60)
    fam = [base, _sub(base, rng), _sub(base, rng)]
    r = svc.align_named("flu", ["a", "b", "c"], fam)
    assert r["created"] is True
    assert r["alignment"]["generation"] == 0
    fp0 = r["alignment"]["fingerprint"]

    # load without sequences returns the committed generation
    r2 = svc.align_named("flu")
    assert r2["created"] is False
    assert r2["alignment"]["fingerprint"] == fp0

    # creating over an existing name is a conflict, not an overwrite
    with pytest.raises(StoreError, match="already exists"):
        svc.align_named("flu", ["x"], ["ACGTACGT"])

    t0 = svc.tree(name="flu")
    t0b = svc.tree(name="flu")
    assert t0["cached_tree"] is False and t0b["cached_tree"] is True
    assert t0["fingerprint"] == fp0

    # an add bumps the generation; the tree key follows the fingerprint,
    # so the next tree is a rebuild — trees never mix generations
    ra = svc.align_add(names=["d"], seqs=[_sub(base, rng)], name="flu")
    assert ra["alignment"]["generation"] == 1
    assert ra["alignment"]["fingerprint"] != fp0
    t1 = svc.tree(name="flu")
    assert t1["cached_tree"] is False
    assert t1["fingerprint"] == ra["alignment"]["fingerprint"]
    assert t1["n_leaves"] == 4

    h = svc.healthz()
    assert h["store"]["names"] == 1
    assert h["store"]["generations"] == {"flu": 1}
    assert "flu" in svc.statusz()
    svc.drain()

    # the service layer restores the store across restarts
    svc2 = MSAService(ServiceConfig(max_wait_ms=1.0,
                                    store_dir=str(tmp_path / "store"),
                                    store_realign="never"))
    r3 = svc2.align_named("flu")
    assert r3["alignment"]["generation"] == 1
    assert r3["alignment"]["fingerprint"] == ra["alignment"]["fingerprint"]
    assert r3["alignment"]["rows"] == ra["alignment"]["rows"]
    svc2.drain()


def test_service_without_store_rejects_named_requests():
    svc = MSAService(ServiceConfig(max_wait_ms=1.0))
    with pytest.raises(ValueError, match="store"):
        svc.align_named("flu", ["a"], ["ACGT"])
    with pytest.raises(ValueError, match="store"):
        svc.tree(name="flu")
    svc.drain()


# ------------------------------------------------- HTTP concurrency stress

def _post(port, path, obj, timeout=120):
    req = urllib.request.Request(
        f"http://127.0.0.1:{port}{path}",
        data=json.dumps(obj).encode(),
        headers={"Content-Type": "application/json"})
    try:
        with urllib.request.urlopen(req, timeout=timeout) as resp:
            return resp.status, json.loads(resp.read())
    except urllib.error.HTTPError as e:
        return e.code, json.loads(e.read())


def _counter_totals(snap):
    out = {}
    for fam in ("repro_requests_started_total",
                "repro_requests_finished_total",
                "repro_requests_rejected_total"):
        out[fam] = sum(s["value"]
                       for s in snap.get(fam, {"samples": []})["samples"])
    return out


def test_concurrent_http_stress_is_consistent_and_replayable(tmp_path):
    """N threads interleave /align/add + /align + /tree on one named
    alignment through the real HTTP front end: no 500s, every response
    internally consistent, per-thread generations monotone, counters
    reconcile on drain, and the final store equals a serial replay of
    the committed add order."""
    svc = MSAService(ServiceConfig(max_wait_ms=1.0,
                                   store_dir=str(tmp_path / "store"),
                                   store_realign="never"))
    httpd = serve_http(svc, "127.0.0.1", 0)
    port = httpd.server_address[1]
    threading.Thread(target=httpd.serve_forever, daemon=True).start()
    before = _counter_totals(REGISTRY.snapshot())

    rng = np.random.default_rng(13)
    base = _seq(rng, 50)
    fam = [base, _sub(base, rng), _sub(base, rng)]
    st_, r = _post(port, "/align", {"name": "stress", "sequences": fam,
                                    "names": ["s0", "s1", "s2"]})
    assert st_ == 200 and r["created"]
    # the create path persists the *canonical* member order — snapshot
    # generation 0 as the replay seed
    seed = svc.store.get("stress")
    assert seed.generation == 0

    n_threads, ops_per_thread = 6, 6
    # substitution-only adds: width stays fixed, so no drift/realign —
    # the interleaving is the only nondeterminism under test
    add_seqs = {f"t{t}a{i}": _sub(base, rng)
                for t in range(n_threads) for i in range(ops_per_thread)}
    failures, lock = [], threading.Lock()

    def worker(t):
        local_rng = np.random.default_rng(100 + t)
        last_gen = -1
        for i in range(ops_per_thread):
            op = ("add", "read", "tree")[int(local_rng.integers(0, 3))]
            try:
                if op == "add":
                    key = f"t{t}a{i}"
                    code, resp = _post(port, "/align/add",
                                       {"name": "stress",
                                        "sequences": [add_seqs[key]],
                                        "names": [key]})
                elif op == "read":
                    code, resp = _post(port, "/align", {"name": "stress"})
                else:
                    code, resp = _post(port, "/tree", {"name": "stress"})
                assert code == 200, f"{op} -> {code}: {resp}"
                if op == "tree":
                    assert resp["newick"].endswith(";")
                    gen = resp["generation"]
                else:
                    aln = resp["alignment"]
                    gen = aln["generation"]
                    # internally consistent: one width, rows decode to
                    # their ungapped members
                    assert all(len(row) == aln["width"]
                               for row in aln["rows"])
                    assert len(aln["rows"]) == len(aln["names"])
                assert gen >= last_gen, "generation went backwards"
                last_gen = gen
            except Exception as e:                # noqa: BLE001
                with lock:
                    failures.append(f"thread {t} op {i} ({op}): {e!r}")

    threads = [threading.Thread(target=worker, args=(t,))
               for t in range(n_threads)]
    for th in threads:
        th.start()
    for th in threads:
        th.join()
    assert not failures, failures

    httpd.shutdown()
    httpd.server_close()
    svc.drain()

    # drain reconciles: started == finished + rejected (delta over test)
    after = _counter_totals(REGISTRY.snapshot())
    d_started = after["repro_requests_started_total"] \
        - before["repro_requests_started_total"]
    d_finished = after["repro_requests_finished_total"] \
        - before["repro_requests_finished_total"]
    d_rejected = after["repro_requests_rejected_total"] \
        - before["repro_requests_rejected_total"]
    assert d_started == d_finished + d_rejected

    # final store contents == serial replay of the committed add order
    final = svc.store.get("stress")
    assert final.names[:len(seed.names)] == seed.names
    committed = list(final.names[len(seed.names):])
    replay = MSAStore(tmp_path / "replay", keep=4, drift_threshold=10.0,
                      realign="never")
    replay.create("stress", msa=seed.msa, center_idx=seed.center_idx,
                  seqs=seed.seqs, names=seed.names)
    for key in committed:
        replay.add("stress", [key], [add_seqs[key]], CFG)
    replayed = replay.get("stress")
    assert replayed.generation == final.generation
    assert np.array_equal(replayed.msa, final.msa)
    assert replayed.fingerprint == final.fingerprint
    replay.close()


# --------------------------------------------------- kill-and-resume (e2e)

def _free_port():
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def _spawn_server(store_dir):
    port = _free_port()
    env = dict(os.environ, PYTHONPATH=SRC)
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.serve_msa",
         "--port", str(port), "--max-wait-ms", "1",
         "--store-dir", str(store_dir), "--store-realign", "never"],
        env=env, stdout=subprocess.PIPE, stderr=subprocess.STDOUT)
    deadline = time.time() + 300
    while True:
        try:
            with urllib.request.urlopen(
                    f"http://127.0.0.1:{port}/healthz", timeout=5) as r:
                json.loads(r.read())
            return proc, port
        except (urllib.error.URLError, OSError):
            if proc.poll() is not None:
                out = proc.stdout.read().decode(errors="replace")
                raise RuntimeError(f"serve_msa died at startup:\n{out}")
            if time.time() > deadline:
                proc.kill()
                raise RuntimeError("serve_msa did not become healthy")
            time.sleep(0.3)


def _rows_fingerprint(aln):
    """Recompute the content fingerprint from a JSON alignment payload —
    the client-side integrity check that a response is not torn."""
    msa = np.stack([DNA.encode_aligned(row) for row in aln["rows"]])
    return content_fingerprint(msa, aln["center_idx"], aln["names"])


def test_kill_and_resume_restores_committed_state(tmp_path):
    """SIGKILL a serving worker (idle, then again mid-traffic); each
    restart from the same --store-dir restores the last committed
    generation bit-identically and ingestion continues."""
    store_dir = tmp_path / "store"
    rng = np.random.default_rng(17)
    base = _seq(rng, 48)
    fam = [base, _sub(base, rng), _sub(base, rng)]

    proc, port = _spawn_server(store_dir)
    try:
        st_, r = _post(port, "/align", {"name": "cov", "sequences": fam,
                                        "names": ["a", "b", "c"]})
        assert st_ == 200
        for i in range(3):
            st_, r = _post(port, "/align/add",
                           {"name": "cov", "sequences": [_sub(base, rng)],
                            "names": [f"d{i}"]})
            assert st_ == 200
        committed = r["alignment"]             # gen 3, quiesced
        assert committed["generation"] == 3
        assert _rows_fingerprint(committed) == committed["fingerprint"]
    finally:
        proc.kill()
        proc.wait()

    # ---- restart 1: idle kill — restore must be bit-identical
    proc, port = _spawn_server(store_dir)
    killed_mid_traffic = []
    try:
        st_, r = _post(port, "/align", {"name": "cov"})
        assert st_ == 200
        aln = r["alignment"]
        assert aln["generation"] == committed["generation"]
        assert aln["fingerprint"] == committed["fingerprint"]
        assert aln["rows"] == committed["rows"]
        assert aln["names"] == committed["names"]

        # now kill MID-TRAFFIC: adds racing the SIGKILL; responses that
        # made it back are commitments the restart must honor
        stop = threading.Event()

        def traffic():
            i = 0
            while not stop.is_set() and i < 50:
                try:
                    code, resp = _post(port, "/align/add",
                                       {"name": "cov",
                                        "sequences": [_sub(base, rng)],
                                        "names": [f"k{i}"]},
                                       timeout=10)
                    if code == 200:
                        killed_mid_traffic.append(resp["alignment"])
                except Exception:              # noqa: BLE001
                    return                     # server died under us
                i += 1

        t = threading.Thread(target=traffic)
        t.start()
        time.sleep(0.4)                        # let some adds commit
        proc.send_signal(signal.SIGKILL)
        stop.set()
        t.join(timeout=60)
    finally:
        proc.kill()
        proc.wait()

    # ---- restart 2: mid-traffic kill — last acknowledged add is durable
    proc, port = _spawn_server(store_dir)
    try:
        st_, r = _post(port, "/align", {"name": "cov"})
        assert st_ == 200
        aln = r["alignment"]
        # never torn: the payload's content hashes to its fingerprint
        assert _rows_fingerprint(aln) == aln["fingerprint"]
        acked = killed_mid_traffic[-1] if killed_mid_traffic else committed
        assert aln["generation"] >= acked["generation"]
        if aln["generation"] == acked["generation"]:
            # bit-identical to the last acknowledged committed state
            assert aln["fingerprint"] == acked["fingerprint"]
            assert aln["rows"] == acked["rows"]
        else:
            # at most one unacknowledged-but-committed add beyond it
            n = len(acked["names"])
            assert aln["names"][:n] == acked["names"]
        # ingestion continues across the crash
        st_, r2 = _post(port, "/align/add",
                        {"name": "cov", "sequences": [_sub(base, rng)],
                         "names": ["resumed"]})
        assert st_ == 200
        assert r2["alignment"]["generation"] == aln["generation"] + 1
        st_, t2 = _post(port, "/tree", {"name": "cov"})
        assert st_ == 200 and t2["newick"].endswith(";")
        assert t2["fingerprint"] == r2["alignment"]["fingerprint"]
    finally:
        proc.kill()
        proc.wait()
