"""End-to-end behaviour: the full HAlign-II pipeline on a simulated family —
align (kmer center-star), score (SP), distance, NJ + HPTree cluster-merge
phylogeny, ML evaluation, newick export — with ground-truth validation."""
import jax.numpy as jnp
import numpy as np

from repro.core import alphabet as ab
from repro.core import cluster, distance, likelihood, nj, sp_score, treeio
from repro.core.msa import MSAConfig, center_star_msa, decode_msa
from repro.data import SimConfig, simulate_family, write_fasta, read_fasta


class _T:
    def __init__(self, children, root):
        self.children, self.root = children, root


def test_full_pipeline(tmp_path):
    fam = simulate_family(SimConfig(n_leaves=20, root_len=600,
                                    branch_sub=0.02, branch_indel=0.001,
                                    seed=42))
    # FASTA round trip (the HDFS stand-in)
    write_fasta(tmp_path / "fam.fasta", fam.names, fam.seqs)
    names, seqs = read_fasta(tmp_path / "fam.fasta")
    assert seqs == fam.seqs

    # 1. MSA
    cfg = MSAConfig(method="kmer", k=10, max_anchors=128, max_seg=48)
    res = center_star_msa(seqs, cfg)
    rows = decode_msa(res.msa, cfg)
    for s, r in zip(seqs, rows):
        assert r.replace("-", "") == s

    # 2. quality
    msa = jnp.asarray(res.msa)
    gap, nch = ab.DNA.gap_code, ab.DNA.n_chars
    sp = float(sp_score.avg_sp(msa, gap_code=gap, n_chars=nch))
    assert sp >= 0

    # 3. trees: direct NJ and HPTree-style cluster-merge
    D = distance.distance_matrix(msa, gap_code=gap, n_chars=nch)
    tree = nj.neighbor_joining(D, 20)
    rf_direct = treeio.normalized_rf(
        _T(np.asarray(tree.children), int(tree.root)),
        _T(fam.children, fam.root), 20)
    assert rf_direct <= 0.4

    cp = cluster.cluster_phylogeny(res.msa, gap_code=gap, n_chars=nch,
                                   cfg=cluster.ClusterConfig(target_cluster=8,
                                                             seed=0))
    sets = treeio.leaf_sets(cp.children, cp.root, 20)
    assert sets[cp.root] == frozenset(range(20))

    # 4. ML evaluation: both trees produce finite logL
    ll_direct = float(likelihood.log_likelihood(
        msa, tree.children, tree.blen, tree.root, gap_code=gap))
    ll_cluster = float(likelihood.log_likelihood(
        msa, jnp.asarray(cp.children), jnp.asarray(cp.blen),
        cp.root, gap_code=gap))
    assert np.isfinite(ll_direct) and np.isfinite(ll_cluster)

    # 5. newick
    nwk = treeio.to_newick(tree.children, tree.blen, int(tree.root), names)
    assert all(n in nwk for n in names)


def test_simulator_ground_truth_consistency():
    fam = simulate_family(SimConfig(n_leaves=8, root_len=200, seed=1))
    assert len(fam.seqs) == 8
    sets = treeio.leaf_sets(fam.children, fam.root, 8)
    assert sets[fam.root] == frozenset(range(8))


def test_protein_family_pipeline():
    fam = simulate_family(SimConfig(n_leaves=10, root_len=300,
                                    alphabet="protein", branch_sub=0.05,
                                    branch_indel=0.002, seed=9))
    cfg = MSAConfig(method="sw", alphabet="protein", gap_open=11, gap_extend=1)
    res = center_star_msa(fam.seqs, cfg)
    for s, r in zip(fam.seqs, decode_msa(res.msa, cfg)):
        assert r.replace("-", "") == s
    gap, nch = ab.PROTEIN.gap_code, ab.PROTEIN.n_chars
    D = distance.distance_matrix(jnp.asarray(res.msa), gap_code=gap,
                                 n_chars=nch, correct=False)
    tree = nj.neighbor_joining(D, 10)
    rf = treeio.normalized_rf(
        _T(np.asarray(tree.children), int(tree.root)),
        _T(fam.children, fam.root), 10)
    assert rf <= 0.5
