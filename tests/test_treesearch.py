"""Multi-start NNI+SPR tree search: fleet behavior, restartability
(StepFailure replay and kill-and-resume must be bit-identical to the
uninterrupted run), host==mesh determinism, and the engine/CLI wiring."""
import json
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.core import treeio
from repro.core.alphabet import DNA
from repro.core.msa import MSAConfig, center_star_msa
from repro.data import SimConfig, phi_dna, simulate_family
from repro.dist.fault import StepFailure
from repro.phylo import TreeEngine
from repro.phylo.treesearch import TreeSearcher

BASE = dict(gap_code=DNA.gap_code, starts=3, spr_radius=2, rounds=3,
            model="jc69", steps=40, seed=0)


@pytest.fixture(scope="module")
def msa8():
    fam = simulate_family(SimConfig(n_leaves=8, root_len=120, seed=1))
    return center_star_msa(fam.seqs, MSAConfig(method="kmer")).msa


def _newick(res):
    return treeio.to_newick(res.children, res.blen, res.root)


def _same(a, b):
    assert _newick(a) == _newick(b)
    assert a.logl_final == b.logl_final
    assert np.array_equal(a.trajectories, b.trajectories, equal_nan=True)


# ------------------------------------------------------------------- fleet

def test_fleet_improves_and_trajectories_monotone(msa8):
    res = TreeSearcher(**BASE).search(msa8)
    assert res.start_labels == ("nj", "cluster", "random2")
    assert res.logl_final >= res.logl_init
    assert res.best_start == int(np.argmax(res.trajectories[:, -1]))
    # per-start logL never decreases across rounds (moves are accepted
    # only when strictly improving; a deactivated search stays flat)
    traj = res.trajectories
    assert np.isfinite(traj).all()
    assert (np.diff(traj, axis=1) >= -1e-4).all()
    # the random start must have climbed via accepted moves
    assert res.n_moves.sum() > 0


def test_random_start_diversity(msa8):
    """Distinct seeds give distinct random-addition topologies."""
    from repro.phylo.treesearch import random_addition_tree
    t0 = random_addition_tree(8, np.random.default_rng((0, 2)))
    t1 = random_addition_tree(8, np.random.default_rng((1, 2)))
    b0 = treeio.bipartitions(t0[0], t0[2], 8)
    b1 = treeio.bipartitions(t1[0], t1[2], 8)
    assert b0 != b1


# ----------------------------------------------------------- restartability

def test_step_failure_replay_bit_identical(msa8, tmp_path):
    """Inject StepFailure at a randomized round; the replayed run must
    produce bit-identical Newick bytes, logL, and trajectories."""
    clean = TreeSearcher(ckpt_dir=str(tmp_path / "clean"),
                         **BASE).search(msa8)
    fail_at = int(np.random.default_rng(42).integers(1, BASE["rounds"] + 1))

    class Once:
        fired = False

        def __call__(self, step):
            if step == fail_at and not self.fired:
                self.fired = True
                raise StepFailure(f"injected at round {step}")

    faulty = TreeSearcher(ckpt_dir=str(tmp_path / "faulty"),
                          failure_hook=Once(), **BASE).search(msa8)
    _same(clean, faulty)
    assert _newick(clean).encode() == _newick(faulty).encode()


def test_kill_and_resume_bit_identical(msa8, tmp_path):
    """A non-StepFailure kill escapes the loop; resume=True continues
    from the newest checkpoint to the same final tree, bit for bit."""
    clean = TreeSearcher(ckpt_dir=str(tmp_path / "clean"),
                         **BASE).search(msa8)

    def kill(step):
        if step == 2:
            raise RuntimeError("killed")

    with pytest.raises(RuntimeError, match="killed"):
        TreeSearcher(ckpt_dir=str(tmp_path / "killed"),
                     failure_hook=kill, **BASE).search(msa8)
    resumed = TreeSearcher(ckpt_dir=str(tmp_path / "killed"),
                           resume=True, **BASE).search(msa8)
    _same(clean, resumed)
    assert _newick(clean).encode() == _newick(resumed).encode()


def test_inline_loop_matches_checkpointed(msa8, tmp_path):
    """ckpt_dir=None takes the plain loop — same deterministic result."""
    _same(TreeSearcher(**BASE).search(msa8),
          TreeSearcher(ckpt_dir=str(tmp_path), **BASE).search(msa8))


# ------------------------------------------------------- host == mesh

MESH_SCRIPT = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import sys
sys.path.insert(0, %r)
import json
import numpy as np
from repro.core import treeio
from repro.core.msa import MSAConfig, center_star_msa
from repro.data import SimConfig, simulate_family
from repro.launch.mesh import make_local_mesh
from repro.phylo.treesearch import TreeSearcher

fam = simulate_family(SimConfig(n_leaves=8, root_len=120, seed=1))
msa = center_star_msa(fam.seqs, MSAConfig(method="kmer")).msa
base = dict(gap_code=4, starts=3, spr_radius=2, rounds=2, model="jc69",
            steps=30, seed=0)
host = TreeSearcher(**base).search(msa)
mesh = make_local_mesh((2, 1), ("data", "model"))
dist = TreeSearcher(mesh=mesh, **base).search(msa)
print("RESULT " + json.dumps({
    "same_newick": treeio.to_newick(host.children, host.blen, host.root)
        == treeio.to_newick(dist.children, dist.blen, dist.root),
    "same_logl": bool(host.logl_final == dist.logl_final),
    "same_traj": bool(np.array_equal(host.trajectories, dist.trajectories,
                                     equal_nan=True)),
    "moved": int(host.n_moves.sum())}))
'''


def test_search_host_vs_mesh_bit_identical():
    """Fixed seed, K=3 starts: host run and 2x1-mesh run must agree on
    the best tree AND every per-start logL trajectory, bit for bit."""
    src = str(Path(__file__).resolve().parent.parent / "src")
    proc = subprocess.run([sys.executable, "-c", MESH_SCRIPT % src],
                          capture_output=True, text=True, timeout=560)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines()
            if l.startswith("RESULT ")][-1]
    out = json.loads(line[len("RESULT "):])
    assert out["same_newick"]
    assert out["same_logl"]
    assert out["same_traj"]
    assert out["moved"] > 0        # the comparison exercised real moves


# ----------------------------------------------------- engine + acceptance

def test_engine_refine_search_dispatch(msa8):
    eng = TreeEngine(gap_code=DNA.gap_code, n_chars=DNA.n_chars,
                     refine="search", model="jc69", starts=3, spr_radius=2,
                     search_rounds=2, ml_steps=30)
    res = eng.build(msa8)
    assert res.backend.endswith("+search")
    assert res.logl["final"] >= res.logl["initial"]
    assert res.search["start_labels"] == ["nj", "cluster", "random2"]
    assert len(res.search["trajectories"]) == 3
    assert res.n_nni == int(np.asarray(res.search["n_moves"]).sum())


def test_engine_search_validation():
    with pytest.raises(ValueError, match="nucleotide"):
        TreeEngine(gap_code=20, n_chars=21, refine="search").build(
            np.zeros((4, 10), np.int8))
    with pytest.raises(ValueError, match="bootstrap"):
        TreeEngine(gap_code=DNA.gap_code, n_chars=DNA.n_chars,
                   refine="none", bootstrap=4).build(
            np.zeros((4, 10), np.int8))


def test_search_bootstrap_support(msa8):
    eng = TreeEngine(gap_code=DNA.gap_code, n_chars=DNA.n_chars,
                     refine="search", model="jc69", starts=2, spr_radius=1,
                     search_rounds=1, ml_steps=30, bootstrap=8)
    res = eng.build(msa8)
    finite = res.support[np.isfinite(res.support)]
    assert finite.size > 0
    assert ((finite >= 0) & (finite <= 1)).all()


def test_multistart_beats_single_start_nni_on_phi_dna():
    """The ISSUE acceptance gate: K=4 starts with SPR reach a logL at
    least as good as the single-start NJ+NNI refiner (same model, same
    per-fit budget)."""
    fam = phi_dna()
    msa = center_star_msa(fam.seqs, MSAConfig(method="kmer")).msa
    common = dict(gap_code=DNA.gap_code, n_chars=DNA.n_chars,
                  model="jc69", ml_steps=60)
    single = TreeEngine(refine="ml", nni_rounds=3, **common).build(msa)
    fleet = TreeEngine(refine="search", starts=4, spr_radius=2,
                       search_rounds=3, **common).build(msa)
    assert fleet.logl["final"] >= single.logl["final"] - 1e-3
    assert fleet.search["best_start"] is not None


# ----------------------------------------------------------------- CLI

def test_tree_run_search_cli(msa8, tmp_path):
    from repro.launch import tree_run
    fa = tmp_path / "aligned.fasta"
    fa.write_text("".join(f">s{i}\n{DNA.decode(row)}\n"
                          for i, row in enumerate(msa8)))
    out = tmp_path / "out"
    tree_run.main(["--fasta", str(fa), "--out", str(out),
                   "--refine", "search", "--model", "jc69", "--starts", "3",
                   "--spr-radius", "2", "--search-rounds", "2",
                   "--ml-steps", "30", "--restartable"])
    report = json.loads((out / "report.json").read_text())
    assert report["refine"] == "search"
    assert report["search"]["starts"] == 3
    assert report["search"]["spr_radius"] == 2
    assert len(report["search"]["trajectories"]) == 3
    assert (out / "tree.nwk").read_text().strip().endswith(";")
    assert Path(report["search"]["ckpt_dir"]).is_dir()


def test_tree_run_search_flag_validation(tmp_path):
    from repro.launch import tree_run
    fa = tmp_path / "a.fasta"
    fa.write_text(">a\nACGT\n>b\nACGT\n")
    with pytest.raises(SystemExit):
        tree_run.main(["--fasta", str(fa), "--resume"])
    with pytest.raises(SystemExit):
        tree_run.main(["--fasta", str(fa), "--refine", "ml",
                       "--restartable"])
