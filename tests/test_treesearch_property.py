"""Property tests for the tree-search move generators.

Every SPR/NNI candidate must preserve the leaf set, remain a valid
rooted binary tree with a topological processing order,
``renumber_topological`` must be idempotent on its output, and the
candidate count must match the closed-form bound (unbounded radius) and
an independently implemented undirected-BFS oracle (bounded radius).

Uses hypothesis when installed, the seeded fallback otherwise (same
protocol as test_property.py).
"""
from collections import defaultdict, deque

import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                    # pragma: no cover
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import treeio
from repro.phylo.ml import nni_candidates, renumber_topological
from repro.phylo.treesearch import (random_addition_tree, spr_candidates,
                                    topological_order)


def _tree(n, seed):
    """A random index-topological tree with its processing order."""
    ch, bl, rt = random_addition_tree(n, np.random.default_rng(seed))
    return ch, bl, rt, np.arange(n, 2 * n - 1, dtype=np.int32)


def _assert_valid(ch, od, n, root):
    """Rooted binary + leaf-set + topological-order invariants."""
    assert int(od[-1]) == root
    assert len(od) == n - 1
    seen = set(range(n))                       # leaves are always "done"
    parents = defaultdict(int)
    for node in od:
        a, b = int(ch[node, 0]), int(ch[node, 1])
        assert a >= 0 and b >= 0               # internal nodes are binary
        assert a in seen and b in seen         # children before parents
        parents[a] += 1
        parents[b] += 1
        seen.add(int(node))
    # every node except the root has exactly one parent; the root none
    for node in range(2 * n - 1):
        assert parents[node] == (0 if node == root else 1)
    assert treeio.leaf_sets(ch, root, n)[root] == frozenset(range(n))


def _oracle_spr_count(children, root, n, radius):
    """Independent SPR candidate counter: undirected edge-set BFS.

    Deliberately re-derived from the move definition (not the generator's
    parent-map BFS): for each prune node, build the pruned tree's edge
    set explicitly, take multi-source BFS depths over an undirected
    adjacency map, and count edges within radius — minus the merged edge.
    """
    children = np.asarray(children)
    M = children.shape[0]
    par = {}
    for p in range(M):
        if children[p, 0] >= 0:
            par[int(children[p, 0])] = int(p)
            par[int(children[p, 1])] = int(p)

    def subtree(v):
        out, stack = set(), [v]
        while stack:
            x = stack.pop()
            out.add(x)
            if children[x, 0] >= 0:
                stack += [int(children[x, 0]), int(children[x, 1])]
        return out

    total = 0
    for v in range(M):
        if v == root or v not in par or par[v] == root:
            continue
        u = par[v]
        g = par[u]
        w = int(children[u, 1]) if int(children[u, 0]) == v \
            else int(children[u, 0])
        gone = subtree(v) | {u}
        edges = {(int(p), int(c))
                 for p in range(M) if children[p, 0] >= 0 and p not in gone
                 for c in children[p] if int(c) not in gone}
        edges.add((g, w))
        adj = defaultdict(set)
        for a, b in edges:
            adj[a].add(b)
            adj[b].add(a)
        depth = {g: 0, w: 0}
        dq = deque((g, w))
        while dq:
            x = dq.popleft()
            for y in adj[x]:
                if y not in depth:
                    depth[y] = depth[x] + 1
                    dq.append(y)
        total += sum(1 for (a, b) in edges if (a, b) != (g, w)
                     and 1 + min(depth[a], depth[b]) <= radius)
    return total


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=4, max_value=10),
       st.integers(min_value=0, max_value=10 ** 6),
       st.integers(min_value=1, max_value=4))
def test_spr_candidates_are_valid_trees(n, seed, radius):
    ch, bl, rt, od = _tree(n, seed)
    chs, bls, ods = spr_candidates(ch, bl, od, n, radius=radius)
    assert chs.shape[0] > 0                    # radius>=1 always has targets
    for i in range(chs.shape[0]):
        _assert_valid(chs[i], ods[i], n, rt)
        assert (bls[i][np.asarray(ods[i])] >= 0).all()


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=4, max_value=10),
       st.integers(min_value=0, max_value=10 ** 6))
def test_nni_candidates_are_valid_trees(n, seed):
    ch, bl, rt, od = _tree(n, seed)
    chs, _, ods = nni_candidates(ch, bl, od, n)
    assert chs.shape[0] == 2 * (n - 2)         # the NNI closed form
    for i in range(chs.shape[0]):
        _assert_valid(chs[i], ods[i], n, rt)


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=4, max_value=9),
       st.integers(min_value=0, max_value=10 ** 6),
       st.integers(min_value=1, max_value=4))
def test_spr_count_matches_independent_oracle(n, seed, radius):
    ch, bl, rt, od = _tree(n, seed)
    chs, _, _ = spr_candidates(ch, bl, od, n, radius=radius)
    assert chs.shape[0] == _oracle_spr_count(ch, rt, n, radius)


@settings(max_examples=10, deadline=None)
@given(st.integers(min_value=4, max_value=9),
       st.integers(min_value=0, max_value=10 ** 6))
def test_spr_unbounded_count_matches_closed_form(n, seed):
    """radius >= diameter enumerates 2*(n - leaves(v)) - 3 targets per
    valid prune node v (merged edge excluded)."""
    ch, bl, rt, od = _tree(n, seed)
    chs, _, _ = spr_candidates(ch, bl, od, n, radius=2 * n)
    sets = treeio.leaf_sets(ch, rt, n)
    par = {}
    for p in od:
        par[int(ch[p, 0])] = int(p)
        par[int(ch[p, 1])] = int(p)
    expect = sum(2 * (n - len(sets.get(v, {v}))) - 3
                 for v in range(2 * n - 1)
                 if v != rt and v in par and par[v] != rt)
    assert chs.shape[0] == expect


@settings(max_examples=8, deadline=None)
@given(st.integers(min_value=4, max_value=8),
       st.integers(min_value=0, max_value=10 ** 6))
def test_renumber_topological_idempotent_on_candidates(n, seed):
    """Renumbering a candidate once yields index-topological arrays; a
    second renumber with the identity order must be a no-op."""
    ch, bl, rt, od = _tree(n, seed)
    chs, bls, ods = spr_candidates(ch, bl, od, n, radius=3)
    idx = np.random.default_rng(seed).integers(chs.shape[0])
    c1, b1, r1 = renumber_topological(chs[idx], bls[idx], rt, ods[idx], n)
    assert r1 == 2 * n - 2
    order1 = topological_order(c1, r1, n)
    np.testing.assert_array_equal(order1,
                                  np.arange(n, 2 * n - 1, dtype=np.int32))
    c2, b2, r2 = renumber_topological(c1, b1, r1, order1, n)
    np.testing.assert_array_equal(c1, c2)
    np.testing.assert_array_equal(b1, b2)
    assert r1 == r2
